"""Bench-regression gate: current fabric sweep vs the checked-in baseline.

Compares a ``BENCH_fabric.json`` produced by ``benchmarks/run.py --json``
(or, with no ``--current``, a fresh in-process ``run_structured`` sweep)
against ``benchmarks/baselines/BENCH_fabric.json`` and exits non-zero if
any TAGGED cell's ``us_per_call`` regressed more than ``--max-regression``
(default 1.5x), or if a baseline cell vanished from the current run —
renaming or deleting a benchmark must be an explicit baseline refresh,
not a silent gap in coverage.  Cells whose ``backend`` field differs
from the baseline's are skipped: wall-clock is only comparable within
one backend, so a baseline recorded on CPU never gates a TPU run (or
vice versa) — refresh the baseline on the new backend instead.

Only tagged cells (the ``Fabric``-API feature rows: hetero / mcast /
adaptive / lossless / batch) gate; the untagged ring/mesh grid is tracked but
machine-noise-dominated at small N.  Cells whose baseline wall-clock is
under ``--min-us`` are skipped outright: at tens of microseconds the
comparison measures the allocator, not the engine.

Refresh after an intentional perf change::

    python benchmarks/run.py \
        --tags hetero,mcast,adaptive,lossless,batch,verify \
        --json benchmarks/baselines/BENCH_fabric.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "BENCH_fabric.json")
MAX_REGRESSION = 1.5
MIN_US = 500.0


def _load_cells(path: str) -> dict[str, dict]:
    with open(path) as f:
        payload = json.load(f)
    return {c["name"]: c for c in payload["cells"]}


def compare(current: dict[str, dict], baseline: dict[str, dict], *,
            max_regression: float = MAX_REGRESSION,
            min_us: float = MIN_US) -> list[str]:
    """Return the list of failure messages (empty = gate passes)."""
    failures = []
    for name, base in sorted(baseline.items()):
        if not base.get("tags"):
            continue
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: present in baseline but missing "
                            f"from the current sweep")
            continue
        if cur.get("backend") != base.get("backend"):
            # wall-clock is only comparable within one backend: a CPU
            # interpret-mode cell vs a compiled TPU/GPU cell differ by
            # orders of magnitude in BOTH directions of "regression"
            print(f"  skip {name}: backend changed "
                  f"{base.get('backend')} -> {cur.get('backend')} "
                  f"(cross-backend wall-clock is not comparable; "
                  f"refresh the baseline on this backend)")
            continue
        b_us, c_us = float(base["us_per_call"]), float(cur["us_per_call"])
        if b_us < min_us:
            print(f"  skip {name}: baseline {b_us:.0f} us < {min_us:.0f} "
                  f"us noise floor")
            continue
        ratio = c_us / b_us
        status = "FAIL" if ratio > max_regression else "ok"
        print(f"  {status:4s} {name}: {c_us:.0f} us vs baseline "
              f"{b_us:.0f} us ({ratio:.2f}x, limit {max_regression:.1f}x)")
        if ratio > max_regression:
            failures.append(f"{name}: {ratio:.2f}x regression "
                            f"({c_us:.0f} us vs {b_us:.0f} us)")
    return failures


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--current", metavar="PATH", default=None,
                   help="BENCH_fabric.json from benchmarks/run.py --json; "
                        "omitted = run the tagged sweep in-process")
    p.add_argument("--baseline", metavar="PATH", default=BASELINE)
    p.add_argument("--max-regression", type=float, default=MAX_REGRESSION)
    p.add_argument("--min-us", type=float, default=MIN_US)
    p.add_argument("--update-baseline", action="store_true",
                   help="overwrite the baseline with the current cells "
                        "instead of comparing")
    args = p.parse_args(argv)

    if args.current:
        current = _load_cells(args.current)
        engine = "(from file)"
    else:
        from benchmarks import fabric_sweep
        engine = fabric_sweep.DEFAULT_ENGINE
        cells = fabric_sweep.run_structured(
            engine=engine, tags=sorted(fabric_sweep.KNOWN_TAGS))
        current = {c["name"]: c for c in cells}

    if args.update_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump({"bench": "fabric_sweep", "engine": engine,
                       "slow_lane": False,
                       "cells": sorted(current.values(),
                                       key=lambda c: c["name"])},
                      f, indent=2)
        print(f"baseline updated: {args.baseline} "
              f"({len(current)} cells)")
        return 0

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; generate one with "
              f"--update-baseline")
        return 1
    baseline = _load_cells(args.baseline)
    failures = compare(current, baseline,
                       max_regression=args.max_regression,
                       min_us=args.min_us)
    if failures:
        print(f"\nFAIL: {len(failures)} benchmark regression(s):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print(f"\nbench gate passed: {len(baseline)} baseline cells checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
