"""Roofline analysis over the dry-run artifacts (§Roofline of EXPERIMENTS).

Reads experiments/dryrun/<arch>--<shape>--<mesh>[--tag].json and derives,
per cell, on TPU v5e constants:

  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory term     = HLO_bytes(per-device) / HBM_bw
  collective term = collective_bytes(per-device) / link_bw

(the dry-run JSON stores PER-DEVICE numbers: the HLO module is the
post-SPMD per-device program), plus MODEL_FLOPS = 6·N·D (dense) /
6·N_active·D (MoE) and the useful-compute ratio.

Plus a FABRIC roofline mode (:func:`fabric_roofline_cells`): the slot
engines are memory-bound — every micro-transaction moves the packed
carry (``network.slot_carry_bytes``: 3 (Q, C) slot planes + link/side
lanes + logs) through memory, so the events/s ceiling is

  bound_ev_s = HBM_bw / bytes_per_event
  bytes_per_event = 2 * carry_bytes * launches_per_step * max_steps
                    / delivered

with ``launches_per_step`` = 1 for the per-step kernel pair (one full
read+write round-trip per micro-transaction) and ``1 / chunk`` for the
fused multi-step kernel (carry resident across ``chunk`` steps).  The
mode times both kernels on the benchmark ring and emits per-backend
cells (measured MEv/s vs the bound) into ``BENCH_fabric.json``.
"""

from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per direction per link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

# active params (N for MODEL_FLOPS): computed from configs
def _active_params(arch: str) -> float:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs.base import get_config
    from repro.models.layers import padded_vocab
    cfg = get_config(arch)
    D, L, V = cfg.d_model, cfg.n_layers, padded_vocab(cfg.vocab)
    H, Kv, dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff

    def attn_p():
        return D * H * dh + 2 * D * Kv * dh + H * dh * D

    def ffn_p(f=None):
        f = f or F
        gated = cfg.act in ("silu", "gelu") and cfg.family != "encoder"
        return (3 if gated else 2) * D * f

    def moe_active():
        m = cfg.moe
        return m.top_k * 3 * D * F + D * m.num_experts

    def mamba_p():
        m = cfg.mamba
        d_in = m.expand * D
        R = cfg.dt_rank
        return (D * 2 * d_in + m.d_conv * d_in + d_in * (R + 2 * m.d_state)
                + R * d_in + d_in * D)

    from repro.models.transformer import pattern_for
    pat = pattern_for(cfg)
    per_period = 0.0
    for kind in pat:
        if kind.startswith("attn") or kind.startswith("xattn"):
            per_period += attn_p()
        else:
            per_period += mamba_p()
        if kind.endswith("_ffn"):
            per_period += ffn_p()
        elif kind.endswith("_moe"):
            per_period += moe_active()
    n_periods = L // len(pat)
    body = per_period * n_periods
    embed = V * D + (0 if cfg.tie_embeddings else D * V)
    return body + embed


def _ssm_state_flops_per_token(arch: str) -> float:
    """Selective-scan state math NOT captured by 6·N·D: per mamba layer
    ~9 multiply-adds per (d_inner × d_state) element per token (discretize,
    recurrence, output contraction), ×3 for fwd+bwd+remat."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs.base import get_config
    from repro.models.transformer import pattern_for
    cfg = get_config(arch)
    if cfg.mamba is None:
        return 0.0
    pat = pattern_for(cfg)
    n_mamba = sum(1 for k in pat if k.startswith("mamba")) * (
        cfg.n_layers // len(pat))
    d_in = cfg.mamba.expand * cfg.d_model
    return 9.0 * 3.0 * n_mamba * d_in * cfg.mamba.d_state


def analyze_cell(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    flops_dev = rec["flops"]
    bytes_dev = rec["bytes_accessed"]
    coll_dev = rec.get("collective_bytes_total", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    out = dict(rec)
    out.update({
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "bound_time_s": max(terms.values()),
        "roofline_fraction": t_compute / max(terms.values())
        if max(terms.values()) > 0 else 0.0,
    })

    # useful-compute ratio for train cells
    if rec["kind"] == "train":
        try:
            n_active = _active_params(rec["arch"])
            # tokens per step (global)
            from repro.configs.base import ALL_SHAPES
            sh = ALL_SHAPES[rec["shape"]]
            model_flops_global = 6.0 * n_active * sh.global_batch * sh.seq_len
            hlo_flops_global = flops_dev * n_dev
            out["model_flops_global"] = model_flops_global
            out["useful_ratio"] = model_flops_global / max(
                hlo_flops_global, 1.0)
            ssm = _ssm_state_flops_per_token(rec["arch"])
            if ssm:
                adj = model_flops_global + ssm * sh.global_batch * sh.seq_len
                out["useful_ratio_ssm_adjusted"] = adj / max(
                    hlo_flops_global, 1.0)
        except Exception as e:          # pragma: no cover
            out["useful_ratio_error"] = repr(e)
    return out


def load_cells(mesh="pod", tag=None, dryrun_dir=DRYRUN_DIR):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh_kind") != mesh:
            continue
        base = os.path.basename(path)[:-5].split("--")
        cell_tag = base[3] if len(base) > 3 else ""
        if (tag or "") != cell_tag:
            continue
        cells.append(analyze_cell(rec))
    return cells


def table(cells, fmt="md"):
    hdr = ["arch", "shape", "dominant", "t_comp(ms)", "t_mem(ms)",
           "t_coll(ms)", "roofline", "useful"]
    lines = ["| " + " | ".join(hdr) + " |",
             "|" + "---|" * len(hdr)]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        lines.append("| " + " | ".join([
            c["arch"], c["shape"], c["dominant"],
            f"{c['t_compute_s']*1e3:.2f}", f"{c['t_memory_s']*1e3:.2f}",
            f"{c['t_collective_s']*1e3:.2f}",
            f"{c['roofline_fraction']:.2f}",
            f"{c.get('useful_ratio', float('nan')):.2f}"
            if "useful_ratio" in c else "-",
        ]) + " |")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Fabric roofline: packed-carry traffic vs memory bandwidth, per kernel
# ---------------------------------------------------------------------------

FABRIC_ROOFLINE_CHUNK = 64


def fabric_roofline_cells() -> list:
    """Measured fabric throughput vs the memory-bandwidth roofline.

    Runs the ring-16 hot-spot workload through ``engine="pallas"`` with
    both kernel choices and derives, from the engine's OWN packed state
    shapes (no profiler):

    * ``carry_bytes``     — ``slot_carry_bytes(L, E, C)``, the int32
      words one micro-transaction round-trips;
    * ``bytes_per_event`` — carry read+write per launch group, times
      launch groups per run, over delivered events;
    * ``bound_ev_s``      — ``HBM_BW / bytes_per_event``, the roofline
      ceiling for this shape on the modeled part;
    * ``measured_ev_s``   — delivered events over wall-clock, and the
      fraction of the bound it reaches.

    On this CPU interpret-mode container the measured fraction is tiny
    (interpret mode executes the kernel body as jnp ops — it measures
    semantics, not deployment speed); the cells exist so a compiled
    backend (TPU/GPU) reports its fraction against the SAME bound, and
    so the multi-step kernel's ``chunk``-fold bytes/event reduction is
    visible in the artifact.  Every cell carries ``backend`` +
    ``kernel`` fields; ``compare.py`` only gates same-backend ratios.
    """
    import time

    import jax
    import numpy as np

    from benchmarks.fabric_sweep import _derived, _metrics, stamp_env, _cell
    from repro.core import traffic as tr
    from repro.core.fabric import EngineSpec, Fabric
    from repro.core.network import slot_carry_bytes
    from repro.core.router import ring_topology

    topo = ring_topology(16)
    spec = tr.hot_spot(jax.random.PRNGKey(5), 16, 3, mean_gap_ns=150.0,
                       hot_frac=0.75)
    cells = []
    for kern in ("step", "multistep"):
        fab = Fabric(topo, engine=EngineSpec(
            name="pallas", kernel=kern, chunk_size=FABRIC_ROOFLINE_CHUNK))
        cf = fab.compile(spec)          # warmed: timing excludes compile
        t0 = time.perf_counter()
        res = cf.run(spec)
        jax.block_until_ready(res.log_del)
        us = (time.perf_counter() - t0) * 1e6

        _eng, L, E, C, max_steps, _mb, _R, _K, _kern, chunk = cf.bucket
        carry_bytes = slot_carry_bytes(L, E, C)
        steps_per_launch = chunk if kern == "multistep" else 1
        bytes_per_step = 2.0 * carry_bytes / steps_per_launch
        delivered = max(int(res.delivered), 1)
        bytes_per_event = bytes_per_step * max_steps / delivered
        bound_ev_s = HBM_BW / bytes_per_event
        measured_ev_s = delivered / (us * 1e-6)
        m = _metrics(res)
        m.update({"carry_bytes": carry_bytes,
                  "bytes_per_event": bytes_per_event,
                  "bound_mev_s": bound_ev_s / 1e6,
                  "measured_wallclock_mev_s": measured_ev_s / 1e6,
                  "roofline_fraction": measured_ev_s / bound_ev_s,
                  "max_steps": max_steps,
                  "chunk": steps_per_launch})
        cells.append(_cell(
            f"fabric_roofline_pallas_{kern}", us,
            f"{_derived(m)} carry={carry_bytes}B "
            f"bound={m['bound_mev_s']:.0f}MEv/s "
            f"wallclock={m['measured_wallclock_mev_s']:.3f}MEv/s "
            f"({m['roofline_fraction']:.1e} of bound)",
            "pallas", metrics=m, api="Fabric", kernel=kern))
    return stamp_env(cells)


def run():
    """Benchmark-harness entry: summarize baseline cells."""
    cells = load_cells("pod")
    rows = []
    for c in cells:
        rows.append((f"roofline_{c['arch']}--{c['shape']}", 0.0,
                     f"dom={c['dominant']} frac={c['roofline_fraction']:.2f} "
                     f"comp={c['t_compute_s']*1e3:.1f}ms "
                     f"mem={c['t_memory_s']*1e3:.1f}ms "
                     f"coll={c['t_collective_s']*1e3:.1f}ms"))
    if not rows:
        rows.append(("roofline", 0.0, "no dryrun artifacts yet"))
    return rows


if __name__ == "__main__":
    cells = load_cells(sys.argv[1] if len(sys.argv) > 1 else "pod")
    print(table(cells))
