"""Benchmarks reproducing each measured table/figure of the paper.

Each function returns rows of (name, us_per_call, derived-metrics-string).
Wall-clock here is the *simulator's* cost; the derived column carries the
reproduced paper figure vs. its published value.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol_sim as ps
from repro.core import sparse_collectives as sc
from repro.core.link import PAPER_TIMING
from repro.kernels import ops as K


def _timed(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out))
    return out, (time.perf_counter() - t0) / reps * 1e6


def bench_fig7_onedir():
    """Fig. 7: continuous one-direction stream -> 32.3 MEvents/s."""
    res, us = _timed(ps.saturated_onedir, 4096)
    thr = float(ps.throughput_mev_s(res))
    return [("fig7_onedir_throughput", us,
             f"measured={thr:.2f}MEv/s paper=32.3 err={abs(thr-32.3)/32.3:.2%}")]


def bench_fig8_bidir():
    """Fig. 8: alternating bi-directional load -> 28.6 MEvents/s."""
    res, us = _timed(ps.alternating_bidir, 2048)
    thr = float(ps.throughput_mev_s(res))
    return [("fig8_bidir_throughput", us,
             f"measured={thr:.2f}MEv/s paper=28.6 err={abs(thr-28.6)/28.6:.2%}")]


def bench_table2():
    """Table II: the four key figures of the fabricated block."""
    rows = []
    res1, us1 = _timed(ps.saturated_onedir, 2048)
    rows.append(("table2_throughput_onedir", us1,
                 f"{float(ps.throughput_mev_s(res1)):.2f}MEv/s (paper 32.3)"))
    res2, us2 = _timed(ps.alternating_bidir, 1024)
    rows.append(("table2_throughput_bidir", us2,
                 f"{float(ps.throughput_mev_s(res2)):.2f}MEv/s (paper 28.6)"))
    rows.append(("table2_switch_latency", 0.0,
                 f"{PAPER_TIMING.t_sw_ns}ns (paper 5ns)"))
    rows.append(("table2_energy_per_event", 0.0,
                 f"{PAPER_TIMING.e_event_pj}pJ@26bit (paper 11pJ)"))
    return rows


def bench_io_savings():
    """§IV: 100 I/O pins saved on a 4-border 180-I/O chip; plus the
    byte-domain analogue for the TPU adaptation."""
    pins = PAPER_TIMING.io_pins_saved(n_links=4)
    rows = [("io_pins_saved_4links", 0.0,
             f"{pins} pins (paper 100; 180-I/O prototype -> "
             f"{pins/180:.0%} of budget)")]
    n = 1_000_000  # 1M-param gradient
    for dev in (16, 256):
        uni = sc.dense_allreduce_bytes(n, dev, bidirectional=False)
        bi = sc.dense_allreduce_bytes(n, dev, bidirectional=True)
        aer = sc.aer_allreduce_bytes(n, dev, frac=0.02)
        rows.append((f"wire_bytes_per_dir_{dev}dev", 0.0,
                     f"uni={uni:.3e} bidir={bi:.3e} (2x) "
                     f"aer2%={aer:.3e} ({uni/max(aer,1):.0f}x)"))
    return rows


def bench_switch_timing():
    """Fig. 2/7 detail: idle-switch vs overlapped reversal latencies."""
    # single event after an idle switch: t = t_sw + t_sw2req + t_req2req
    res = ps.simulate(jnp.zeros(1, jnp.int32), jnp.zeros(0, jnp.int32),
                      initial_tx=0)
    t_first = int(res.t_end)
    # ping-pong: per-event cost under busy reversal
    res2 = ps.alternating_bidir(256)
    t_rev = (int(res2.t_end) - PAPER_TIMING.t_req2req_ns) / max(
        int(res2.sent_l + res2.sent_r) - 1, 1)
    return [
        ("switch_idle_first_event", 0.0,
         f"{t_first}ns = t_sw(5)+t_sw2req(5)+t_cycle(31)"),
        ("switch_busy_reversal_cycle", 0.0,
         f"{t_rev:.1f}ns/event (paper ~35ns)"),
    ]


def bench_aer_kernels():
    """Compression path microbench: encode/decode throughput + ratio."""
    rows = []
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (64, 1024)), jnp.float32)
    tau = K.tau_from_fraction(x, 0.02)
    evb, us_enc = _timed(K.aer_compress, x, tau, 128)
    dense, us_dec = _timed(K.aer_decompress, evb, 1024)
    ratio = x.size * 4 / float(evb.wire_bytes())
    rows.append(("aer_encode_64x1024", us_enc,
                 f"{x.size*4/us_enc/1e3:.1f}MB/s_sim ratio={ratio:.1f}x"))
    rows.append(("aer_decode_64x1024", us_dec, "scatter-accumulate"))
    # every decoded nonzero equals the original entry (events are exact)
    d = np.asarray(dense)
    xo = np.asarray(x)
    nz = d != 0
    ok = np.allclose(d[nz], xo[nz], atol=1e-6)
    rows.append(("aer_roundtrip_events_exact", 0.0, f"ok={bool(ok)}"))
    return rows


def bench_subwords():
    """Paper §V conclusion: sub-word serialization trades wires for beats.
    The whole point vs full bit-serial: pins shrink ~linearly, throughput
    degrades SUB-linearly (handshake overhead amortizes)."""
    rows = []
    for f in (1, 2, 13):
        t = PAPER_TIMING.subword(f) if f > 1 else PAPER_TIMING
        res = ps.simulate(jnp.zeros(256, jnp.int32),
                          jnp.zeros(0, jnp.int32), initial_tx=1, timing=t)
        thr = float(ps.throughput_mev_s(res))
        rows.append((f"subword_factor_{f}", 0.0,
                     f"wires={t.word_bits + 2} thr={thr:.1f}MEv/s "
                     f"(pins/{f} costs thr x{32.26 / max(thr, 1e-9):.2f})"))
    return rows


def bench_snn_chip_array():
    """Fig. 6 system context: 4x4 chip array, AER buses on every border."""
    from repro.models import snn
    cfg = snn.SnnConfig(grid=(4, 4), neurons=256)
    params, state = snn.init_snn(cfg, jax.random.PRNGKey(0))
    run = jax.jit(lambda p, s: snn.run_snn(p, cfg, s, 50))
    (state2, ticks), us = _timed(run, params, state)
    rep = snn.link_report(jax.tree.map(np.asarray, ticks))
    return [
        ("snn_4x4_50ticks", us,
         f"{rep['events_per_s']:.3e}ev/s busy={rep['bus_busy_frac']:.2%} "
         f"E={rep['energy_uj']:.2f}uJ "
         f"wires/link {rep['shared_bus_wires_per_link']} vs dual "
         f"{rep['dual_bus_wires_per_link']}"),
    ]


ALL = [bench_fig7_onedir, bench_fig8_bidir, bench_table2,
       bench_switch_timing, bench_io_savings, bench_subwords,
       bench_aer_kernels, bench_snn_chip_array]
