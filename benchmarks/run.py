# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import fabric_sweep, paper_benches, roofline
    rows = []
    for fn in paper_benches.ALL:
        rows.extend(fn())
    rows.extend(fabric_sweep.run())
    rows.extend(roofline.run())
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
