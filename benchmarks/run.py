# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# ``--json PATH`` additionally writes the fabric sweep as machine-readable
# JSON (name, us_per_call, derived, engine tag, parsed metrics) — the
# ``BENCH_fabric.json`` artifact CI tracks PR-over-PR.
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main(argv=None) -> None:
    from benchmarks import fabric_sweep, paper_benches, roofline
    from repro.core.network import ENGINES
    p = argparse.ArgumentParser()
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the fabric sweep cells as JSON (e.g. "
                        "BENCH_fabric.json)")
    p.add_argument("--only", default=None, metavar="NAME",
                   help="run only the fabric bench family; values other "
                        "than 'fabric' additionally keep only cells "
                        "whose name contains NAME as a substring "
                        "(e.g. --only hotspot).  All fabric sweep "
                        "families still execute — use --tags to skip "
                        "whole families.  Errors if nothing matches.")
    p.add_argument("--tags", default=None, metavar="TAG[,TAG...]",
                   help="run only the fabric sweep families whose cells "
                        "carry one of these tags (e.g. 'adaptive' or "
                        "'mcast,hetero'); implies skipping the "
                        "paper/roofline families")
    p.add_argument("--engine", default=fabric_sweep.DEFAULT_ENGINE,
                   choices=sorted(ENGINES),
                   help="fabric event-transport engine")
    p.add_argument("--slow", action="store_true",
                   help="include the slow-lane fabric rows (N=32/64, 8x8)")
    args = p.parse_args(argv)

    fabric_only = args.only is not None or args.tags is not None
    rows = []
    if not fabric_only:
        for fn in paper_benches.ALL:
            rows.extend(fn())
    tag_sel = args.tags.split(",") if args.tags else None
    try:
        fabric_cells = fabric_sweep.run_structured(engine=args.engine,
                                                   slow=args.slow,
                                                   tags=tag_sel)
    except ValueError as e:   # unknown --tags: fail loudly, not empty
        p.error(str(e))
    if tag_sel is None:
        # the fabric roofline cells (both pallas kernels vs the
        # memory-bandwidth bound) ride every untagged fabric sweep —
        # they are the per-backend MEv/s-vs-roofline artifact rows
        fabric_cells.extend(roofline.fabric_roofline_cells())
    if args.only not in (None, "fabric"):
        all_names = [c["name"] for c in fabric_cells]
        fabric_cells = [c for c in fabric_cells if args.only in c["name"]]
        if not fabric_cells:
            # a typo must not silently produce an empty CSV/JSON
            p.error(f"--only {args.only!r} matched no fabric cells; "
                    f"available: {', '.join(all_names)}")
    rows.extend((c["name"], c["us_per_call"], c["derived"])
                for c in fabric_cells)
    if not fabric_only:
        rows.extend(roofline.run())

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        import jax
        import jaxlib
        with open(args.json, "w") as f:
            json.dump({"bench": "fabric_sweep", "engine": args.engine,
                       "slow_lane": args.slow,
                       "backend": jax.default_backend(),
                       "jax_version": jax.__version__,
                       "jaxlib_version": jaxlib.__version__,
                       "cells": fabric_cells},
                      f, indent=2)
        print(f"# wrote {len(fabric_cells)} fabric cells to {args.json}",
              file=sys.stderr)


if __name__ == '__main__':
    main()
