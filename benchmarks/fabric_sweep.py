"""Fabric sweep: N-chip AER fabrics x traffic patterns.

Sweeps ring fabrics of N in {2, 4, 8, 16} chips (plus a 4x4 mesh at
N = 16) under every ``traffic.PATTERNS`` generator, reporting delivery,
aggregate + per-link throughput, end-to-end latency percentiles, switch
counts and energy.  The N = 2 ring IS the paper's measured configuration,
so its saturated rows must land on the Table II figures — the sweep's
built-in calibration anchor, enforced to 0.1 % against the paper's
28.6 MEvents/s (Fig. 8) on every run.

The slow lane (``--slow`` / ``run(slow=True)``) adds the DYNAP-scale
rows the O(1) ring engine affords: N in {32, 64} rings and an 8x8 mesh.

Rows follow the repo convention ``(name, us_per_call, derived)``;
``run_structured`` returns the same rows as dicts with the engine tag
and parsed metrics for ``BENCH_fabric.json``.
"""

from __future__ import annotations

import functools
import hashlib
import inspect
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.adaptive import AdaptiveRouting
from repro.core.fabric import Fabric, MulticastPolicy, QueuePolicy
from repro.core.link import (PAPER_TIMING, SERIAL_LVDS_TIMING,
                             per_link_timing)
from repro.core.router import (AddressSpec, MulticastTable, mesh2d_topology,
                               ring_topology)

EVENTS_PER_CHIP = 48
SWEEP_N = (2, 4, 8, 16)
SLOW_SWEEP_N = (32, 64)      # slow lane: DYNAP-scale rings
ANCHOR_MEV_S = 28.6          # paper Fig. 8 worst-case bidirectional rate
ANCHOR_TOL = 0.001           # enforced relative error of the N=2 anchor
DEFAULT_ENGINE = "ring"

# The sampled workloads are a pure function of (pattern, n, epc, key) and
# the generator code itself, so they are memoized on disk keyed on all of
# those (the generator contributes a source hash — editing traffic.py
# invalidates the cache): regenerating them costs ~8 s of eager
# jax.random compiles per run — noise that has nothing to do with the
# fabric engine being benchmarked.
_TRAFFIC_CACHE = os.path.join(os.path.dirname(__file__), ".traffic_cache")


@functools.lru_cache(maxsize=None)
def _traffic_version() -> str:
    src = inspect.getsource(tr).encode()
    return hashlib.sha1(src).hexdigest()[:10]


def _spec_cached(pattern: str, key, n_chips: int, epc: int):
    tag = "-".join(str(int(w)) for w in np.asarray(key).ravel())
    path = os.path.join(
        _TRAFFIC_CACHE,
        f"{pattern}_n{n_chips}_e{epc}_k{tag}_v{_traffic_version()}.npz")
    if os.path.exists(path):
        z = np.load(path)
        return tr.TrafficSpec(src=jax.numpy.asarray(z["src"]),
                              t=jax.numpy.asarray(z["t"]),
                              dest=jax.numpy.asarray(z["dest"]))
    spec = tr.PATTERNS[pattern](key, n_chips, epc)
    os.makedirs(_TRAFFIC_CACHE, exist_ok=True)
    np.savez(path, src=np.asarray(spec.src), t=np.asarray(spec.t),
             dest=np.asarray(spec.dest))
    return spec


def _run_one(topo, spec, engine=DEFAULT_ENGINE, **kw):
    t0 = time.perf_counter()
    res = net.simulate_fabric(topo, spec, engine=engine, **kw)
    jax.block_until_ready(res.log_del)
    us = (time.perf_counter() - t0) * 1e6
    return res, us


def _metrics(res) -> dict:
    st = net.latency_stats(res)
    per_link = np.asarray(net.per_link_throughput_mev_s(res))
    return {
        "delivered": st["delivered"],
        "injected": st["injected"],
        "offered": st["offered"],
        "fanout": st["fanout"],
        "traversals": st["traversals"],
        "thr_mev_s": float(net.fabric_throughput_mev_s(res)),
        "max_link_mev_s": float(per_link.max()),
        "p50_ns": st["p50_ns"],
        "p99_ns": st["p99_ns"],
        "switches": int(np.asarray(res.n_switches).sum()),
        "energy_nj": float(net.fabric_energy_pj(res, PAPER_TIMING)) * 1e-3,
        "drops": int(res.drops),
        "stall_steps": (int(np.asarray(res.telemetry.stall_steps).sum())
                        if res.telemetry is not None else 0),
        "credit_waits": (int(np.asarray(res.telemetry.credit_waits).sum())
                         if res.telemetry is not None else 0),
    }


def _derived(m: dict) -> str:
    return (f"delivered={m['delivered']}/{m['injected']} "
            f"thr={m['thr_mev_s']:.1f}MEv/s "
            f"maxlink={m['max_link_mev_s']:.1f}MEv/s "
            f"p50={m['p50_ns']:.0f}ns p99={m['p99_ns']:.0f}ns "
            f"sw={m['switches']} trav={m['traversals']} "
            f"E={m['energy_nj']:.1f}nJ")


def _cell(name, us, derived, engine, metrics=None, lane="fast",
          api="simulate_fabric", tags=(), kernel="step") -> dict:
    return {"name": name, "us_per_call": us, "derived": derived,
            "engine": engine, "kernel": kernel, "lane": lane, "api": api,
            "tags": list(tags), "metrics": metrics or {}}


def stamp_env(cells):
    """Stamp every cell with the execution environment: the XLA backend
    actually running the sweep plus the jax/jaxlib versions.  Timings
    are only comparable within one backend (a CPU interpret-mode cell
    vs a TPU compiled cell differ by orders of magnitude), so the
    regression gate (``compare.py``) refuses cross-backend ratios."""
    import jaxlib
    backend = jax.default_backend()
    for c in cells:
        c["backend"] = backend
        c["jax_version"] = jax.__version__
        c["jaxlib_version"] = jaxlib.__version__
    return cells


def sweep_rings(engine=DEFAULT_ENGINE, slow=False):
    rows = []
    key = jax.random.PRNGKey(0)
    lanes = [(n, "fast") for n in SWEEP_N]
    if slow:
        lanes += [(n, "slow") for n in SLOW_SWEEP_N]
    for n, lane in lanes:
        topo = ring_topology(n)
        for name in sorted(tr.PATTERNS):
            key, cell_key = jax.random.split(key)
            spec = _spec_cached(name, cell_key, n, EVENTS_PER_CHIP)
            # ping-pong saturates; grant after each event as in Fig. 8
            mb = 1 if name == "ping_pong" else 0
            res, us = _run_one(topo, spec, engine=engine, max_burst=mb)
            m = _metrics(res)
            rows.append(_cell(f"fabric_{topo.name}_{name}", us,
                              _derived(m), engine, m, lane))
    return rows


def sweep_mesh(engine=DEFAULT_ENGINE, slow=False):
    rows = []
    shapes = [(4, 4, "fast")] + ([(8, 8, "slow")] if slow else [])
    for r, c, lane in shapes:
        topo = mesh2d_topology(r, c)
        spec = _spec_cached("poisson", jax.random.PRNGKey(1), topo.n_chips,
                            EVENTS_PER_CHIP)
        res, us = _run_one(topo, spec, engine=engine)
        m = _metrics(res)
        rows.append(_cell(f"fabric_{topo.name}_poisson", us,
                          _derived(m), engine, m, lane))
    return rows


def sweep_anchor(engine=DEFAULT_ENGINE):
    """N=2 ping-pong must reproduce the paper's 28.6 MEvents/s (Fig. 8),
    within ``ANCHOR_TOL`` — asserted, not just reported.  Runs through
    the declarative ``Fabric`` API, so the anchor also gates the new
    front door (not just the ``simulate_fabric`` wrapper)."""
    fab = Fabric(ring_topology(2), queues=QueuePolicy(max_burst=1),
                 engine=engine)
    spec = tr.ping_pong(2, 1024)
    t0 = time.perf_counter()
    res = fab.run(spec)
    jax.block_until_ready(res.log_del)
    us = (time.perf_counter() - t0) * 1e6
    thr = float(net.fabric_throughput_mev_s(res))
    err = abs(thr - ANCHOR_MEV_S) / ANCHOR_MEV_S
    if err > ANCHOR_TOL:  # a hard gate (assert would vanish under -O)
        raise RuntimeError(
            f"fabric anchor drifted off the paper: measured {thr:.3f} "
            f"MEv/s vs {ANCHOR_MEV_S} (err {err:.2%} > {ANCHOR_TOL:.1%})")
    m = {"thr_mev_s": thr, "paper_mev_s": ANCHOR_MEV_S, "err": err}
    return [_cell("fabric_ring2_anchor_fig8", us,
                  f"measured={thr:.2f}MEv/s paper={ANCHOR_MEV_S} "
                  f"err={err:.2%}", engine, m, api="fabric")]


def sweep_heterogeneous(engine=DEFAULT_ENGINE):
    """Per-link timing heterogeneity row: an 8-ring whose 7-0 edge is
    the bit-serial LVDS class (331 ns/event) next to paper-timing links,
    driven through ``Fabric.sweep`` so one compile serves both the
    uniform baseline and the mixed cell (they share a shape bucket)."""
    topo = ring_topology(8)
    spec = _spec_cached("poisson", jax.random.PRNGKey(7), 8,
                        EVENTS_PER_CHIP)
    mixed = per_link_timing(
        [PAPER_TIMING, SERIAL_LVDS_TIMING],
        [1 if l == topo.n_links - 1 else 0 for l in range(topo.n_links)])
    rows = []
    for tag, timing in (("uniform", PAPER_TIMING), ("hetero", mixed)):
        fab = Fabric(topo, timing=timing, engine=engine)
        # warm=False: us_per_call stays "wall-clock, compile + run" like
        # every other BENCH cell (the rows still share one engine
        # compilation — timing is a dynamic operand)
        (cell,) = fab.sweep([spec], warm=False)
        m = _metrics(cell.result)
        rows.append(_cell(f"fabric_{topo.name}_poisson_{tag}",
                          cell.us_per_call, _derived(m), engine, m,
                          api="fabric", tags=("hetero",)))
    return rows


def sweep_multicast(engine=DEFAULT_ENGINE):
    """Multicast A/B rows: the same fanout-7 tagged workload on an
    8-ring, transported by ``source_expand`` (one unicast copy per
    member at the source) vs ``in_fabric`` (tag routed, replicated at
    the Steiner-tree branch points).  Both rows report the delivery
    metrics plus ``traversals`` and ``fanout``; both modes share ONE
    ring-engine shape bucket (replication dims are bucketed), so the
    A/B cost is one compile.  The in-fabric row must save traversals —
    the CI-gated assertion lives in ``fabric_smoke.py``."""
    topo = ring_topology(8)
    addr = AddressSpec()
    mc = MulticastTable(np.ones((1, 8), bool))   # tag 0 = every chip
    rng = np.random.default_rng(5)
    n = 8 * EVENTS_PER_CHIP
    src = rng.integers(0, 8, n).astype(np.int32)
    t = np.sort(rng.integers(0, 80_000, n)).astype(np.int32)
    spec = tr.TrafficSpec(
        src=jax.numpy.asarray(src),
        t=jax.numpy.asarray(t),
        dest=jax.numpy.asarray(addr.pack_multicast(np.zeros(n, np.int64))))
    rows = []
    for tag, mode in (("source", "source_expand"), ("infabric",
                                                    "in_fabric")):
        fab = Fabric(topo, addr=addr, engine=engine,
                     mcast=MulticastPolicy(mode, mc))
        (cell,) = fab.sweep([spec], warm=False)
        m = _metrics(cell.result)
        rows.append(_cell(f"fabric_{topo.name}_mcast_{tag}",
                          cell.us_per_call, _derived(m), engine, m,
                          api="fabric", tags=("mcast",)))
    return rows


# Adaptive hot-spot A/B configuration (shared with the CI smoke gate in
# fabric_smoke.py: the gate asserts the ring row's strict win, the sweep
# reports both rows' metrics).  Static rows run ``run_epochs`` with the
# SAME epoch partition, so the only difference is the routing tables.
ADAPTIVE_RING = dict(n_chips=16, key=3, epc=EVENTS_PER_CHIP, capacity=48,
                     policy="min_backlog", epochs=4, alpha=4.0, ema=0.5)
ADAPTIVE_MESH = dict(rows=4, cols=4, key=5, epc=EVENTS_PER_CHIP,
                     hot_chip=5, capacity=40,
                     policy="min_backlog", epochs=4, alpha=0.5, ema=0.3)


def _hotspot_ab_rows(topo, spec, cfg, engine):
    """One static / adaptive A/B pair on a hot-spot workload.

    Both rows run the identical engine shape bucket (routing tables are
    dynamic operands), so it is pre-warmed ONCE before either row is
    timed — otherwise the first row would absorb the compile time and
    skew the A/B comparison the rows exist for."""
    from repro.core.adaptive import partition_epochs, shared_max_steps
    routing = AdaptiveRouting(policy=cfg["policy"], epochs=cfg["epochs"],
                              alpha=cfg["alpha"], ema=cfg["ema"])
    queues = QueuePolicy(capacity=cfg["capacity"])
    # warm with the first epoch slice UNDER THE SHARED STEP BOUND both
    # rows run with (the slot engines key their bucket on it): ONE
    # bucket for every epoch of both rows, the slice prefill fits the
    # per-epoch capacity, and static/adaptive see the identical bound
    parts = partition_epochs(spec, cfg["epochs"])
    warm_fab = Fabric(topo, queues=queues, engine=engine)
    ms = shared_max_steps(warm_fab, parts,
                          detour_factor=1.0 + cfg["alpha"])
    warm_fab.compile(parts[0], max_steps=ms)
    rows = []
    for tag, fab, runner in (
            ("static", Fabric(topo, queues=queues, engine=engine),
             lambda f: f.run_epochs(spec, epochs=cfg["epochs"],
                                    max_steps=ms)),
            ("adaptive", Fabric(topo, routing=routing, queues=queues,
                                engine=engine),
             lambda f: f.run(spec, max_steps=ms))):
        t0 = time.perf_counter()
        res = runner(fab)           # merge syncs: results land in numpy
        us = (time.perf_counter() - t0) * 1e6
        m = _metrics(res)
        m.update(epochs=cfg["epochs"], policy=cfg["policy"],
                 alpha=cfg["alpha"], ema=cfg["ema"],
                 capacity=cfg["capacity"])
        rows.append(_cell(f"fabric_{topo.name}_hotspot_{tag}", us,
                          _derived(m), engine, m, api="fabric",
                          tags=("adaptive",)))
    return rows


def sweep_adaptive(engine=DEFAULT_ENGINE):
    """Congestion-control A/B rows: identical hot-spot workloads routed
    statically (BFS shortest path, epoch-partitioned for a fair drain /
    capacity comparison) vs adaptively (per-epoch telemetry re-weighting
    the tables — ``core/adaptive.py``).  The adaptive ring row must
    strictly reduce drops AND p99 latency; that assertion is the CI gate
    in ``fabric_smoke.run_adaptive_gate``."""
    r = ADAPTIVE_RING
    ring_spec = tr.hot_spot(jax.random.PRNGKey(r["key"]), r["n_chips"],
                            r["epc"])
    rows = _hotspot_ab_rows(ring_topology(r["n_chips"]), ring_spec, r,
                            engine)
    m = ADAPTIVE_MESH
    mesh_spec = tr.hot_spot(jax.random.PRNGKey(m["key"]),
                            m["rows"] * m["cols"], m["epc"],
                            hot_chip=m["hot_chip"])
    rows += _hotspot_ab_rows(mesh2d_topology(m["rows"], m["cols"]),
                             mesh_spec, m, engine)
    return rows


# Lossless flow-control A/B configurations (shared with the CI gate in
# fabric_smoke.run_lossless_gate and examples/lossless_hotspot.py).  The
# engines are deterministic, so these fixed (key, config) points
# reproduce bit-for-bit in CI:
#
# - LOSSLESS_RING: mild overload.  Drop mode exhausts its one-shot
#   per-endpoint budget (hundreds of drops) while credit mode delivers
#   everything AND strictly wins the delivered-events p99 — the wasted
#   transmissions of doomed events in drop mode starve live traffic
#   under the max_burst=0 grant rule.
# - LOSSLESS_RING_HOT: saturating flood at the smallest drop-legal
#   capacity.  Credit backpressure demonstrably engages (stall_steps
#   > 0) and still delivers 100%; drop mode loses most of the offered
#   load, so its loss-inclusive p99 (a dropped event never arrives =
#   unbounded latency) is infinite.
LOSSLESS_RING = dict(n_chips=16, key=2, epc=EVENTS_PER_CHIP,
                     mean_gap_ns=300.0, hot_frac=0.65, capacity=64)
LOSSLESS_RING_HOT = dict(n_chips=16, key=0, epc=EVENTS_PER_CHIP,
                         mean_gap_ns=150.0, hot_frac=0.85, capacity=48)


def _lossless_spec(cfg):
    return tr.hot_spot(jax.random.PRNGKey(cfg["key"]), cfg["n_chips"],
                       cfg["epc"], mean_gap_ns=cfg["mean_gap_ns"],
                       hot_frac=cfg["hot_frac"])


def sweep_lossless(engine=DEFAULT_ENGINE):
    """Flow-control A/B rows: the identical hot-spot workload transported
    under every ``QueuePolicy.flow`` mode.  All modes share ONE engine
    shape bucket (the flow mode, capacity and xon threshold are dynamic
    operands), so the bucket is pre-warmed once and no row absorbs the
    compile.  The strict-win assertions live in
    ``fabric_smoke.run_lossless_gate``; the sweep reports the metrics
    (including the stall/credit-wait telemetry unique to the lossless
    modes)."""
    topo = ring_topology(LOSSLESS_RING["n_chips"])
    spec = _lossless_spec(LOSSLESS_RING)
    cap = LOSSLESS_RING["capacity"]
    Fabric(topo, queues=QueuePolicy(capacity=cap),
           engine=engine).compile(spec)
    rows = []
    for flow in ("drop", "credit", "onoff"):
        fab = Fabric(topo, queues=QueuePolicy(capacity=cap, flow=flow),
                     engine=engine)
        (cell,) = fab.sweep([spec], warm=False)
        m = _metrics(cell.result)
        m.update(flow=flow, capacity=cap)
        rows.append(_cell(f"fabric_{topo.name}_hotspot_{flow}",
                          cell.us_per_call,
                          _derived(m) + f" stalls={m['stall_steps']}",
                          engine, m, api="fabric", tags=("lossless",)))
    # the saturating point: credit backpressure engages (stalls > 0)
    # and the fabric still delivers 100% of a flood drop mode mostly
    # loses
    hot = LOSSLESS_RING_HOT
    spec_hot = _lossless_spec(hot)
    fab = Fabric(topo, queues=QueuePolicy(capacity=hot["capacity"],
                                          flow="credit"), engine=engine)
    (cell,) = fab.sweep([spec_hot], warm=False)
    m = _metrics(cell.result)
    m.update(flow="credit", capacity=hot["capacity"])
    rows.append(_cell(f"fabric_{topo.name}_hotspot_credit_hot",
                      cell.us_per_call,
                      _derived(m) + f" stalls={m['stall_steps']}",
                      engine, m, api="fabric", tags=("lossless",)))
    return rows


BATCH_RING = dict(n_chips=16, key=7, epc=EVENTS_PER_CHIP,
                  pattern="hot_spot")
BATCH_SIZES = (1, 8, 32)


# Closed-loop co-simulation configuration (shared with the CI gate in
# fabric_smoke.run_cosim_gate and sized like examples/closed_loop_snn.py):
# a recurrent SNN on the benchmark ring-16, credit flow control.  The
# sweep rows transport the OPEN-LOOP spike stream of this network (the
# traffic-bridge A/B against the synthetic fabric_ring16_* rows on the
# identical topology); the smoke gate closes the loop and asserts
# lossless delivery plus the open-vs-closed divergence floor.
COSIM_RING = dict(n_chips=16, key=9, epc=EVENTS_PER_CHIP, capacity=96,
                  input_rate=0.06, ticks=24)

# The bridge rollout is a pure function of (pattern, n, epc, key) and the
# cosim-layer + LIF-kernel code, so specs memoize on disk like the
# synthetic patterns — regenerating one costs an open-loop LIF rollout
# (seconds of jit compiles) that has nothing to do with the fabric
# engine being benchmarked.
@functools.lru_cache(maxsize=None)
def _snn_version() -> str:
    import repro.cosim.engine as _ce
    import repro.cosim.placement as _cp
    import repro.cosim.traffic_bridge as _cb
    import repro.kernels.ops as _ko
    src = b"".join(inspect.getsource(m).encode()
                   for m in (_cb, _ce, _cp, _ko))
    return hashlib.sha1(src).hexdigest()[:10]


def _snn_spec_cached(pattern: str, key, n_chips: int, epc: int):
    from repro.cosim.traffic_bridge import SNN_PATTERNS
    tag = "-".join(str(int(w)) for w in np.asarray(key).ravel())
    path = os.path.join(
        _TRAFFIC_CACHE,
        f"{pattern}_n{n_chips}_e{epc}_k{tag}_v{_snn_version()}.npz")
    if os.path.exists(path):
        z = np.load(path)
        return tr.TrafficSpec(src=jax.numpy.asarray(z["src"]),
                              t=jax.numpy.asarray(z["t"]),
                              dest=jax.numpy.asarray(z["dest"]))
    spec = SNN_PATTERNS[pattern](key, n_chips, epc)
    os.makedirs(_TRAFFIC_CACHE, exist_ok=True)
    np.savez(path, src=np.asarray(spec.src), t=np.asarray(spec.t),
             dest=np.asarray(spec.dest))
    return spec


def sweep_cosim(engine=DEFAULT_ENGINE):
    """Spike-driven traffic rows: the two ``SNN_PATTERNS`` bridge
    workloads (feedforward chain vs bidirectional recurrent coupling,
    sampled from real LIF rollouts on the ``COSIM_RING`` ring) run
    through the fabric exactly like any synthetic pattern — same
    topology, same event budget as the ``fabric_ring16_*`` rows, so the
    A/B between modelled and network-generated load is a straight row
    comparison.  SNN load is tick-phased and projection-structured;
    these rows pin how the fabric carries it."""
    cfg = COSIM_RING
    topo = ring_topology(cfg["n_chips"])
    from repro.cosim.traffic_bridge import SNN_PATTERNS
    rows = []
    key = jax.random.PRNGKey(cfg["key"])
    for name in sorted(SNN_PATTERNS):
        key, cell_key = jax.random.split(key)
        spec = _snn_spec_cached(name, cell_key, cfg["n_chips"],
                                cfg["epc"])
        fab = Fabric(topo, engine=engine)
        (cell,) = fab.sweep([spec], warm=False)
        m = _metrics(cell.result)
        rows.append(_cell(f"fabric_{name}", cell.us_per_call,
                          _derived(m), engine, m, api="fabric",
                          tags=("cosim",)))
    return rows


def sweep_batched(engine=DEFAULT_ENGINE):
    """Batched Monte-Carlo rows: B independently-seeded hot-spot ring-16
    instances as ONE compiled dispatch (``Fabric.sweep_batch``).

    The amortization curve is the row family's whole point:
    ``us_per_call`` grows sub-linearly in B while ``us_per_instance``
    falls — the per-dispatch overhead (argument marshalling, one XLA
    launch) is paid once for the whole batch instead of once per seed.
    Each row's bucket is pre-warmed (``warm=True``), so the timing is
    the steady-state dispatch, matching the other tagged families; the
    >= 3x per-instance strict win over the sequential loop is asserted
    in ``fabric_smoke.run_batch_gate`` — the sweep reports the curve.
    """
    topo = ring_topology(BATCH_RING["n_chips"])
    fab = Fabric(topo, engine=engine)
    specs = tr.monte_carlo(BATCH_RING["pattern"],
                           jax.random.PRNGKey(BATCH_RING["key"]),
                           max(BATCH_SIZES), BATCH_RING["n_chips"],
                           BATCH_RING["epc"])
    rows = []
    for b in BATCH_SIZES:
        cell = fab.sweep_batch(specs[:b])
        batch = cell.result
        m = _metrics(batch.instance(0))
        thr = np.asarray(net.batch_throughput_mev_s(batch))
        m.update(batch=b, us_per_instance=cell.us_per_instance,
                 delivered_total=int(np.asarray(batch.delivered).sum()),
                 thr_mean_mev_s=float(thr.mean()),
                 thr_min_mev_s=float(thr.min()))
        rows.append(_cell(
            f"fabric_{topo.name}_batch{b}", cell.us_per_call,
            f"B={b} us/inst={cell.us_per_instance:.1f} "
            f"delivered={m['delivered_total']} "
            f"thr={m['thr_mean_mev_s']:.1f}MEv/s(mean) "
            f"min={m['thr_min_mev_s']:.1f}MEv/s",
            engine, m, api="fabric", tags=("batch",)))
    return rows


def sweep_verify(engine=DEFAULT_ENGINE, slow=False):
    """Pre-flight lane: ``Fabric.verify()`` over every sweep config.

    Runs the static verifier (``repro.analysis.verify``) against each
    (fabric, spec) pair the other families execute — rings x patterns,
    mesh, heterogeneous timing, multicast modes, every lossless flow
    mode, the batch instances and the adaptive epoch slices — and
    HARD-FAILS if any config is not statically admitted: the sweep must
    never benchmark a workload the verifier can prove deadlocks or
    overflows the clock.  The single cell reports total configs, the
    certificate histogram and the whole lane's wall-time (the cost of
    pre-flighting an entire benchmark campaign, all setup-time numpy —
    no engine compile, no device dispatch).
    """
    t0 = time.perf_counter()
    certs: dict[str, int] = {}
    failures: list[str] = []
    checked = 0

    def check(label, fab, spec):
        nonlocal checked
        rep = fab.verify(spec)
        checked += 1
        cert = rep.certificate or "none"
        certs[cert] = certs.get(cert, 0) + 1
        if not rep.ok:
            failures.append(f"{label}: {rep.summary()}")

    # anchor + rings x patterns (same key schedule as sweep_rings, so
    # the disk-cached specs are shared, not regenerated)
    check("ring2/anchor", Fabric(ring_topology(2),
                                 queues=QueuePolicy(max_burst=1),
                                 engine=engine), tr.ping_pong(2, 1024))
    key = jax.random.PRNGKey(0)
    ns = SWEEP_N + (SLOW_SWEEP_N if slow else ())
    for n in ns:
        topo = ring_topology(n)
        for name in sorted(tr.PATTERNS):
            key, cell_key = jax.random.split(key)
            spec = _spec_cached(name, cell_key, n, EVENTS_PER_CHIP)
            check(f"ring{n}/{name}", Fabric(topo, engine=engine), spec)
    for r, c in ((4, 4),) + (((8, 8),) if slow else ()):
        topo = mesh2d_topology(r, c)
        spec = _spec_cached("poisson", jax.random.PRNGKey(1), topo.n_chips,
                            EVENTS_PER_CHIP)
        check(f"{topo.name}/poisson", Fabric(topo, engine=engine), spec)

    # heterogeneous per-link timing
    topo = ring_topology(8)
    spec = _spec_cached("poisson", jax.random.PRNGKey(7), 8,
                        EVENTS_PER_CHIP)
    mixed = per_link_timing(
        [PAPER_TIMING, SERIAL_LVDS_TIMING],
        [1 if l == topo.n_links - 1 else 0 for l in range(topo.n_links)])
    for tag, timing in (("uniform", PAPER_TIMING), ("hetero", mixed)):
        check(f"ring8/{tag}", Fabric(topo, timing=timing, engine=engine),
              spec)

    # multicast transport modes (the sweep_multicast workload)
    addr = AddressSpec()
    mc = MulticastTable(np.ones((1, 8), bool))
    rng = np.random.default_rng(5)
    n_ev = 8 * EVENTS_PER_CHIP
    src = rng.integers(0, 8, n_ev).astype(np.int32)
    t = np.sort(rng.integers(0, 80_000, n_ev)).astype(np.int32)
    mspec = tr.TrafficSpec(
        src=jax.numpy.asarray(src), t=jax.numpy.asarray(t),
        dest=jax.numpy.asarray(addr.pack_multicast(np.zeros(n_ev,
                                                            np.int64))))
    for mode in ("source_expand", "in_fabric"):
        check(f"ring8/mcast_{mode}",
              Fabric(topo, addr=addr, engine=engine,
                     mcast=MulticastPolicy(mode, mc)), mspec)

    # lossless flow modes on both hot-spot points
    topo16 = ring_topology(LOSSLESS_RING["n_chips"])
    spec16 = _lossless_spec(LOSSLESS_RING)
    for flow in ("drop", "credit", "onoff"):
        check(f"ring16/lossless_{flow}",
              Fabric(topo16, queues=QueuePolicy(
                  capacity=LOSSLESS_RING["capacity"], flow=flow),
                  engine=engine), spec16)
    check("ring16/lossless_credit_hot",
          Fabric(topo16, queues=QueuePolicy(
              capacity=LOSSLESS_RING_HOT["capacity"], flow="credit"),
              engine=engine), _lossless_spec(LOSSLESS_RING_HOT))

    # batch instances (each seeded spec is its own verification)
    bspecs = tr.monte_carlo(BATCH_RING["pattern"],
                            jax.random.PRNGKey(BATCH_RING["key"]),
                            max(BATCH_SIZES), BATCH_RING["n_chips"],
                            BATCH_RING["epc"])
    bfab = Fabric(ring_topology(BATCH_RING["n_chips"]), engine=engine)
    for i, bspec in enumerate(bspecs):
        check(f"ring16/batch_inst{i}", bfab, bspec)

    # spike-driven bridge workloads (sweep_cosim), both on the plain
    # benchmark ring AND on the closed-loop smoke gate's credit fabric —
    # the co-simulation must never run a config the verifier refuses
    from repro.cosim.traffic_bridge import SNN_PATTERNS
    ctopo = ring_topology(COSIM_RING["n_chips"])
    ckey = jax.random.PRNGKey(COSIM_RING["key"])
    for name in sorted(SNN_PATTERNS):
        ckey, cell_key = jax.random.split(ckey)
        cspec = _snn_spec_cached(name, cell_key, COSIM_RING["n_chips"],
                                 COSIM_RING["epc"])
        check(f"ring16/{name}", Fabric(ctopo, engine=engine), cspec)
        check(f"ring16/{name}_credit",
              Fabric(ctopo, queues=QueuePolicy(
                  capacity=COSIM_RING["capacity"], flow="credit"),
                  engine=engine), cspec)

    # adaptive A/B epoch slices (run_epochs executes per-slice, so the
    # slices are what must be admitted)
    from repro.core.adaptive import partition_epochs
    for cfg, topo_a in ((ADAPTIVE_RING,
                         ring_topology(ADAPTIVE_RING["n_chips"])),
                        (ADAPTIVE_MESH,
                         mesh2d_topology(ADAPTIVE_MESH["rows"],
                                         ADAPTIVE_MESH["cols"]))):
        hot = cfg.get("hot_chip")
        aspec = tr.hot_spot(jax.random.PRNGKey(cfg["key"]),
                            topo_a.n_chips, cfg["epc"],
                            **({"hot_chip": hot} if hot is not None
                               else {}))
        afab = Fabric(topo_a, queues=QueuePolicy(
            capacity=cfg["capacity"]), engine=engine)
        for e, part in enumerate(partition_epochs(aspec, cfg["epochs"])):
            check(f"{topo_a.name}/hotspot_epoch{e}", afab, part)

    if failures:
        raise RuntimeError(
            f"fabric pre-flight verification failed for "
            f"{len(failures)}/{checked} config(s):\n" +
            "\n".join(failures))
    us = (time.perf_counter() - t0) * 1e6
    cert_str = " ".join(f"{k}={v}" for k, v in sorted(certs.items()))
    m = {"configs": checked, "us_per_config": us / max(checked, 1),
         "certificates": certs}
    return [_cell("fabric_verify_preflight", us,
                  f"configs={checked} all-ok {cert_str}", engine, m,
                  api="fabric.verify", tags=("verify",))]


def enable_persistent_compile_cache():
    """Opt this process into a persistent XLA compile cache so repeat
    sweep runs (and CI with a cache action) skip the one shared engine
    compilation.  Called from sweep entry points only — importing this
    module must not mutate global JAX config, which would silently
    change what other benchmarks measure."""
    try:
        jax.config.update(
            "jax_compilation_cache_dir",
            os.environ.get("JAX_COMPILATION_CACHE_DIR",
                           os.path.join(os.path.dirname(__file__),
                                        ".jax_cache")))
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
    except Exception:  # pragma: no cover - older jax without the knobs
        pass


#: Every cell tag a sweep family can emit — the single source of truth
#: the CLIs validate ``--tags`` against.
KNOWN_TAGS = frozenset({"hetero", "mcast", "adaptive", "lossless",
                        "batch", "cosim", "verify"})


def run_structured(engine=DEFAULT_ENGINE, slow=False, tags=None):
    """All sweep cells as dicts (the ``BENCH_fabric.json`` payload).

    ``tags`` — optional iterable of tag names (``KNOWN_TAGS``): run only
    the sweep families whose cells carry one of them, and keep only the
    matching cells.  ``None`` runs everything (untagged families
    included).  Unknown tags raise — a typo must not produce an empty
    benchmark run that looks successful.
    """
    enable_persistent_compile_cache()
    wanted = frozenset(tags) if tags else None
    families = (
        (sweep_anchor, (engine,), frozenset()),
        (sweep_rings, (engine, slow), frozenset()),
        (sweep_mesh, (engine, slow), frozenset()),
        (sweep_heterogeneous, (engine,), frozenset({"hetero"})),
        (sweep_multicast, (engine,), frozenset({"mcast"})),
        (sweep_adaptive, (engine,), frozenset({"adaptive"})),
        (sweep_lossless, (engine,), frozenset({"lossless"})),
        (sweep_batched, (engine,), frozenset({"batch"})),
        (sweep_cosim, (engine,), frozenset({"cosim"})),
        (sweep_verify, (engine, slow), frozenset({"verify"})),
    )
    if wanted is not None and wanted - KNOWN_TAGS:
        raise ValueError(f"unknown sweep tags "
                         f"{sorted(wanted - KNOWN_TAGS)}; known tags: "
                         f"{sorted(KNOWN_TAGS)}")
    cells = []
    for fn, args, family_tags in families:
        if wanted is not None and not (wanted & family_tags):
            continue  # genuine selection: unselected families never run
        cells.extend(fn(*args))
    if wanted is not None:
        cells = [c for c in cells if wanted & set(c["tags"])]
    return stamp_env(cells)


def run(engine=DEFAULT_ENGINE, slow=False, tags=None):
    """Legacy row tuples for the CSV convention of ``benchmarks/run.py``."""
    return [(c["name"], c["us_per_call"], c["derived"])
            for c in run_structured(engine, slow, tags)]


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--engine", default=DEFAULT_ENGINE,
                   choices=sorted(net.ENGINES))
    p.add_argument("--slow", action="store_true",
                   help="add the N in {32, 64} ring and 8x8 mesh rows")
    p.add_argument("--tags", default=None,
                   help="comma-separated cell-tag filter (e.g. "
                        "'adaptive,mcast'): run only those families")
    args = p.parse_args()
    sel = args.tags.split(",") if args.tags else None
    try:
        rows = run(engine=args.engine, slow=args.slow, tags=sel)
    except ValueError as e:   # unknown --tags: fail loudly, not a trace
        p.error(str(e))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
