"""Fabric sweep: N-chip AER fabrics x traffic patterns.

Sweeps ring fabrics of N in {2, 4, 8, 16} chips (plus a 4x4 mesh at
N = 16) under every ``traffic.PATTERNS`` generator, reporting delivery,
aggregate + per-link throughput, end-to-end latency percentiles, switch
counts and energy.  The N = 2 ring IS the paper's measured configuration,
so its saturated rows must land on the Table II figures — the sweep's
built-in calibration anchor.

Rows follow the repo convention: ``(name, us_per_call, derived)``.
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.link import PAPER_TIMING
from repro.core.router import mesh2d_topology, ring_topology

EVENTS_PER_CHIP = 48
SWEEP_N = (2, 4, 8, 16)


def _run_one(topo, spec, **kw):
    t0 = time.perf_counter()
    res = net.simulate_fabric(topo, spec, **kw)
    jax.block_until_ready(res.log_del)
    us = (time.perf_counter() - t0) * 1e6
    return res, us


def _derived(res) -> str:
    st = net.latency_stats(res)
    thr = float(net.fabric_throughput_mev_s(res))
    per_link = np.asarray(net.per_link_throughput_mev_s(res))
    e_nj = float(net.fabric_energy_pj(res, PAPER_TIMING)) * 1e-3
    return (f"delivered={st['delivered']}/{st['injected']} "
            f"thr={thr:.1f}MEv/s maxlink={per_link.max():.1f}MEv/s "
            f"p50={st['p50_ns']:.0f}ns p99={st['p99_ns']:.0f}ns "
            f"sw={int(np.asarray(res.n_switches).sum())} E={e_nj:.1f}nJ")


def sweep_rings():
    rows = []
    key = jax.random.PRNGKey(0)
    for n in SWEEP_N:
        topo = ring_topology(n)
        for name, gen in sorted(tr.PATTERNS.items()):
            key, cell_key = jax.random.split(key)
            spec = gen(cell_key, n, EVENTS_PER_CHIP)
            # ping-pong saturates; grant after each event as in Fig. 8
            mb = 1 if name == "ping_pong" else 0
            res, us = _run_one(topo, spec, max_burst=mb)
            rows.append((f"fabric_{topo.name}_{name}", us, _derived(res)))
    return rows


def sweep_mesh():
    rows = []
    topo = mesh2d_topology(4, 4)
    spec = tr.poisson(jax.random.PRNGKey(1), topo.n_chips, EVENTS_PER_CHIP)
    res, us = _run_one(topo, spec)
    rows.append((f"fabric_{topo.name}_poisson", us, _derived(res)))
    return rows


def sweep_anchor():
    """N=2 ping-pong must reproduce the paper's 28.6 MEvents/s (Fig. 8)."""
    topo = ring_topology(2)
    spec = tr.ping_pong(2, 1024)
    res, us = _run_one(topo, spec, max_burst=1)
    thr = float(net.fabric_throughput_mev_s(res))
    return [("fabric_ring2_anchor_fig8", us,
             f"measured={thr:.2f}MEv/s paper=28.6 "
             f"err={abs(thr - 28.6) / 28.6:.2%}")]


def run():
    return sweep_anchor() + sweep_rings() + sweep_mesh()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
