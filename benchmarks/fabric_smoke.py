"""CI bench-smoke: engines agree bit-exactly and the hot path stays fast.

Runs one small fabric through every engine: ring-4 under all traffic
patterns on the ``reference`` slot-scan engine vs. the ``ring`` hot
path, plus one Poisson cell on the ``pallas`` fused-kernel engine
(interpret mode off-TPU) — asserting the ``FabricResult``s identical
field-for-field.  A multicast cell gates the in-fabric replication
claim: ``in_fabric`` must deliver the identical destination multiset as
``source_expand`` while using STRICTLY fewer link traversals on a
shared-path ring (and stay bit-exact across engines itself).  An
adaptive cell gates the congestion-control claim: epoch-based adaptive
routing must strictly reduce drops AND p99 latency vs static routing on
the benchmark hot-spot ring with zero recompiles across epochs.  A batch
cell gates the batched-execution claim: 32 seeded instances of the
Monte-Carlo hot-spot ring must run as ONE dispatch, bit-exact with the
sequential loop, with one compilation and a strict >= 3x per-instance
wall-clock win (``run_batch_gate``).  A verifier cell gates the static
pre-flight claim in both directions: the cyclic-route/acyclic-CDG
table must be admitted and run lossless bit-exactly, the saturable
channel-dependency cycle must be refused with every channel named
(``run_verifier_gate``).  A kernels cell
(``fabric_ring16_pallas_multistep``) gates the fused multi-step kernel:
bit-exact with the ring engine, one compilation, and strictly fewer
Pallas launches than the per-step path by trace-probe count
(``run_kernels_gate``).  A co-simulation cell gates the closed-loop
claim: a recurrent SNN on the benchmark ring-16 must run fully closed
loop over a credit fabric — exact per-tick conservation, 100% lossless
delivery, and a spike-trajectory divergence from the open-loop control
above a hard floor (``run_cosim_gate``).  Then it
times the ring engine end-to-end (compile + run, the number a user
feels) and fails if it regressed more than ``MAX_REGRESSION``x against
the checked-in baseline in ``baselines/fabric_smoke.json``.

The 5x headroom absorbs CI machine variance; a genuine complexity
regression (e.g. the per-step queue read going back to O(C)) overshoots
it immediately.  Refresh the baseline with ``--update-baseline`` after
an intentional perf change.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.adaptive import AdaptiveRouting
from repro.core.fabric import Fabric, MulticastPolicy, QueuePolicy
from repro.core.router import AddressSpec, MulticastTable, ring_topology

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "fabric_smoke.json")
MAX_REGRESSION = 5.0
N_CHIPS = 4
EVENTS_PER_CHIP = 16

_assert_bit_exact = net.assert_results_equal  # one shared field list


def run_smoke() -> dict:
    topo = ring_topology(N_CHIPS)
    t_ring = 0.0
    for i, (name, gen) in enumerate(sorted(tr.PATTERNS.items())):
        spec = gen(jax.random.PRNGKey(i), N_CHIPS, EVENTS_PER_CHIP)
        mb = 1 if name == "ping_pong" else 0
        ref = net.simulate_fabric(topo, spec, engine="reference",
                                  max_burst=mb)
        t0 = time.perf_counter()
        ring = net.simulate_fabric(topo, spec, engine="ring", max_burst=mb)
        jax.block_until_ready(ring.log_del)
        t_ring += time.perf_counter() - t0
        _assert_bit_exact(ref, ring, f"ring{N_CHIPS}/{name}")
        # the simulate_fabric wrapper IS the Fabric object API: identical
        # smoke results, cell for cell
        fab = Fabric(topo, queues=QueuePolicy(max_burst=mb))
        _assert_bit_exact(ring, fab.run(spec),
                          f"ring{N_CHIPS}/{name}/fabric-api")
        if name == "poisson":  # one cell through the fused-kernel engine
            pal = net.simulate_fabric(topo, spec, engine="pallas",
                                      max_burst=mb)
            _assert_bit_exact(ref, pal, f"ring{N_CHIPS}/{name}/pallas")
    saved = run_multicast_gate()
    adaptive = run_adaptive_gate()
    lossless = run_lossless_gate()
    batched = run_batch_gate()
    verifier = run_verifier_gate()
    kernels = run_kernels_gate()
    cosim = run_cosim_gate()
    return {"ring_us": t_ring * 1e6,
            "cells": len(tr.PATTERNS),
            "n_chips": N_CHIPS,
            "events_per_chip": EVENTS_PER_CHIP,
            "mcast_traversals_saved": saved,
            **adaptive, **lossless, **batched, **verifier, **kernels,
            **cosim}


def run_multicast_gate() -> int:
    """Gate the in-fabric multicast claim: identical delivery multiset,
    strictly fewer link traversals than source expansion on a fanout-8
    shared-path ring, bit-exact across ring and reference engines.
    Returns the traversals saved (> 0 or the run fails)."""
    topo = ring_topology(16)
    addr = AddressSpec()
    members = np.zeros((1, 16), bool)
    members[0, 4:12] = True               # fanout 8 from chip 0
    mc = MulticastTable(members)
    n = 12
    spec = tr.TrafficSpec(
        src=jax.numpy.zeros(n, jax.numpy.int32),
        t=jax.numpy.arange(n, dtype=jax.numpy.int32) * 400,
        dest=jax.numpy.asarray(addr.pack_multicast(np.zeros(n, np.int64))))

    def run(mode, engine="ring"):
        return Fabric(topo, addr=addr, engine=engine,
                      mcast=MulticastPolicy(mode, mc)).run(spec)

    infab = run("in_fabric")
    _assert_bit_exact(infab, run("in_fabric", engine="reference"),
                      "mcast/in_fabric ring-vs-reference")
    source = run("source_expand")

    if net.delivery_multiset(infab) != net.delivery_multiset(source):
        raise RuntimeError("in_fabric multicast delivered a different "
                           "destination multiset than source_expand")
    if infab.traversals >= source.traversals:
        raise RuntimeError(
            f"in-fabric multicast did not save traversals: "
            f"{infab.traversals} vs {source.traversals} (source expand)")
    return source.traversals - infab.traversals


def run_adaptive_gate() -> dict:
    """Gate the congestion-control claim: on the benchmark hot-spot ring
    workload (``fabric_sweep.ADAPTIVE_RING``), epoch-based adaptive
    routing must STRICTLY reduce both drops and p99 latency vs static
    shortest-path routing of the identical workload (identical epoch
    partition, so the only difference is the tables), while keeping the
    delivered + drops == injected accounting exact and running all
    epochs through ONE engine compilation."""
    from benchmarks.fabric_sweep import ADAPTIVE_RING as cfg
    topo = ring_topology(cfg["n_chips"])
    spec = tr.hot_spot(jax.random.PRNGKey(cfg["key"]), cfg["n_chips"],
                       cfg["epc"])
    queues = QueuePolicy(capacity=cfg["capacity"])
    static = Fabric(topo, queues=queues)
    res_s = static.run_epochs(spec, epochs=cfg["epochs"])
    adaptive = Fabric(topo, routing=AdaptiveRouting(
        policy=cfg["policy"], epochs=cfg["epochs"], alpha=cfg["alpha"],
        ema=cfg["ema"]), queues=queues)
    res_a = adaptive.run(spec)

    for tag, res in (("static", res_s), ("adaptive", res_a)):
        if int(res.delivered) + int(res.drops) != res.injected:
            raise RuntimeError(f"{tag}: delivered + drops != injected")
    report = adaptive.last_report
    if report.recompiled:
        raise RuntimeError(
            f"adaptive epochs recompiled: buckets={report.buckets}, "
            f"per-epoch cache sizes "
            f"{[r.cache_size for r in report.records]} (expected one "
            f"bucket and a flat jit cache after epoch 0)")
    p99_s = net.latency_stats(res_s)["p99_ns"]
    p99_a = net.latency_stats(res_a)["p99_ns"]
    if not (int(res_a.drops) < int(res_s.drops) and p99_a < p99_s):
        raise RuntimeError(
            f"adaptive routing did not strictly beat static on the "
            f"hot-spot ring: drops {int(res_a.drops)} vs "
            f"{int(res_s.drops)}, p99 {p99_a:.0f} vs {p99_s:.0f} ns")
    return {"adaptive_drops_saved": int(res_s.drops) - int(res_a.drops),
            "adaptive_p99_saved_ns": float(p99_s - p99_a)}


def _p99_loss_inclusive(res) -> float:
    """p99 end-to-end latency over the OFFERED load: a dropped event
    never arrives, so it counts as unbounded latency.  A lossy run
    dropping more than 1% of its traffic therefore has an infinite
    loss-inclusive p99 — the honest tail metric for an A/B against a
    lossless transport."""
    lat = np.asarray(res.log_del[:int(res.delivered)], np.float64) - \
        np.asarray(res.log_inj[:int(res.delivered)], np.float64)
    all_lat = np.sort(np.concatenate([lat, np.full(int(res.drops),
                                                   np.inf)]))
    # nearest-rank order statistic (linear interpolation between a
    # finite value and inf is nan)
    return float(all_lat[max(int(np.ceil(0.99 * all_lat.size)) - 1, 0)])


def run_lossless_gate() -> dict:
    """Gate the lossless-fabric claim end to end.

    Two deterministic hot-spot ring-16 workloads
    (``fabric_sweep.LOSSLESS_RING`` / ``LOSSLESS_RING_HOT``), identical
    ``QueuePolicy`` capacity, only ``flow`` differs:

    1. Mild overload — credit flow control must deliver every offered
       event with ZERO drops while drop mode loses traffic, and credit
       must STRICTLY beat drop mode on p99 even on the delivered-only
       metric (which is survivorship-biased toward drop mode: its
       survivors are the early, uncongested events).
    2. Saturating flood — backpressure must demonstrably engage
       (``stall_steps > 0``) and the fabric must STILL deliver 100%;
       drop mode loses most of the load, so its loss-inclusive p99 is
       infinite while credit's stays finite.

    Cross-engine: ring and reference must agree bit-for-bit on the
    full-size credit run (pallas is gated at ring-4 size inside
    ``run_smoke``'s per-pattern loop cost budget — here a reduced
    ring-8 credit cell keeps interpret-mode cost bounded), and the
    delivered + drops == injected accounting must hold in every mode.
    The three flow modes must also share ONE engine compilation (flow
    mode, capacity and xon are dynamic operands — zero new shape
    buckets, flat jit cache)."""
    from benchmarks.fabric_sweep import (LOSSLESS_RING, LOSSLESS_RING_HOT,
                                         _lossless_spec)
    topo = ring_topology(LOSSLESS_RING["n_chips"])
    spec = _lossless_spec(LOSSLESS_RING)
    cap = LOSSLESS_RING["capacity"]

    def run(flow, engine="ring", cfg_spec=None, capacity=cap, t=topo):
        res = Fabric(t, queues=QueuePolicy(capacity=capacity, flow=flow),
                     engine=engine).run(cfg_spec if cfg_spec is not None
                                        else spec)
        if int(res.delivered) + int(res.drops) != res.injected:
            raise RuntimeError(
                f"lossless gate [{flow}/{engine}]: delivered + drops != "
                f"injected ({int(res.delivered)} + {int(res.drops)} != "
                f"{res.injected})")
        return res

    # -- 1. mild overload: lossless AND a strict survivor-p99 win ------
    res_d, res_c = run("drop"), run("credit")
    if int(res_c.drops) != 0 or int(res_c.delivered) != res_c.injected:
        raise RuntimeError(
            f"credit flow control dropped events: delivered "
            f"{int(res_c.delivered)}/{res_c.injected}, "
            f"drops {int(res_c.drops)}")
    if int(res_d.drops) == 0:
        raise RuntimeError("lossless gate workload no longer congests: "
                           "drop mode dropped nothing (gate is vacuous)")
    p99_d = net.latency_stats(res_d)["p99_ns"]
    p99_c = net.latency_stats(res_c)["p99_ns"]
    if not p99_c < p99_d:
        raise RuntimeError(
            f"credit flow control did not strictly beat drop mode on "
            f"delivered-events p99: {p99_c:.0f} vs {p99_d:.0f} ns "
            f"(drop mode lost {int(res_d.drops)} events)")

    # -- 2. saturating flood: backpressure engages, still 100% ---------
    hot_spec = _lossless_spec(LOSSLESS_RING_HOT)
    hot_cap = LOSSLESS_RING_HOT["capacity"]
    res_hd = run("drop", cfg_spec=hot_spec, capacity=hot_cap)
    res_hc = run("credit", cfg_spec=hot_spec, capacity=hot_cap)
    stalls = int(np.asarray(res_hc.telemetry.stall_steps).sum())
    if int(res_hc.drops) != 0 or stalls == 0:
        raise RuntimeError(
            f"saturating lossless cell: drops={int(res_hc.drops)} "
            f"stall_steps={stalls} (want zero drops with backpressure "
            f"demonstrably engaged)")
    p99_all_d, p99_all_c = (_p99_loss_inclusive(res_hd),
                            _p99_loss_inclusive(res_hc))
    if not p99_all_c < p99_all_d:
        raise RuntimeError(
            f"loss-inclusive p99 did not favor credit under saturation: "
            f"{p99_all_c:.0f} vs {p99_all_d}")

    # -- cross-engine bit-exactness ------------------------------------
    for flow, full in (("credit", res_c), ("onoff", None)):
        got = run(flow, engine="reference")
        if full is not None:
            _assert_bit_exact(full, got, f"lossless/{flow} ring-vs-ref")
        else:
            _assert_bit_exact(run(flow), got,
                              f"lossless/{flow} ring-vs-ref")
    small = ring_topology(8)
    small_spec = tr.hot_spot(jax.random.PRNGKey(2), 8, 12,
                             mean_gap_ns=200.0, hot_frac=0.75)
    _assert_bit_exact(
        run("credit", cfg_spec=small_spec, capacity=12, t=small),
        run("credit", engine="pallas", cfg_spec=small_spec, capacity=12,
            t=small),
        "lossless/credit ring-vs-pallas (ring8)")

    # -- one compilation serves all three flow modes -------------------
    fab = Fabric(topo, queues=QueuePolicy(capacity=cap), engine="ring")
    cf = fab.compile(spec)
    fab.run(spec)
    size0 = cf.cache_size()
    for flow in ("credit", "onoff"):
        other = Fabric(topo, queues=QueuePolicy(capacity=cap, flow=flow),
                       engine="ring")
        cf2 = other.compile(spec, warm=False)
        if cf2.bucket != cf.bucket:
            raise RuntimeError(
                f"flow={flow} split the engine shape bucket: "
                f"{cf2.bucket} vs {cf.bucket}")
        other.run(spec)
    if cf.cache_size() != size0:
        raise RuntimeError(
            f"flow modes grew the jit cache: {cf.cache_size()} vs "
            f"{size0} entries (capacity/flow/xon must stay dynamic)")

    return {"lossless_p99_saved_ns": float(p99_d - p99_c),
            "lossless_drop_mode_drops": int(res_d.drops),
            "lossless_stall_steps": stalls}


MIN_BATCH_SPEEDUP = 3.0        # parallel-capable backends (GPU/TPU,
#                                multi-device or multi-core CPU)
MIN_BATCH_SPEEDUP_SERIAL = 0.6  # single-core CPU floor, see below
BATCH_B = 32


def _batch_speedup_floor() -> float:
    """Pick the per-instance speedup bound this machine must clear.

    The batch win comes from two sources: amortizing per-op fixed
    overhead (dispatch, loop plumbing — always available) and running
    instances' element work in parallel (needs parallel hardware).  On
    a single-core CPU only the first exists: XLA executes the batched
    element work serially, so the measured ceiling is ~1x (typical run:
    0.85-1.0x) and demanding 3x would gate on hardware, not on the
    code.  The serial floor of ``MIN_BATCH_SPEEDUP_SERIAL`` is still a
    REAL regression gate: the naive formulation (vmapping the whole
    runner, batched scatters in the hot loop) measures 8-13x SLOWER
    per instance than sequential (0.08-0.12x), so any return of that
    pathology class fails the floor with a 5x margin while normal
    machine noise clears it.
    Everything else the gate asserts (bit-exactness, single compile) is
    backend-independent and always hard.
    """
    if jax.default_backend() != "cpu" or jax.local_device_count() > 1:
        return MIN_BATCH_SPEEDUP
    cores = os.cpu_count() or 1
    return MIN_BATCH_SPEEDUP if cores >= 4 else MIN_BATCH_SPEEDUP_SERIAL


def run_verifier_gate() -> dict:
    """Gate the static verifier's precision claim in both directions.

    1. Precision (no false refusal): a ring-4 table whose dest-1 routes
       are bent into a 0 <-> 3 next-hop cycle has a CYCLIC route graph
       but an ACYCLIC channel-dependency graph — PR 7 refused it
       outright; the Dally–Seitz criterion must ADMIT it
       (``certificate == "acyclic-cdg"``), and traffic avoiding the
       quarantined pairs must run lossless under credit flow,
       bit-exact between the ring and reference engines.
    2. Soundness (no false admission): the all-clockwise ring-4 table
       under credit flow with capacity 2 and antipodal traffic is a
       genuine saturable channel-dependency cycle; ``verify`` must
       REFUSE it and NAME every channel of the cycle.
    """
    from repro.core.fabric import StaticShortestPath
    from repro.core.router import RoutingTable

    def bent(topo_, rt):
        nl, os_ = rt.next_link.copy(), rt.out_side.copy()
        nl[0, 1], os_[0, 1] = 3, 1
        nl[3, 1], os_[3, 1] = 3, 0
        return RoutingTable(next_link=nl, out_side=os_, hops=rt.hops)

    def clockwise(topo_, rt):
        n = rt.next_link.shape[0]
        nl, os_, hops = (rt.next_link.copy(), rt.out_side.copy(),
                         rt.hops.copy())
        for c in range(n):
            for d in range(n):
                if c != d:
                    nl[c, d], os_[c, d], hops[c, d] = c, 0, (d - c) % n
        return RoutingTable(next_link=nl, out_side=os_, hops=hops)

    i32 = lambda x: np.asarray(x, np.int32)  # noqa: E731

    # -- 1. cyclic routes, acyclic CDG: admitted and lossless ----------
    def bent_fab(engine):
        return Fabric(ring_topology(4),
                      routing=StaticShortestPath(table_override=bent),
                      queues=QueuePolicy(capacity=8, flow="credit"),
                      engine=engine)

    rep = bent_fab("ring").verify()
    if not rep.ok or rep.certificate != "acyclic-cdg":
        raise RuntimeError(
            f"verifier gate: the bent-route table must be admitted "
            f"with the acyclic-cdg certificate, got {rep.summary()}")
    clean = tr.TrafficSpec(src=i32([0, 1, 2, 3, 0, 2]),
                           t=i32([0, 0, 0, 0, 40, 40]),
                           dest=i32([2, 3, 0, 2, 3, 1]))
    res_ring = bent_fab("ring").run(clean)
    res_ref = bent_fab("reference").run(clean)
    _assert_bit_exact(res_ring, res_ref, "verifier/bent-credit")
    if int(res_ring.delivered) != res_ring.injected \
            or int(res_ring.drops) != 0:
        raise RuntimeError(
            f"verifier gate: admitted bent-route fabric did not drain "
            f"losslessly ({int(res_ring.delivered)}/{res_ring.injected}"
            f" delivered, {int(res_ring.drops)} drops)")

    # -- 2. saturable CDG cycle: refused with the cycle named ----------
    dead = Fabric(ring_topology(4),
                  routing=StaticShortestPath(table_override=clockwise),
                  queues=QueuePolicy(capacity=2, flow="credit"))
    src = np.repeat(np.arange(4, dtype=np.int32), 8)
    spec = tr.TrafficSpec(src=src, t=i32(np.arange(32) * 5),
                          dest=i32((src + 3) % 4))
    rep = dead.verify(spec)
    errs = [f for f in rep.findings
            if f.severity == "error" and f.check == "cdg-cycle"]
    if rep.ok or not errs:
        raise RuntimeError(
            f"verifier gate: the all-clockwise deadlock must be "
            f"refused with a cdg-cycle error, got {rep.summary()}")
    channels = ("L0:0->1", "L1:1->2", "L2:2->3", "L3:3->0")
    missing = [ch for ch in channels if ch not in errs[0].message]
    if missing:
        raise RuntimeError(
            f"verifier gate: deadlock refusal must name every channel "
            f"of the cycle; missing {missing} in: {errs[0].message}")
    return {"verifier_bent_delivered": int(res_ring.delivered),
            "verifier_cycle_channels": len(channels)}


def run_batch_gate() -> dict:
    """Gate the batched-execution claim end to end.

    B = 32 independently-seeded hot-spot ring-16 instances
    (``fabric_sweep.BATCH_RING``, the Monte-Carlo scenario) run as ONE
    batched dispatch (``Fabric.run_batch``) and must be

    1. bit-exact, instance for instance, with the sequential
       ``fab.run`` loop over the identical specs (the batch axis must
       never couple instances — the ring engine's early-exit
       while_loop freezes each instance's carry after its own drain);
    2. served by exactly ONE batched-engine compilation
       (``batch_cache_size`` on the shared shape bucket); and
    3. STRICTLY >= the backend's speedup floor per instance vs the
       warmed sequential loop: ``MIN_BATCH_SPEEDUP``x where the batch
       axis can actually parallelize, the
       ``MIN_BATCH_SPEEDUP_SERIAL``x anti-pathology floor on a
       single-core CPU (see :func:`_batch_speedup_floor`).
    """
    from benchmarks.fabric_sweep import BATCH_RING as cfg
    from repro.core.fabric import batch_cache_size

    topo = ring_topology(cfg["n_chips"])
    specs = tr.monte_carlo(cfg["pattern"], jax.random.PRNGKey(cfg["key"]),
                           BATCH_B, cfg["n_chips"], cfg["epc"])

    solo_fab = Fabric(topo)
    solo = [solo_fab.run(s) for s in specs]   # one bucket, warmed now
    t0 = time.perf_counter()
    for s in specs:
        jax.block_until_ready(solo_fab.run(s).log_del)
    us_seq = (time.perf_counter() - t0) * 1e6 / BATCH_B

    cell = Fabric(topo).sweep_batch(specs)    # warm=True: no compile bias
    for i, r in enumerate(solo):
        _assert_bit_exact(r, cell.result.instance(i),
                          f"batch{BATCH_B}/{i}")
    n_entries = batch_cache_size(cell.bucket)
    if n_entries != 1:
        raise RuntimeError(
            f"batched engine compiled {n_entries} times for one "
            f"(bucket, B) signature (want exactly 1: the warm dispatch "
            f"and the timed dispatch must share the jit cache entry)")
    speedup = us_seq / cell.us_per_instance
    floor = _batch_speedup_floor()
    if speedup < floor:
        raise RuntimeError(
            f"run_batch per-instance win too small: {speedup:.2f}x vs "
            f"the sequential loop ({cell.us_per_instance:.0f} vs "
            f"{us_seq:.0f} us/instance; want >= {floor:.1f}x on "
            f"{jax.default_backend()} x{jax.local_device_count()} "
            f"device(s), {os.cpu_count()} core(s))")
    return {"batch_b": BATCH_B,
            "batch_us_per_instance": cell.us_per_instance,
            "batch_seq_us_per_instance": us_seq,
            "batch_speedup": speedup,
            "batch_speedup_floor": floor}


MIN_COSIM_DIVERGENCE = 16


def run_cosim_gate() -> dict:
    """Gate the closed-loop co-simulation claim end to end.

    A recurrent SNN on the benchmark ring-16
    (``fabric_sweep.COSIM_RING``: forward + backward ring projections
    plus local recurrence, deterministic key) runs fully closed-loop —
    every inter-chip spike transported by a credit-flow-controlled
    fabric, every delivered event fed back into the next tick's
    membrane currents — and must satisfy:

    1. exact conservation EVERY tick: delivered + drops == injected;
    2. losslessness: credit flow control delivers 100% with ZERO drops;
    3. the loop is real: the closed-loop spike trajectory diverges from
       the open-loop control (identical placement, weights and drive;
       the fabric path severed) by at least ``MIN_COSIM_DIVERGENCE``
       spike-count units — a vacuously-closed loop (feedback never
       arriving, scatter mapping broken, weights zeroed) fails the
       floor immediately.
    """
    from benchmarks.fabric_sweep import COSIM_RING as cfg
    from repro.cosim import CosimConfig, CosimEngine
    from repro.cosim.traffic_bridge import _ring_placement

    pl = _ring_placement(cfg["n_chips"], "recurrent", addr=AddressSpec())
    key = jax.random.PRNGKey(cfg["key"])
    ccfg = CosimConfig(input_rate=cfg["input_rate"], feedback="none")
    opn = CosimEngine(pl, ccfg, key=key).run(cfg["ticks"])
    fab = pl.fabric(queues=QueuePolicy(capacity=cfg["capacity"],
                                       flow="credit"))
    cls = CosimEngine(pl, ccfg._replace(feedback="next_tick"),
                      fabric=fab, key=key).run(cfg["ticks"])

    if not cls.conservation_exact:
        bad = np.flatnonzero(cls.delivered + cls.drops != cls.injected)
        raise RuntimeError(
            f"cosim gate: delivered + drops != injected on tick(s) "
            f"{bad.tolist()}")
    if int(cls.drops.sum()) != 0 or \
            int(cls.delivered.sum()) != int(cls.injected.sum()):
        raise RuntimeError(
            f"cosim gate: credit fabric was not lossless — delivered "
            f"{int(cls.delivered.sum())}/{int(cls.injected.sum())}, "
            f"drops {int(cls.drops.sum())}")
    if int(cls.delivered.sum()) == 0:
        raise RuntimeError("cosim gate is vacuous: the network never "
                           "spiked across chips")
    divergence = int(np.abs(cls.spikes - opn.spikes).sum())
    if divergence < MIN_COSIM_DIVERGENCE:
        raise RuntimeError(
            f"cosim gate: closed-loop spiking diverged from open loop "
            f"by only {divergence} (< {MIN_COSIM_DIVERGENCE}) — the "
            f"fabric feedback path is not reaching the dynamics")
    return {"cosim_ticks": cfg["ticks"],
            "cosim_delivered": int(cls.delivered.sum()),
            "cosim_divergence": divergence}


MULTISTEP_CHUNK = 64
MIN_DISPATCH_WIN = 16.0


def run_kernels_gate() -> dict:
    """Gate the fused multi-step kernel claim
    (``fabric_ring16_pallas_multistep``).

    A hot-spot ring-16 workload through ``engine="pallas"`` with
    ``kernel="multistep"`` must be

    1. bit-exact with the ``ring`` engine (full ``FabricResult`` field
       list) with ``delivered + drops == injected``;
    2. served by exactly ONE compilation (``cache_size`` flat across a
       repeat run — the no-recompile contract); and
    3. STRICTLY cheaper in kernel dispatches than the per-step pallas
       path on the same shape bucket: the trace probe
       (``repro.analysis.dispatch``) must count ``2 * max_steps``
       launches for the per-step engine, ``ceil(max_steps / chunk)``
       for the fused one, a >= ``MIN_DISPATCH_WIN``x win.  The count is
       a static program property, so the gate is immune to CI machine
       noise.
    """
    from repro.analysis.dispatch import pallas_dispatches

    topo = ring_topology(16)
    spec = tr.hot_spot(jax.random.PRNGKey(5), 16, 3, mean_gap_ns=150.0,
                       hot_frac=0.75)
    from repro.core.fabric import EngineSpec
    fab = Fabric(topo, engine=EngineSpec(name="pallas",
                                         kernel="multistep",
                                         chunk_size=MULTISTEP_CHUNK))
    cf = fab.compile(spec, warm=False)
    res = cf.run(spec)
    n0 = cf.cache_size()
    cf.run(spec)
    if cf.cache_size() != n0 or n0 != 1:
        raise RuntimeError(
            f"multistep kernel gate: want exactly one compilation with "
            f"a flat cache across runs, got {n0} -> {cf.cache_size()}")
    _assert_bit_exact(Fabric(topo, engine="ring").run(spec), res,
                      "kernels/ring16-multistep")
    if int(res.delivered) + int(res.drops) != res.injected:
        raise RuntimeError("multistep kernel gate: delivered + drops != "
                           "injected")

    # dispatch economy: trace both engine builds over this bucket's
    # operand shapes and count pallas_call launches (loop trips applied)
    _eng, L, E, C, max_steps, mb, R, K, _kern, chunk = cf.bucket
    N = topo.n_chips
    i32 = np.int32
    args = (np.zeros((2 * L, C), i32), np.zeros((2 * L, C), i32),
            np.zeros((2 * L, C), i32), np.zeros((L, 2), i32),
            np.ones(L, i32), np.zeros((L, 2), i32),
            np.zeros((N, R, K), i32), np.zeros((N, R), i32),
            np.zeros((N, R, K), i32),
            np.zeros(L, i32), np.zeros(L, i32), np.zeros(L, i32),
            jax.numpy.int32(C), jax.numpy.int32(0), jax.numpy.int32(0))
    d_step = pallas_dispatches(
        net._slot_run(L, E, C, max_steps, mb, True), *args)
    d_ms = pallas_dispatches(
        net._slot_run_multistep(L, E, C, max_steps, mb, chunk), *args)
    want_step, want_ms = 2 * max_steps, -(-max_steps // chunk)
    if (d_step, d_ms) != (want_step, want_ms):
        raise RuntimeError(
            f"dispatch probe mismatch: per-step {d_step} (want "
            f"{want_step}), multistep {d_ms} (want {want_ms})")
    win = d_step / d_ms
    if win < MIN_DISPATCH_WIN:
        raise RuntimeError(
            f"multistep kernel dispatch win too small: {win:.1f}x "
            f"({d_step} vs {d_ms} launches; want >= "
            f"{MIN_DISPATCH_WIN:.0f}x)")
    return {"multistep_chunk": chunk,
            "multistep_dispatches": d_ms,
            "step_dispatches": d_step,
            "multistep_dispatch_win": win}


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--update-baseline", action="store_true",
                   help="overwrite the checked-in wall-clock baseline")
    args = p.parse_args(argv)

    result = run_smoke()
    print(f"engines bit-exact on {result['cells']} ring{N_CHIPS} cells; "
          f"in-fabric multicast saves "
          f"{result['mcast_traversals_saved']} traversals; "
          f"adaptive routing saves {result['adaptive_drops_saved']} "
          f"drops / {result['adaptive_p99_saved_ns']:.0f} ns p99 on the "
          f"hot-spot ring; "
          f"credit flow control recovers "
          f"{result['lossless_drop_mode_drops']} dropped events and "
          f"{result['lossless_p99_saved_ns']:.0f} ns p99 "
          f"({result['lossless_stall_steps']} stall steps under "
          f"saturation); "
          f"batch B={result['batch_b']} runs "
          f"{result['batch_speedup']:.1f}x cheaper per instance than "
          f"the sequential loop "
          f"({result['batch_us_per_instance']:.0f} vs "
          f"{result['batch_seq_us_per_instance']:.0f} us); "
          f"static verifier admits the bent-route ring and names the "
          f"{result['verifier_cycle_channels']}-channel deadlock "
          f"cycle; "
          f"multistep kernel cuts dispatches "
          f"{result['multistep_dispatch_win']:.0f}x "
          f"({result['step_dispatches']} -> "
          f"{result['multistep_dispatches']} launches at chunk "
          f"{result['multistep_chunk']}); "
          f"closed-loop SNN delivers {result['cosim_delivered']} events "
          f"losslessly over {result['cosim_ticks']} ticks and diverges "
          f"from open loop by {result['cosim_divergence']}; "
          f"ring engine {result['ring_us'] / 1e3:.0f} ms total "
          f"(compile + run)")

    if args.update_baseline:
        os.makedirs(os.path.dirname(BASELINE), exist_ok=True)
        with open(BASELINE, "w") as f:
            json.dump(result, f, indent=2)
        print(f"baseline updated: {BASELINE}")
        return 0

    with open(BASELINE) as f:
        base = json.load(f)
    ratio = result["ring_us"] / base["ring_us"]
    print(f"wall-clock vs baseline: {ratio:.2f}x "
          f"(limit {MAX_REGRESSION:.1f}x)")
    if ratio > MAX_REGRESSION:
        print(f"FAIL: ring engine regressed {ratio:.2f}x over the "
              f"checked-in baseline ({base['ring_us'] / 1e3:.0f} ms)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
