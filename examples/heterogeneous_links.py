"""Per-link timing heterogeneity: fast on-board buses + one slow LVDS link.

Real multi-chip AER systems rarely get a uniform interconnect: chips on
one board talk over the paper's fast parallel bus, while inter-board hops
ride slow bit-serial LVDS bridges (Qiao & Indiveri 2019; the paper's own
§V "sub-words" proposal trades wires for cycle time).  This example
builds an 8-chip ring where link 7 — think "the board-to-board cable" —
is the paper's sub-word contract taken to bit-serial (1 wire, 26 beats,
331 ns/event vs 31 ns), runs identical Poisson traffic through the
uniform and the mixed fabric with the declarative ``Fabric`` API, and
prints the per-link throughput and latency deltas: the slow link
bottlenecks only the flows that cross it.

    PYTHONPATH=src python examples/heterogeneous_links.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import Fabric, QueuePolicy
from repro.core.link import PAPER_TIMING, SERIAL_LVDS_TIMING, per_link_timing
from repro.core.router import ring_topology

N_CHIPS = 8
SLOW_LINK = 7            # the ring's 7-0 edge: the "inter-board" hop
EVENTS_PER_CHIP = 48


def stats_line(tag, res, timing):
    st = net.latency_stats(res)
    thr = float(net.fabric_throughput_mev_s(res))
    e_nj = float(net.fabric_energy_pj(res, timing)) * 1e-3
    return (f"  {tag:<12} delivered={st['delivered']}/{st['injected']} "
            f"thr={thr:5.1f}MEv/s p50={st['p50_ns']:6.0f}ns "
            f"p99={st['p99_ns']:6.0f}ns max={st['max_ns']:6d}ns "
            f"E={e_nj:.1f}nJ")


def main():
    topo = ring_topology(N_CHIPS)
    spec = tr.poisson(jax.random.PRNGKey(0), N_CHIPS, EVENTS_PER_CHIP,
                      mean_gap_ns=400.0)

    mixed = per_link_timing(
        [PAPER_TIMING, SERIAL_LVDS_TIMING],
        [1 if l == SLOW_LINK else 0 for l in range(topo.n_links)])

    print(f"link classes: parallel bus {PAPER_TIMING.t_req2req_ns} ns/event"
          f" ({PAPER_TIMING.word_bits} wires) | serial LVDS "
          f"{SERIAL_LVDS_TIMING.t_req2req_ns} ns/event "
          f"({SERIAL_LVDS_TIMING.word_bits} wire) on link {SLOW_LINK}")

    # --- declarative fabrics, explicit compile/run lifecycle ------------
    uniform = Fabric(topo, timing=PAPER_TIMING)
    hetero = Fabric(topo, timing=mixed)
    # one shape bucket serves both (timing is a dynamic operand): the
    # second compile is a cache hit inside the shared engine
    cf_u = uniform.compile(spec)
    cf_h = hetero.compile(spec)
    print(f"compiled bucket: {cf_u.bucket} "
          f"(shared by both fabrics: {cf_u.bucket == cf_h.bucket})")

    res_u = cf_u.run(spec)
    res_h = cf_h.run(spec)

    print("\n=== fabric totals ===")
    print(stats_line("uniform", res_u, PAPER_TIMING))
    print(stats_line("mixed", res_h, mixed))

    # --- per-link deltas -------------------------------------------------
    # Occupancy = time the bus spends moving events / link-local clock:
    # the slow link saturates while the parallel links stay mostly idle —
    # the bottleneck is local even though every flow crossing it stalls.
    thr_u = np.asarray(net.per_link_throughput_mev_s(res_u))
    thr_h = np.asarray(net.per_link_throughput_mev_s(res_h))
    tc = np.asarray([PAPER_TIMING.t_req2req_ns] * topo.n_links)
    tc[SLOW_LINK] = SERIAL_LVDS_TIMING.t_req2req_ns
    sent_u = np.asarray(res_u.sent).sum(axis=1)
    sent_h = np.asarray(res_h.sent).sum(axis=1)
    occ_u = 100.0 * sent_u * PAPER_TIMING.t_req2req_ns \
        / np.asarray(res_u.t_link)
    occ_h = 100.0 * sent_h * tc / np.asarray(res_h.t_link)
    print("\n=== per-link throughput (MEv/s) and bus occupancy ===")
    print(f"  {'link':<6}{'class':<10}{'thr(u)':>8}{'thr(m)':>8}"
          f"{'occ(u)':>8}{'occ(m)':>8}  hops")
    for l, (a, b) in enumerate(topo.links):
        cls = "lvds" if l == SLOW_LINK else "parallel"
        print(f"  {l}:{a}-{b:<3} {cls:<10}{thr_u[l]:>8.2f}{thr_h[l]:>8.2f}"
              f"{occ_u[l]:>7.0f}%{occ_h[l]:>7.0f}%  {int(sent_h[l])}")

    # --- latency deltas ---------------------------------------------------
    lat_u = net.delivered_latencies(res_u)
    lat_h = net.delivered_latencies(res_h)
    d_p50 = np.percentile(lat_h, 50) - np.percentile(lat_u, 50)
    d_p99 = np.percentile(lat_h, 99) - np.percentile(lat_u, 99)
    print(f"\nlatency delta (mixed - uniform): p50 {d_p50:+.0f} ns, "
          f"p99 {d_p99:+.0f} ns")
    print("the long tail is the queue behind the serial link; the p50 "
          "barely moves because\nmost routes never cross it.")

    # sanity for the CI fast lane: everything delivers on both fabrics,
    # and heterogeneity can only stretch the end time
    assert int(res_u.delivered) == res_u.injected
    assert int(res_h.delivered) == res_h.injected
    assert int(res_h.t_end) >= int(res_u.t_end)
    print("\nOK")


if __name__ == "__main__":
    main()
