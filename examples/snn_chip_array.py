"""Paper-native example: 2D neuromorphic chip array with bi-directional
AER inter-chip links (the system of paper §IV / Fig. 6).

A 4x4 grid of LIF "chips" runs for N ticks; spikes crossing chip borders
become 26-bit Address-Events on SHARED per-pair buses (one bus per link,
direction switched on demand by the transceiver protocol) instead of the
conventional two unidirectional buses.  The run reports:

  * network activity and inter-chip event rates,
  * bus occupancy vs. the measured 28.6 MEvents/s worst-case capacity,
  * energy at 11 pJ/event,
  * the wire economy (27 vs 54 wires per link — the paper's 100-pin saving),
  * an exact protocol-simulator replay of the busiest link's trace.

    PYTHONPATH=src python examples/snn_chip_array.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol_sim as ps
from repro.core.link import PAPER_TIMING
from repro.models import snn

TICKS = 200
TICK_DT_US = 100.0   # 100 us per network tick (10 kHz update)


def main():
    cfg = snn.SnnConfig(grid=(4, 4), neurons=256, input_rate=0.08)
    params, state = snn.init_snn(cfg, jax.random.PRNGKey(42))
    run = jax.jit(lambda p, s: snn.run_snn(p, cfg, s, TICKS))
    state, ticks = run(params, state)
    ticks = jax.tree.map(np.asarray, ticks)

    rep = snn.link_report(ticks, tick_dt_us=TICK_DT_US)
    print(f"4x4 chip array, {cfg.neurons} LIF neurons/chip, {TICKS} ticks")
    print(f"  mean firing rate      : {ticks['rate'].mean():.4f} /neuron/tick")
    print(f"  inter-chip events     : {rep['events_total']:.0f} "
          f"({rep['events_per_s']:.3e} ev/s aggregate)")
    print(f"  bus occupancy         : {rep['bus_busy_frac']:.3%} of wall "
          f"time (capacity 28.6 MEv/s/link)")
    print(f"  energy (AER transfer) : {rep['energy_uj']:.2f} uJ @ 11 pJ/event")
    print(f"  wires per link        : {rep['shared_bus_wires_per_link']} "
          f"shared-bus vs {rep['dual_bus_wires_per_link']} dual-bus "
          f"(paper: 100 pins saved on 4 borders)")

    # exact replay of the busiest East-West link through the protocol sim
    lr = ticks["ew_events_lr"].sum() / TICKS
    rl = ticks["ew_events_rl"].sum() / TICKS
    per_tick_lr = max(int(round(lr / 12)), 1)   # per-link share (12 EW links)
    per_tick_rl = max(int(round(rl / 12)), 1)
    tick_ns = int(TICK_DT_US * 1e3)
    arr_l = np.concatenate([t * tick_ns + np.arange(per_tick_lr)
                            for t in range(50)]).astype(np.int32)
    arr_r = np.concatenate([t * tick_ns + np.arange(per_tick_rl)
                            for t in range(50)]).astype(np.int32)
    res = ps.simulate(jnp.asarray(np.sort(arr_l)), jnp.asarray(np.sort(arr_r)),
                      initial_tx=1)
    print(f"  busiest-link replay   : {int(res.sent_l)}+{int(res.sent_r)} "
          f"events, {int(res.n_switches)} direction switches, "
          f"all delivered by t={int(res.t_end)}ns "
          f"(energy {float(ps.energy_pj(res))/1e3:.2f} nJ)")
    assert int(res.sent_l) == arr_l.shape[0]
    assert int(res.sent_r) == arr_r.shape[0]
    print("  OK — event conservation + deadlock-freedom on the replay")


if __name__ == "__main__":
    main()
