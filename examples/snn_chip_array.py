"""Paper-native example: 2D neuromorphic chip array with bi-directional
AER inter-chip links (the system of paper §IV / Fig. 6) — now CLOSED
LOOP through the fabric.

A 4x4 mesh of LIF "chips" (one population per chip) runs with every
border-crossing spike routed as a real 26-bit Address-Event through a
credit-flow-controlled :class:`~repro.core.fabric.Fabric`: each chip's
neighbor projection fans out over its 2–4 mesh neighbors as an
in-fabric multicast tree, and delivered events feed back into next
tick's membrane currents.  Earlier revisions of this example ESTIMATED
bus figures from expected event counts (``snn.link_report``); this one
MEASURES them — ``snn.fabric_report`` rolls the fabric's own per-link
transmission and busy-time telemetry into the same report shape, so
occupancy, energy and latency come from transported events, not a
traffic model.  The run asserts what the estimate could not:

  * exact conservation — per tick, delivered + drops == injected;
  * losslessness — credit flow control delivers 100%, zero drops;
  * the wire economy (27 vs 54 wires/link — the paper's 100-pin saving).

    PYTHONPATH=src python examples/snn_chip_array.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.fabric import QueuePolicy
from repro.core.router import AddressSpec, mesh2d_topology
from repro.cosim import CosimConfig, CosimEngine, Population, Projection, place
from repro.models import snn

ROWS, COLS = 4, 4
NEURONS = 256            # per chip (2 rows of 128 LIF lanes)
TICKS = 24
TICK_DT_NS = 100_000     # 100 us per network tick (10 kHz update)


def build_placement():
    """One population per mesh chip; a local recurrent projection plus a
    neighbor projection that fans out over the chip's 4-neighborhood
    (multicast tags — replicated on Steiner trees inside the fabric)."""
    pops = [Population(f"chip{r}{c}", NEURONS)
            for r in range(ROWS) for c in range(COLS)]
    projs = []
    for r in range(ROWS):
        for c in range(COLS):
            i = r * COLS + c
            nbrs = tuple((rr * COLS + cc) for rr, cc in
                         ((r - 1, c), (r + 1, c), (r, c - 1), (r, c + 1))
                         if 0 <= rr < ROWS and 0 <= cc < COLS)
            projs.append(Projection(pre=i, posts=(i,), w_scale=0.3))
            projs.append(Projection(pre=i, posts=nbrs, w_scale=0.25))
    return place(pops, projs, mesh2d_topology(ROWS, COLS),
                 chips=range(ROWS * COLS), addr=AddressSpec())


def main():
    pl = build_placement()
    n_mcast = sum(1 for r in pl.cross if r.tag >= 0)
    print(f"{ROWS}x{COLS} chip mesh, {NEURONS} LIF neurons/chip, "
          f"{TICKS} ticks")
    print(f"  placement             : {len(pl.projections)} projections -> "
          f"{len(pl.local)} local routes + {len(pl.cross)} cross routes "
          f"({n_mcast} multicast tags)")

    fab = pl.fabric(queues=QueuePolicy(capacity=512, flow="credit"))
    eng = CosimEngine(pl, CosimConfig(input_rate=0.08,
                                      tick_dt_ns=TICK_DT_NS),
                      fabric=fab, key=jax.random.PRNGKey(42))
    res = eng.run(TICKS)

    assert res.conservation_exact, "delivered + drops != injected"
    assert int(res.drops.sum()) == 0, "credit flow control dropped events"
    assert int(res.delivered.sum()) == int(res.injected.sum())
    rate = res.total_spikes / (TICKS * pl.n_pops * NEURONS)
    print(f"  mean firing rate      : {rate:.4f} /neuron/tick")
    print(f"  conservation          : delivered {int(res.delivered.sum())} "
          f"+ drops {int(res.drops.sum())} == injected "
          f"{int(res.injected.sum())}  (exact, every tick)")
    if res.latency_ns.size:
        print(f"  fabric latency        : p50 "
              f"{int(np.percentile(res.latency_ns, 50))} ns, p99 "
              f"{int(np.percentile(res.latency_ns, 99))} ns, max "
              f"{int(res.latency_ns.max())} ns")

    rep = snn.fabric_report(res, TICKS, tick_dt_us=TICK_DT_NS / 1e3)
    print(f"  inter-chip events     : {rep['events_total']:.0f} delivered "
          f"({rep['events_per_s']:.3e} ev/s aggregate, "
          f"{rep['traversals']} link traversals)")
    print(f"  bus occupancy         : mean {rep['bus_busy_frac']:.3%}, "
          f"busiest link {rep['max_link_busy_frac']:.3%} of wall time "
          f"(measured busy-ns telemetry)")
    print(f"  energy (AER transfer) : {rep['energy_uj']:.3f} uJ @ 11 "
          f"pJ/event-hop (per-traversal, multicast billed on tree edges)")
    print(f"  wires per link        : {rep['shared_bus_wires_per_link']} "
          f"shared-bus vs {rep['dual_bus_wires_per_link']} dual-bus "
          f"(paper: 100 pins saved on 4 borders)")
    print("  OK — closed-loop conservation + lossless credit delivery")


if __name__ == "__main__":
    main()
