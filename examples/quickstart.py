"""Quickstart: build a model from the arch registry, train it briefly on
the synthetic stream, then serve a few tokens from it.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""

import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs.base import RunConfig, get_smoke_config
from repro.data import SyntheticLM
from repro.models.model import build_model, param_count
from repro.runtime.train_loop import init_state, make_train_step


def main(arch: str = "granite_3_2b"):
    cfg = get_smoke_config(arch)
    run_cfg = RunConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60)
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab, seq_len=32, global_batch=8, seed=0,
                       modality=cfg.modality, d_frontend=cfg.d_frontend,
                       n_img_tokens=cfg.n_img_tokens)

    state = init_state(model, jax.random.PRNGKey(0), run_cfg)
    print(f"{cfg.name}: {param_count(state.params):,} params "
          f"(reduced config of the {arch} family)")
    step = make_train_step(model, run_cfg)

    for s in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        state, metrics = step(state, batch)
        if s % 10 == 0 or s == 59:
            print(f"  step {s:3d}  loss={float(metrics['loss']):.4f}  "
                  f"grad_norm={float(metrics['grad_norm']):.3f}")

    if cfg.causal:
        prompt = {k: v[:2, :16] if v.ndim >= 2 else v[:2]
                  for k, v in batch.items() if k not in ("labels", "mask")}
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=24))(
                state.params, prompt)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [int(tok[0, 0])]
        for i in range(7):
            pos = jnp.full((2,), 16 + i, jnp.int32)
            logits, cache = jax.jit(model.decode_step)(
                state.params, cache, tok, pos)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            outs.append(int(tok[0, 0]))
        print(f"  greedy continuation: {outs}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "granite_3_2b")
