"""Monte-Carlo fabric sweep: 64 seeds of one scenario, ONE dispatch.

The question a fabric architect actually asks is statistical: "what is
the p99 delivery latency of this topology under hot-spot load?" — one
seed is an anecdote.  This example answers it the batched way:
``traffic.monte_carlo`` samples 64 independently-seeded instances of
the hot-spot scenario in one vmapped draw, and ``Fabric.sweep_batch``
simulates all 64 as ONE compiled, batched computation
(``run_batch``) — the (B,) instance axis rides through the whole
engine, so the sweep compiles exactly once no matter how many seeds
are requested (asserted below via ``batch_cache_size``), and each
instance remains bit-exact with a solo ``fab.run`` of the same spec
(the contract ``tests/test_fabric_batch.py`` and the CI batch gate
enforce).

What the batch buys depends on the backend: on parallel hardware the
instances' element work overlaps (the Monte-Carlo sweep costs about
one instance); on a single-core CPU the win is amortized dispatch and
loop bookkeeping — and, either way, one compilation instead of a
recompile risk per shape wiggle.  See ``benchmarks/fabric_smoke.py``'s
``run_batch_gate`` for the measured per-backend bounds.

    PYTHONPATH=src python examples/monte_carlo_sweep.py
"""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import numpy as np

from benchmarks.fabric_sweep import BATCH_RING
from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import Fabric, batch_cache_size
from repro.core.router import ring_topology

N_SEEDS = 64


def main():
    cfg = BATCH_RING
    topo = ring_topology(cfg["n_chips"])
    fab = Fabric(topo)

    # 64 independently-seeded hot-spot instances, one vmapped draw.
    specs = tr.monte_carlo(cfg["pattern"], jax.random.PRNGKey(cfg["key"]),
                           N_SEEDS, cfg["n_chips"], cfg["epc"])
    print(f"=== {N_SEEDS}-seed Monte-Carlo: {cfg['pattern']} on a "
          f"ring-{cfg['n_chips']}, {cfg['epc']} events/chip ===")

    # All 64 fabrics as ONE batched dispatch (sweep_batch pre-warms the
    # compile so the timing below is pure execution).
    cell = fab.sweep_batch(specs)
    batch = cell.result

    # The sweep compiled the batched engine exactly once.
    n_compiles = batch_cache_size(cell.bucket)
    assert n_compiles == 1, f"expected 1 batched compile, saw {n_compiles}"

    # Conservation holds per seed: nothing is lost silently.
    delivered = np.asarray(batch.delivered)
    drops = np.asarray(batch.drops)
    assert (delivered + drops == batch.injected).all(), "conservation"

    # Per-seed latency stats -> the spread that one seed can't show.
    stats = net.batch_latency_stats(batch)
    p50 = np.array([s["p50_ns"] for s in stats])
    p99 = np.array([s["p99_ns"] for s in stats])
    thr = np.asarray(net.batch_throughput_mev_s(batch))
    print(f"  delivered {int(delivered.sum())}/{int(batch.injected.sum())}"
          f" events across {N_SEEDS} seeds "
          f"(drops: {int(drops.sum())}, charged per seed)")
    print(f"  p50  across seeds: {p50.min():5.0f} .. {p50.max():5.0f} ns "
          f"(median {np.median(p50):.0f})")
    print(f"  p99  across seeds: {p99.min():5.0f} .. {p99.max():5.0f} ns "
          f"(median {np.median(p99):.0f}, worst seed "
          f"#{int(p99.argmax())})")
    print(f"  throughput: {thr.mean():.1f} MEv/s mean, "
          f"{thr.min():.1f} MEv/s worst seed")

    # The number Monte-Carlo costing cares about: us per seed when the
    # whole sweep is one dispatch.
    print(f"  one batched dispatch: {cell.us_per_call / 1e3:.0f} ms total"
          f" = {cell.us_per_instance / 1e3:.1f} ms/seed amortized, "
          f"1 compilation")

    # The tail is a distribution property, not a fluke of one seed: the
    # spread across seeds is real signal for capacity planning.
    spread = p99.max() / max(p99.min(), 1.0)
    print(f"  -> p99 varies {spread:.1f}x across seeds of the SAME "
          f"scenario: sizing from one seed under-provisions the tail")


if __name__ == "__main__":
    main()
