"""Adaptive routing on a hot-spot ring: telemetry finds the saturated
link, epochs spread the load.

A 16-chip ring where every chip fires mostly at chip 0 (the convergecast
/ hot-spot regime of ``traffic.hot_spot``).  Static BFS routing sends
each source down its shorter arc, so the two links next to the hot chip
saturate — the per-link telemetry shows link 0 at ~100% bus occupancy
while the antipodal link idles — and with bounded queues the hot arcs
drop events while parallel capacity sits unused.

The congestion control plane (``core/adaptive.py``) fixes what routing
*can* fix: it splits the run into epochs, reads each epoch's per-link
``LinkLoad`` (occupancy / backlog / drops — ``core/telemetry.py``), and
re-weights the next epoch's shortest-path tables with the congestion
signal.  Marginal sources shift to the lighter arc, the two hot queues
even out, and both drops and p99 latency strictly improve vs static
routing of the identical workload under the identical epoch partition
(the CI-gated claim of ``benchmarks/fabric_smoke.run_adaptive_gate``).

    PYTHONPATH=src python examples/adaptive_hotspot.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.adaptive import AdaptiveRouting
from repro.core.fabric import Fabric, QueuePolicy
from repro.core.telemetry import link_load
from repro.core.router import ring_topology

N_CHIPS = 16
EVENTS_PER_CHIP = 48
MEAN_GAP_NS = 100.0      # saturating arrival rate at the hot links
CAPACITY = 48            # per-endpoint budget: the hot arcs will drop
EPOCHS = 4
POLICY = AdaptiveRouting(policy="min_backlog", epochs=EPOCHS, alpha=4.0,
                         ema=0.5)


def stats_line(tag, res):
    st = net.latency_stats(res)
    return (f"  {tag:<9} delivered={st['delivered']}/{st['injected']} "
            f"drops={int(res.drops)} p50={st['p50_ns']:5.0f}ns "
            f"p99={st['p99_ns']:6.0f}ns max={st['max_ns']:6d}ns")


def main():
    topo = ring_topology(N_CHIPS)
    spec = tr.hot_spot(jax.random.PRNGKey(3), N_CHIPS, EVENTS_PER_CHIP,
                       mean_gap_ns=MEAN_GAP_NS)

    # --- 1. diagnose: telemetry of one lossless static run --------------
    print(f"=== static routing, lossless queues: per-link telemetry "
          f"(ring{N_CHIPS}, hot chip 0) ===")
    diag = Fabric(topo).run(spec)
    ll = link_load(diag)
    print(ll.table(topo.links))
    occ = np.asarray(ll.occupancy)
    print(f"  -> link 0 carries {100 * occ.max():.0f}% bus occupancy; "
          f"the antipodal link sits at {100 * occ.min():.0f}% — the "
          f"shared-arc bottleneck, not link bandwidth, is the limit")
    assert occ.max() > 0.9, "expected a saturated hot link"
    assert occ.min() < 0.3, "expected idle capacity on the far arc"

    # --- 2. act: epoch-adaptive routing vs static, bounded queues -------
    queues = QueuePolicy(capacity=CAPACITY)
    static = Fabric(topo, queues=queues)
    res_s = static.run_epochs(spec, epochs=EPOCHS)
    adaptive = Fabric(topo, routing=POLICY, queues=queues)
    res_a = adaptive.run(spec)

    print(f"\n=== bounded queues (capacity {CAPACITY}/endpoint), "
          f"{EPOCHS} epochs: static vs adaptive ===")
    print(stats_line("static", res_s))
    print(stats_line("adaptive", res_a))

    report = adaptive.last_report
    print(f"\n=== epoch by epoch: telemetry-reweighted tables vs the "
          f"same epochs on static tables ===")
    print(f"  {'epoch':<7}{'s.drops':>8}{'a.drops':>8}{'s.p99':>8}"
          f"{'a.p99':>8}  note")
    for e, (rs, ra) in enumerate(zip(static.last_report.records,
                                     report.records)):
        note = ("identical tables (epoch 0 IS static)" if e == 0 else
                "tables re-weighted by epoch %d telemetry" % (e - 1))
        print(f"  {e:<7}{int(rs.load.drops.sum()):>8}"
              f"{int(ra.load.drops.sum()):>8}"
              f"{net.latency_stats(rs.result)['p99_ns']:>8.0f}"
              f"{net.latency_stats(ra.result)['p99_ns']:>8.0f}  {note}")

    # --- CI-gated claims -------------------------------------------------
    # identical workload + epoch partition: only the tables differ, and
    # adaptive must strictly win on both drops and tail latency
    assert int(res_a.delivered) + int(res_a.drops) == res_a.injected
    assert int(res_a.drops) < int(res_s.drops)
    p99_s = net.latency_stats(res_s)["p99_ns"]
    p99_a = net.latency_stats(res_a)["p99_ns"]
    assert p99_a < p99_s
    # one engine compilation served every epoch (tables are dynamic)
    assert not report.recompiled
    print(f"\nadaptive saved {int(res_s.drops) - int(res_a.drops)} drops "
          f"and {p99_s - p99_a:.0f} ns of p99 with "
          f"{len(report.buckets)} engine compilation(s) for "
          f"{report.n_epochs} epochs")
    print("OK")


if __name__ == "__main__":
    main()
