"""End-to-end driver: train a ~100M-parameter granite-family LM for a few
hundred steps on the synthetic pipeline, with checkpointing, a mid-run
injected failure + restart, and straggler monitoring — the full production
loop at laptop scale.

    PYTHONPATH=src python examples/train_lm_100m.py               # full
    PYTHONPATH=src python examples/train_lm_100m.py --tiny        # CI-sized
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig, RunConfig
from repro.data import SyntheticLM
from repro.models.model import build_model, param_count
from repro.runtime.fault import (FailureInjector, StragglerMonitor,
                                 run_with_restarts)
from repro.runtime.train_loop import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="2M-param config for quick verification")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.tiny:
        cfg = ModelConfig(name="lm-tiny", n_layers=4, d_model=128,
                          n_heads=4, n_kv_heads=2, d_ff=512, vocab=2048)
        steps, batch, seq = args.steps or 120, 8, 64
    else:
        # ~100M params: 12L x d768 (GQA 12/4) + 32k vocab
        cfg = ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=4, d_ff=3072, vocab=32768)
        steps, batch, seq = args.steps or 300, 16, 256

    run_cfg = RunConfig(learning_rate=3e-3, warmup_steps=steps // 10,
                        total_steps=steps, grad_clip=1.0)
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab, seq, batch, seed=1)

    state = init_state(model, jax.random.PRNGKey(0), run_cfg)
    print(f"{cfg.name}: {param_count(state.params):,} params — "
          f"{steps} steps x {batch}x{seq} tokens")
    step_fn = make_train_step(model, run_cfg)

    class JaxData:
        def batch(self, s):
            return {k: jnp.asarray(v) for k, v in data.batch(s).items()}

    with tempfile.TemporaryDirectory() as d:
        ckpt = Checkpointer(d, keep=2)
        injector = FailureInjector(frozenset({steps // 2}))  # mid-run crash
        monitor = StragglerMonitor()
        state, info = run_with_restarts(
            n_steps=steps, state=state, train_step=step_fn, data=JaxData(),
            ckpt=ckpt, checkpoint_every=max(steps // 6, 1),
            injector=injector, monitor=monitor,
            log_every=max(steps // 12, 1))
        print(f"finished at step {steps}: restarts={info['restarts']} "
              f"(injected 1), stragglers flagged="
              f"{len(info['straggler_events'])}")


if __name__ == "__main__":
    main()
