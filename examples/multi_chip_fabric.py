"""Multi-chip AER fabric demo: the paper's link, scaled to a system.

Builds an 8-chip ring and a 4x4 mesh out of bi-directional transceiver
links, pushes Poisson background traffic plus a multicast population
broadcast (Su et al.-style tag expansion) through them, and prints what a
system architect would ask of the fabric:

  * delivery and per-event end-to-end latency percentiles,
  * aggregate fabric throughput vs. the single-link Table II ceiling
    (the multi-chip scaling argument of the paper's introduction),
  * per-link utilisation and direction-switch counts,
  * the energy roll-up at 11 pJ per hop.

    PYTHONPATH=src python examples/multi_chip_fabric.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import Fabric
from repro.core.link import PAPER_TIMING
from repro.core.router import (AddressSpec, MulticastTable, mesh2d_topology,
                               ring_topology)

EVENTS_PER_CHIP = 64


def report(tag, topo, res):
    st = net.latency_stats(res)
    thr = float(net.fabric_throughput_mev_s(res))
    per_link = np.asarray(net.per_link_throughput_mev_s(res))
    sw = np.asarray(res.n_switches)
    print(f"\n=== {tag} ({topo.name}: {topo.n_chips} chips, "
          f"{topo.n_links} links) ===")
    print(f"  delivered        : {st['delivered']}/{st['injected']} "
          f"(drops={int(res.drops)})")
    print(f"  latency          : p50={st['p50_ns']:.0f}ns "
          f"p90={st['p90_ns']:.0f}ns p99={st['p99_ns']:.0f}ns "
          f"max={st['max_ns']}ns")
    print(f"  fabric throughput: {thr:.1f} MEv/s "
          f"(single link ceiling {PAPER_TIMING.onedir_throughput_mev_s():.1f})")
    print(f"  busiest link     : {per_link.max():.1f} MEv/s, "
          f"{int(sw.max())} direction switches")
    print(f"  energy           : "
          f"{float(net.fabric_energy_pj(res, PAPER_TIMING)) / 1e3:.2f} nJ "
          f"({PAPER_TIMING.e_event_pj} pJ/hop)")


def main():
    key = jax.random.PRNGKey(0)

    # --- 8-chip ring, Poisson background --------------------------------
    # Declarative Fabric + explicit compile/run: the ring fabric is
    # reused (and its engine compilation amortised) across workloads.
    ring = ring_topology(8)
    ring_fab = Fabric(ring)
    spec = tr.poisson(key, ring.n_chips, EVENTS_PER_CHIP, mean_gap_ns=300.0)
    cf = ring_fab.compile(spec)         # pre-warm the shape bucket
    res = cf.run(spec)
    report("Poisson background", ring, res)

    # --- multicast population broadcast over the same ring ---------------
    addr = AddressSpec()  # [mcast | 8-bit chip | 17-bit neuron tag]
    groups = np.zeros((2, 8), bool)
    groups[0, :4] = True          # tag 0: chips 0-3 (a population)
    groups[1, ::2] = True         # tag 1: the even chips
    mcast = MulticastTable(groups)
    n_bc = 24
    bcast = tr.TrafficSpec(
        src=jnp.zeros(n_bc, jnp.int32),
        t=jnp.arange(n_bc, dtype=jnp.int32) * 500,
        dest=jnp.asarray(addr.pack_multicast(
            np.arange(n_bc, dtype=np.int32) % 2,
            core=np.arange(n_bc, dtype=np.int32))))
    mc_fab = Fabric(ring, addr=addr, mcast=mcast)
    res = mc_fab.run(bcast)             # same bucket: zero new compiles
    report("Multicast broadcast (tag expansion)", ring, res)

    # --- 4x4 mesh, hot-spot convergecast ---------------------------------
    # (one-shot workloads keep the simulate_fabric convenience wrapper)
    mesh = mesh2d_topology(4, 4)
    spec = tr.hot_spot(key, mesh.n_chips, EVENTS_PER_CHIP // 2,
                       hot_chip=5, hot_frac=0.6)
    res = net.simulate_fabric(mesh, spec)
    report("Hot-spot convergecast", mesh, res)

    print("\nThe N=2 degenerate fabric reproduces the measured two-block "
          "link bit-exactly\n(tests/test_fabric.py::TestTwoChipEquivalence); "
          "everything above is that same\nFSM pair, vmapped across links.")


if __name__ == "__main__":
    main()
