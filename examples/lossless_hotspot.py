"""Lossless fabric on a hot-spot ring: credit backpressure instead of
drops.

A 16-chip ring where most traffic converges on chip 0 and every endpoint
queue has a bounded budget.  Under the default ``flow="drop"`` policy an
overflowing queue discards the arriving event — the transmitter has
already burned bus time carrying it to a full queue, and under the
``max_burst=0`` grant rule those doomed transmissions also starve the
reverse-direction traffic that WOULD have been delivered.  Credit-based
flow control (``flow="credit"``) instead stalls the upstream pop in
place until the downstream queue returns a credit: head-of-line blocking
propagates backpressure toward the sources, the bus carries only events
with somewhere to go, and the fabric delivers 100% of the offered load.

Two operating points (both deterministic, both CI-gated by
``benchmarks/fabric_smoke.run_lossless_gate``):

1. Mild overload (``fabric_sweep.LOSSLESS_RING``): drop mode loses
   hundreds of events AND has the worse delivered-events p99 — a strict
   loss for lossy transport even on its own survivorship-biased metric.
2. Saturating flood (``fabric_sweep.LOSSLESS_RING_HOT``): the per-link
   stall telemetry shows WHERE backpressure engaged, and the fabric
   still delivers everything while drop mode loses most of the load.

    PYTHONPATH=src python examples/lossless_hotspot.py
"""

import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import numpy as np

from benchmarks.fabric_sweep import (LOSSLESS_RING, LOSSLESS_RING_HOT,
                                     _lossless_spec)
from repro.core import network as net
from repro.core.fabric import Fabric, QueuePolicy
from repro.core.router import ring_topology
from repro.core.telemetry import link_load


def stats_line(tag, res):
    st = net.latency_stats(res)
    stalls = int(np.asarray(res.telemetry.stall_steps).sum())
    return (f"  {tag:<7} delivered={st['delivered']:4d}/{st['injected']} "
            f"drops={int(res.drops):3d} p50={st['p50_ns']:5.0f}ns "
            f"p99={st['p99_ns']:6.0f}ns stalls={stalls}")


def run_modes(topo, cfg):
    spec = _lossless_spec(cfg)
    out = {}
    for flow in ("drop", "credit", "onoff"):
        fab = Fabric(topo, queues=QueuePolicy(capacity=cfg["capacity"],
                                              flow=flow), engine="ring")
        out[flow] = fab.run(spec)
        # conservation holds in every mode: nothing is lost silently
        assert (int(out[flow].delivered) + int(out[flow].drops)
                == out[flow].injected)
    return out


def main():
    topo = ring_topology(LOSSLESS_RING["n_chips"])

    # --- 1. mild overload: lossless AND faster tails --------------------
    print(f"=== mild overload (capacity "
          f"{LOSSLESS_RING['capacity']}/endpoint): drop vs credit vs "
          f"onoff ===")
    mild = run_modes(topo, LOSSLESS_RING)
    for flow, res in mild.items():
        print(stats_line(flow, res))
    p99_d = net.latency_stats(mild["drop"])["p99_ns"]
    p99_c = net.latency_stats(mild["credit"])["p99_ns"]
    print(f"  -> drop mode lost {int(mild['drop'].drops)} events and "
          f"still has the worse p99 ({p99_d:.0f} vs {p99_c:.0f} ns): "
          f"transmitting doomed events starves deliverable ones")

    # --- 2. saturating flood: where did backpressure engage? ------------
    print(f"\n=== saturating flood (capacity "
          f"{LOSSLESS_RING_HOT['capacity']}/endpoint): per-link stall "
          f"telemetry, credit mode ===")
    hot = run_modes(topo, LOSSLESS_RING_HOT)
    ll = link_load(hot["credit"])
    print(ll.table(topo.links))
    stalls = np.asarray(ll.stalls)
    hot_links = np.flatnonzero(stalls > 0)
    print(f"  -> {len(hot_links)} of {topo.n_links} links stalled "
          f"(links {hot_links.tolist()}): backpressure concentrated on "
          f"the hot arcs, the far arc never blocked")
    for flow, res in hot.items():
        print(stats_line(flow, res))

    # --- CI-gated claims -------------------------------------------------
    # mild point: credit is lossless and strictly beats drop on p99
    assert int(mild["credit"].drops) == 0
    assert int(mild["drop"].drops) > 0
    assert p99_c < p99_d
    # onoff with the default threshold is lossless too
    assert int(mild["onoff"].drops) == 0
    # hot point: backpressure engaged, still zero drops
    assert int(hot["credit"].drops) == 0
    assert int(np.asarray(hot["credit"].telemetry.stall_steps).sum()) > 0
    assert int(hot["drop"].drops) > 0
    print(f"\ncredit flow control recovered "
          f"{int(mild['drop'].drops)} + {int(hot['drop'].drops)} dropped "
          f"events across both operating points with zero loss")
    print("OK")


if __name__ == "__main__":
    main()
