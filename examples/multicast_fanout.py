"""In-fabric multicast replication vs. source expansion, side by side.

The paper's 26-bit AE word reserves a multicast flag; DYNAPs-style
boards resolve it by replicating events *inside* the fabric at routing
branch points instead of injecting one unicast copy per member at the
source.  This demo drives the same fanout-8 tagged workload over a
16-chip ring both ways with the declarative ``Fabric`` API:

    Fabric(topo, addr=addr, mcast=MulticastPolicy("source_expand", mc))
    Fabric(topo, addr=addr, mcast=MulticastPolicy("in_fabric", mc))

Five members sit clockwise behind the shared 0-1-2-3 path and three
counter-clockwise behind 0-15-14-13, so source expansion pays for every
copy on every shared link while the replication tree pays once per
edge.  Both modes deliver the identical destination multiset — the
difference is pure transport cost: link traversals, occupancy of the
first-hop buses, energy, and the latency tail behind the duplicated
copies.

    PYTHONPATH=src python examples/multicast_fanout.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np
import jax.numpy as jnp

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import Fabric, MulticastPolicy
from repro.core.link import PAPER_TIMING
from repro.core.router import (AddressSpec, MulticastTable, MulticastTree,
                               RoutingTable, ring_topology)

N_CHIPS = 16
MEMBERS = np.arange(4, 12)      # fanout 8 from chip 0
N_EVENTS = 48


def stats_line(tag, res):
    st = net.latency_stats(res)
    e_nj = float(net.fabric_energy_pj(res, PAPER_TIMING)) * 1e-3
    return (f"  {tag:<14} delivered={st['delivered']}/{st['injected']} "
            f"fanout={st['fanout']:.0f} traversals={st['traversals']:4d} "
            f"p50={st['p50_ns']:5.0f}ns p99={st['p99_ns']:5.0f}ns "
            f"E={e_nj:.1f}nJ")


def main():
    topo = ring_topology(N_CHIPS)
    addr = AddressSpec()
    members = np.zeros((1, N_CHIPS), bool)
    members[0, MEMBERS] = True
    mc = MulticastTable(members)
    spec = tr.TrafficSpec(
        src=jnp.zeros(N_EVENTS, jnp.int32),
        t=jnp.arange(N_EVENTS, dtype=jnp.int32) * 300,
        dest=jnp.asarray(addr.pack_multicast(np.zeros(N_EVENTS, np.int64))))

    print(f"ring{N_CHIPS}, tag 0 = chips {MEMBERS.min()}..{MEMBERS.max()} "
          f"(fanout {len(MEMBERS)}), {N_EVENTS} tagged events from chip 0")

    # the replication tree the in_fabric mode routes along
    rt = RoutingTable.build(topo)
    tree = MulticastTree.build(topo, rt, 0, MEMBERS)
    hops = int(rt.hops[0, MEMBERS].sum())
    print(f"\nSteiner-branching tree: {tree.n_edges} edges vs "
          f"{hops} per-copy hops -> {hops - tree.n_edges} link traversals "
          f"saved PER EVENT")

    results = {}
    for mode in ("source_expand", "in_fabric"):
        fab = Fabric(topo, addr=addr, mcast=MulticastPolicy(mode, mc))
        results[mode] = fab.run(spec)

    print("\n=== fabric totals ===")
    for mode, res in results.items():
        print(stats_line(mode, res))

    se, infab = results["source_expand"], results["in_fabric"]

    # --- per-link traversal counts: where the savings live --------------
    sent_se = np.asarray(se.sent).sum(axis=1)
    sent_if = np.asarray(infab.sent).sum(axis=1)
    print("\n=== per-link traversals (source_expand vs in_fabric) ===")
    print(f"  {'link':<8}{'source':>8}{'infabric':>10}  saved")
    for l, (a, b) in enumerate(topo.links):
        if sent_se[l] or sent_if[l]:
            print(f"  {l}:{a}-{b:<4}{sent_se[l]:>8}{sent_if[l]:>10}"
                  f"  {sent_se[l] - sent_if[l]:+d}")

    # --- the contract ----------------------------------------------------
    assert int(se.delivered) == se.injected == N_EVENTS * len(MEMBERS)
    assert int(infab.delivered) == infab.injected == se.injected
    assert net.delivery_multiset(se) == net.delivery_multiset(infab)
    assert infab.traversals == N_EVENTS * tree.n_edges
    assert infab.traversals < se.traversals
    saved = 100.0 * (1.0 - infab.traversals / se.traversals)
    print(f"\nidentical delivery multiset; {saved:.0f}% of link "
          f"traversals (and link energy) saved in-fabric")
    print("OK")


if __name__ == "__main__":
    main()
