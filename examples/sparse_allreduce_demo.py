"""Paper technique at the training level: data-parallel training where the
gradient sync is the AER event-sparse all-reduce (top-k + error feedback)
or the bidirectional ring, compared against dense psum.

Runs 8-way manual DP on forced host devices (re-execs itself with
XLA_FLAGS) and reports loss parity + wire bytes per step.

    PYTHONPATH=src python examples/sparse_allreduce_demo.py
"""

import os
import subprocess
import sys

if "XLA_FLAGS" not in os.environ:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    sys.exit(subprocess.call([sys.executable, __file__], env=env))

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig, get_smoke_config
from repro.core import sparse_collectives as sc
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.parallel.sharding import make_rules
from repro.runtime.train_loop import init_state, make_train_step

STEPS = 40


def train(dp_reduce: str):
    cfg = get_smoke_config("granite_3_2b")
    run_cfg = RunConfig(learning_rate=3e-3, warmup_steps=4,
                        total_steps=STEPS, dp_reduce=dp_reduce,
                        aer_frac=0.05, aer_budget=128, fsdp=False)
    model = build_model(cfg)
    mesh = make_host_mesh(data=8, model=1)
    rules = make_rules(mesh, fsdp=False, kv_heads=cfg.n_kv_heads,
                       d_head=cfg.d_head)
    data = SyntheticLM(cfg.vocab, 32, 16, seed=7)
    state = init_state(model, jax.random.PRNGKey(0), run_cfg)
    step = make_train_step(model, run_cfg, rules)
    losses, words = [], 0.0
    for s in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        words += float(m["wire_words"])
    return losses, words


def main():
    n_params = None
    results = {}
    for mode in ("psum", "bidir_ring", "aer_topk"):
        losses, words = train(mode)
        results[mode] = (losses, words)
        print(f"{mode:11s} loss[0]={losses[0]:.4f} "
              f"loss[-1]={losses[-1]:.4f} wire_words/step="
              f"{words/STEPS:,.0f}")
    l_psum = results["psum"][0][-1]
    l_ring = results["bidir_ring"][0][-1]
    l_aer = results["aer_topk"][0][-1]
    print(f"\nbidir_ring vs psum final-loss delta: {abs(l_ring-l_psum):.5f} "
          f"(exact schedule, must be ~float noise)")
    print(f"aer_topk  vs psum final-loss delta: {abs(l_aer-l_psum):.5f} "
          f"(5% events/step + error feedback)")
    # wire economy: dense allreduce ships full grads; AER ships event slots
    cfg = get_smoke_config("granite_3_2b")
    model = build_model(cfg)
    p, _ = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(p))
    dense_b = sc.dense_allreduce_bytes(n, 8)
    aer_words = results["aer_topk"][1] / STEPS
    print(f"dense wire ≈ {dense_b:.3e} B/step/dir vs AER "
          f"{aer_words*4:.3e} B/step ({dense_b/(aer_words*4):.1f}x less)")


if __name__ == "__main__":
    main()
