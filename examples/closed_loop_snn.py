"""Closed-loop SNN <-> fabric co-simulation on a 16-chip AER ring.

A recurrent LIF network (forward + backward ring projections plus local
recurrence, one population per chip) runs with its inter-chip spikes
transported by a real credit-flow-controlled
:class:`~repro.core.fabric.Fabric`, and the delivered events fed back
into future membrane updates.  The run demonstrates the full contract
stack of the ``repro.cosim`` layer, in order:

  1. **transport adds nothing** — the open-loop run (``feedback="none"``)
     is bit-exact with a standalone LIF rollout of the same dynamics;
  2. **lossless closed loop** — under credit flow control every tick
     satisfies delivered + drops == injected with ZERO drops;
  3. **the loop is real** — closed-loop spike counts DIVERGE from the
     open-loop control: fabric feedback changes the dynamics;
  4. **congestion couples back** — on slow serial links with
     ``feedback="measured"``, delivery latency crosses tick boundaries
     and the delayed current measurably changes spiking vs the
     idealized ``next_tick`` mode on the same fabric.

    PYTHONPATH=src python examples/closed_loop_snn.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.fabric import QueuePolicy
from repro.core.link import SERIAL_LVDS_TIMING
from repro.core.router import AddressSpec, ring_topology
from repro.cosim import (CosimConfig, CosimEngine, Population, Projection,
                         place, reference_rollout)

N_CHIPS = 16
NEURONS = 128
TICKS = 32
KEY = jax.random.PRNGKey(7)


def build_placement():
    """Recurrent ring: chip i drives chips i+1 and i-1 (unicast cross
    routes) and itself (local, never touches the fabric)."""
    pops = [Population(f"pop{i}", NEURONS) for i in range(N_CHIPS)]
    projs = []
    for i in range(N_CHIPS):
        projs.append(Projection(pre=i, posts=((i + 1) % N_CHIPS,),
                                w_scale=0.4))
        projs.append(Projection(pre=i, posts=((i - 1) % N_CHIPS,),
                                w_scale=0.4))
        projs.append(Projection(pre=i, posts=(i,), w_scale=0.3))
    return place(pops, projs, ring_topology(N_CHIPS), addr=AddressSpec())


def main():
    pl = build_placement()
    print(f"recurrent ring, {N_CHIPS} chips x {NEURONS} LIF neurons, "
          f"{TICKS} ticks")

    # 1. open-loop == standalone rollout, bit for bit
    eng_open = CosimEngine(pl, CosimConfig(feedback="none"), key=KEY)
    ref = reference_rollout(eng_open, TICKS, record_state=True)
    opn = eng_open.run(TICKS, record_state=True)
    assert np.array_equal(ref.v, opn.v)
    assert np.array_equal(ref.raster, opn.raster)
    print(f"  open loop == reference : bit-exact over {TICKS} ticks "
          f"({opn.total_spikes} spikes)")

    # 2. + 3. closed loop over a lossless credit fabric
    fab = pl.fabric(queues=QueuePolicy(capacity=256, flow="credit"))
    eng = CosimEngine(pl, CosimConfig(feedback="next_tick"),
                      fabric=fab, key=KEY)
    res = eng.run(TICKS)
    assert res.conservation_exact
    assert int(res.drops.sum()) == 0
    assert int(res.delivered.sum()) == int(res.injected.sum())
    print("  tick   spikes  offered  injected  delivered  drops")
    show = list(range(4)) + [TICKS - 1]
    for t in show:
        print(f"  {t:4d} {int(res.spikes[t].sum()):8d} "
              f"{int(res.offered[t]):8d} {int(res.injected[t]):9d} "
              f"{int(res.delivered[t]):10d} {int(res.drops[t]):6d}")
    print(f"  total conservation     : delivered {int(res.delivered.sum())}"
          f" + drops 0 == injected {int(res.injected.sum())} "
          f"(exact, every tick; credit flow => lossless)")
    diverge = int(np.abs(res.spikes - opn.spikes).sum())
    assert diverge > 0, "fabric feedback left the dynamics unchanged"
    print(f"  closed vs open loop    : spike trajectories diverge by "
          f"{diverge} (the feedback loop is real)")

    # 4. measured feedback on slow serial links: congestion-delayed
    # current vs the idealized next-tick delivery, same fabric + key
    cfg_m = CosimConfig(feedback="measured", tick_dt_ns=600)
    cfg_i = cfg_m._replace(feedback="next_tick")
    qp = QueuePolicy(capacity=256, flow="credit")

    def run_slow(cfg):
        f = pl.fabric(timing=SERIAL_LVDS_TIMING, queues=qp)
        return CosimEngine(pl, cfg, fabric=f, key=KEY).run(TICKS)

    res_m, res_i = run_slow(cfg_m), run_slow(cfg_i)
    assert res_m.conservation_exact and res_i.conservation_exact
    lag = int(res_m.latency_ns.max()) / cfg_m.tick_dt_ns
    delayed = int((res_m.latency_ns >= cfg_m.tick_dt_ns).sum())
    gap = int(np.abs(res_m.spikes - res_i.spikes).sum())
    assert delayed > 0, "serial links never crossed a tick boundary"
    assert gap > 0, "delivery timing did not affect the dynamics"
    print(f"  measured feedback      : serial links stretch delivery to "
          f"{lag:.1f} ticks worst-case; {delayed} events land >=1 tick "
          f"late")
    print(f"  measured vs next_tick  : spike trajectories diverge by "
          f"{gap} — fabric congestion perturbs the network dynamics")
    print("  OK — closed-loop contracts all hold")


if __name__ == "__main__":
    main()
