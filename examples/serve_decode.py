"""Batched serving example: prefill + KV/SSM-cache decode across
architecture families (dense GQA, SWA ring-cache MoE, pure SSM).

    PYTHONPATH=src python examples/serve_decode.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve


def main():
    for arch in ("granite_3_2b", "mixtral_8x22b", "falcon_mamba_7b"):
        print(f"=== {arch} ===")
        serve.main(["--arch", arch, "--smoke", "--batch", "4",
                    "--prompt-len", "32", "--gen", "12"])


if __name__ == "__main__":
    main()
