"""Multi-device tests (8 forced host devices, subprocess): ring schedules
equal psum; AER sparse all-reduce converges with error feedback and ships
the promised wire volume."""

import pytest

from tests._subproc import run_with_devices

RING_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import halfduplex as hd
from repro.parallel.compat import AXIS_TYPE_AUTO, make_mesh, shard_map

mesh = make_mesh((8,), ("data",), axis_types=(AXIS_TYPE_AUTO,))
rng = np.random.default_rng(0)
for shape in [(8, 64), (8, 37), (8, 1), (8, 1024)]:
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)

    def run(fn):
        f = shard_map(fn, mesh=mesh, in_specs=P("data", None),
                      out_specs=P("data", None))
        return np.array(f(x))

    want = run(lambda t: jax.lax.psum(t, "data"))
    uni = run(lambda t: hd.ring_allreduce(t[0], "data")[None])
    bi = run(lambda t: hd.ring_allreduce(t[0], "data",
                                         bidirectional=True)[None])
    assert np.allclose(uni, want, rtol=1e-5, atol=1e-5), shape
    assert np.allclose(bi, want, rtol=1e-5, atol=1e-5), shape

# reduce-scatter places chunk i on device i
x = jnp.tile(jnp.arange(8.0)[None], (8, 1))  # every device holds [0..7]
@partial(shard_map, mesh=mesh, in_specs=P("data", None),
         out_specs=P("data"))
def rs(t):
    return hd.ring_reduce_scatter(t[0], "data")
out = np.array(rs(x))  # (8,) — device i's chunk = 8 * i
assert np.allclose(out, 8.0 * np.arange(8)), out
print("RING-OK")
"""

AER_CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core import sparse_collectives as sc
from repro.parallel.compat import AXIS_TYPE_AUTO, make_mesh, shard_map

mesh = make_mesh((8,), ("data",), axis_types=(AXIS_TYPE_AUTO,))
rng = np.random.default_rng(1)
g = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)
target = np.array(g).mean(axis=0)

@partial(shard_map, check_vma=False, mesh=mesh,
         in_specs=(P("data", None), P("data", None)),
         out_specs=(P("data", None), P("data", None), P("data")))
def step(gl, res):
    red, st, words = sc.aer_allreduce(
        gl[0], sc.AerState(res[0]), "data", frac=0.25, budget=1024,
        interpret=True)
    return red[None], st.residual[None], words[None]

res = jnp.zeros_like(g)
# 1) every member gets the IDENTICAL reduced tensor
red, res1, words = step(g, res)
red = np.array(red)
assert np.allclose(red, red[0:1], atol=0), "members disagree"
# 2) reduced + mean(residual) == true mean  (conservation)
recon = red[0] + np.array(res1).mean(axis=0)
assert np.allclose(recon, target, atol=1e-5), np.abs(recon-target).max()
# 3) error feedback: the TIME-AVERAGE of applied updates converges to the
# true mean at rate |r_T|/T (sum_t dec_t = T*g + r_0 - r_T)
T = 30
acc = np.zeros_like(target); cur_res = res
for t in range(T):
    red_t, cur_res, w = step(g, cur_res)
    acc += np.array(red_t[0])
err0 = np.abs(np.array(step(g, jnp.zeros_like(g))[0][0]) - target).max()
errT = np.abs(acc / T - target).max()
assert errT < err0 * 0.25, (err0, errT)
# 4) wire volume: <= budget words per block per device
nb = 4096 // 1024
assert int(np.array(words)[0]) <= nb * 1024
print("AER-OK", err0, errT)
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_ring_schedules_equal_psum():
    out = run_with_devices(RING_CODE, 8)
    assert "RING-OK" in out


@pytest.mark.slow
@pytest.mark.multidevice
def test_aer_allreduce_conservation_and_convergence():
    out = run_with_devices(AER_CODE, 8)
    assert "AER-OK" in out
