"""Integration tests: the discrete-event simulator reproduces the paper's
measured figures (§IV, Table II) and conserves events."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol_sim as ps
from repro.core.link import PAPER_TIMING


class TestPaperFigures:
    def test_onedir_throughput_fig7(self):
        """Fig. 7: continuous one-direction stream -> 32.3 MEvents/s."""
        res = ps.saturated_onedir(2048)
        assert int(res.sent_l) == 2048
        thr = float(ps.throughput_mev_s(res))
        assert abs(thr - PAPER_TIMING.onedir_throughput_mev_s()) < 0.05
        assert abs(thr - 32.3) < 0.1  # the paper's quoted number

    def test_bidir_throughput_fig8(self):
        """Fig. 8: alternating-direction load -> 28.6 MEvents/s worst case."""
        res = ps.alternating_bidir(1024)
        assert int(res.sent_l) == 1024 and int(res.sent_r) == 1024
        thr = float(ps.throughput_mev_s(res))
        assert abs(thr - PAPER_TIMING.bidir_throughput_mev_s()) < 0.05
        assert abs(thr - 28.6) < 0.1

    def test_switch_latency_constants(self):
        """Table II: 5 ns switch; Fig. 7: ~5 ns switch-to-request."""
        assert PAPER_TIMING.t_sw_ns == 5
        assert PAPER_TIMING.t_idle_switch_ns == 10
        # an idle-bus direction flip delays the first event by exactly 10 ns
        res = ps.saturated_onedir(16)
        expected = 10 + 31 * 16
        assert int(res.t_end) == expected

    def test_energy_per_event(self):
        res = ps.alternating_bidir(64)
        e = float(ps.energy_pj(res))
        assert e == pytest.approx(11.0 * 128)

    def test_io_pin_savings(self):
        # paper: 100 I/Os saved on the 4 borders of a 180-I/O prototype
        assert PAPER_TIMING.io_pins_saved(n_links=4) == 100


class TestConservationAndOrder:
    def test_event_conservation_sparse_load(self):
        rng = np.random.default_rng(7)
        al = np.sort(rng.integers(0, 100_000, 200)).astype(np.int32)
        ar = np.sort(rng.integers(0, 100_000, 150)).astype(np.int32)
        res = ps.simulate(jnp.array(al), jnp.array(ar), initial_tx=1)
        assert int(res.sent_l) == 200
        assert int(res.sent_r) == 150

    def test_saturated_both_sides_paper_faithful_completes(self):
        """Paper-faithful grant rule (drain-first): both directions finish;
        the loser waits for full drain (head-of-line), but no deadlock."""
        res = ps.simulate(jnp.zeros(128, jnp.int32), jnp.zeros(128, jnp.int32),
                          initial_tx=1, max_burst=0)
        assert int(res.sent_l) == 128 and int(res.sent_r) == 128
        # drain-first ⇒ exactly one direction reversal
        assert int(res.n_switches) <= 2

    def test_bounded_burst_fairness(self):
        """max_burst=B bounds the reverse-traffic head-of-line blocking."""
        res = ps.simulate(jnp.zeros(128, jnp.int32), jnp.zeros(128, jnp.int32),
                          initial_tx=1, max_burst=8)
        assert int(res.sent_l) == 128 and int(res.sent_r) == 128
        assert int(res.n_switches) >= 128 // 8  # alternates every ≤8 events

    def test_no_bus_contention_ever(self):
        """Safety: the two blocks are never both in TX mode."""
        for mb in (0, 1, 4):
            res = ps.simulate(jnp.zeros(64, jnp.int32),
                              jnp.arange(64, dtype=jnp.int32) * 17,
                              initial_tx=1, max_burst=mb)
            both_tx = np.logical_and(np.array(res.trace.mode_l) == 1,
                                     np.array(res.trace.mode_r) == 1)
            assert not both_tx.any()

    def test_throughput_converges_regardless_of_burst(self):
        """Same-direction cycles dominate for large bursts: throughput
        approaches the one-direction rate as max_burst grows."""
        r1 = ps.simulate(jnp.zeros(512, jnp.int32), jnp.zeros(512, jnp.int32),
                         initial_tx=1, max_burst=1)
        r64 = ps.simulate(jnp.zeros(512, jnp.int32), jnp.zeros(512, jnp.int32),
                          initial_tx=1, max_burst=64)
        t1 = float(ps.throughput_mev_s(r1))
        t64 = float(ps.throughput_mev_s(r64))
        assert t1 == pytest.approx(28.6, abs=0.1)
        assert t64 > 31.5  # approaches 32.3
