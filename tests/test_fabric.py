"""Fabric-level tests: the N-chip simulator must degenerate to the paper's
measured two-block link bit-exactly, conserve events on multi-hop
topologies under every traffic generator, and route/address correctly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network as net
from repro.core import protocol_sim as ps
from repro.core import traffic as tr
from repro.core.router import (AddressSpec, MulticastTable, RoutingTable,
                               Topology, line_topology, mesh2d_topology,
                               ring_topology)


def _two_chip_spec(arr_l, arr_r):
    """arr_l/arr_r arrival arrays -> flat spec on the 2-chip topology."""
    nl, nr = len(arr_l), len(arr_r)
    return tr.TrafficSpec(
        src=jnp.concatenate([jnp.zeros(nl, jnp.int32),
                             jnp.ones(nr, jnp.int32)]),
        t=jnp.concatenate([jnp.asarray(arr_l, jnp.int32),
                           jnp.asarray(arr_r, jnp.int32)]),
        dest=jnp.concatenate([jnp.ones(nl, jnp.int32),
                              jnp.zeros(nr, jnp.int32)]))


class TestTwoChipEquivalence:
    """The refactor's safety net: a degenerate 2-chip fabric reproduces
    ``protocol_sim.simulate`` departures, switch counts and t_end
    bit-exactly."""

    @pytest.mark.parametrize("seed,initial_tx,max_burst", [
        (0, 1, 0), (1, 0, 0), (2, 1, 1), (3, 0, 8),
    ])
    def test_bit_exact(self, seed, initial_tx, max_burst):
        rng = np.random.default_rng(seed)
        arr_l = np.sort(rng.integers(0, 40_000, 70)).astype(np.int32)
        arr_r = np.sort(rng.integers(0, 40_000, 50)).astype(np.int32)
        ref = ps.simulate(jnp.array(arr_l), jnp.array(arr_r),
                          initial_tx=initial_tx, max_burst=max_burst)
        res = net.simulate_fabric(line_topology(2),
                                  _two_chip_spec(arr_l, arr_r),
                                  initial_tx=initial_tx, max_burst=max_burst)
        assert int(res.delivered) == res.injected == 120
        assert int(res.t_end) == int(ref.t_end)
        assert np.asarray(res.sent).tolist() == [
            [int(ref.sent_l), int(ref.sent_r)]]
        assert int(res.n_switches[0]) == int(ref.n_switches)
        # per-direction departure (== delivery) time multisets
        act = np.asarray(ref.trace.action)
        t_tr = np.asarray(ref.trace.t)
        n = int(res.delivered)
        dlv = np.asarray(res.log_del)[:n]
        dst = np.asarray(res.log_dest)[:n]
        np.testing.assert_array_equal(np.sort(t_tr[act == ps.A_TX_L]),
                                      np.sort(dlv[dst == 1]))
        np.testing.assert_array_equal(np.sort(t_tr[act == ps.A_TX_R]),
                                      np.sort(dlv[dst == 0]))

    def test_saturated_onedir_rate_survives(self):
        """Fig. 7 condition through the fabric path: 32.3 MEvents/s."""
        n = 512
        res = net.simulate_fabric(
            line_topology(2),
            tr.TrafficSpec(src=jnp.zeros(n, jnp.int32),
                           t=jnp.zeros(n, jnp.int32),
                           dest=jnp.ones(n, jnp.int32)),
            initial_tx=0)
        assert int(res.delivered) == n
        assert int(res.t_end) == 10 + 31 * n  # idle switch + n cycles
        thr = float(net.fabric_throughput_mev_s(res))
        assert thr == pytest.approx(32.3, abs=0.2)


class TestConservation:
    """Events injected == events delivered, multi-hop, all generators."""

    @pytest.mark.parametrize("pattern", sorted(tr.PATTERNS))
    def test_ring4_all_generators(self, pattern):
        spec = tr.PATTERNS[pattern](jax.random.PRNGKey(11), 4, 32)
        res = net.simulate_fabric(ring_topology(4), spec)
        assert int(res.drops) == 0
        assert int(res.delivered) == res.injected == spec.n_events
        # every delivery reached its addressed chip
        n = int(res.delivered)
        lat = net.delivered_latencies(res)
        assert (lat >= 0).all()
        assert len(lat) == n

    @pytest.mark.parametrize("pattern", sorted(tr.PATTERNS))
    def test_ring4_bounded_burst(self, pattern):
        spec = tr.PATTERNS[pattern](jax.random.PRNGKey(5), 4, 24)
        res = net.simulate_fabric(ring_topology(4), spec, max_burst=4)
        assert int(res.delivered) == res.injected

    @pytest.mark.parametrize("topo_fn", [
        lambda: line_topology(4),
        lambda: ring_topology(8),
        lambda: mesh2d_topology(2, 3),
    ])
    def test_other_topologies_poisson(self, topo_fn):
        topo = topo_fn()
        spec = tr.poisson(jax.random.PRNGKey(3), topo.n_chips, 24)
        res = net.simulate_fabric(topo, spec)
        assert int(res.delivered) == res.injected
        assert int(res.drops) == 0

    def test_hop_energy_rollup(self):
        """Energy counts every hop: a 4-ring Poisson run costs
        sum(hops) * 11 pJ."""
        topo = ring_topology(4)
        rt = RoutingTable.build(topo)
        spec = tr.poisson(jax.random.PRNGKey(9), 4, 16)
        res = net.simulate_fabric(topo, spec, routing=rt)
        src = np.asarray(spec.src)
        dest = np.asarray(spec.dest)
        expected_tx = rt.hops[src, dest].sum()
        assert int(np.asarray(res.sent).sum()) == expected_tx
        assert float(net.fabric_energy_pj(res)) == pytest.approx(
            11.0 * expected_tx)

    def test_multihop_latency_not_blocked_by_future_injections(self):
        """A forward already in flight must not wait behind a pre-routed
        injection that has not happened yet (conservative clock sync)."""
        spec = tr.TrafficSpec(src=jnp.array([0, 1], jnp.int32),
                              t=jnp.array([0, 100_000], jnp.int32),
                              dest=jnp.array([2, 2], jnp.int32))
        res = net.simulate_fabric(line_topology(3), spec)
        assert int(res.delivered) == 2
        n = int(res.delivered)
        inj = np.asarray(res.log_inj)[:n]
        lat = net.delivered_latencies(res)
        # two hops of 31 ns for the t=0 event, one for the t=100000 one
        assert lat[np.argmin(inj)] == 62
        assert lat[np.argmax(inj)] == 31

    def test_per_flow_fifo_under_contention(self):
        """Busy links never pop an entry a still-in-flight forward should
        precede: deliveries of each flow stay in injection order even when
        a relay link's wall-clock runs ahead (ping-pong + stream mix)."""
        n = 48
        base = jnp.arange(n, dtype=jnp.int32) * 40
        spec = tr.TrafficSpec(
            src=jnp.concatenate([jnp.zeros(n, jnp.int32),   # 0->2 stream
                                 jnp.ones(n, jnp.int32),    # 1->0 ping
                                 jnp.zeros(n, jnp.int32)]),  # 0->1 pong
            t=jnp.concatenate([base, base, base + 7]),
            dest=jnp.concatenate([jnp.full((n,), 2, jnp.int32),
                                  jnp.zeros(n, jnp.int32),
                                  jnp.ones(n, jnp.int32)]))
        res = net.simulate_fabric(line_topology(3), spec, max_burst=1)
        m = int(res.delivered)
        assert m == res.injected
        inj = np.asarray(res.log_inj)[:m]
        dst = np.asarray(res.log_dest)[:m]
        for d in (0, 1, 2):  # one flow per destination here
            assert (np.diff(inj[dst == d]) >= 0).all()

    def test_parked_link_wakes_on_forward(self):
        """A link with no injected traffic must still relay forwards."""
        # line 0-1-2: all traffic 0 -> 2; link (1,2) has no injections.
        n = 40
        spec = tr.TrafficSpec(src=jnp.zeros(n, jnp.int32),
                              t=jnp.arange(n, dtype=jnp.int32) * 100,
                              dest=jnp.full((n,), 2, jnp.int32))
        res = net.simulate_fabric(line_topology(3), spec)
        assert int(res.delivered) == n
        # each event crossed two links
        assert int(np.asarray(res.sent).sum()) == 2 * n
        # two-hop latency is at least two event cycles
        assert net.delivered_latencies(res).min() >= 2 * 31


class TestRoutingAndAddressing:
    def test_bfs_table_ring(self):
        rt = RoutingTable.build(ring_topology(4))
        # opposite corners are 2 hops, neighbours 1
        assert rt.hops[0, 2] == 2 and rt.hops[0, 1] == 1
        assert rt.diameter == 2
        assert (np.diag(rt.hops) == 0).all()
        assert (rt.hops == rt.hops.T).all()

    def test_bfs_next_hop_advances(self):
        """Following next_link/out_side always reduces hops by one."""
        topo = mesh2d_topology(3, 3)
        rt = RoutingTable.build(topo)
        for c in range(topo.n_chips):
            for d in range(topo.n_chips):
                if c == d:
                    continue
                l = rt.next_link[c, d]
                side = rt.out_side[c, d]
                assert topo.links[l][side] == c  # we sit on the out side
                nxt = topo.links[l][1 - side]
                assert rt.hops[nxt, d] == rt.hops[c, d] - 1

    def test_address_pack_roundtrip(self):
        addr = AddressSpec()
        chips = np.array([0, 3, 255], np.int32)
        cores = np.array([0, 12345, (1 << addr.core_bits) - 1], np.int32)
        w = addr.pack(chips, cores)
        assert (w < (1 << (addr.word_bits - 1))).all()  # fits, no mcast bit
        c2, k2 = addr.unpack(w)
        np.testing.assert_array_equal(c2, chips)
        np.testing.assert_array_equal(k2, cores)
        assert not addr.is_multicast(w).any()
        assert addr.is_multicast(addr.pack_multicast(np.int32(7))).all()

    def test_address_range_checks(self):
        addr = AddressSpec(chip_bits=4)
        with pytest.raises(ValueError):
            addr.pack(16, 0)
        with pytest.raises(ValueError):
            addr.pack(0, 1 << addr.core_bits)

    def test_multicast_expansion_conserved(self):
        """Tag expansion delivers one copy per member (source excluded)."""
        addr = AddressSpec()
        mc = MulticastTable(np.array([[True, True, True, True, False,
                                       False, False, False]]))
        n = 12
        spec = tr.TrafficSpec(
            src=jnp.zeros(n, jnp.int32),
            t=jnp.arange(n, dtype=jnp.int32) * 400,
            dest=jnp.asarray(addr.pack_multicast(np.zeros(n, np.int32))))
        res = net.simulate_fabric(ring_topology(8), spec, addr=addr,
                                  mcast=mc)
        # tag 0 = chips 0..3, src 0 excluded -> 3 copies per event
        assert res.injected == 3 * n
        assert int(res.delivered) == 3 * n
        dst = np.asarray(res.log_dest)[:int(res.delivered)]
        assert sorted(set(dst.tolist())) == [1, 2, 3]

    def test_self_addressed_rejected(self):
        spec = tr.TrafficSpec(src=jnp.zeros(1, jnp.int32),
                              t=jnp.zeros(1, jnp.int32),
                              dest=jnp.zeros(1, jnp.int32))
        with pytest.raises(ValueError, match="self-addressed"):
            net.simulate_fabric(line_topology(2), spec)


class TestTrafficGenerators:
    @pytest.mark.parametrize("pattern", sorted(tr.PATTERNS))
    def test_well_formed(self, pattern):
        n_chips, epc = 6, 20
        spec = tr.PATTERNS[pattern](jax.random.PRNGKey(2), n_chips, epc)
        src = np.asarray(spec.src)
        t = np.asarray(spec.t)
        dest = np.asarray(spec.dest)
        assert (dest != src).all()
        assert (0 <= dest).all() and (dest < n_chips).all()
        assert (t >= 0).all()
        for c in np.unique(src):  # nondecreasing per source
            tc = t[src == c]
            assert (np.diff(tc) >= 0).all()

    def test_ping_pong_pairs(self):
        spec = tr.ping_pong(4, 8)
        src = np.asarray(spec.src)
        dest = np.asarray(spec.dest)
        assert (dest == (src ^ 1)).all()
        assert (np.asarray(spec.t) == 0).all()

    def test_ping_pong_odd_chip_silent(self):
        spec = tr.ping_pong(5, 4)
        assert spec.n_events == 4 * 4
        assert (np.asarray(spec.src) < 4).all()

    def test_hot_spot_concentrates(self):
        spec = tr.hot_spot(jax.random.PRNGKey(0), 8, 200, hot_chip=3,
                           hot_frac=0.8)
        dest = np.asarray(spec.dest)
        src = np.asarray(spec.src)
        frac = np.mean(dest[src != 3] == 3)
        assert frac > 0.6  # concentrated, allowing sampling noise

    def test_poisson_mean_gap(self):
        spec = tr.poisson(jax.random.PRNGKey(4), 2, 2000, mean_gap_ns=100.0)
        t = np.asarray(spec.t)[np.asarray(spec.src) == 0]
        gaps = np.diff(t)
        assert abs(gaps.mean() - 100.0) < 15.0


class TestCapacityLimits:
    def test_undersized_queue_raises_on_backlog(self):
        spec = tr.ping_pong(2, 64)
        with pytest.raises(ValueError, match="queue capacity"):
            net.simulate_fabric(ring_topology(2), spec, queue_capacity=8)

    def test_forward_drops_counted(self):
        """A relay queue overwhelmed by converging forwards drops (and
        says so) instead of corrupting state: delivered + drops accounts
        for every injected event."""
        # chips 0 and 1 flood chip 3 through relay chip 2: the (2,3)
        # queue sees 2x its drain rate and overflows a one-source-sized
        # capacity.
        topo = Topology(4, np.array([(0, 2), (1, 2), (2, 3)], np.int32))
        n = 64
        spec = tr.TrafficSpec(
            src=jnp.concatenate([jnp.zeros(n, jnp.int32),
                                 jnp.ones(n, jnp.int32)]),
            t=jnp.zeros(2 * n, jnp.int32),
            dest=jnp.full((2 * n,), 3, jnp.int32))
        res = net.simulate_fabric(topo, spec, queue_capacity=n)
        assert int(res.drops) > 0
        assert int(res.delivered) + int(res.drops) == 2 * n
