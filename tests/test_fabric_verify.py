"""Fabric static verifier: ``Fabric.verify`` / ``repro.analysis.verify``.

Contracts under test:

* certificates — drop mode is always deadlock-free (``"drop-mode"``),
  an acyclic channel-dependency graph certifies the stall modes
  (``"acyclic-cdg"``), and a cyclic CDG whose every cycle crosses an
  unsaturable channel certifies by demand (``"capacity-slack"``);
* a verify()-admitted lossless config actually drains: delivered ==
  injected, zero drops, and the step bound is non-binding (doubling it
  changes nothing);
* a cyclic ROUTE graph with an acyclic CDG (the ring(4) 0 <-> 3 bend)
  is admitted and runs lossless bit-exactly on all three engines —
  the precise Dally–Seitz criterion, not PR 7's blanket refusal;
* a genuine saturable CDG cycle (all-clockwise ring(4) under credit
  flow with tiny capacity) is named by verify() as an error, and the
  engine run it predicts really does stall forever: delivered is
  identical at the step bound and at twice the step bound, below
  injected;
* ``find_route_cycles`` extended over multicast trees reports
  ``(chip, n_chips + i)`` coordinates for a hand-built cyclic tree,
  and ``verify_fabric`` folds the same traversal into its findings;
* the tight per-link clock budget admits heterogeneous-timing configs
  the global worst-cost bound falsely refused, and reports the
  headroom against the ``BIG_NS`` sentinel.
"""

import jax
import numpy as np
import pytest

from repro.analysis import verify_fabric
from repro.analysis.verify import channel_graph, describe_channel
from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import Fabric, QueuePolicy, StaticShortestPath
from repro.core.link import PAPER_TIMING, per_link_timing
from repro.core.router import (MulticastTree, RoutingTable, find_route_cycles,
                               find_tree_cycles, line_topology, ring_topology)

assert_bit_exact = net.assert_results_equal
BIG = 2 ** 30


def i32(x):
    return np.asarray(x, np.int32)


def _poisson(key=3, n=8, epc=24):
    return tr.poisson(jax.random.PRNGKey(key), n, epc)


def _bent_override(topo_, rt):
    """Ring(4) dest-1 bend: routes (0,1)/(3,1) loop 0 <-> 3 forever,
    yet the surviving routes' CDG is acyclic."""
    nl = rt.next_link.copy()
    os = rt.out_side.copy()
    nl[0, 1], os[0, 1] = 3, 1
    nl[3, 1], os[3, 1] = 3, 0
    return RoutingTable(next_link=nl, out_side=os, hops=rt.hops)


def _clockwise(topo_, rt):
    """All-clockwise ring table: every route circles one way, so the
    channel-dependency graph is one big cycle."""
    n = rt.next_link.shape[0]
    nl = rt.next_link.copy()
    os = rt.out_side.copy()
    hops = rt.hops.copy()
    for c in range(n):
        for d in range(n):
            if c != d:
                nl[c, d], os[c, d], hops[c, d] = c, 0, (d - c) % n
    return RoutingTable(next_link=nl, out_side=os, hops=hops)


def _checks(report):
    return {f.check for f in report.findings}


class TestCertificates:
    def test_drop_mode_always_certified(self):
        fab = Fabric(ring_topology(16))
        rep = fab.verify()
        assert rep.ok and rep.deadlock_free
        assert rep.certificate == "drop-mode"
        # the method delegates to the functional entrypoint
        assert verify_fabric(fab).certificate == "drop-mode"

    def test_small_ring_acyclic_cdg(self):
        """Ring(4) BFS routes are <= 2 hops; their CDG has no cycle, so
        credit flow is certified structurally, before any spec."""
        fab = Fabric(ring_topology(4),
                     queues=QueuePolicy(capacity=8, flow="credit"))
        rep = fab.verify()
        assert rep.ok and rep.deadlock_free
        assert rep.certificate == "acyclic-cdg"
        assert rep.cdg_cycle is None

    def test_big_ring_cyclic_cdg_warns_without_spec(self):
        """Ring(16) BFS routes wrap far enough that the CDG is cyclic.
        Without a spec the hazard cannot be graded by demand: warning,
        not proven deadlock-free, but not an error either."""
        fab = Fabric(ring_topology(16),
                     queues=QueuePolicy(capacity=64, flow="credit"))
        rep = fab.verify()
        assert rep.ok and not rep.deadlock_free
        assert rep.certificate == ""
        assert rep.cdg_cycle is not None
        assert any(f.severity == "warning" and f.check == "cdg-cycle"
                   for f in rep.findings)

    def test_big_ring_capacity_slack_with_spec(self):
        """With a spec the same cyclic CDG is graded by static demand:
        uniform ring(16) traffic never fills the antipodal channels, so
        every cycle crosses an unsaturable channel and credit flow is
        certified."""
        fab = Fabric(ring_topology(16),
                     queues=QueuePolicy(capacity=64, flow="credit"))
        rep = fab.verify(_poisson(2, 16, 24))
        assert rep.ok and rep.deadlock_free
        assert rep.certificate == "capacity-slack"
        assert any(f.severity == "info" and f.check == "cdg-cycle"
                   for f in rep.findings)

    def test_summary_mentions_certificate(self):
        rep = Fabric(ring_topology(8)).verify(_poisson())
        assert "drop-mode" in rep.summary()
        assert rep.raise_if_failed() is rep


class TestAdmittedConfigsDrain:
    """The verifier's soundness direction: admitted => drains."""

    @pytest.mark.parametrize("flow,cap", [("drop", None), ("credit", 64),
                                          ("onoff", 64)])
    def test_admitted_lossless_delivers_everything(self, flow, cap):
        spec = _poisson(5, 8, 16)
        fab = Fabric(ring_topology(8),
                     queues=QueuePolicy(capacity=cap, flow=flow))
        rep = fab.verify(spec)
        assert rep.ok, rep.summary()
        res = fab.run(spec)
        assert int(res.delivered) == res.injected
        assert int(res.drops) == 0

    def test_step_bound_non_binding(self):
        """Admitted configs drain strictly before the default bound:
        doubling max_steps is bit-identical."""
        spec = _poisson(7, 8, 16)
        fab = Fabric(ring_topology(8),
                     queues=QueuePolicy(capacity=64, flow="credit"))
        assert fab.verify(spec).ok
        base = fab._plan(spec, None).max_steps
        a = fab.run(spec, max_steps=base)
        b = fab.run(spec, max_steps=2 * base)
        assert int(a.delivered) == a.injected
        assert int(a.delivered) == int(b.delivered)
        assert int(a.t_end) == int(b.t_end)


class TestCyclicRouteAcyclicCDG:
    """The precision gate: a cyclic route graph alone is NOT a deadlock
    — only a cyclic channel-dependency graph is."""

    def _fabric(self, engine):
        return Fabric(ring_topology(4),
                      routing=StaticShortestPath(
                          table_override=_bent_override),
                      queues=QueuePolicy(capacity=8, flow="credit"),
                      engine=engine)

    def test_admitted_with_quarantine_warning(self):
        rep = self._fabric("reference").verify()
        assert rep.ok and rep.deadlock_free
        assert rep.certificate == "acyclic-cdg"
        assert any(f.severity == "warning"
                   and f.check == "route-termination"
                   for f in rep.findings)
        assert {tuple(p) for p in rep.route_cycles.tolist()} \
            == {(0, 1), (3, 1)}

    def test_runs_lossless_bit_exact_on_all_engines(self):
        clean = tr.TrafficSpec(src=i32([0, 1, 2, 3, 0, 2]),
                               t=i32([0, 0, 0, 0, 40, 40]),
                               dest=i32([2, 3, 0, 2, 3, 1]))
        ref = self._fabric("reference").run(clean)
        assert int(ref.delivered) == ref.injected
        assert int(ref.drops) == 0
        for engine in ("ring", "pallas"):
            assert_bit_exact(ref, self._fabric(engine).run(clean),
                             f"bent/{engine}")

    def test_quarantined_traffic_refused_with_spec_verify(self):
        fab = self._fabric("reference")
        rep = fab.verify(tr.TrafficSpec(src=i32([0]), t=i32([0]),
                                        dest=i32([1])))
        assert not rep.ok
        assert any(f.severity == "error"
                   and f.check == "route-termination"
                   for f in rep.findings)


class TestDeadlockPrediction:
    """The verifier's completeness direction: the saturable-cycle error
    it reports corresponds to a real permanent stall."""

    def _fabric(self):
        return Fabric(ring_topology(4),
                      routing=StaticShortestPath(
                          table_override=_clockwise),
                      queues=QueuePolicy(capacity=2, flow="credit"))

    def _spec(self):
        src = np.repeat(np.arange(4, dtype=np.int32), 8)
        return tr.TrafficSpec(src=src,
                              t=i32(np.arange(32) * 5),
                              dest=i32((src + 3) % 4))

    def test_verify_names_saturable_cycle(self):
        rep = self._fabric().verify(self._spec())
        assert not rep.ok and not rep.deadlock_free
        err = [f for f in rep.findings
               if f.severity == "error" and f.check == "cdg-cycle"]
        assert err, rep.summary()
        for ch in ("L0:0->1", "L1:1->2", "L2:2->3", "L3:3->0"):
            assert ch in err[0].message

    def test_stall_is_permanent(self):
        """Forcing the refused config past the verifier: delivery stops
        dead and MORE steps change nothing — the signature of a
        deadlock, not slow progress truncated early."""
        spec = self._spec()
        a = self._fabric().run(spec, max_steps=400)
        b = self._fabric().run(spec, max_steps=800)
        assert int(a.delivered) == int(b.delivered) < a.injected
        assert int(a.drops) == 0  # stalled, not dropped

    def test_clean_table_same_capacity_drains(self):
        """Control: identical traffic and capacity under the BFS table
        (acyclic CDG) drains completely — the stall above really is
        the routing cycle, not the tiny capacity."""
        fab = Fabric(ring_topology(4),
                     queues=QueuePolicy(capacity=2, flow="credit"))
        assert fab.verify(self._spec()).ok
        res = fab.run(self._spec())
        assert int(res.delivered) == res.injected


class TestTreeCycles:
    def _cyclic_tree(self, topo):
        """Hand-built 'tree' on ring(4) whose edges 1->2->3->1 loop."""
        edges = i32([[0, 0, 0, 1],      # src out-edge 0 -> 1
                     [1, 1, 0, 2],      # 1 -> 2
                     [2, 2, 0, 3],      # 2 -> 3
                     [3, 1, 1, 1]])     # 3 -> 1 : closes the loop
        deliver = np.zeros(topo.n_chips, bool)
        deliver[[1, 2, 3]] = True
        return MulticastTree(src=0, edges=edges,
                             parent=i32([-1, 0, 1, 2]),
                             deliver=deliver,
                             subtree=i32([3, 2, 1, 1]))

    def test_find_tree_cycles_reports_tree_coordinates(self):
        topo = ring_topology(4)
        bad = find_tree_cycles(topo, [self._cyclic_tree(topo)])
        # chips 1, 2, 3 ride the loop and the source 0 feeds into it;
        # route id = n_chips + tree index
        assert {tuple(p) for p in bad.tolist()} \
            == {(0, 4), (1, 4), (2, 4), (3, 4)}

    def test_find_route_cycles_merges_trees(self):
        topo = ring_topology(4)
        rt = RoutingTable.build(topo)
        bad = find_route_cycles(topo, rt, [self._cyclic_tree(topo)])
        assert {tuple(p) for p in bad.tolist()} \
            == {(0, 4), (1, 4), (2, 4), (3, 4)}
        assert len(find_route_cycles(topo, rt)) == 0

    def test_acyclic_built_tree_is_clean(self):
        topo = ring_topology(8)
        rt = RoutingTable.build(topo)
        tree = MulticastTree.build(topo, rt, src=0,
                                   members=np.asarray([2, 4, 6]))
        assert len(find_tree_cycles(topo, [tree])) == 0


class TestChannelGraph:
    def test_describe_channel_names_link_and_direction(self):
        topo = ring_topology(4)
        # link 0 connects chips 0-1; side 0 transmits 0->1
        assert describe_channel(topo, 0) == "L0:0->1"
        assert describe_channel(topo, 1) == "L0:1->0"

    def test_bfs_ring4_edges_exact(self):
        topo = ring_topology(4)
        g = channel_graph(topo, RoutingTable.build(topo))
        assert g.find_cycle() is None
        assert sorted(map(tuple, g.edges.tolist())) \
            == [(0, 2), (1, 7), (3, 1), (6, 0)]

    def test_restrict_breaks_cycle(self):
        topo = ring_topology(4)
        g = channel_graph(topo, _clockwise(topo, RoutingTable.build(topo)))
        cycle = g.find_cycle()
        assert cycle is not None and len(cycle) == 4
        keep = np.ones(g.n_channels, bool)
        keep[cycle[0]] = False
        assert g.restrict(keep).find_cycle() is None


class TestTightClockBudget:
    def _fabric(self):
        timing = per_link_timing(
            [PAPER_TIMING, PAPER_TIMING.subword(26)], [0, 1])
        return Fabric(line_topology(3), timing=timing)

    def _spec(self, t_max):
        return tr.TrafficSpec(
            src=i32([0, 1] * 4),
            t=i32(sorted(t_max - 70 * k for k in range(8))),
            dest=i32([1, 0] * 4))

    def test_routed_bound_admits_what_global_bound_refused(self):
        """Traffic confined to the fast link, injected close to the
        sentinel: the fabric-wide worst-cost bound overflows but the
        per-link budget does not — the run is admitted and drains."""
        fab = self._fabric()
        t_max = BIG - 1000
        with pytest.raises(ValueError, match="overflow"):
            net._overflow_guard(t_max, 8, fab._worst_cost)
        rep = fab.verify(self._spec(t_max))
        assert rep.ok
        assert rep.clock_bound_ns < BIG
        assert 0 < rep.clock_headroom_ns == BIG - rep.clock_bound_ns
        res = fab.run(self._spec(t_max))
        assert int(res.delivered) == res.injected

    def test_slow_link_traffic_still_refused(self):
        """The same injection times crossing the slow link exceed the
        budget: verify() reports the overflow as an error and plan
        refuses."""
        fab = self._fabric()
        t_max = BIG - 1000
        spec = tr.TrafficSpec(
            src=i32([1, 2] * 4),
            t=i32(sorted(t_max - 70 * k for k in range(8))),
            dest=i32([2, 1] * 4))
        rep = fab.verify(spec)
        assert not rep.ok
        assert rep.clock_headroom_ns <= 0
        assert "clock-overflow" in _checks(rep)
        with pytest.raises(ValueError, match="overflow"):
            fab.run(spec)

    def test_route_link_tx_falls_back_on_broken_walk(self):
        """A cyclic override defeats the route walk; the helper reports
        ok=False so planning falls back to the global bound."""
        topo = ring_topology(4)
        rt = _bent_override(topo, RoutingTable.build(topo))
        counts, ok = net._route_link_tx(
            rt, topo.links, np.asarray([0]), np.asarray([1]),
            topo.n_links, topo.n_chips)
        assert not ok
