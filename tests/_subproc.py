"""Run a JAX snippet in a subprocess with N forced host devices.

The main pytest process must keep the default single CPU device (the
dry-run is the only place 512 devices are forced), so multi-device
collective tests re-exec in a child process.
"""

import os
import subprocess
import sys

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def run_with_devices(code: str, n_devices: int = 8, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout
