"""Batched fabric execution: ``Fabric.run_batch`` / module ``run_batch``.

Contracts under test:

* B instances run as ONE batched computation and every instance is
  bit-exact with its solo ``fabric.run(spec)`` — on all three engines,
  including heterogeneous per-link timing, credit flow control and
  in-fabric multicast (the ring engine's early-exit while_loop is
  batch-aware: exit when ALL instances drain, per-instance carries
  frozen after their own drain);
* the batch compiles exactly once per (bucket, B) signature and a
  repeated same-shape batch adds ZERO cache entries
  (``batch_cache_size``);
* ``run_many`` dispatches same-bucket multi-spec calls to the batch
  path (``last_dispatch == "batch"``) and loops otherwise, bit-exact
  both ways;
* batches refuse mixed shape buckets, empty spec lists, fabric/spec
  count mismatches and AdaptiveRouting (sequential feedback);
* the route-cycle detector (``find_route_cycles``) reports exactly the
  (chip, dest) pairs whose walk never arrives; lossless flow modes
  refuse a broken table at construction only when the terminating
  routes' channel-dependency graph also carries a cycle (Dally–Seitz),
  otherwise the broken pairs are quarantined and traffic addressing
  them is refused at plan time (drop mode keeps the historical
  truncation behaviour);
* ``traffic.monte_carlo`` instance i is bit-identical to the solo
  generator under subkey i; ``telemetry.link_load_batch`` matches
  per-instance ``link_load``;
* the shard_map device path (``devices=``) is bit-exact with the
  unsharded batch and validates divisibility (multidevice lane).
"""

import jax
import numpy as np
import pytest

from repro.core import network as net
from repro.core import telemetry as tm
from repro.core import traffic as tr
from repro.core.adaptive import AdaptiveRouting
from repro.core.fabric import (EngineSpec, Fabric, MulticastPolicy,
                               QueuePolicy, StaticShortestPath,
                               batch_cache_size)
from repro.core.fabric import run_batch as run_batch_fn
from repro.core.link import PAPER_TIMING, SERIAL_LVDS_TIMING, per_link_timing
from repro.core.router import (AddressSpec, MulticastTable, RoutingTable,
                               find_route_cycles, ring_topology)
from tests._subproc import run_with_devices

assert_bit_exact = net.assert_results_equal


def _spec(key=3, n=8, epc=24):
    return tr.poisson(jax.random.PRNGKey(key), n, epc)


def _hot(key, n=8, epc=24):
    return tr.hot_spot(jax.random.PRNGKey(key), n, epc)


def _mixed_timing(n_links, slow=(0,)):
    cls = [0] * n_links
    for l in slow:
        cls[l] = 1
    return per_link_timing([PAPER_TIMING, SERIAL_LVDS_TIMING], cls)


def _mcast_spec(addr, n=24, seed=0):
    """Tagged stream from chip 0 plus unicast cross-traffic (the
    in-fabric replication exercise from the adaptive tests)."""
    rng = np.random.default_rng(seed)
    src = np.concatenate([np.zeros(n, np.int64), np.ones(n // 2, np.int64)])
    t = np.concatenate([np.sort(rng.integers(0, n * 40, n)),
                        10 + np.arange(n // 2) * 40])
    dest = np.concatenate([addr.pack_multicast(np.zeros(n, np.int64)),
                           addr.pack(np.full(n // 2, 3, np.int64))])
    order = np.argsort(t, kind="stable")
    ji = jax.numpy.int32
    return tr.TrafficSpec(src=jax.numpy.asarray(src[order], ji),
                          t=jax.numpy.asarray(t[order], ji),
                          dest=jax.numpy.asarray(dest[order], ji))


class TestRunBatchBitExact:
    """The headline contract: instance i of a batch == solo run i."""

    @pytest.mark.parametrize("engine", sorted(net.ENGINES))
    def test_batch_matches_solo_every_engine(self, engine):
        topo = ring_topology(8)
        specs = [_spec(k, 8, 24) for k in range(5)]
        fab = Fabric(topo, engine=EngineSpec(name=engine))
        batch = fab.run_batch(specs)
        assert batch.n_instances == 5
        solo = Fabric(topo, engine=EngineSpec(name=engine))
        for i, s in enumerate(specs):
            assert_bit_exact(solo.run(s), batch.instance(i),
                             f"batch/{engine}/{i}")

    @pytest.mark.parametrize("engine", sorted(net.ENGINES))
    def test_hetero_timing_batch(self, engine):
        """Per-link heterogeneous timing batches bit-exactly (timing is
        a dynamic operand, stacked per instance)."""
        topo = ring_topology(6)
        timing = _mixed_timing(topo.n_links, slow=(0, 3))
        specs = [_spec(k, 6, 20) for k in (2, 5, 9)]
        fab = Fabric(topo, timing=timing, engine=EngineSpec(name=engine))
        batch = fab.run_batch(specs)
        solo = Fabric(topo, timing=timing, engine=EngineSpec(name=engine))
        for i, s in enumerate(specs):
            assert_bit_exact(solo.run(s), batch.instance(i),
                             f"hetero/{engine}/{i}")

    def test_credit_flow_batch(self):
        """Lossless credit flow under a batch: zero drops per instance,
        bit-exact with solo (the stall/credit FSM is part of the
        vmapped carry)."""
        topo = ring_topology(8)
        q = QueuePolicy(capacity=6, flow="credit")
        specs = [_hot(k, 8, 24) for k in range(4)]
        fab = Fabric(topo, queues=q)
        batch = fab.run_batch(specs)
        solo = Fabric(topo, queues=q)
        for i, s in enumerate(specs):
            r = batch.instance(i)
            assert int(r.drops) == 0
            assert_bit_exact(solo.run(s), r, f"credit/{i}")

    def test_in_fabric_multicast_batch(self):
        """Tagged events replicate at branch points inside a batch,
        bit-exact with solo (replication tables are per-instance
        operands)."""
        addr = AddressSpec()
        members = np.zeros((1, 8), bool)
        members[0, 2:7] = True
        kw = dict(addr=addr,
                  mcast=MulticastPolicy("in_fabric", MulticastTable(members)))
        topo = ring_topology(8)
        specs = [_mcast_spec(addr, seed=s) for s in (0, 1)]
        fab = Fabric(topo, **kw)
        batch = fab.run_batch(specs)
        solo = Fabric(topo, **kw)
        for i, s in enumerate(specs):
            assert_bit_exact(solo.run(s), batch.instance(i), f"mcast/{i}")

    def test_cross_fabric_heterogeneous_batch(self):
        """Module-level run_batch accepts B distinct fabrics (same
        shape bucket, different timing contracts) in one dispatch."""
        topo = ring_topology(6)
        fabs = [Fabric(topo),
                Fabric(topo, timing=_mixed_timing(topo.n_links))]
        specs = [_spec(7, 6, 20), _spec(7, 6, 20)]
        batch = run_batch_fn(fabs, specs)
        for i, (f, s) in enumerate(zip(fabs, specs)):
            assert_bit_exact(Fabric(topo, timing=f.timing).run(s),
                             batch.instance(i), f"cross/{i}")

    def test_conservation_and_rollups(self):
        """Per-instance conservation + the batched roll-up helpers."""
        topo = ring_topology(8)
        fab = Fabric(topo, queues=QueuePolicy(capacity=48))
        specs = [_hot(k, 8, 32) for k in range(6)]
        batch = fab.run_batch(specs)
        assert any(int(batch.instance(i).drops) > 0
                   for i in range(batch.n_instances))
        for i in range(batch.n_instances):
            r = batch.instance(i)
            assert int(r.delivered) + int(r.drops) == r.injected
        thr = np.asarray(net.batch_throughput_mev_s(batch))
        assert thr.shape == (6,) and (thr > 0).all()
        stats = net.batch_latency_stats(batch)
        assert len(stats) == 6
        solo = net.latency_stats(Fabric(
            topo, queues=QueuePolicy(capacity=48)).run(specs[0]))
        assert stats[0] == solo


class TestBatchCompilation:
    def test_one_compile_flat_cache(self):
        """The perf contract: a batch traces once per (bucket, B)
        signature; repeated same-shape batches add ZERO entries."""
        fab = Fabric(ring_topology(8))
        specs = [_spec(k, 8, 24) for k in range(4)]
        cell = fab.sweep_batch(specs)
        n0 = batch_cache_size(cell.bucket)
        assert n0 >= 1  # >1 only if other same-bucket batch sizes ran
        assert cell.us_per_instance * len(specs) == \
            pytest.approx(cell.us_per_call)
        fab.run_batch([_spec(k + 10, 8, 24) for k in range(4)])
        assert batch_cache_size(cell.bucket) == n0

    def test_mixed_bucket_refused(self):
        """Slot engines key max_steps/E into the bucket: a mixed batch
        is refused with a pointer at run_many."""
        fab = Fabric(ring_topology(4), engine=EngineSpec(name="reference"))
        with pytest.raises(ValueError, match="ONE shape bucket"):
            fab.run_batch([_spec(1, 4, 8), _spec(1, 4, 12)])

    def test_empty_refused(self):
        with pytest.raises(ValueError, match="at least one"):
            Fabric(ring_topology(4)).run_batch([])

    def test_fabric_spec_count_mismatch(self):
        topo = ring_topology(4)
        with pytest.raises(ValueError, match="1:1"):
            run_batch_fn([Fabric(topo)], [_spec(1, 4, 8), _spec(2, 4, 8)])

    def test_adaptive_refused(self):
        """Epoch feedback is sequential; the batch path refuses it
        loudly instead of fusing wrong."""
        fab = Fabric(ring_topology(8), routing=AdaptiveRouting(epochs=2))
        with pytest.raises(NotImplementedError, match="AdaptiveRouting"):
            fab.run_batch([_hot(0), _hot(1)])


class TestRunManyDispatch:
    def test_same_bucket_dispatches_batch(self):
        topo = ring_topology(4)
        specs = [_spec(k, 4, 24) for k in range(4)]
        fab = Fabric(topo)
        results = fab.run_many(specs)
        assert fab.last_dispatch == "batch"
        for s, r in zip(specs, results):
            assert_bit_exact(net.simulate_fabric(topo, s), r, "many-batch")

    def test_single_spec_loops(self):
        fab = Fabric(ring_topology(4))
        fab.run_many([_spec(1, 4, 16)])
        assert fab.last_dispatch == "loop"

    def test_mixed_buckets_loop(self):
        topo = ring_topology(4)
        fab = Fabric(topo, engine=EngineSpec(name="reference"))
        specs = [_spec(1, 4, 8), _spec(1, 4, 12)]
        results = fab.run_many(specs)
        assert fab.last_dispatch == "loop"
        for s, r in zip(specs, results):
            assert_bit_exact(net.simulate_fabric(topo, s,
                                                 engine="reference"),
                             r, "many-loop")

    def test_adaptive_loops(self):
        fab = Fabric(ring_topology(8), routing=AdaptiveRouting(epochs=2))
        results = fab.run_many([_hot(0), _hot(1)])
        assert fab.last_dispatch == "loop"
        assert len(results) == 2


def jnp_i32(x):
    return np.asarray(x, np.int32)


def _cyclic_override(topo_, rt):
    """Bend dest-1 routing on ring(4) into the 2-cycle 0 <-> 3."""
    nl = rt.next_link.copy()
    os = rt.out_side.copy()
    nl[0, 1], os[0, 1] = 3, 1   # chip 0 -> link 3 -> chip 3
    nl[3, 1], os[3, 1] = 3, 0   # chip 3 -> link 3 -> chip 0
    return RoutingTable(next_link=nl, out_side=os, hops=rt.hops)


def _clockwise(topo_, rt):
    """All-clockwise table on ring(n): chip c always exits on link c."""
    n = rt.next_link.shape[0]
    nl = rt.next_link.copy()
    os = rt.out_side.copy()
    hops = rt.hops.copy()
    for c in range(n):
        for d in range(n):
            if c != d:
                nl[c, d], os[c, d], hops[c, d] = c, 0, (d - c) % n
    return RoutingTable(next_link=nl, out_side=os, hops=hops)


def _cw_broken(topo_, rt):
    """All-clockwise plus the dest-1 bend: the surviving routes still
    carry the full clockwise channel cycle, so lossless flow must
    refuse at construction."""
    cw = _clockwise(topo_, rt)
    nl = cw.next_link.copy()
    os = cw.out_side.copy()
    nl[0, 1], os[0, 1] = 3, 1
    nl[3, 1], os[3, 1] = 3, 0
    return RoutingTable(next_link=nl, out_side=os, hops=cw.hops)


class TestRouteCycleDetector:
    def test_bfs_table_is_acyclic(self):
        topo = ring_topology(8)
        assert len(find_route_cycles(topo, RoutingTable.build(topo))) == 0

    def test_reports_exact_pairs(self):
        topo = ring_topology(4)
        rt = _cyclic_override(topo, RoutingTable.build(topo))
        bad = find_route_cycles(topo, rt)
        assert {tuple(p) for p in bad.tolist()} == {(0, 1), (3, 1)}

    @pytest.mark.parametrize("flow,cap", [("credit", 4), ("onoff", 4)])
    def test_lossless_quarantines_acyclic_cdg_table(self, flow, cap):
        """On ring(4) the 0 <-> 3 bend leaves the terminating routes'
        channel-dependency graph acyclic, so the table is ADMITTED
        (Dally-Seitz: the stall chain cannot loop) with the broken
        pairs quarantined — clean traffic runs lossless, traffic
        addressing a quarantined pair is refused at plan time."""
        fab = Fabric(ring_topology(4),
                     routing=StaticShortestPath(
                         table_override=_cyclic_override),
                     queues=QueuePolicy(capacity=cap, flow=flow))
        clean = tr.TrafficSpec(
            src=jnp_i32([0, 1, 2, 3]), t=jnp_i32([0, 0, 0, 0]),
            dest=jnp_i32([2, 3, 0, 2]))  # avoids (0,1) and (3,1)
        res = fab.run(clean)
        assert int(res.delivered) == 4
        assert int(res.drops) == 0
        quarantined = tr.TrafficSpec(
            src=jnp_i32([0]), t=jnp_i32([0]), dest=jnp_i32([1]))
        with pytest.raises(ValueError, match=r"quarantined.*never "
                                             r"reaches"):
            fab.run(quarantined)

    @pytest.mark.parametrize("flow,cap", [("credit", 4), ("onoff", 4)])
    def test_lossless_refuses_cyclic_cdg_table(self, flow, cap):
        """When the surviving routes' channel-dependency graph is
        itself cyclic the table is refused at construction, naming a
        broken pair and the channel cycle."""
        with pytest.raises(ValueError, match=r"never reaches.*0->1"):
            Fabric(ring_topology(4),
                   routing=StaticShortestPath(
                       table_override=_cw_broken),
                   queues=QueuePolicy(capacity=cap, flow=flow))

    def test_drop_mode_keeps_cyclic_table(self):
        """Drop mode keeps the historical truncate/drop behaviour — the
        eager check only guards the lossless modes."""
        fab = Fabric(ring_topology(4),
                     routing=StaticShortestPath(
                         table_override=_cyclic_override))
        assert fab.queues.flow == "drop"

    def test_acyclic_override_passes_lossless(self):
        """A legal detour override still constructs under credit flow."""
        def long_way(topo_, rt):
            nl = rt.next_link.copy()
            os = rt.out_side.copy()
            hops = rt.hops.copy()
            nl[0, 1], os[0, 1], hops[0, 1] = 3, 1, 3
            nl[3, 1], os[3, 1], hops[3, 1] = 2, 1, 2
            return RoutingTable(next_link=nl, out_side=os, hops=hops)
        Fabric(ring_topology(4),
               routing=StaticShortestPath(table_override=long_way),
               queues=QueuePolicy(capacity=8, flow="credit"))


class TestDevices:
    def test_devices_one_is_unsharded(self):
        topo = ring_topology(8)
        specs = [_spec(k, 8, 24) for k in range(3)]
        a = Fabric(topo).run_batch(specs)
        b = Fabric(topo).run_batch(specs, devices=1)
        for i in range(3):
            assert_bit_exact(a.instance(i), b.instance(i), f"dev1/{i}")

    def test_too_many_devices_refused(self):
        fab = Fabric(ring_topology(4))
        with pytest.raises(ValueError, match="local"):
            fab.run_batch([_spec(1, 4, 16)] * 2,
                          devices=jax.local_device_count() + 1)


SHARD_CODE = """
import jax
import numpy as np
from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import Fabric
from repro.core.router import ring_topology

assert jax.local_device_count() == 4, jax.local_device_count()
topo = ring_topology(8)
specs = [tr.poisson(jax.random.PRNGKey(k), 8, 24) for k in range(8)]
sharded = Fabric(topo).run_batch(specs, devices="all")
plain = Fabric(topo).run_batch(specs)
for i in range(8):
    net.assert_results_equal(plain.instance(i), sharded.instance(i),
                             f"shard/{i}")
try:
    Fabric(topo).run_batch(specs[:6], devices=4)
except ValueError as e:
    assert "divisible" in str(e), e
else:
    raise AssertionError("expected divisibility ValueError")
print("SHARD_OK")
"""


@pytest.mark.multidevice
def test_shard_map_batch_bit_exact():
    """devices='all' shards the batch axis over 4 forced host devices
    and stays bit-exact with the unsharded batch; non-divisible batch
    sizes are refused."""
    out = run_with_devices(SHARD_CODE, 4)
    assert "SHARD_OK" in out


class TestMonteCarloTraffic:
    def test_instances_match_solo_subkeys(self):
        key = jax.random.PRNGKey(11)
        specs = tr.monte_carlo("hot_spot", key, 4, 8, 16)
        keys = jax.random.split(key, 4)
        for i, s in enumerate(specs):
            solo = tr.PATTERNS["hot_spot"](keys[i], 8, 16)
            for f in tr.TrafficSpec._fields:
                assert np.array_equal(np.asarray(getattr(s, f)),
                                      np.asarray(getattr(solo, f))), (i, f)

    def test_validation(self):
        key = jax.random.PRNGKey(0)
        with pytest.raises(ValueError, match="unknown pattern"):
            tr.monte_carlo("nope", key, 2, 4, 8)
        with pytest.raises(ValueError, match="batch"):
            tr.monte_carlo("poisson", key, 0, 4, 8)


class TestTelemetryBatch:
    def test_link_load_batch_matches_solo(self):
        topo = ring_topology(8)
        specs = [_hot(k, 8, 24) for k in range(3)]
        fab = Fabric(topo, queues=QueuePolicy(capacity=48))
        loads = tm.link_load_batch(fab.run_batch(specs))
        assert len(loads) == 3
        for i, s in enumerate(specs):
            solo = tm.link_load(Fabric(
                topo, queues=QueuePolicy(capacity=48)).run(s))
            for f in tm.LinkLoad._fields:
                assert np.array_equal(np.asarray(getattr(loads[i], f)),
                                      np.asarray(getattr(solo, f))), (i, f)
