"""Graceful degradation when ``hypothesis`` is not installed.

CI installs the full dev manifest (``requirements-dev.txt``) and gets real
property-based testing.  Minimal environments (the bare runtime image) can
still *collect and run* every non-property test: this shim supplies
signature-compatible ``given`` / ``settings`` / ``st`` stand-ins whose
decorated tests skip with a clear reason instead of erroring the whole
module at import time.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in: any attribute access / call yields a strategy."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

    class _StrategiesModule:
        def __getattr__(self, name):
            return _Strategy()

    st = _StrategiesModule()

    def settings(*args, **kwargs):
        if args and callable(args[0]):  # bare @settings
            return args[0]
        return lambda fn: fn

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
