"""Integration: manual-DP training with each gradient-reduction schedule
(the paper technique) matches / tracks the dense psum baseline (subprocess,
4 forced host devices)."""

import pytest

from tests._subproc import run_with_devices

CODE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import RunConfig, get_smoke_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.parallel.sharding import make_rules
from repro.runtime.train_loop import init_state, make_train_step

STEPS = 12

def train(mode):
    cfg = get_smoke_config("granite_3_2b")
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=2,
                        total_steps=STEPS, dp_reduce=mode, aer_frac=0.1,
                        aer_budget=256, fsdp=False)
    model = build_model(cfg)
    mesh = make_host_mesh(data=4, model=1)
    rules = make_rules(mesh, fsdp=False, kv_heads=cfg.n_kv_heads,
                       d_head=cfg.d_head)
    data = SyntheticLM(cfg.vocab, 16, 8, seed=7)
    state = init_state(model, jax.random.PRNGKey(0), run_cfg)
    step = make_train_step(model, run_cfg, rules)
    losses = []
    for s in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return np.array(losses), state

l_psum, s_psum = train("psum")
l_ring, s_ring = train("ring")
l_bidi, s_bidi = train("bidir_ring")
l_aer, s_aer = train("aer_topk")

# same math, different reduction ORDER: float-noise compounds
# through optimizer steps -> tolerance is loose but far from the AER band
assert np.allclose(l_psum, l_ring, atol=8e-3), (l_psum - l_ring)
assert np.allclose(l_psum, l_bidi, atol=8e-3), (l_psum - l_bidi)
# AER: lossy but convergent — tracks the psum band (at 12 steps the
# error-feedback ramp makes per-step decrease noisy; the longer-run
# decrease is covered by examples/sparse_allreduce_demo.py at 40 steps)
assert abs(l_aer[-1] - l_psum[-1]) < 0.35, (l_aer[-1], l_psum[-1])
assert np.isfinite(l_aer).all()
# params of exact schedules agree up to compounded reduction-order noise
# (AdamW's rsqrt normalization amplifies ulp-level gradient differences;
# bitwise equality is a property of restart replay, not of re-ordered sums)
pa = jax.tree.leaves(s_psum.params); pb = jax.tree.leaves(s_bidi.params)
d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(pa, pb))
assert d < 5e-2, d
print("MODES-OK", l_psum[-1], l_aer[-1])
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_dp_reduce_modes_track_psum():
    out = run_with_devices(CODE, 4, timeout=1800)
    assert "MODES-OK" in out
