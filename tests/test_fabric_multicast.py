"""In-fabric multicast replication (``MulticastPolicy("in_fabric")``).

The tentpole contract, asserted here from three angles:

* **Cross-engine matrix** — ``in_fabric`` mode is bit-exact across the
  ``ring`` / ``reference`` / ``pallas`` engines (destinations, drops,
  ordering — the full ``FabricResult`` field list), including the
  weighted-drop path where one dropped copy forfeits a whole subtree.
* **Mode equivalence** — ``in_fabric`` and ``source_expand`` deliver the
  IDENTICAL destination multiset (per injected event), while
  ``in_fabric`` uses strictly fewer link traversals whenever member
  paths share links (the fanout-8 shared-path ring of the acceptance
  criteria).
* **Replication-tree invariants** — the Steiner-branching of the BFS
  shortest paths is a tree (one in-edge per node), covers every member,
  and its subtree weights sum consistently.

Plus the satellite: the vectorized ``MulticastTable.expand_stream`` must
reproduce the historical per-event Python loop bit-for-bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import (EngineSpec, Fabric, MulticastPolicy,
                               QueuePolicy)
from repro.core.router import (AddressSpec, MulticastTable, MulticastTree,
                               RoutingTable, line_topology, mesh2d_topology,
                               ring_topology)

assert_bit_exact = net.assert_results_equal

ADDR = AddressSpec()


def _mcast_spec(src, t, tag):
    """Tagged-event spec from plain arrays."""
    return tr.TrafficSpec(
        src=jnp.asarray(np.asarray(src, np.int32)),
        t=jnp.asarray(np.asarray(t, np.int32)),
        dest=jnp.asarray(ADDR.pack_multicast(np.asarray(tag, np.int64))))


def _fanout8_ring():
    """The acceptance-criteria fabric: a 16-ring whose tag spans chips
    4..11 (fanout 8 from chip 0) — five clockwise members share the
    0-1-2-3 path and three counter-clockwise ones share 0-15-14-13."""
    topo = ring_topology(16)
    members = np.zeros((1, 16), bool)
    members[0, 4:12] = True
    return topo, MulticastTable(members)


_delivery_multiset = net.delivery_multiset


def _run(topo, spec, mode, mc, engine="ring", **kw):
    return Fabric(topo, addr=ADDR, engine=engine,
                  mcast=MulticastPolicy(mode, mc), **kw).run(spec)


class TestCrossEngineMatrix:
    """in_fabric mode must be indistinguishable across all three
    engines: same deliveries, same ordering, same drops."""

    def test_fanout8_ring_all_engines(self):
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(12), np.arange(12) * 400, np.zeros(12))
        rs = {e: _run(topo, spec, "in_fabric", mc, engine=e)
              for e in sorted(net.ENGINES)}
        ref = rs["reference"]
        assert int(ref.delivered) == ref.injected == 12 * 8
        for e in sorted(net.ENGINES):
            assert_bit_exact(ref, rs[e], f"in_fabric/{e}")

    def test_mixed_unicast_multicast_mesh(self):
        """Unicast and tagged events interleaved on a mesh (replication
        branch factor up to 4), all engines."""
        topo = mesh2d_topology(3, 3)
        members = np.zeros((2, 9), bool)
        members[0, [0, 2, 6, 8]] = True   # the corners
        members[1, [1, 3, 5, 7]] = True   # the edge midpoints
        mc = MulticastTable(members)
        rng = np.random.default_rng(0)
        n_u, n_m = 20, 12
        u_src = rng.integers(0, 9, n_u)
        u_dst = (u_src + rng.integers(1, 9, n_u)) % 9
        m_src = rng.integers(0, 9, n_m)
        src = np.concatenate([u_src, m_src]).astype(np.int32)
        t = np.sort(rng.integers(0, 20_000, n_u + n_m)).astype(np.int32)
        dest = np.concatenate([
            ADDR.pack(u_dst.astype(np.int64)),
            ADDR.pack_multicast(rng.integers(0, 2, n_m).astype(np.int64)),
        ]).astype(np.int32)
        spec = tr.TrafficSpec(src=jnp.asarray(src), t=jnp.asarray(t),
                              dest=jnp.asarray(dest))
        rs = {e: _run(topo, spec, "in_fabric", mc, engine=e)
              for e in sorted(net.ENGINES)}
        ref = rs["reference"]
        assert int(ref.delivered) == ref.injected
        for e in sorted(net.ENGINES):
            assert_bit_exact(ref, rs[e], f"mesh-mixed/{e}")

    @pytest.mark.parametrize("capacity", [16, 21])
    def test_weighted_drops_identical(self, capacity):
        """A dropped copy forfeits its whole subtree: the weighted drop
        count keeps delivered + drops == expected on every engine, and
        the engines agree bit-for-bit mid-overflow."""
        # line 0-1-2-3, sources 0 AND 1 multicast to {2, 3}: the (1, 2)
        # endpoint holds source-1 prefill plus source-0 forwards and
        # overflows a one-source-sized capacity.
        topo = line_topology(4)
        mc = MulticastTable(np.array([[False, False, True, True]]))
        n = 16
        spec = _mcast_spec(np.concatenate([np.zeros(n), np.ones(n)]),
                           np.zeros(2 * n), np.zeros(2 * n))
        rs = {e: _run(topo, spec, "in_fabric", mc, engine=e,
                      queues=QueuePolicy(capacity=capacity))
              for e in sorted(net.ENGINES)}
        ref = rs["reference"]
        assert int(ref.drops) > 0
        assert int(ref.delivered) + int(ref.drops) == ref.injected
        for e in sorted(net.ENGINES):
            assert_bit_exact(ref, rs[e], f"drops-cap{capacity}/{e}")

    @pytest.mark.parametrize("max_steps", [7, 19, 33])
    def test_binding_max_steps_exact(self, max_steps):
        """A binding step bound interacts with mid-flight replication:
        the chunked ring engine must still execute EXACTLY max_steps
        micro-transactions and match the reference scan."""
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(12), np.zeros(12), np.zeros(12))
        a = Fabric(topo, addr=ADDR, engine="reference",
                   mcast=MulticastPolicy("in_fabric", mc)).run(
                       spec, max_steps=max_steps)
        assert int(a.delivered) < a.injected  # the bound really binds
        for chunk in (16, 256):
            b = Fabric(topo, addr=ADDR,
                       engine=EngineSpec("ring", chunk_size=chunk),
                       mcast=MulticastPolicy("in_fabric", mc)).run(
                           spec, max_steps=max_steps)
            assert_bit_exact(a, b, f"ms{max_steps}/chunk{chunk}")


class TestModeEquivalence:
    """in_fabric and source_expand are the same *logical* multicast:
    identical destination multiset, strictly cheaper transport."""

    def test_fanout8_shared_path_ring(self):
        """The acceptance criterion: same (injection, destination)
        delivery multiset, strictly fewer link traversals on the
        fanout-8 shared-path ring — and exactly one traversal per tree
        edge (12 events x 13 edges) vs one per copy-hop (12 x 48)."""
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(12), np.arange(12) * 400, np.zeros(12))
        infab = _run(topo, spec, "in_fabric", mc)
        source = _run(topo, spec, "source_expand", mc)
        assert infab.injected == source.injected == 12 * 8
        assert int(infab.delivered) == int(source.delivered)
        assert _delivery_multiset(infab) == _delivery_multiset(source)
        assert infab.traversals < source.traversals
        rt = RoutingTable.build(topo)
        tree = MulticastTree.build(topo, rt, 0, np.arange(4, 12))
        assert infab.traversals == 12 * tree.n_edges
        assert source.traversals == 12 * int(rt.hops[0, 4:12].sum())
        assert infab.fanout == source.fanout == 8.0

    def test_multisource_multitag_equivalence(self):
        """Every (source, tag) pair gets its own tree; the delivery
        multiset still matches source expansion exactly."""
        topo = ring_topology(8)
        members = np.zeros((2, 8), bool)
        members[0, [1, 2, 3]] = True
        members[1, [2, 5, 6, 7]] = True
        mc = MulticastTable(members)
        rng = np.random.default_rng(3)
        n = 24
        spec = _mcast_spec(rng.integers(0, 8, n),
                           np.sort(rng.integers(0, 30_000, n)),
                           rng.integers(0, 2, n))
        infab = _run(topo, spec, "in_fabric", mc)
        source = _run(topo, spec, "source_expand", mc)
        assert int(infab.delivered) == infab.injected == source.injected
        assert _delivery_multiset(infab) == _delivery_multiset(source)
        assert infab.traversals <= source.traversals

    def test_source_expand_is_default_and_unchanged(self):
        """MulticastPolicy() defaults to source_expand and a bare
        MulticastTable still means source expansion — bit-exact with
        the explicit policy spelling."""
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(6), np.arange(6) * 500, np.zeros(6))
        legacy = Fabric(topo, addr=ADDR, mcast=mc).run(spec)
        explicit = _run(topo, spec, "source_expand", mc)
        assert_bit_exact(legacy, explicit, "legacy-table-vs-policy")
        assert MulticastPolicy().mode == "source_expand"

    def test_wrapper_accepts_policy(self):
        """simulate_fabric passes a MulticastPolicy straight through."""
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(4), np.arange(4) * 500, np.zeros(4))
        a = net.simulate_fabric(topo, spec, addr=ADDR,
                                mcast=MulticastPolicy("in_fabric", mc))
        b = _run(topo, spec, "in_fabric", mc)
        assert_bit_exact(a, b, "wrapper-policy")

    def test_modes_share_ring_shape_bucket(self):
        """The two modes of one workload land in the SAME ring-engine
        shape bucket (replication dims are bucketed), so an A/B sweep
        pays for one compilation."""
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(6), np.arange(6) * 500, np.zeros(6))
        f_se = Fabric(topo, addr=ADDR,
                      mcast=MulticastPolicy("source_expand", mc))
        f_if = Fabric(topo, addr=ADDR,
                      mcast=MulticastPolicy("in_fabric", mc))
        assert f_se._plan(spec, None).bucket == f_if._plan(spec, None).bucket


class TestReplicationTree:
    def test_tree_covers_members_once(self):
        """One in-edge per node (tree), every member delivered, subtree
        weights consistent with the member count."""
        topo = mesh2d_topology(4, 4)
        rt = RoutingTable.build(topo)
        members = np.array([0, 3, 10, 12, 15])
        tree = MulticastTree.build(topo, rt, 5, members)
        v = tree.edges[:, 3]
        assert len(np.unique(v)) == len(v)          # one in-edge per node
        assert tree.fanout == len(members)          # src not a member here
        assert bool(tree.deliver[members].all())
        # root subtree weights account for every delivery exactly once
        roots = tree.parent < 0
        assert int(tree.subtree[roots].sum()) == tree.fanout

    def test_tree_cheaper_than_paths(self):
        topo = ring_topology(16)
        rt = RoutingTable.build(topo)
        tree = MulticastTree.build(topo, rt, 0, np.arange(4, 12))
        assert tree.n_edges < int(rt.hops[0, 4:12].sum())

    def test_source_member_excluded(self):
        topo = ring_topology(4)
        rt = RoutingTable.build(topo)
        tree = MulticastTree.build(topo, rt, 0, np.array([0, 1, 2]))
        assert not tree.deliver[0]
        assert tree.fanout == 2

    def test_unreachable_member_raises(self):
        from repro.core.router import Topology
        topo = Topology(4, np.array([(0, 1), (2, 3)], np.int32))
        rt = RoutingTable.build(topo)
        with pytest.raises(ValueError, match="unreachable"):
            MulticastTree.build(topo, rt, 0, np.array([2]))

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError, match="multicast mode"):
            MulticastPolicy("broadcast")

    def test_missing_table_rejected(self):
        topo, _mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(2), np.zeros(2), np.zeros(2))
        with pytest.raises(ValueError, match="MulticastTable"):
            _run(topo, spec, "in_fabric", None)


class TestExpandStreamVectorized:
    """Satellite: the vectorized expand_stream must reproduce the
    historical per-event loop bit-for-bit (event order, then ascending
    member chips, source excluded)."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_matches_reference_loop(self, seed):
        rng = np.random.default_rng(seed)
        n_tags, n_chips, n_ev = 5, 12, 200
        mc = MulticastTable(rng.random((n_tags, n_chips)) < 0.4)
        src = rng.integers(0, n_chips, n_ev).astype(np.int32)
        t = np.sort(rng.integers(0, 50_000, n_ev)).astype(np.int32)
        tag = rng.integers(0, n_tags, n_ev).astype(np.int32)
        want_s, want_t, want_d = [], [], []
        for s_, t_, g_ in zip(src, t, tag):
            for d in mc.expand(int(g_), int(s_)):
                want_s.append(s_)
                want_t.append(t_)
                want_d.append(d)
        got = mc.expand_stream(src, t, tag)
        np.testing.assert_array_equal(got[0], np.asarray(want_s, np.int32))
        np.testing.assert_array_equal(got[1], np.asarray(want_t, np.int32))
        np.testing.assert_array_equal(got[2], np.asarray(want_d, np.int32))

    def test_empty_stream(self):
        mc = MulticastTable(np.ones((1, 4), bool))
        s, t, d = mc.expand_stream(np.zeros(0), np.zeros(0), np.zeros(0))
        assert s.size == t.size == d.size == 0


class TestMetrics:
    def test_fanout_and_traversals_reported(self):
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(6), np.arange(6) * 500, np.zeros(6))
        res = _run(topo, spec, "in_fabric", mc)
        st = net.latency_stats(res)
        assert st["offered"] == 6
        assert st["fanout"] == 8.0
        assert st["traversals"] == res.traversals > 0
        assert st["injected"] == 48

    def test_energy_counts_actual_traversals(self):
        """fabric_energy_pj bills per-link traversals: in_fabric pays
        for tree edges, source_expand for every copy-hop."""
        from repro.core.link import PAPER_TIMING
        topo, mc = _fanout8_ring()
        spec = _mcast_spec(np.zeros(6), np.arange(6) * 500, np.zeros(6))
        infab = _run(topo, spec, "in_fabric", mc)
        source = _run(topo, spec, "source_expand", mc)
        e_if = float(net.fabric_energy_pj(infab, PAPER_TIMING))
        e_se = float(net.fabric_energy_pj(source, PAPER_TIMING))
        assert e_if == pytest.approx(11.0 * infab.traversals)
        assert e_se == pytest.approx(11.0 * source.traversals)
        assert e_if < e_se
