"""Checkpointing (atomic/async/keep-N/elastic) + fault-tolerance driver
(bitwise-identical restart replay, straggler detection) + data determinism.
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.base import RunConfig, get_smoke_config
from repro.data import SyntheticLM
from repro.models.model import build_model
from repro.runtime.fault import (FailureInjector, SimulatedFailure,
                                 StragglerMonitor, run_with_restarts)
from repro.runtime.train_loop import init_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("granite_3_2b")
    run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=30)
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab, 16, 4, seed=2)
    step = make_train_step(model, run_cfg)

    class JaxData:
        def batch(self, s):
            return {k: jnp.asarray(v) for k, v in data.batch(s).items()}

    return cfg, run_cfg, model, JaxData(), step


class TestCheckpointer:
    def test_save_restore_roundtrip(self, setup):
        cfg, run_cfg, model, data, step = setup
        state = init_state(model, jax.random.PRNGKey(0), run_cfg)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(3, state, blocking=True)
            assert ck.latest_step() == 3
            restored = ck.restore(3, state)
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(restored.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save_and_gc_keeps_n(self, setup):
        cfg, run_cfg, model, data, step = setup
        state = init_state(model, jax.random.PRNGKey(1), run_cfg)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            for s in (1, 2, 3, 4):
                ck.save(s, state)
            ck.wait()
            ck._gc()
            assert ck.all_steps() == [3, 4]

    def test_atomic_no_partial_on_existing(self, setup):
        cfg, run_cfg, model, data, step = setup
        state = init_state(model, jax.random.PRNGKey(1), run_cfg)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, state, blocking=True)
            # a tmp dir left behind (simulated crash) is never listed
            os.makedirs(os.path.join(d, ".tmp_step_9_123"), exist_ok=True)
            assert ck.all_steps() == [1]

    def test_elastic_reshard_restore(self, setup):
        """Restore onto a different mesh: leaves re-device_put with new
        shardings (1-device container: degenerate meshes, same contract)."""
        cfg, run_cfg, model, data, step = setup
        state = init_state(model, jax.random.PRNGKey(0), run_cfg)
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, state, blocking=True)
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            from jax.sharding import NamedSharding, PartitionSpec as P
            sh = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), state)
            restored = ck.restore(1, state, shardings=sh)
            leaf = jax.tree.leaves(restored.params)[0]
            assert isinstance(leaf.sharding, NamedSharding)


class TestFaultTolerance:
    def test_restart_replay_is_bitwise_identical(self, setup):
        cfg, run_cfg, model, data, step = setup
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            s0 = init_state(model, jax.random.PRNGKey(0), run_cfg)
            clean, _ = run_with_restarts(
                n_steps=20, state=s0, train_step=step, data=data,
                ckpt=Checkpointer(d1), checkpoint_every=5)
            s0 = init_state(model, jax.random.PRNGKey(0), run_cfg)
            faulty, info = run_with_restarts(
                n_steps=20, state=s0, train_step=step, data=data,
                ckpt=Checkpointer(d2), checkpoint_every=5,
                injector=FailureInjector(frozenset({7, 13, 18})))
            assert info["restarts"] == 3
            for a, b in zip(jax.tree.leaves(clean.params),
                            jax.tree.leaves(faulty.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_failure_before_checkpoint_is_fatal(self, setup):
        cfg, run_cfg, model, data, step = setup
        s0 = init_state(model, jax.random.PRNGKey(0), run_cfg)
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(SimulatedFailure):
                run_with_restarts(
                    n_steps=10, state=s0, train_step=step, data=data,
                    ckpt=Checkpointer(d), checkpoint_every=0,  # never saves
                    injector=FailureInjector(frozenset({2})))

    def test_straggler_monitor_flags_outliers(self):
        mon = StragglerMonitor(threshold=2.0, alpha=0.5)
        for s in range(10):
            assert not mon.record(s, 1.0)
        assert mon.record(10, 5.0)          # 5x the EMA
        assert len(mon.events) == 1
        assert mon.ema == pytest.approx(1.0)  # outlier didn't poison EMA

    def test_max_restarts_bound(self, setup):
        cfg, run_cfg, model, data, step = setup
        s0 = init_state(model, jax.random.PRNGKey(0), run_cfg)

        class AlwaysFail:
            def check(self, step):
                raise SimulatedFailure("flaky node")

        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(SimulatedFailure):
                run_with_restarts(
                    n_steps=10, state=s0, train_step=step, data=data,
                    ckpt=Checkpointer(d), checkpoint_every=1,
                    injector=AlwaysFail(), max_restarts=3)


class TestDataPipeline:
    def test_batches_are_pure_functions_of_step(self):
        d1 = SyntheticLM(512, 16, 4, seed=9)
        d2 = SyntheticLM(512, 16, 4, seed=9)
        for s in (0, 5, 1000):
            a, b = d1.batch(s), d2.batch(s)
            np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_local_slices_partition_global_batch(self):
        d = SyntheticLM(512, 16, 8, seed=9)
        full = d.batch(3)
        parts = [d.local_slice(3, r, 4) for r in range(4)]
        np.testing.assert_array_equal(
            np.concatenate([p["tokens"] for p in parts]), full["tokens"])

    def test_prefetch_matches_sync(self):
        d = SyntheticLM(512, 16, 4, seed=9)
        got = list(d.prefetch(2, 3))
        assert [s for s, _ in got] == [2, 3, 4]
        np.testing.assert_array_equal(got[0][1]["tokens"],
                                      d.batch(2)["tokens"])

    def test_labels_are_learnable_structure(self):
        d = SyntheticLM(512, 64, 4, seed=0, structure=1.0)
        b = d.batch(0)
        # pure ramp: next token == current + stride (mod v)
        t = b["tokens"].astype(np.int64)
        strides = (t[:, 1:] - t[:, :-1]) % 512
        assert (strides == strides[:, :1]).all()
