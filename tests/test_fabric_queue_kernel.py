"""Pallas fabric_queue kernels vs. their pure-jnp oracles (ref.py).

The kernels run in interpret mode here (CPU container); integer outputs
must match the oracles bit-for-bit, including the sentinel conventions
(BIG_NS = empty slot, queue id >= Q = skip link) and the argmin tie rule
(lowest slot among equal release times)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import fabric_queue as fq
from repro.kernels import ops, ref

BIG = int(ref._QBIG)


def _random_queues(rng, nq, ncols, empty_frac=0.4, t_hi=60_000):
    q_time = rng.integers(0, 50_000, (nq, ncols)).astype(np.int32)
    q_time[rng.random((nq, ncols)) < empty_frac] = BIG
    q_dest = rng.integers(0, 9, (nq, ncols)).astype(np.int32)
    t_q = rng.integers(0, t_hi, (nq,)).astype(np.int32)
    return jnp.asarray(q_time), jnp.asarray(q_dest), jnp.asarray(t_q)


SCAN_OUTS = ("pend", "r_min", "nxt", "amin", "busy", "head_route")


class TestQueueScanKernel:
    @pytest.mark.parametrize("nq,ncols", [(8, 32), (16, 96), (32, 257),
                                          (2, 5)])
    def test_matches_oracle(self, nq, ncols):
        rng = np.random.default_rng(nq * 1000 + ncols)
        q_time, q_dest, t_q = _random_queues(rng, nq, ncols)
        want = ref.fabric_queue_scan(q_time, q_dest, t_q)
        got = ops.fabric_queue_scan(q_time, q_dest, t_q)
        for w, g, name in zip(want, got, SCAN_OUTS):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=name)

    def test_ties_resolve_to_lowest_slot(self):
        """FIFO among simultaneous arrivals: duplicate minima pick the
        first slot, exactly like jnp.argmin."""
        q_time = jnp.asarray([[50, 10, 10, BIG], [BIG, BIG, BIG, BIG],
                              [7, 7, 7, 7], [BIG, 3, BIG, 3]], jnp.int32)
        q_dest = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8],
                              [4, 3, 2, 1], [8, 7, 6, 5]], jnp.int32)
        t_q = jnp.asarray([100, 100, 100, 100], jnp.int32)
        want = ref.fabric_queue_scan(q_time, q_dest, t_q)
        got = ops.fabric_queue_scan(q_time, q_dest, t_q)
        np.testing.assert_array_equal(np.asarray(got[3]), [1, 0, 0, 1])
        # head_route rides the winning (tie-broken) slot
        np.testing.assert_array_equal(np.asarray(got[5]), [2, 5, 4, 7])
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))

    def test_empty_and_all_released_rows(self):
        q_time = jnp.asarray([[BIG] * 6, [1, 2, 3, 4, 5, 6]], jnp.int32)
        q_dest = jnp.asarray([[9, 8, 7, 6, 5, 4], [3, 1, 4, 1, 5, 9]],
                             jnp.int32)
        t_q = jnp.asarray([0, 10], jnp.int32)
        pend, r_min, nxt, amin, busy, head_route = [
            np.asarray(x) for x in
            ops.fabric_queue_scan(q_time, q_dest, t_q)]
        assert pend.tolist() == [0, 6]
        assert r_min.tolist() == [BIG, 1]
        assert nxt.tolist() == [BIG, BIG]
        assert amin.tolist() == [0, 0]
        assert busy.tolist() == [0, 1]  # the telemetry plane's indicator
        # empty rows resolve to slot 0: garbage-but-valid head route
        assert head_route.tolist() == [9, 3]


class TestQueueUpdateKernel:
    @pytest.mark.parametrize("nq,ncols,nlk", [(8, 32, 4), (16, 64, 16),
                                              (6, 17, 3)])
    def test_matches_oracle(self, nq, ncols, nlk):
        rng = np.random.default_rng(nq * 77 + nlk)
        q_time, q_dest, _ = _random_queues(rng, nq, ncols)
        q_inj = jnp.asarray(rng.integers(0, 50_000, (nq, ncols)),
                            jnp.int32)
        # unique pop rows, some sentinel-skipped; appends disjoint from
        # pops (the engine's contract: appends land beyond released slots)
        pop_q = np.array([r if r % 3 else nq
                          for r in rng.permutation(nq)[:nlk]], np.int32)
        pop_slot = rng.integers(0, ncols // 2, (nlk,)).astype(np.int32)
        app_q = np.array([r if r % 2 else nq
                          for r in rng.permutation(nq)[:nlk]], np.int32)
        app_slot = (ncols // 2
                    + rng.permutation(ncols - ncols // 2)[:nlk]).astype(
                        np.int32)
        app_t = rng.integers(0, 50_000, (nlk,)).astype(np.int32)
        app_d = rng.integers(0, 9, (nlk,)).astype(np.int32)
        app_i = rng.integers(0, 50_000, (nlk,)).astype(np.int32)
        args = [q_time, q_dest, q_inj] + [jnp.asarray(x) for x in
                (pop_q, pop_slot, app_q, app_slot, app_t, app_d, app_i)]
        want = ref.fabric_queue_update(*args)
        got = ops.fabric_queue_update(*args)
        for w, g, name in zip(want, got, ("q_time", "q_dest", "q_inj")):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=name)

    def test_sentinel_skips_and_big_values_exact(self):
        """Skipped links change nothing, and values near the BIG_NS
        sentinel survive the int32 matmul path exactly."""
        q_time = jnp.asarray([[BIG - 3, BIG, 5], [7, BIG - 1, BIG]],
                             jnp.int32)
        q_dest = jnp.zeros((2, 3), jnp.int32)
        q_inj = jnp.zeros((2, 3), jnp.int32)
        nq = 2
        pop_q = jnp.asarray([0, nq], jnp.int32)     # pop row 0 slot 2
        pop_slot = jnp.asarray([2, 0], jnp.int32)
        app_q = jnp.asarray([nq, 1], jnp.int32)     # append row 1 slot 2
        app_slot = jnp.asarray([0, 2], jnp.int32)
        app_t = jnp.asarray([0, BIG - 2], jnp.int32)
        app_d = jnp.asarray([0, 3], jnp.int32)
        app_i = jnp.asarray([0, BIG - 7], jnp.int32)
        args = (q_time, q_dest, q_inj, pop_q, pop_slot, app_q, app_slot,
                app_t, app_d, app_i)
        for impl in (ref.fabric_queue_update,
                     lambda *a: ops.fabric_queue_update(*a)):
            qt, qd, qi = [np.asarray(x) for x in impl(*args)]
            assert qt.tolist() == [[BIG - 3, BIG, BIG],
                                   [7, BIG - 1, BIG - 2]]
            assert qd[1, 2] == 3 and qi[1, 2] == BIG - 7

    @pytest.mark.parametrize("k", [2, 4])
    def test_multi_append_lanes(self, k):
        """In-fabric multicast replication: L·K append lanes against L
        pop lanes (masked multi-column scatter), unique (queue, slot)
        targets, oracle-exact."""
        rng = np.random.default_rng(k)
        nq, ncols, nlk = 8, 48, 4
        q_time, q_dest, _ = _random_queues(rng, nq, ncols)
        q_inj = jnp.asarray(rng.integers(0, 50_000, (nq, ncols)),
                            jnp.int32)
        pop_q = np.array([r if r % 3 else nq
                          for r in rng.permutation(nq)[:nlk]], np.int32)
        pop_slot = rng.integers(0, ncols // 2, (nlk,)).astype(np.int32)
        # La = nlk * k lanes; unique (queue, slot) targets in the upper
        # half of the slot range, some sentinel-dropped
        la = nlk * k
        app_q = rng.integers(0, nq, (la,)).astype(np.int32)
        app_q[rng.random(la) < 0.3] = nq          # dropped lanes
        app_slot = np.empty(la, np.int32)
        for q in range(nq + 1):                    # unique slots per queue
            idx = np.flatnonzero(app_q == q)
            app_slot[idx] = ncols // 2 + np.arange(len(idx))
        app_t = rng.integers(0, 50_000, (la,)).astype(np.int32)
        app_d = rng.integers(0, 9, (la,)).astype(np.int32)
        app_i = rng.integers(0, 50_000, (la,)).astype(np.int32)
        args = [q_time, q_dest, q_inj] + [jnp.asarray(x) for x in
                (pop_q, pop_slot, app_q, app_slot, app_t, app_d, app_i)]
        want = ref.fabric_queue_update(*args)
        got = ops.fabric_queue_update(*args)
        for w, g, name in zip(want, got, ("q_time", "q_dest", "q_inj")):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=name)

    def test_direct_kernel_entry_points(self):
        """The raw pallas wrappers (bypassing ops) agree too."""
        rng = np.random.default_rng(3)
        q_time, q_dest, t_q = _random_queues(rng, 8, 16)
        want = ref.fabric_queue_scan(q_time, q_dest, t_q)
        got = fq.fabric_queue_step_pallas(q_time, q_dest, t_q,
                                          rows_per_block=4,
                                          interpret=True)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
