"""Pallas fabric_queue kernels vs. their pure-jnp oracles (ref.py).

The kernels run in interpret mode here (CPU container); integer outputs
must match the oracles bit-for-bit, including the sentinel conventions
(BIG_NS = empty slot, queue id >= Q = skip link) and the argmin tie rule
(lowest slot among equal release times)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import fabric_queue as fq
from repro.kernels import ops, ref

BIG = int(ref._QBIG)


def _random_queues(rng, nq, ncols, empty_frac=0.4, t_hi=60_000):
    q_time = rng.integers(0, 50_000, (nq, ncols)).astype(np.int32)
    q_time[rng.random((nq, ncols)) < empty_frac] = BIG
    q_dest = rng.integers(0, 9, (nq, ncols)).astype(np.int32)
    t_q = rng.integers(0, t_hi, (nq,)).astype(np.int32)
    return jnp.asarray(q_time), jnp.asarray(q_dest), jnp.asarray(t_q)


SCAN_OUTS = ("pend", "r_min", "nxt", "amin", "busy", "head_route")


class TestQueueScanKernel:
    @pytest.mark.parametrize("nq,ncols", [(8, 32), (16, 96), (32, 257),
                                          (2, 5)])
    def test_matches_oracle(self, nq, ncols):
        rng = np.random.default_rng(nq * 1000 + ncols)
        q_time, q_dest, t_q = _random_queues(rng, nq, ncols)
        want = ref.fabric_queue_scan(q_time, q_dest, t_q)
        got = ops.fabric_queue_scan(q_time, q_dest, t_q)
        for w, g, name in zip(want, got, SCAN_OUTS):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=name)

    def test_ties_resolve_to_lowest_slot(self):
        """FIFO among simultaneous arrivals: duplicate minima pick the
        first slot, exactly like jnp.argmin."""
        q_time = jnp.asarray([[50, 10, 10, BIG], [BIG, BIG, BIG, BIG],
                              [7, 7, 7, 7], [BIG, 3, BIG, 3]], jnp.int32)
        q_dest = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8],
                              [4, 3, 2, 1], [8, 7, 6, 5]], jnp.int32)
        t_q = jnp.asarray([100, 100, 100, 100], jnp.int32)
        want = ref.fabric_queue_scan(q_time, q_dest, t_q)
        got = ops.fabric_queue_scan(q_time, q_dest, t_q)
        np.testing.assert_array_equal(np.asarray(got[3]), [1, 0, 0, 1])
        # head_route rides the winning (tie-broken) slot
        np.testing.assert_array_equal(np.asarray(got[5]), [2, 5, 4, 7])
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))

    def test_empty_and_all_released_rows(self):
        q_time = jnp.asarray([[BIG] * 6, [1, 2, 3, 4, 5, 6]], jnp.int32)
        q_dest = jnp.asarray([[9, 8, 7, 6, 5, 4], [3, 1, 4, 1, 5, 9]],
                             jnp.int32)
        t_q = jnp.asarray([0, 10], jnp.int32)
        pend, r_min, nxt, amin, busy, head_route = [
            np.asarray(x) for x in
            ops.fabric_queue_scan(q_time, q_dest, t_q)]
        assert pend.tolist() == [0, 6]
        assert r_min.tolist() == [BIG, 1]
        assert nxt.tolist() == [BIG, BIG]
        assert amin.tolist() == [0, 0]
        assert busy.tolist() == [0, 1]  # the telemetry plane's indicator
        # empty rows resolve to slot 0: garbage-but-valid head route
        assert head_route.tolist() == [9, 3]


class TestQueueUpdateKernel:
    @pytest.mark.parametrize("nq,ncols,nlk", [(8, 32, 4), (16, 64, 16),
                                              (6, 17, 3)])
    def test_matches_oracle(self, nq, ncols, nlk):
        rng = np.random.default_rng(nq * 77 + nlk)
        q_time, q_dest, _ = _random_queues(rng, nq, ncols)
        q_inj = jnp.asarray(rng.integers(0, 50_000, (nq, ncols)),
                            jnp.int32)
        # unique pop rows, some sentinel-skipped; appends disjoint from
        # pops (the engine's contract: appends land beyond released slots)
        pop_q = np.array([r if r % 3 else nq
                          for r in rng.permutation(nq)[:nlk]], np.int32)
        pop_slot = rng.integers(0, ncols // 2, (nlk,)).astype(np.int32)
        app_q = np.array([r if r % 2 else nq
                          for r in rng.permutation(nq)[:nlk]], np.int32)
        app_slot = (ncols // 2
                    + rng.permutation(ncols - ncols // 2)[:nlk]).astype(
                        np.int32)
        app_t = rng.integers(0, 50_000, (nlk,)).astype(np.int32)
        app_d = rng.integers(0, 9, (nlk,)).astype(np.int32)
        app_i = rng.integers(0, 50_000, (nlk,)).astype(np.int32)
        args = [q_time, q_dest, q_inj] + [jnp.asarray(x) for x in
                (pop_q, pop_slot, app_q, app_slot, app_t, app_d, app_i)]
        want = ref.fabric_queue_update(*args)
        got = ops.fabric_queue_update(*args)
        for w, g, name in zip(want, got, ("q_time", "q_dest", "q_inj")):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=name)

    def test_sentinel_skips_and_big_values_exact(self):
        """Skipped links change nothing, and values near the BIG_NS
        sentinel survive the int32 matmul path exactly."""
        q_time = jnp.asarray([[BIG - 3, BIG, 5], [7, BIG - 1, BIG]],
                             jnp.int32)
        q_dest = jnp.zeros((2, 3), jnp.int32)
        q_inj = jnp.zeros((2, 3), jnp.int32)
        nq = 2
        pop_q = jnp.asarray([0, nq], jnp.int32)     # pop row 0 slot 2
        pop_slot = jnp.asarray([2, 0], jnp.int32)
        app_q = jnp.asarray([nq, 1], jnp.int32)     # append row 1 slot 2
        app_slot = jnp.asarray([0, 2], jnp.int32)
        app_t = jnp.asarray([0, BIG - 2], jnp.int32)
        app_d = jnp.asarray([0, 3], jnp.int32)
        app_i = jnp.asarray([0, BIG - 7], jnp.int32)
        args = (q_time, q_dest, q_inj, pop_q, pop_slot, app_q, app_slot,
                app_t, app_d, app_i)
        for impl in (ref.fabric_queue_update,
                     lambda *a: ops.fabric_queue_update(*a)):
            qt, qd, qi = [np.asarray(x) for x in impl(*args)]
            assert qt.tolist() == [[BIG - 3, BIG, BIG],
                                   [7, BIG - 1, BIG - 2]]
            assert qd[1, 2] == 3 and qi[1, 2] == BIG - 7

    @pytest.mark.parametrize("k", [2, 4])
    def test_multi_append_lanes(self, k):
        """In-fabric multicast replication: L·K append lanes against L
        pop lanes (masked multi-column scatter), unique (queue, slot)
        targets, oracle-exact."""
        rng = np.random.default_rng(k)
        nq, ncols, nlk = 8, 48, 4
        q_time, q_dest, _ = _random_queues(rng, nq, ncols)
        q_inj = jnp.asarray(rng.integers(0, 50_000, (nq, ncols)),
                            jnp.int32)
        pop_q = np.array([r if r % 3 else nq
                          for r in rng.permutation(nq)[:nlk]], np.int32)
        pop_slot = rng.integers(0, ncols // 2, (nlk,)).astype(np.int32)
        # La = nlk * k lanes; unique (queue, slot) targets in the upper
        # half of the slot range, some sentinel-dropped
        la = nlk * k
        app_q = rng.integers(0, nq, (la,)).astype(np.int32)
        app_q[rng.random(la) < 0.3] = nq          # dropped lanes
        app_slot = np.empty(la, np.int32)
        for q in range(nq + 1):                    # unique slots per queue
            idx = np.flatnonzero(app_q == q)
            app_slot[idx] = ncols // 2 + np.arange(len(idx))
        app_t = rng.integers(0, 50_000, (la,)).astype(np.int32)
        app_d = rng.integers(0, 9, (la,)).astype(np.int32)
        app_i = rng.integers(0, 50_000, (la,)).astype(np.int32)
        args = [q_time, q_dest, q_inj] + [jnp.asarray(x) for x in
                (pop_q, pop_slot, app_q, app_slot, app_t, app_d, app_i)]
        want = ref.fabric_queue_update(*args)
        got = ops.fabric_queue_update(*args)
        for w, g, name in zip(want, got, ("q_time", "q_dest", "q_inj")):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=name)

    def test_direct_kernel_entry_points(self):
        """The raw pallas wrappers (bypassing ops) agree too."""
        rng = np.random.default_rng(3)
        q_time, q_dest, t_q = _random_queues(rng, 8, 16)
        want = ref.fabric_queue_scan(q_time, q_dest, t_q)
        got = fq.fabric_queue_step_pallas(q_time, q_dest, t_q,
                                          rows_per_block=4,
                                          interpret=True)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


# ---------------------------------------------------------------------------
# Multi-step fused kernel: chunked launches, VMEM-resident carry
# ---------------------------------------------------------------------------

import jax

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import (EngineSpec, Fabric, MulticastPolicy,
                               QueuePolicy)
from repro.core.router import AddressSpec, MulticastTable, ring_topology

EQ = net.assert_results_equal


def _hot(key, n_chips, epc):
    return tr.hot_spot(jax.random.PRNGKey(key), n_chips, epc,
                       mean_gap_ns=100.0, hot_frac=0.9)


def _ms(chunk):
    return EngineSpec(name="pallas", kernel="multistep", chunk_size=chunk)


class TestMultistepKernelLevel:
    """Direct kernel-level checks: ``fabric_queue_multistep_pallas`` vs
    the pure-jnp oracle ``ref.fabric_queue_multistep`` with the same
    injected step function (a pop-only queue drainer over
    scan_math/update_math for the pallas side, the jnp oracles for the
    ref side — the value-level math must make them indistinguishable)."""

    @staticmethod
    def _step_fns(nq):
        def mk(scan, update):
            def step(carry, consts, step_i):
                qt, qd, qi, cnt = carry
                (t_q,) = consts
                pend, _r, _n, amin, busy, _hr = scan(qt, qd, t_q + step_i)
                lidx = jnp.arange(nq, dtype=jnp.int32)
                pop_q = jnp.where(pend > 0, lidx, nq).astype(jnp.int32)
                skip = jnp.full((nq,), nq, jnp.int32)
                z = jnp.zeros((nq,), jnp.int32)
                qt2, qd2, qi2 = update(qt, qd, qi, pop_q, amin,
                                       skip, z, z, z, z)
                return (qt2, qd2, qi2, cnt + jnp.sum(busy))
            return step
        return (mk(fq.scan_math, fq.update_math),
                mk(ref.fabric_queue_scan, ref.fabric_queue_update))

    @pytest.mark.parametrize("chunk", [1, 4, 16])
    def test_matches_oracle(self, chunk):
        rng = np.random.default_rng(chunk)
        nq, ncols, max_steps = 8, 24, 10
        q_time, q_dest, t_q = _random_queues(rng, nq, ncols, t_hi=100)
        q_inj = jnp.asarray(rng.integers(0, 1000, (nq, ncols)), jnp.int32)
        carry = (q_time, q_dest, q_inj,
                 jnp.zeros((1,), jnp.int32))
        step_pal, step_ref = self._step_fns(nq)
        base = jnp.zeros((1,), jnp.int32)
        got = fq.fabric_queue_multistep_pallas(
            carry, (t_q,), base, step_fn=step_pal, chunk=chunk,
            max_steps=max_steps, interpret=True)
        want = ref.fabric_queue_multistep(
            carry, (t_q,), base, step_fn=step_ref, chunk=chunk,
            max_steps=max_steps)
        for w, g, name in zip(want, got,
                              ("q_time", "q_dest", "q_inj", "busy_acc")):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=name)

    def test_binding_max_steps_truncates_final_chunk(self):
        """chunk=4, max_steps=5: the second launch must run exactly ONE
        step (min(chunk, max_steps - base)) — post-bound steps are not
        no-ops, so over-running would corrupt the busy accumulator."""
        rng = np.random.default_rng(7)
        nq, ncols = 4, 8
        q_time, q_dest, t_q = _random_queues(rng, nq, ncols,
                                             empty_frac=0.0, t_hi=10)
        q_inj = jnp.zeros((nq, ncols), jnp.int32)
        step_pal, step_ref = self._step_fns(nq)
        carry = (q_time, q_dest, q_inj, jnp.zeros((1,), jnp.int32))

        def run_chunked(launch, step):
            c, b = carry, jnp.zeros((1,), jnp.int32)
            for _ in range(2):  # ceil(5 / 4) launches
                c = tuple(launch(c, (t_q,), b, step_fn=step, chunk=4,
                                 max_steps=5))
                b = b + 4
            return c

        got = run_chunked(
            lambda *a, **k: fq.fabric_queue_multistep_pallas(
                *a, interpret=True, **k), step_pal)
        # oracle of the same schedule AND a flat 5-step single chunk:
        # both must agree (chunking is an implementation detail)
        want = run_chunked(ref.fabric_queue_multistep, step_ref)
        flat = ref.fabric_queue_multistep(
            carry, (t_q,), jnp.zeros((1,), jnp.int32), step_fn=step_ref,
            chunk=5, max_steps=5)
        for w, f, g in zip(want, flat, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
            np.testing.assert_array_equal(np.asarray(f), np.asarray(g))


class TestMultistepEngine:
    """Full-engine matrix: ``kernel="multistep"`` vs the per-step pallas
    engine vs the reference oracle engine — bit-exact FabricResults."""

    @pytest.mark.parametrize("chunk", [1, 16, 64])
    def test_chunk_matrix_vs_step_and_reference(self, chunk):
        topo, spec = ring_topology(8), _hot(0, 8, 8)
        r_ref = Fabric(topo, engine="reference").run(spec)
        r_step = Fabric(topo, engine="pallas").run(spec)
        r_ms = Fabric(topo, engine=_ms(chunk)).run(spec)
        EQ(r_ref, r_step, "reference-vs-step")
        EQ(r_ref, r_ms, f"reference-vs-multistep(chunk={chunk})")

    @pytest.mark.parametrize("flow,cap,xon", [("drop", 12, None),
                                              ("credit", 6, None),
                                              ("onoff", 6, 3)])
    def test_flow_modes(self, flow, cap, xon):
        topo, spec = ring_topology(8), _hot(1, 8, 12)
        qp = QueuePolicy(capacity=cap, flow=flow, xon=xon)
        a = Fabric(topo, queues=qp, engine="reference").run(spec)
        b = Fabric(topo, queues=qp, engine=_ms(16)).run(spec)
        EQ(a, b, flow)
        assert int(b.delivered) + int(b.drops) == int(b.injected)
        if flow == "drop":
            assert int(b.drops) > 0  # the capacity binds in this workload

    def test_in_fabric_multicast(self):
        """K>1 append lanes (tree replication) through the fused loop."""
        addr = AddressSpec()
        topo = ring_topology(16)
        members = np.zeros((1, 16), bool)
        members[0, 4:12] = True
        spec = tr.TrafficSpec(
            src=jnp.zeros(6, jnp.int32),
            t=jnp.arange(6, dtype=jnp.int32) * 200,
            dest=jnp.asarray(addr.pack_multicast(np.zeros(6, np.int64))))
        kw = dict(addr=addr,
                  mcast=MulticastPolicy("in_fabric",
                                        MulticastTable(members)))
        a = Fabric(topo, engine="reference", **kw).run(spec)
        b = Fabric(topo, engine=_ms(16), **kw).run(spec)
        EQ(a, b, "in_fabric-multistep")
        assert int(b.delivered) == 6 * 8

    def test_binding_max_steps(self):
        topo, spec = ring_topology(8), _hot(2, 8, 8)
        for ms in (23, 64):
            a = Fabric(topo, engine="reference").run(spec, max_steps=ms)
            b = Fabric(topo, engine=_ms(16)).run(spec, max_steps=ms)
            EQ(a, b, f"max_steps={ms}")

    def test_hetero_timing(self):
        from repro.core.link import LinkTiming
        topo, spec = ring_topology(8), _hot(3, 8, 6)
        L = topo.n_links
        idx = np.arange(L)
        timing = LinkTiming(
            t_sw_ns=np.where(idx % 2, 5, 9),
            t_req2req_ns=np.where(idx % 2, 31, 61),
            t_bidir_ns=np.where(idx % 2, 35, 70))
        a = Fabric(topo, timing=timing, engine="reference").run(spec)
        b = Fabric(topo, timing=timing, engine=_ms(16)).run(spec)
        EQ(a, b, "hetero-timing")

    def test_kernel_knob_cache_flat(self):
        """Each kernel choice binds its OWN bucket, compiles ONCE, and
        repeated runs add zero jit entries; the chunk keys the bucket
        only under multistep."""
        topo, spec = ring_topology(8), _hot(4, 8, 6)
        fab_step = Fabric(topo, engine="pallas")
        fab_ms = Fabric(topo, engine=_ms(16))
        cf_step = fab_step.compile(spec)
        cf_ms = fab_ms.compile(spec)
        assert cf_step.bucket != cf_ms.bucket
        assert cf_step.bucket[-2:] == ("step", 0)
        assert cf_ms.bucket[-2:] == ("multistep", 16)
        for cf in (cf_step, cf_ms):
            n0 = cf.cache_size()
            cf.run(spec)
            cf.run(spec)
            assert cf.cache_size() == n0  # no-recompile contract
        EQ(cf_step.run(spec), cf_ms.run(spec), "step-vs-multistep")
