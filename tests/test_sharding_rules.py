"""Logical-axis rules: resolution, divisibility fallbacks, param specs."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (make_rules, param_specs, partition_params,
                                     shard_activation, use_rules)


def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestRules:
    def test_param_map_defaults(self):
        r = make_rules(mesh11(), kv_heads=8, d_head=128)
        assert r.param_map["ff"] == "model"
        assert r.param_map["embed"] == "data"       # FSDP on
        assert r.param_map["heads_kv"] == "model"   # 8*128 % 1 == 0

    def test_no_fsdp(self):
        r = make_rules(mesh11(), fsdp=False, kv_heads=8, d_head=128)
        assert r.param_map["embed"] is None

    def test_kv_fallback_to_seq_sharding(self):
        # tp=16 with 8 kv heads: activations replicate heads, shard cache seq
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        r = make_rules(mesh, kv_heads=8, d_head=128)
        tp = mesh.shape["model"]
        if 8 % tp == 0:
            assert r.act_map["kv_seq"] is None
        r2 = make_rules(mesh, kv_heads=3, d_head=100)  # never divisible
        # with tp=1 everything divides; simulate via direct dict check
        assert "kv_seq" in r2.act_map

    def test_seq_parallel_toggle(self):
        r = make_rules(mesh11(), seq_parallel=True, kv_heads=8, d_head=128)
        assert r.act_map["seq_sp"] == "model"
        r2 = make_rules(mesh11(), seq_parallel=False, kv_heads=8, d_head=128)
        assert r2.act_map["seq_sp"] is None

    def test_partition_params_maps_axes_tree(self):
        r = make_rules(mesh11(), kv_heads=8, d_head=128)
        axes = {"w": ("embed", "ff"), "b": ("none",), "g": ()}
        specs = param_specs(axes, r)
        assert specs["w"] == P("data", "model")
        assert specs["b"] == P(None)
        assert specs["g"] == P()

    def test_shard_activation_noop_without_rules(self):
        x = jnp.ones((4, 8))
        assert shard_activation(x, ("batch", None)) is x

    def test_shard_activation_rank_mismatch_raises(self):
        r = make_rules(mesh11(), kv_heads=8, d_head=128)
        with use_rules(r):
            with pytest.raises(ValueError):
                shard_activation(jnp.ones((4, 8)), ("batch",))

    def test_shard_activation_applies_constraint_under_jit(self):
        r = make_rules(mesh11(), kv_heads=8, d_head=128)

        @jax.jit
        def f(x):
            with use_rules(r):
                return shard_activation(x, ("batch", None)) * 2

        out = f(jnp.ones((4, 8)))
        assert out.shape == (4, 8)


class TestVocabPadding:
    def test_padded_vocab(self):
        from repro.models.layers import padded_vocab
        assert padded_vocab(49155) % 128 == 0
        assert padded_vocab(49152) == 49152
        assert padded_vocab(504) == 512

    def test_padded_logits_never_win(self):
        from repro.models.layers import mask_padded_vocab
        logits = jnp.zeros((2, 3, 512))
        masked = mask_padded_vocab(logits, 504)
        am = jnp.argmax(masked, -1)
        assert (am < 504).all()
