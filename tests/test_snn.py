"""SNN chip-array (paper-native application) tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.models import snn


def test_snn_runs_and_spikes():
    cfg = snn.SnnConfig(grid=(2, 2), neurons=128, input_rate=0.2)
    params, state = snn.init_snn(cfg, jax.random.PRNGKey(0))
    state2, ticks = jax.jit(
        lambda p, s: snn.run_snn(p, cfg, s, 20))(params, state)
    rate = float(np.asarray(ticks["rate"]).mean())
    assert 0.0 < rate < 1.0
    assert np.isfinite(np.asarray(state2.v)).all()


def test_link_report_consistency():
    cfg = snn.SnnConfig(grid=(2, 2), neurons=128, input_rate=0.2)
    params, state = snn.init_snn(cfg, jax.random.PRNGKey(0))
    _, ticks = jax.jit(lambda p, s: snn.run_snn(p, cfg, s, 10))(params, state)
    rep = snn.link_report(jax.tree.map(np.asarray, ticks))
    assert rep["events_total"] >= 0
    assert 0 <= rep["bus_busy_frac"]
    assert rep["dual_bus_wires_per_link"] == 2 * rep[
        "shared_bus_wires_per_link"]
    # energy = 11 pJ per event
    assert rep["energy_uj"] == (
        11.0 * rep["events_total"] * 1e-6) or rep["events_total"] == 0


def test_spikes_to_events_packs_active_units():
    spk = jnp.zeros(64).at[jnp.array([3, 17])].set(1.0)
    words, count = snn.spikes_to_events(spk, core_id=5)
    assert int(count) == 2
    core, neuron = ev.unpack_aer_address(words[:2])
    assert set(np.asarray(neuron)) == {3, 17}
    assert (np.asarray(core)[:2] == 5).all()


def test_membrane_resets_after_spike():
    cfg = snn.SnnConfig(grid=(1, 1), neurons=128, input_rate=0.0,
                        w_scale=0.0)
    params, state = snn.init_snn(cfg, jax.random.PRNGKey(0))
    state = state._replace(v=jnp.full_like(state.v, 2.0))  # above threshold
    state2, tick = snn.snn_step(params, cfg, state)
    assert float(tick["rate"]) == 1.0                      # all spiked
    assert np.allclose(np.asarray(state2.v), cfg.v_reset)  # all reset
