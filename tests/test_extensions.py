"""Beyond-paper extensions: sub-word serialization (paper §V), chunked
prefill, MoE dispatch invariants, mamba chunk invariance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.configs.base import MambaConfig, MoeConfig, ModelConfig
from repro.core import protocol_sim as ps
from repro.core.link import PAPER_TIMING


class TestSubwords:
    """Paper §V: 'combine proposed scheme with sub-words to further reduce
    I/O numbers and power consumption'."""

    def test_pins_shrink_by_factor(self):
        half = PAPER_TIMING.subword(2)
        assert half.word_bits == 13
        assert half.io_pins_saved(4) == 4 * 12

    def test_throughput_degrades_sublinearly(self):
        """2x fewer wires must cost LESS than 2x throughput (the argument
        for sub-words over full bit-serial)."""
        base = PAPER_TIMING.onedir_throughput_mev_s()
        half = PAPER_TIMING.subword(2).onedir_throughput_mev_s()
        assert half < base
        assert half > base / 2

    def test_simulator_runs_with_subword_timing(self):
        t = PAPER_TIMING.subword(2)
        res = ps.simulate(jnp.zeros(128, jnp.int32), jnp.zeros(0, jnp.int32),
                          initial_tx=1, timing=t)
        assert int(res.sent_l) == 128
        assert int(res.t_end) == 128 * t.t_req2req_ns

    def test_energy_per_event_unchanged(self):
        # same charge moves, over more beats on fewer wires
        assert PAPER_TIMING.subword(2).e_event_pj == PAPER_TIMING.e_event_pj


class TestChunkedPrefill:
    """flash_attention(q_offset=...) supports Sarathi-style chunked
    prefill: processing the prompt in pieces must equal one-shot prefill."""

    def test_two_chunk_prefill_equals_one_shot(self):
        from repro.models.layers import flash_attention
        B, S, K, G, dh = 1, 64, 2, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, K, G, dh))
        k = jax.random.normal(ks[1], (B, S, K, dh))
        v = jax.random.normal(ks[2], (B, S, K, dh))
        full = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
        # chunk 2: queries [32:64] against the whole kv prefix
        part1 = flash_attention(q[:, :32], k[:, :32], v[:, :32], causal=True,
                                q_chunk=16, kv_chunk=16)
        part2 = flash_attention(q[:, 32:], k, v, causal=True, q_offset=32,
                                q_chunk=16, kv_chunk=16)
        got = jnp.concatenate([part1, part2], axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=2e-5, atol=2e-5)


def _moe_cfg(E=8, K=2, cf=1.25):
    return ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_head=8, d_ff=64,
                       vocab=128, compute_dtype=jnp.float32,
                       moe=MoeConfig(num_experts=E, top_k=K,
                                     capacity_factor=cf))


class TestMoeDispatch:
    def test_no_drops_under_large_capacity_and_exact_combine(self):
        """With drop-free capacity the MoE equals the explicit per-token
        dense mixture."""
        from repro.models import moe
        cfg = _moe_cfg(E=4, K=2, cf=4.0)
        p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        out, aux = moe.moe_apply(p, cfg, x)
        assert float(aux["drop_frac"]) == 0.0

        # dense reference: route every token through its top-k experts
        logits = x @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        g, ch = jax.lax.top_k(probs, 2)
        g = g / g.sum(-1, keepdims=True)
        ref = jnp.zeros_like(x)
        for b in range(2):
            for s in range(16):
                acc = jnp.zeros((32,))
                for k in range(2):
                    e = int(ch[b, s, k])
                    h = x[b, s] @ p["wi"][e]
                    hg = jax.nn.silu(x[b, s] @ p["wg"][e])
                    acc += float(g[b, s, k]) * ((hg * h) @ p["wo"][e])
                ref = ref.at[b, s].set(acc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_capacity_drops_reported(self):
        from repro.models import moe
        cfg = _moe_cfg(E=8, K=2, cf=0.25)   # tiny capacity -> drops
        p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
        out, aux = moe.moe_apply(p, cfg, x)
        assert float(aux["drop_frac"]) > 0.0
        assert np.isfinite(np.asarray(out)).all()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), E=st.sampled_from([4, 8]),
           K=st.sampled_from([1, 2]))
    def test_property_gate_mass_conservation(self, seed, E, K):
        """Combined output norm never exceeds the max expert output norm
        (gates are a convex combination; drops only remove mass)."""
        from repro.models import moe
        cfg = _moe_cfg(E=E, K=K, cf=8.0)
        p, _ = moe.moe_init(jax.random.PRNGKey(seed), cfg)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 8, 32))
        out, aux = moe.moe_apply(p, cfg, x)
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux["aux_loss"]) >= 0.0


class TestMambaChunkInvariance:
    @settings(max_examples=8, deadline=None)
    @given(chunk=st.sampled_from([4, 8, 16, 64]), seed=st.integers(0, 100))
    def test_scan_chunk_size_does_not_change_results(self, chunk, seed):
        from repro.models.mamba import selective_scan
        B, S, d_in, N = 2, 64, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        x = jax.random.normal(ks[0], (B, S, d_in))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d_in)))
        Bs = jax.random.normal(ks[2], (B, S, N))
        Cs = jax.random.normal(ks[3], (B, S, N))
        A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (d_in, N)))
        y64, h64 = selective_scan(x, dt, Bs, Cs, A, 64)
        yc, hc = selective_scan(x, dt, Bs, Cs, A, chunk)
        np.testing.assert_allclose(np.asarray(yc), np.asarray(y64),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(hc), np.asarray(h64),
                                   rtol=1e-4, atol=1e-4)
