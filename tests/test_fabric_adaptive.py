"""Congestion control plane: telemetry counters + epoch-based adaptive
routing (core/telemetry.py + core/adaptive.py).

Contracts under test:

* telemetry counters are bit-exact across all three engines (they are
  part of ``assert_results_equal``) and internally consistent
  (``q_drops`` sums to ``drops``; ``busy_ns`` bounded by the clocks);
* weighted-BFS routing is deterministic, degenerates to the BFS tables
  bit-exactly under uniform costs, and detours around expensive links;
* epoch partitioning covers the workload exactly once, epoch 0 is
  bit-exact with static routing, and ``alpha = 0`` makes a whole
  adaptive run bit-exact with an epoched static run;
* on the benchmark hot-spot ring, adaptive routing strictly reduces
  drops AND p99 latency vs static routing of the identical workload —
  and the merged adaptive result is engine-independent, so the win
  holds on all three engines;
* all epochs of one run share ONE engine compilation
  (``cache_size() == 1`` on a dedicated engine instance).
"""

import jax
import numpy as np
import pytest

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.adaptive import (AdaptiveRouting, merge_results,
                                 partition_epochs)
from repro.core.fabric import (EngineSpec, Fabric, MulticastPolicy,
                               QueuePolicy)
from repro.core.router import (AddressSpec, MulticastTable, RoutingTable,
                               line_topology, mesh2d_topology,
                               ring_topology)
from repro.core.telemetry import Telemetry, link_load

assert_bit_exact = net.assert_results_equal

# the benchmark gate configuration (kept in sync with
# benchmarks/fabric_sweep.ADAPTIVE_RING by value; duplicated here so the
# tier-1 suite needs no path tricks to import the benchmarks package)
RING_CFG = dict(n_chips=16, key=3, epc=48, capacity=48,
                policy="min_backlog", epochs=4, alpha=4.0, ema=0.5)


def _ring_cfg_spec():
    return tr.hot_spot(jax.random.PRNGKey(RING_CFG["key"]),
                       RING_CFG["n_chips"], RING_CFG["epc"])


# -----------------------------------------------------------------------
# Telemetry plane
# -----------------------------------------------------------------------

class TestTelemetry:
    @pytest.mark.parametrize("pattern", sorted(tr.PATTERNS))
    def test_counters_bit_exact_across_engines(self, pattern):
        """assert_results_equal now covers the telemetry fields — run
        all three engines and compare them explicitly too."""
        spec = tr.PATTERNS[pattern](jax.random.PRNGKey(11), 4, 16)
        mb = 1 if pattern == "ping_pong" else 0
        res = {e: net.simulate_fabric(ring_topology(4), spec, engine=e,
                                      max_burst=mb, queue_capacity=24)
               for e in net.ENGINES}
        for e in ("reference", "pallas"):
            assert_bit_exact(res["ring"], res[e], f"telemetry/{pattern}")
            for f in Telemetry._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(res["ring"].telemetry, f)),
                    np.asarray(getattr(res[e].telemetry, f)),
                    err_msg=f"{pattern}/{e}/{f}")

    def test_counter_invariants(self):
        spec = tr.hot_spot(jax.random.PRNGKey(0), 4, 16)
        res = net.simulate_fabric(ring_topology(4), spec,
                                  queue_capacity=20)
        tel = res.telemetry
        assert tel is not None
        # per-queue drops are exactly the scalar drop counter, resolved
        assert int(np.asarray(tel.q_drops).sum()) == int(res.drops)
        assert int(res.drops) > 0  # the workload must exercise drops
        # a link can never be busy longer than its clock ran
        assert np.all(np.asarray(tel.busy_ns) <= np.asarray(res.t_link))
        assert np.all(np.asarray(tel.busy_ns) >= 0)
        assert np.all(np.asarray(tel.busy_steps) >= 0)
        # busy links transmitted; parked links did not
        sent = np.asarray(res.sent).sum(axis=1)
        assert np.all((np.asarray(tel.busy_ns) > 0) == (sent > 0))

    def test_subtree_weighted_drops_with_in_fabric_multicast(self):
        """q_drops carries the multicast subtree weights: the sum still
        equals the scalar drop counter under in-fabric replication."""
        addr = AddressSpec()
        members = np.zeros((1, 8), bool)
        members[0, 3:7] = True
        n = 24
        # tagged stream from chip 0 plus unicast cross-traffic from chip
        # 1 into the same clockwise path queues: the forwards overflow
        # mid-path, where a dropped multicast copy carries its subtree
        src = np.concatenate([np.zeros(n, np.int64), np.ones(12, np.int64)])
        t = np.concatenate([np.arange(n) * 40, 10 + np.arange(12) * 40])
        dest = np.concatenate([addr.pack_multicast(np.zeros(n, np.int64)),
                               addr.pack(np.full(12, 3, np.int64))])
        order = np.argsort(t, kind="stable")
        spec = tr.TrafficSpec(
            src=jax.numpy.asarray(src[order], jax.numpy.int32),
            t=jax.numpy.asarray(t[order], jax.numpy.int32),
            dest=jax.numpy.asarray(dest[order], jax.numpy.int32))
        fab = Fabric(ring_topology(8), addr=addr,
                     queues=QueuePolicy(capacity=24),
                     mcast=MulticastPolicy("in_fabric",
                                           MulticastTable(members)))
        res = fab.run(spec)
        assert int(res.drops) > 0
        assert int(np.asarray(res.telemetry.q_drops).sum()) == \
            int(res.drops)
        assert int(res.delivered) + int(res.drops) == res.injected

    def test_link_load_rollup(self):
        spec = tr.hot_spot(jax.random.PRNGKey(0), 4, 16)
        res = net.simulate_fabric(ring_topology(4), spec,
                                  queue_capacity=20)
        ll = link_load(res)
        np.testing.assert_array_equal(
            ll.traversals, np.asarray(res.sent).sum(axis=1))
        assert np.all(ll.occupancy >= 0) and np.all(ll.occupancy <= 1)
        assert int(ll.drops.sum()) == int(res.drops)
        # the human-readable table renders one row per link
        topo_links = np.asarray(ring_topology(4).links)
        assert len(ll.table(topo_links).splitlines()) == 5

    def test_link_load_requires_telemetry(self):
        spec = tr.poisson(jax.random.PRNGKey(0), 4, 8)
        res = net.simulate_fabric(ring_topology(4), spec)
        legacy = res._replace(telemetry=None)
        with pytest.raises(ValueError, match="telemetry"):
            link_load(legacy)


# -----------------------------------------------------------------------
# Weighted shortest-path tables
# -----------------------------------------------------------------------

class TestWeightedRouting:
    @pytest.mark.parametrize("topo", [ring_topology(8), ring_topology(2),
                                      mesh2d_topology(3, 4),
                                      line_topology(5)],
                             ids=lambda t: t.name)
    def test_uniform_cost_degenerates_to_bfs(self, topo):
        bfs = RoutingTable.build(topo)
        for scale in (1, 1024):
            w = RoutingTable.build_weighted(
                topo, np.full(topo.n_links, scale, np.int64))
            for f in ("next_link", "out_side", "hops"):
                np.testing.assert_array_equal(getattr(bfs, f),
                                              getattr(w, f),
                                              err_msg=f"{topo.name}/{f}")

    def test_deterministic(self):
        topo = mesh2d_topology(4, 4)
        rng = np.random.default_rng(7)
        cost = rng.integers(1, 2000, topo.n_links)
        a = RoutingTable.build_weighted(topo, cost)
        b = RoutingTable.build_weighted(topo, cost.copy())
        for f in ("next_link", "out_side", "hops"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))

    def test_detour_around_expensive_link(self):
        topo = ring_topology(6)
        cost = np.ones(6, np.int64)
        cost[0] = 100          # link 0 joins chips 0 and 1
        w = RoutingTable.build_weighted(topo, cost)
        assert w.hops[0, 1] == 5       # the long way round
        assert w.hops[1, 0] == 5
        # far pairs never crossed link 0 anyway: unchanged hop counts
        bfs = RoutingTable.build(topo)
        assert w.hops[3, 4] == bfs.hops[3, 4] == 1

    def test_hops_count_links_not_cost(self):
        topo = ring_topology(4)
        cost = np.asarray([3, 1, 1, 1], np.int64)
        w = RoutingTable.build_weighted(topo, cost)
        # 1 -> 0 pays 3 via link 0 (1 hop) or 3 via links 1,2,3 (3 hops);
        # the tie breaks to the lower predecessor chip... and hops must
        # report actual links traversed on the chosen route
        path_hops = w.hops[1, 0]
        assert path_hops in (1, 3)
        c = 1
        seen = 0
        while c != 0:
            l = int(w.next_link[c, 0])
            s = int(w.out_side[c, 0])
            c = int(topo.links[l][1 - s])
            seen += 1
            assert seen <= 4
        assert seen == path_hops

    def test_validation(self):
        topo = ring_topology(4)
        with pytest.raises(ValueError, match="shape"):
            RoutingTable.build_weighted(topo, np.ones(3, np.int64))
        with pytest.raises(ValueError, match=">= 1"):
            RoutingTable.build_weighted(topo, np.zeros(4, np.int64))
        with pytest.raises(ValueError, match="integers"):
            RoutingTable.build_weighted(topo, np.full(4, 1.5))


# -----------------------------------------------------------------------
# Epoch partitioning + merging
# -----------------------------------------------------------------------

class TestEpochs:
    def test_partition_covers_exactly_once(self):
        spec = tr.poisson(jax.random.PRNGKey(2), 6, 20)   # 120 events
        parts = partition_epochs(spec, 4)
        assert len(parts) == 4
        assert all(p.n_events == 30 for p in parts)       # divisible
        cat = sorted(
            (int(t), int(s), int(d))
            for p in parts
            for s, t, d in zip(np.asarray(p.src), np.asarray(p.t),
                               np.asarray(p.dest)))
        orig = sorted(
            (int(t), int(s), int(d))
            for s, t, d in zip(np.asarray(spec.src), np.asarray(spec.t),
                               np.asarray(spec.dest)))
        assert cat == orig
        # time-contiguous: epoch boundaries are nondecreasing in time
        maxes = [int(np.asarray(p.t).max()) for p in parts]
        mins = [int(np.asarray(p.t).min()) for p in parts]
        assert all(maxes[i] <= mins[i + 1] for i in range(3))

    def test_partition_more_epochs_than_events(self):
        spec = tr.TrafficSpec(src=jax.numpy.asarray([0, 1, 2]),
                              t=jax.numpy.asarray([5, 1, 9]),
                              dest=jax.numpy.asarray([1, 2, 0]))
        parts = partition_epochs(spec, 7)
        assert len(parts) == 3
        assert [int(np.asarray(p.t)[0]) for p in parts] == [1, 5, 9]

    def test_merged_accounting_and_telemetry(self):
        topo = ring_topology(8)
        spec = tr.hot_spot(jax.random.PRNGKey(1), 8, 24)
        fab = Fabric(topo, queues=QueuePolicy(capacity=32))
        merged = fab.run_epochs(spec, epochs=3)
        singles = [fab._run_single(p)
                   for p in partition_epochs(spec, 3)]
        assert int(merged.delivered) + int(merged.drops) == \
            merged.injected == sum(r.injected for r in singles)
        assert merged.offered == spec.n_events
        np.testing.assert_array_equal(
            np.asarray(merged.sent),
            sum(np.asarray(r.sent, np.int64) for r in singles))
        np.testing.assert_array_equal(
            np.asarray(merged.telemetry.busy_ns),
            sum(np.asarray(r.telemetry.busy_ns, np.int64)
                for r in singles))
        assert int(merged.t_end) == max(int(r.t_end) for r in singles)

    def test_merge_results_empty_raises(self):
        with pytest.raises(ValueError):
            merge_results([], offered=0)

    def test_epoch0_bit_exact_with_static(self):
        topo = ring_topology(16)
        spec = _ring_cfg_spec()
        queues = QueuePolicy(capacity=RING_CFG["capacity"])
        fab = Fabric(topo, routing=AdaptiveRouting(
            policy="min_backlog", epochs=4, alpha=4.0), queues=queues)
        fab.run(spec)
        part0 = partition_epochs(spec, 4)[0]
        static0 = Fabric(topo, queues=queues)._run_single(part0)
        assert_bit_exact(fab.last_report.records[0].result, static0,
                         "epoch0-vs-static")

    def test_alpha0_bit_exact_with_static_epochs(self):
        topo = ring_topology(16)
        spec = _ring_cfg_spec()
        queues = QueuePolicy(capacity=RING_CFG["capacity"])
        res_static = Fabric(topo, queues=queues).run_epochs(spec,
                                                            epochs=4)
        res_a0 = Fabric(topo, routing=AdaptiveRouting(epochs=4,
                                                      alpha=0.0),
                        queues=queues).run(spec)
        assert_bit_exact(res_static, res_a0, "alpha0-vs-static")


# -----------------------------------------------------------------------
# The headline claim + the zero-recompile contract
# -----------------------------------------------------------------------

class TestAdaptiveBeatsStatic:
    def test_hot_spot_ring_strictly_better(self):
        """The benchmark-gate workload: strictly fewer drops AND lower
        p99 than static routing of the identical workload (identical
        epoch partition — only the tables differ)."""
        topo = ring_topology(RING_CFG["n_chips"])
        spec = _ring_cfg_spec()
        queues = QueuePolicy(capacity=RING_CFG["capacity"])
        res_s = Fabric(topo, queues=queues).run_epochs(
            spec, epochs=RING_CFG["epochs"])
        fab = Fabric(topo, routing=AdaptiveRouting(
            policy=RING_CFG["policy"], epochs=RING_CFG["epochs"],
            alpha=RING_CFG["alpha"], ema=RING_CFG["ema"]), queues=queues)
        res_a = fab.run(spec)
        assert int(res_a.delivered) + int(res_a.drops) == res_a.injected
        assert int(res_a.drops) < int(res_s.drops)
        assert net.latency_stats(res_a)["p99_ns"] < \
            net.latency_stats(res_s)["p99_ns"]
        # the tables actually changed after epoch 0
        rec = fab.last_report.records
        assert any(
            not np.array_equal(rec[0].table.next_link,
                               r.table.next_link) for r in rec[1:])

    @pytest.mark.parametrize("engine", ["reference", "pallas"])
    def test_merged_adaptive_engine_independent(self, engine):
        """The merged adaptive result is bit-exact across engines (so
        the strict win above holds on all three).  Smaller fabric: the
        slot engines pay O(steps * C) per epoch."""
        topo = ring_topology(8)
        spec = tr.hot_spot(jax.random.PRNGKey(4), 8, 24)   # 192 events
        queues = QueuePolicy(capacity=24)
        routing = AdaptiveRouting(policy="min_backlog", epochs=4,
                                  alpha=4.0)
        base = Fabric(topo, routing=routing, queues=queues,
                      engine="ring").run(spec)
        other = Fabric(topo, routing=routing, queues=queues,
                       engine=engine).run(spec)
        assert_bit_exact(base, other, f"adaptive-merged/{engine}")

    def test_both_policies_run(self):
        topo = ring_topology(8)
        spec = tr.hot_spot(jax.random.PRNGKey(4), 8, 24)
        for pol in AdaptiveRouting.POLICIES:
            fab = Fabric(topo, routing=AdaptiveRouting(policy=pol,
                                                       epochs=2,
                                                       alpha=2.0),
                         queues=QueuePolicy(capacity=24))
            res = fab.run(spec)
            assert int(res.delivered) + int(res.drops) == res.injected
            assert fab.last_report.n_epochs == 2
            assert fab.last_report.records[1].load is not None


class TestZeroRecompile:
    def test_ring_engine_one_compilation_for_all_epochs(self):
        """A dedicated chunk size isolates the jit-cached engine, so the
        absolute count is meaningful: after a 4-epoch adaptive run (4
        different routing tables) the engine has exactly ONE entry."""
        topo = ring_topology(RING_CFG["n_chips"])
        spec = _ring_cfg_spec()
        fab = Fabric(topo, routing=AdaptiveRouting(
            policy="min_backlog", epochs=4, alpha=4.0),
            queues=QueuePolicy(capacity=RING_CFG["capacity"]),
            engine=EngineSpec(name="ring", chunk_size=96))
        fab.run(spec)
        report = fab.last_report
        assert not report.recompiled
        assert len(report.buckets) == 1
        assert report.cache_size == 1
        assert [r.cache_size for r in report.records] == [1, 1, 1, 1]

    def test_slot_engine_flat_cache_across_epochs(self):
        """Slot engines bake (E, C, max_steps) into the bucket; equal
        epoch slices + the shared step bound keep them on one bucket and
        a flat jit cache too."""
        topo = ring_topology(8)
        spec = tr.hot_spot(jax.random.PRNGKey(4), 8, 24)   # 192 % 4 == 0
        fab = Fabric(topo, routing=AdaptiveRouting(epochs=4, alpha=2.0),
                     queues=QueuePolicy(capacity=24), engine="reference")
        fab.run(spec)
        report = fab.last_report
        assert not report.recompiled
        assert len(report.buckets) == 1


class TestPolicyValidation:
    def test_rejects_bad_parameters(self):
        with pytest.raises(ValueError, match="policy"):
            AdaptiveRouting(policy="fastest")
        with pytest.raises(ValueError, match="epochs"):
            AdaptiveRouting(epochs=0)
        with pytest.raises(ValueError, match="alpha"):
            AdaptiveRouting(alpha=-1.0)
        with pytest.raises(ValueError, match="ema"):
            AdaptiveRouting(ema=0.0)
        with pytest.raises(ValueError, match="ema"):
            AdaptiveRouting(ema=1.5)

    def test_epochs_one_is_static(self):
        topo = ring_topology(8)
        spec = tr.hot_spot(jax.random.PRNGKey(4), 8, 24)
        queues = QueuePolicy(capacity=24)
        res_a = Fabric(topo, routing=AdaptiveRouting(epochs=1,
                                                     alpha=8.0),
                       queues=queues).run(spec)
        res_s = Fabric(topo, queues=queues).run_epochs(spec, epochs=1)
        assert_bit_exact(res_a, res_s, "one-epoch")


class TestAdaptiveMulticast:
    def test_trees_rebuilt_per_epoch_lossless_multiset(self):
        """In-fabric multicast under adaptive routing: the Steiner trees
        regrow on each epoch's tables, and with lossless queues the
        delivery multiset matches the static epoched run exactly."""
        addr = AddressSpec()
        members = np.zeros((1, 8), bool)
        members[0, 2:7] = True
        mc = MulticastTable(members)
        rng = np.random.default_rng(9)
        n = 64
        src = np.zeros(n, np.int32)
        t = np.sort(rng.integers(0, 40_000, n)).astype(np.int32)
        spec = tr.TrafficSpec(
            src=jax.numpy.asarray(src), t=jax.numpy.asarray(t),
            dest=jax.numpy.asarray(
                addr.pack_multicast(np.zeros(n, np.int64))))
        topo = ring_topology(8)
        kw = dict(addr=addr, mcast=MulticastPolicy("in_fabric", mc))
        res_a = Fabric(topo, routing=AdaptiveRouting(
            policy="weighted_bfs", epochs=4, alpha=2.0), **kw).run(spec)
        res_s = Fabric(topo, **kw).run_epochs(spec, epochs=4)
        assert int(res_a.delivered) == res_a.injected == 5 * n
        assert net.delivery_multiset(res_a) == net.delivery_multiset(res_s)
