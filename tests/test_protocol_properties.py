"""Property-based tests (hypothesis) for the transceiver protocol invariants.

System invariants checked over randomized arrival processes and parameters:

  P1  bus safety      — never both blocks in TX mode on any trace step;
  P2  conservation    — every arrived event is delivered exactly once;
  P3  liveness        — all events deliver within a finite horizon;
  P4  monotonic clock — simulated time never decreases;
  P5  guarded switch  — a direction reversal implies the new transmitter
                        had pending events (switches are event-driven, the
                        paper's central claim);
  P6  throughput band — delivered rate under saturation lies between the
                        bidirectional worst case and the one-direction best
                        case from Table II.
"""

import jax.numpy as jnp
import numpy as np
from tests._hypothesis_compat import given, settings, st

from repro.core import protocol_sim as ps
from repro.core.link import PAPER_TIMING

arrivals = st.lists(st.integers(min_value=0, max_value=30_000),
                    min_size=0, max_size=60)


def _sim(al, ar, initial_tx, max_burst):
    al = jnp.array(sorted(al), jnp.int32)
    ar = jnp.array(sorted(ar), jnp.int32)
    return ps.simulate(al, ar, initial_tx=initial_tx, max_burst=max_burst), al, ar


@settings(max_examples=40, deadline=None)
@given(al=arrivals, ar=arrivals, initial_tx=st.integers(0, 1),
       max_burst=st.sampled_from([0, 1, 3, 16]))
def test_safety_no_double_tx(al, ar, initial_tx, max_burst):
    res, *_ = _sim(al, ar, initial_tx, max_burst)
    ml = np.array(res.trace.mode_l)
    mr = np.array(res.trace.mode_r)
    assert not np.logical_and(ml == 1, mr == 1).any()  # P1


@settings(max_examples=40, deadline=None)
@given(al=arrivals, ar=arrivals, initial_tx=st.integers(0, 1),
       max_burst=st.sampled_from([0, 1, 3, 16]))
def test_conservation_and_liveness(al, ar, initial_tx, max_burst):
    res, a_l, a_r = _sim(al, ar, initial_tx, max_burst)
    assert int(res.sent_l) == a_l.shape[0]  # P2+P3
    assert int(res.sent_r) == a_r.shape[0]


@settings(max_examples=40, deadline=None)
@given(al=arrivals, ar=arrivals, initial_tx=st.integers(0, 1),
       max_burst=st.sampled_from([0, 2]))
def test_monotonic_time(al, ar, initial_tx, max_burst):
    res, *_ = _sim(al, ar, initial_tx, max_burst)
    t = np.array(res.trace.t)
    assert (np.diff(t) >= 0).all()  # P4


@settings(max_examples=40, deadline=None)
@given(al=arrivals, ar=arrivals, initial_tx=st.integers(0, 1))
def test_switches_are_event_driven(al, ar, initial_tx):
    """P5: after any mode reversal, the next transmission exists and comes
    from the block that just took TX (switching is on-demand, not periodic)."""
    res, a_l, a_r = _sim(al, ar, initial_tx, 0)
    act = np.array(res.trace.action)
    ml = np.array(res.trace.mode_l)
    # every L-RX->TX reversal is eventually followed by an L transmission
    took_tx = np.where(np.diff(ml) == 1)[0]
    for i in took_tx:
        assert (act[i + 1:] == ps.A_TX_L).any() or a_l.shape[0] == int(
            res.sent_l)  # either it transmits later, or all L events done


@settings(max_examples=10, deadline=None)
@given(n=st.integers(32, 200), max_burst=st.sampled_from([1, 2, 8, 0]))
def test_saturated_throughput_band(n, max_burst):
    res, *_ = _sim([0] * n, [0] * n, 1, max_burst)
    thr = float(ps.throughput_mev_s(res))
    lo = PAPER_TIMING.bidir_throughput_mev_s() - 0.2
    hi = PAPER_TIMING.onedir_throughput_mev_s() + 0.2
    assert lo <= thr <= hi  # P6
