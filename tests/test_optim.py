"""AdamW + schedule unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def tree(val=1.0):
    return {"a": jnp.full((4,), val, jnp.float32),
            "b": {"w": jnp.full((2, 3), val, jnp.float32)}}


class TestAdamW:
    def test_first_step_matches_closed_form(self):
        """With bias correction, step 1 update = lr * g/(|g| + eps) + wd."""
        params = tree(1.0)
        grads = tree(0.5)
        state = adamw.init(params)
        lr, wd = 0.1, 0.0
        new, state, gnorm = adamw.update(grads, state, params, lr=lr,
                                         weight_decay=wd, grad_clip=0.0)
        # mhat = g, vhat = g^2  ->  delta = g/(|g|+eps) = sign(g)
        for leaf in jax.tree.leaves(new):
            np.testing.assert_allclose(np.asarray(leaf), 1.0 - lr,
                                       rtol=1e-5)

    def test_weight_decay_pulls_to_zero(self):
        params = tree(1.0)
        grads = tree(0.0)
        state = adamw.init(params)
        new, _, _ = adamw.update(grads, state, params, lr=0.1,
                                 weight_decay=0.5, grad_clip=0.0)
        for leaf in jax.tree.leaves(new):
            assert np.all(np.asarray(leaf) < 1.0)

    def test_grad_clip_bounds_global_norm(self):
        grads = tree(100.0)
        clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
        assert float(norm) > 1.0
        assert float(adamw.global_norm(clipped)) == pytest.approx(1.0,
                                                                  rel=1e-5)

    def test_moments_are_fp32_regardless_of_param_dtype(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw.init(params)
        assert state.mu["w"].dtype == jnp.float32
        new, state, _ = adamw.update({"w": jnp.ones((4,), jnp.bfloat16)},
                                     state, params, lr=0.1)
        assert new["w"].dtype == jnp.bfloat16    # params keep their dtype
        assert state.nu["w"].dtype == jnp.float32

    def test_step_counter_increments(self):
        params = tree()
        state = adamw.init(params)
        _, state, _ = adamw.update(tree(0.1), state, params, lr=0.1)
        _, state, _ = adamw.update(tree(0.1), state, params, lr=0.1)
        assert int(state.step) == 2


class TestSchedule:
    def test_warmup_then_cosine(self):
        lr0 = float(adamw.warmup_cosine(jnp.int32(0), base_lr=1.0,
                                        warmup_steps=10, total_steps=100))
        lr5 = float(adamw.warmup_cosine(jnp.int32(5), base_lr=1.0,
                                        warmup_steps=10, total_steps=100))
        lr10 = float(adamw.warmup_cosine(jnp.int32(10), base_lr=1.0,
                                         warmup_steps=10, total_steps=100))
        lr100 = float(adamw.warmup_cosine(jnp.int32(100), base_lr=1.0,
                                          warmup_steps=10, total_steps=100))
        assert lr0 == 0.0
        assert lr5 == pytest.approx(0.5)
        assert lr10 == pytest.approx(1.0)
        assert lr100 == pytest.approx(0.1)   # final_frac
        assert lr0 <= lr5 <= lr10
