"""Clock-overflow margin of the fabric engines (the BIG_NS sentinel).

Empty queue slots hold ``BIG_NS`` = 2**30 ("never released").  If a
link-local clock could reach it, empty slots would look released and the
simulation would corrupt silently.  ``simulate_fabric`` therefore
refuses traffic whose worst-case end time
``max(t) + total_hops * worst_cost`` reaches the sentinel — and below
that guard, every release-time and ``horizon + t_cycle`` comparison must
stay exact however close the clocks get.  The property here is
time-shift invariance: shifting all injections by a constant shifts
every clock and changes no latency, switch count or ordering, right up
to the admissible limit."""

import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.link import PAPER_TIMING
from repro.core.protocol_sim import BIG_NS
from repro.core.router import line_topology, ring_topology

BIG = int(BIG_NS)
WORST_COST = (PAPER_TIMING.t_req2req_ns
              + max(PAPER_TIMING.t_reverse_penalty_ns,
                    PAPER_TIMING.t_idle_switch_ns))


def _spec(src, t, dest):
    return tr.TrafficSpec(src=jnp.asarray(src, jnp.int32),
                          t=jnp.asarray(t, jnp.int32),
                          dest=jnp.asarray(dest, jnp.int32))


class TestOverflowGuard:
    def test_guard_raises_at_sentinel(self):
        spec = _spec([0], [BIG - 10], [1])
        with pytest.raises(ValueError, match="overflow"):
            net.simulate_fabric(line_topology(2), spec)

    def test_guard_scales_with_workload(self):
        """Many hops push the worst-case bound over even for earlier
        injections."""
        n = 2048
        t0 = BIG - n * WORST_COST  # bound == BIG exactly -> refused
        spec = _spec([0] * n, [t0] * n, [1] * n)
        with pytest.raises(ValueError, match="overflow"):
            net.simulate_fabric(line_topology(2), spec)

    def test_tightest_admissible_time_simulates_exactly(self):
        """One event at the largest time the guard admits: delivered with
        the exact single-hop latency, clocks just below the sentinel."""
        t0 = BIG - WORST_COST - 1
        spec = _spec([0], [t0], [1])
        res = net.simulate_fabric(line_topology(2), spec)
        assert int(res.delivered) == 1
        assert net.delivered_latencies(res).tolist() == [
            PAPER_TIMING.t_req2req_ns]
        assert int(res.t_end) == t0 + PAPER_TIMING.t_req2req_ns
        assert int(res.t_end) < BIG

    @pytest.mark.parametrize("engine", ["reference", "ring"])
    def test_near_sentinel_multihop_both_engines(self, engine):
        """Forward release times and the horizon + t_cycle lookahead stay
        correct when every clock sits just under the sentinel."""
        n = 8
        base = BIG - 40 * WORST_COST
        t = base + 31 * np.arange(n)
        spec = _spec([0] * n, t, [2] * n)
        res = net.simulate_fabric(line_topology(3), spec, engine=engine)
        assert int(res.delivered) == n
        lat = net.delivered_latencies(res)
        assert (lat >= 2 * PAPER_TIMING.t_req2req_ns).all()
        assert int(res.t_end) < BIG


@settings(max_examples=15, deadline=None)
@given(t=st.lists(st.integers(0, 20_000), min_size=1, max_size=24),
       seed=st.integers(0, 2 ** 16))
def test_time_shift_invariance_near_sentinel(t, seed):
    """P: latencies, switch counts, transmissions and drops are invariant
    under shifting all injections close to the admissible limit."""
    rng = np.random.default_rng(seed)
    n = len(t)
    src = rng.integers(0, 3, n).astype(np.int32)
    dest = (src + 1 + rng.integers(0, 2, n).astype(np.int32)) % 3
    t = np.sort(np.asarray(t, np.int64))
    # per-source nondecreasing times (generator contract)
    topo = ring_topology(3)
    lo = net.simulate_fabric(topo, _spec(src, t, dest))
    shift = BIG - int(t.max()) - (3 * n + 4) * WORST_COST
    hi = net.simulate_fabric(topo, _spec(src, t + shift, dest))
    assert int(hi.delivered) == int(lo.delivered) == n
    np.testing.assert_array_equal(net.delivered_latencies(hi),
                                  net.delivered_latencies(lo))
    np.testing.assert_array_equal(np.asarray(hi.sent), np.asarray(lo.sent))
    np.testing.assert_array_equal(np.asarray(hi.n_switches),
                                  np.asarray(lo.n_switches))
    assert int(hi.t_end) == int(lo.t_end) + shift
    assert int(hi.t_end) < BIG
