"""Cross-engine regression net for the fabric event-transport engines.

``simulate_fabric`` ships three engines — ``reference`` (PR 1 flat slot
scan, the semantics oracle), ``ring`` (O(1)-per-step streams, the default
hot path) and ``pallas`` (slot scan through the fused fabric_queue
kernels).  Every configuration must produce an identical ``FabricResult``
on every engine: same departures, switch counts, ``t_end``, drops and
delivery log ordering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network as net
from repro.core import protocol_sim as ps
from repro.core import traffic as tr
from repro.core.router import (Topology, line_topology, mesh2d_topology,
                               ring_topology)

# the engines' shared bit-exactness contract (one field list for tests
# and the CI bench smoke alike)
assert_bit_exact = net.assert_results_equal


class TestRingVsReference:
    """The hot path must be indistinguishable from the slot-scan oracle
    across topologies, traffic patterns and fairness settings."""

    @pytest.mark.parametrize("pattern", sorted(tr.PATTERNS))
    def test_ring4_all_patterns(self, pattern):
        spec = tr.PATTERNS[pattern](jax.random.PRNGKey(13), 4, 24)
        mb = 1 if pattern == "ping_pong" else 0
        a = net.simulate_fabric(ring_topology(4), spec,
                                engine="reference", max_burst=mb)
        b = net.simulate_fabric(ring_topology(4), spec,
                                engine="ring", max_burst=mb)
        assert int(a.delivered) == a.injected
        assert_bit_exact(a, b, f"ring4/{pattern}")

    @pytest.mark.parametrize("topo_fn,max_burst", [
        (lambda: line_topology(4), 0),
        (lambda: mesh2d_topology(2, 3), 4),
        (lambda: ring_topology(6), 1),
    ])
    def test_topologies(self, topo_fn, max_burst):
        topo = topo_fn()
        spec = tr.poisson(jax.random.PRNGKey(5), topo.n_chips, 20)
        a = net.simulate_fabric(topo, spec, engine="reference",
                                max_burst=max_burst)
        b = net.simulate_fabric(topo, spec, engine="ring",
                                max_burst=max_burst)
        assert_bit_exact(a, b, topo.name)

    @pytest.mark.parametrize("initial_tx", [0, 1])
    def test_two_chip_degenerates_to_paper_link(self, initial_tx):
        """The 2-chip fabric on the ring engine still reproduces
        ``protocol_sim.simulate`` departures / switches / t_end."""
        rng = np.random.default_rng(21)
        arr_l = np.sort(rng.integers(0, 30_000, 40)).astype(np.int32)
        arr_r = np.sort(rng.integers(0, 30_000, 30)).astype(np.int32)
        ref = ps.simulate(jnp.asarray(arr_l), jnp.asarray(arr_r),
                          initial_tx=initial_tx)
        spec = tr.TrafficSpec(
            src=jnp.concatenate([jnp.zeros(40, jnp.int32),
                                 jnp.ones(30, jnp.int32)]),
            t=jnp.concatenate([jnp.asarray(arr_l), jnp.asarray(arr_r)]),
            dest=jnp.concatenate([jnp.ones(40, jnp.int32),
                                  jnp.zeros(30, jnp.int32)]))
        res = net.simulate_fabric(line_topology(2), spec, engine="ring",
                                  initial_tx=initial_tx)
        assert int(res.delivered) == 70
        assert int(res.t_end) == int(ref.t_end)
        assert np.asarray(res.sent).tolist() == [
            [int(ref.sent_l), int(ref.sent_r)]]
        assert int(res.n_switches[0]) == int(ref.n_switches)

    def test_chunk_size_invariance(self):
        """Early-exit chunking must not be observable on completed sims."""
        spec = tr.poisson(jax.random.PRNGKey(3), 4, 24)
        a = net.simulate_fabric(ring_topology(4), spec, chunk_size=16)
        b = net.simulate_fabric(ring_topology(4), spec, chunk_size=256)
        assert_bit_exact(a, b, "chunk16-vs-256")

    @pytest.mark.parametrize("max_steps", [5, 17, 130])
    def test_binding_max_steps_is_exact(self, max_steps):
        """Regression for the PR 2 wart: when the step bound binds
        mid-chunk, the ring engine must execute EXACTLY ``max_steps``
        micro-transactions — not up to ``chunk_size - 1`` extra — and so
        match a reference scan of the same length bit-for-bit."""
        spec = tr.poisson(jax.random.PRNGKey(3), 4, 24)
        a = net.simulate_fabric(ring_topology(4), spec,
                                engine="reference", max_steps=max_steps)
        assert int(a.delivered) < a.injected  # the bound really binds
        for chunk in (16, 64, 256):
            b = net.simulate_fabric(ring_topology(4), spec, engine="ring",
                                    max_steps=max_steps, chunk_size=chunk)
            assert_bit_exact(a, b, f"max_steps={max_steps}/chunk={chunk}")

    # Per-link timing heterogeneity is covered by
    # tests/test_fabric_api.py::TestPerLinkTiming (cross-engine
    # bit-exactness, uniform-array ≡ scalar, bursts/drops composition).

    def test_unknown_engine_rejected(self):
        spec = tr.poisson(jax.random.PRNGKey(0), 2, 4)
        with pytest.raises(ValueError, match="unknown engine"):
            net.simulate_fabric(ring_topology(2), spec, engine="warp")

    def test_nonpositive_chunk_size_rejected(self):
        """chunk_size <= 0 would make the early-exit loop spin forever —
        it must raise instead."""
        spec = tr.poisson(jax.random.PRNGKey(0), 2, 4)
        with pytest.raises(ValueError, match="chunk_size"):
            net.simulate_fabric(ring_topology(2), spec, chunk_size=0)

    @pytest.mark.parametrize("engine", sorted(net.ENGINES))
    def test_unreachable_destination_rejected(self, engine):
        """A disconnected fabric raises the clean setup error on every
        engine (the ring engine walks routes for its stream quotas and
        must validate first)."""
        topo = Topology(4, np.array([(0, 1), (2, 3)], np.int32))
        spec = tr.TrafficSpec(src=jnp.zeros(1, jnp.int32),
                              t=jnp.zeros(1, jnp.int32),
                              dest=jnp.full((1,), 2, jnp.int32))
        with pytest.raises(ValueError, match="unreachable"):
            net.simulate_fabric(topo, spec, engine=engine)


class TestPallasEngine:
    """The fused-kernel slot engine (interpret mode off-TPU) is the same
    simulation as the reference engine, step for step."""

    def test_ring4_poisson(self):
        spec = tr.poisson(jax.random.PRNGKey(7), 4, 12)
        a = net.simulate_fabric(ring_topology(4), spec, engine="reference")
        b = net.simulate_fabric(ring_topology(4), spec, engine="pallas")
        assert int(a.delivered) == a.injected
        assert_bit_exact(a, b, "pallas/ring4")

    def test_multihop_with_bursts(self):
        spec = tr.poisson(jax.random.PRNGKey(8), 3, 10)
        a = net.simulate_fabric(line_topology(3), spec,
                                engine="reference", max_burst=2)
        b = net.simulate_fabric(line_topology(3), spec,
                                engine="pallas", max_burst=2)
        assert_bit_exact(a, b, "pallas/line3")


def _convergecast(n):
    """Chips 0 and 1 flood chip 3 through relay chip 2: the (2,3) queue
    sees 2x its drain rate, and links 0 and 1 deliver simultaneous
    forwards into the SAME queue on the same micro-step."""
    topo = Topology(4, np.array([(0, 2), (1, 2), (2, 3)], np.int32))
    spec = tr.TrafficSpec(
        src=jnp.concatenate([jnp.zeros(n, jnp.int32),
                             jnp.ones(n, jnp.int32)]),
        t=jnp.zeros(2 * n, jnp.int32),
        dest=jnp.full((2 * n,), 3, jnp.int32))
    return topo, spec


class TestDropPathRegression:
    """Capacity-limited queues must behave identically on both engines:
    same ``drops``, same delivered set, same delivery order — including
    the simultaneous-forwards-into-one-queue insertion-ordering case."""

    @pytest.mark.parametrize("capacity", [64, 80, 100])
    def test_drops_identical(self, capacity):
        topo, spec = _convergecast(64)
        a = net.simulate_fabric(topo, spec, queue_capacity=capacity,
                                engine="reference")
        b = net.simulate_fabric(topo, spec, queue_capacity=capacity,
                                engine="ring")
        assert int(a.drops) > 0
        assert int(a.delivered) + int(a.drops) == 2 * 64
        assert_bit_exact(a, b, f"drop/cap{capacity}")

    def test_simultaneous_forwards_ordering_lossless(self):
        """With room for everything, the insertion order of simultaneous
        forwards (by link index) is visible in the delivery log — the
        engines must agree entry for entry."""
        topo, spec = _convergecast(32)
        a = net.simulate_fabric(topo, spec, engine="reference")
        b = net.simulate_fabric(topo, spec, engine="ring")
        assert int(a.drops) == 0
        assert int(a.delivered) == a.injected
        assert_bit_exact(a, b, "simultaneous-forwards")

    def test_delivered_set_matches_under_drops(self):
        """Not just the count: the surviving events (by injection time
        multiset) are the same under both engines."""
        topo, spec = _convergecast(48)
        a = net.simulate_fabric(topo, spec, queue_capacity=48,
                                engine="reference")
        b = net.simulate_fabric(topo, spec, queue_capacity=48,
                                engine="ring")
        n = int(a.delivered)
        assert n == int(b.delivered)
        np.testing.assert_array_equal(
            np.sort(np.asarray(a.log_inj)[:n]),
            np.sort(np.asarray(b.log_inj)[:n]))
        assert int(a.drops) == int(b.drops)
