"""Elastic scaling end-to-end: train on a 4-way DP mesh, checkpoint, then
RESUME ON A 2-WAY MESH (half the fleet lost) and keep training — loss
continuity and exact state carry-over (subprocess, 4 host devices)."""

import pytest

from tests._subproc import run_with_devices

CODE = r"""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from repro.checkpoint import Checkpointer
from repro.configs.base import RunConfig, get_smoke_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.parallel.sharding import make_rules
from repro.runtime.train_loop import init_state, make_train_step

cfg = get_smoke_config("granite_3_2b")
run_cfg = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=16,
                    dp_reduce="bidir_ring", fsdp=False)
model = build_model(cfg)
data = SyntheticLM(cfg.vocab, 16, 8, seed=11)

def make_step(dp):
    mesh = make_host_mesh(data=dp, model=1)
    rules = make_rules(mesh, fsdp=False, kv_heads=cfg.n_kv_heads,
                       d_head=cfg.d_head)
    return make_train_step(model, run_cfg, rules)

with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d)
    # phase 1: 4-way DP
    state = init_state(model, jax.random.PRNGKey(0), run_cfg)
    step4 = make_step(4)
    losses = []
    for s in range(8):
        b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        state, m = step4(state, b)
        losses.append(float(m["loss"]))
    ck.save(8, state, blocking=True)

    # phase 2: two nodes die -> resume on 2-way DP from the checkpoint
    fresh = init_state(model, jax.random.PRNGKey(99), run_cfg)  # new fleet
    restored = ck.restore(8, fresh)
    assert int(restored.step) == 8
    # restored params identical to the saved ones
    for a, b2 in zip(jax.tree.leaves(state.params),
                     jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b2))
    step2 = make_step(2)
    for s in range(8, 16):
        b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        restored, m = step2(restored, b)
        losses.append(float(m["loss"]))
    assert int(restored.step) == 16
    assert np.isfinite(losses).all()
    # training continued sensibly (no blow-up across the mesh change)
    assert losses[-1] < losses[0] + 0.5, losses
print("ELASTIC-OK", losses[7], losses[-1])
"""


@pytest.mark.slow
@pytest.mark.multidevice
def test_elastic_resume_on_smaller_mesh():
    out = run_with_devices(CODE, 4, timeout=1800)
    assert "ELASTIC-OK" in out
