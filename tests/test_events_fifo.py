"""Unit tests for AE word packing and the functional FIFO."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import events
from repro.core.fifo import (Fifo, fifo_empty, fifo_full, fifo_peek, fifo_pop,
                             fifo_push, make_fifo)


class TestAerAddress:
    def test_roundtrip(self):
        core = jnp.array([0, 3, 512], dtype=jnp.uint32)
        neuron = jnp.array([0, 65535, 1234], dtype=jnp.uint32)
        word = events.pack_aer_address(core, neuron)
        c2, n2 = events.unpack_aer_address(word)
        np.testing.assert_array_equal(np.array(c2), np.array(core))
        np.testing.assert_array_equal(np.array(n2), np.array(neuron))

    def test_word_fits_26_bits(self):
        word = events.pack_aer_address(jnp.uint32(1023), jnp.uint32(65535))
        assert int(word) < (1 << 26)


class TestPayloadEvents:
    def test_roundtrip_exact_for_bf16_values(self):
        idx = jnp.arange(16, dtype=jnp.int32)
        val = jnp.array([0.0, 1.0, -2.5, 0.15625] * 4, dtype=jnp.float32)
        words = events.pack_events(idx, val)
        i2, v2 = events.unpack_events(words)
        np.testing.assert_array_equal(np.array(i2), np.array(idx))
        np.testing.assert_array_equal(np.array(v2), np.array(val))

    def test_quantisation_error_bound(self):
        rng = np.random.default_rng(0)
        val = jnp.array(rng.standard_normal(1024), dtype=jnp.float32)
        idx = jnp.arange(1024) % events.EVENT_MAX_BLOCK
        _, v2 = events.unpack_events(events.pack_events(idx, val))
        rel = np.abs(np.array(v2) - np.array(val)) / (np.abs(np.array(val)) + 1e-30)
        assert rel.max() <= events.roundtrip_error_bound()

    def test_index_wraps_at_16_bits(self):
        words = events.pack_events(jnp.int32(65537), jnp.float32(1.0))
        i2, _ = events.unpack_events(words)
        assert int(i2) == 1


class TestFifo:
    def test_push_pop_order(self):
        f = make_fifo(4)
        for v in [10, 20, 30]:
            f, ok = fifo_push(f, jnp.uint32(v))
            assert bool(ok)
        out = []
        for _ in range(3):
            f, v, ok = fifo_pop(f)
            assert bool(ok)
            out.append(int(v))
        assert out == [10, 20, 30]
        assert bool(fifo_empty(f))

    def test_overflow_reported_and_dropped(self):
        f = make_fifo(2)
        f, _ = fifo_push(f, jnp.uint32(1))
        f, _ = fifo_push(f, jnp.uint32(2))
        assert bool(fifo_full(f))
        f, ok = fifo_push(f, jnp.uint32(3))
        assert not bool(ok)
        f, v, _ = fifo_pop(f)
        assert int(v) == 1  # oldest survives, overflow dropped

    def test_pop_empty_reports(self):
        f = make_fifo(2)
        f, _, ok = fifo_pop(f)
        assert not bool(ok)

    def test_wraparound(self):
        f = make_fifo(2)
        seq = [1, 2, 3, 4, 5]
        got = []
        for v in seq:
            f, _ = fifo_push(f, jnp.uint32(v))
            f, out, ok = fifo_pop(f)
            got.append(int(out))
        assert got == seq

    def test_peek_nondestructive(self):
        f = make_fifo(3)
        f, _ = fifo_push(f, jnp.uint32(42))
        v, ne = fifo_peek(f)
        assert int(v) == 42 and bool(ne)
        assert int(f.count) == 1
