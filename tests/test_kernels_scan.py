"""Pallas fused selective scan vs oracle + vs the production chunked path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.selective_scan import selective_scan_pallas
from repro.models.mamba import selective_scan as chunked_scan


def make_inputs(B, S, d_in, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, d_in), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d_in)) - 1.0)
    b = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    c = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[4], (d_in, N)) * 0.5)
    return x, dt, b, c, a


SHAPES = [(1, 32, 16, 4), (2, 64, 32, 8), (2, 48, 8, 16), (1, 16, 128, 4)]


@pytest.mark.parametrize("B,S,d_in,N", SHAPES)
@pytest.mark.parametrize("d_block", [8, 16])
def test_pallas_scan_matches_oracle(B, S, d_in, N, d_block):
    x, dt, b, c, a = make_inputs(B, S, d_in, N, seed=B * S)
    y_k, h_k = selective_scan_pallas(x, dt, b, c, a, d_block=d_block,
                                     interpret=True)
    y_r, h_r = ref.selective_scan_ref(x, dt, b, c, a)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-5, atol=1e-5)


def test_pallas_scan_matches_production_chunked_path():
    x, dt, b, c, a = make_inputs(2, 64, 16, 4, seed=3)
    y_k, h_k = selective_scan_pallas(x, dt, b, c, a, d_block=16,
                                     interpret=True)
    y_c, h_c = chunked_scan(x, dt, b, c, a, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_c),
                               rtol=1e-4, atol=1e-4)


def test_state_carries_information_across_time():
    """An impulse at t=0 must echo in y_t for t>0 through the state."""
    B, S, d_in, N = 1, 8, 4, 2
    x = jnp.zeros((B, S, d_in)).at[0, 0].set(1.0)
    dt = jnp.full((B, S, d_in), 0.5)
    b = jnp.ones((B, S, N))
    c = jnp.ones((B, S, N))
    a = -jnp.ones((d_in, N)) * 0.1
    y, h = selective_scan_pallas(x, dt, b, c, a, d_block=4, interpret=True)
    y = np.asarray(y)
    assert abs(y[0, 3]).max() > 0         # impulse propagated
    assert abs(y[0, 7]).max() < abs(y[0, 1]).max()  # and decays
