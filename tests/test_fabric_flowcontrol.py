"""Credit-based flow control: lossless transport, bit-exact engines.

``QueuePolicy.flow`` selects what a full downstream queue does to an
arriving event: ``"drop"`` (the paper's lossy default) discards it,
``"credit"`` stalls the upstream pop in place until the queue returns a
credit, ``"onoff"`` stalls once occupancy reaches capacity and resumes
only when it drains to the ``xon`` threshold.  The contracts under test:

- every mode keeps ``delivered + drops == injected`` exact, and the
  lossless modes keep ``drops == 0`` under arbitrary overload;
- the three engines agree bit-for-bit in every mode, telemetry included
  (stalling changes WHEN pops happen, so any divergence in the
  head-of-line gating shows up immediately);
- ``onoff`` with ``xon = capacity - 1`` IS credit flow control;
- a never-binding capacity makes all three modes identical — flow
  control must cost nothing when it never engages;
- flow mode / capacity / xon travel as dynamic operands: switching
  modes must not grow the engine's shape-bucket or jit cache.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.adaptive import AdaptiveRouting
from repro.core.fabric import FLOW_MODES, Fabric, MulticastPolicy, QueuePolicy
from repro.core.router import (AddressSpec, MulticastTable, line_topology,
                               ring_topology)

EQ = net.assert_results_equal


def _hot(key, n_chips, epc, gap=100.0, hf=0.9):
    return tr.hot_spot(jax.random.PRNGKey(key), n_chips, epc,
                       mean_gap_ns=gap, hot_frac=hf)


def _run(topo, spec, flow, capacity, engine="ring", xon=None, **kw):
    return Fabric(topo, queues=QueuePolicy(capacity=capacity, flow=flow,
                                           xon=xon),
                  engine=engine, **kw).run(spec)


class TestPolicyValidation:
    def test_flow_modes_constant_matches_engine_encoding(self):
        assert FLOW_MODES == ("drop", "credit", "onoff")

    def test_unknown_flow_mode(self):
        with pytest.raises(ValueError, match="flow"):
            QueuePolicy(capacity=4, flow="xonxoff")

    def test_lossless_flow_requires_capacity(self):
        with pytest.raises(ValueError, match="capacity"):
            QueuePolicy(flow="credit")

    def test_xon_only_with_onoff(self):
        with pytest.raises(ValueError, match="xon"):
            QueuePolicy(capacity=4, flow="credit", xon=2)

    @pytest.mark.parametrize("xon", [-1, 4, 7])
    def test_xon_range(self, xon):
        with pytest.raises(ValueError, match="xon"):
            QueuePolicy(capacity=4, flow="onoff", xon=xon)


class TestLosslessContract:
    def test_destination_drain_returns_credits(self):
        """2-chip line, capacity far below the traffic volume: the
        delivery queue keeps draining, credits keep returning, and every
        event lands despite the tiny budget."""
        n = 24
        spec = tr.TrafficSpec(src=jnp.zeros(n, jnp.int32),
                              t=jnp.arange(n, dtype=jnp.int32) * 30,
                              dest=jnp.ones(n, jnp.int32))
        res = _run(line_topology(2), spec, "credit", capacity=4)
        assert int(res.delivered) == n and int(res.drops) == 0

    @pytest.mark.parametrize("flow", ["credit", "onoff"])
    def test_overload_is_lossless_and_stalls(self, flow):
        """Saturating hot-spot with a binding capacity: zero drops, and
        the backpressure telemetry proves the cap actually bound."""
        res = _run(ring_topology(8), _hot(0, 8, 12), flow, capacity=4)
        assert int(res.delivered) == res.injected
        assert int(res.drops) == 0
        assert int(np.asarray(res.telemetry.stall_steps).sum()) > 0

    def test_conservation_every_mode(self):
        spec = _hot(1, 8, 12)
        for flow in FLOW_MODES:
            res = _run(ring_topology(8), spec, flow, capacity=12)
            assert (int(res.delivered) + int(res.drops)
                    == res.injected), flow

    def test_drop_mode_matches_legacy_and_never_stalls(self):
        """flow="drop" with a binding capacity is the pre-flow-control
        fabric bit-for-bit, with zeroed stall counters."""
        topo, spec = ring_topology(8), _hot(2, 8, 12)
        legacy = Fabric(topo, queues=QueuePolicy(capacity=12)).run(spec)
        res = _run(topo, spec, "drop", capacity=12)
        EQ(legacy, res, "drop-vs-legacy")
        assert int(res.drops) > 0  # the capacity binds in this workload
        assert not np.asarray(res.telemetry.stall_steps).any()
        assert not np.asarray(res.telemetry.credit_waits).any()


class TestCrossEngine:
    @pytest.mark.parametrize("flow", ["credit", "onoff"])
    @pytest.mark.parametrize("pattern", ["hot", "bursty"])
    def test_ring_vs_reference(self, flow, pattern):
        spec = (_hot(3, 8, 12) if pattern == "hot" else
                tr.bursty(jax.random.PRNGKey(3), 8, 3))
        a = _run(ring_topology(8), spec, flow, capacity=5)
        b = _run(ring_topology(8), spec, flow, capacity=5,
                 engine="reference")
        EQ(a, b, f"{flow}/{pattern}")

    @pytest.mark.parametrize("flow", ["credit", "onoff"])
    def test_pallas_engine(self, flow):
        spec = _hot(4, 4, 8)
        a = _run(ring_topology(4), spec, flow, capacity=4)
        b = _run(ring_topology(4), spec, flow, capacity=4,
                 engine="pallas")
        EQ(a, b, f"{flow}/pallas")


class TestModeEquivalences:
    def test_onoff_at_cap_minus_one_is_credit(self):
        """xon = capacity - 1 resumes on every returned credit — the
        on/off policy degenerates to credit flow control exactly."""
        topo, spec = ring_topology(8), _hot(5, 8, 12)
        EQ(_run(topo, spec, "credit", capacity=5),
           _run(topo, spec, "onoff", capacity=5, xon=4),
           "onoff(xon=cap-1)-vs-credit")

    def test_never_binding_capacity_makes_modes_identical(self):
        """With capacity above any occupancy the fabric reaches, flow
        control never engages and all three modes are the same run."""
        topo, spec = ring_topology(8), _hot(6, 8, 12)
        runs = [_run(topo, spec, flow, capacity=512)
                for flow in FLOW_MODES]
        for flow, res in zip(FLOW_MODES[1:], runs[1:]):
            EQ(runs[0], res, f"unbounded/{flow}")
            assert not np.asarray(res.telemetry.stall_steps).any()
        assert int(runs[0].drops) == 0


class TestMulticastInteraction:
    def test_in_fabric_multicast_lossless_multiset(self):
        """Credit flow control composes with in-fabric replication: the
        tagged workload delivers the identical destination multiset as
        source expansion, with zero drops despite a binding capacity."""
        topo = ring_topology(8)
        addr = AddressSpec()
        mc = MulticastTable(np.ones((1, 8), bool))
        n = 24
        spec = tr.TrafficSpec(
            src=jnp.asarray(np.arange(n) % 8, jnp.int32),
            t=jnp.asarray(np.arange(n) * 300, jnp.int32),
            dest=jnp.asarray(addr.pack_multicast(np.zeros(n, np.int64))))

        def run(mode, engine="ring"):
            return Fabric(topo, addr=addr, engine=engine,
                          queues=QueuePolicy(capacity=16, flow="credit"),
                          mcast=MulticastPolicy(mode, mc)).run(spec)

        infab, source = run("in_fabric"), run("source_expand")
        assert int(infab.drops) == 0 and int(source.drops) == 0
        assert (net.delivery_multiset(infab)
                == net.delivery_multiset(source))
        EQ(infab, run("in_fabric", engine="reference"),
           "mcast/credit ring-vs-ref")


class TestCompileNeutrality:
    def test_flow_modes_share_one_bucket_and_jit_entry(self):
        topo, spec = ring_topology(8), _hot(7, 8, 12)
        fab = Fabric(topo, queues=QueuePolicy(capacity=12), engine="ring")
        cf = fab.compile(spec)
        fab.run(spec)
        size0 = cf.cache_size()
        for flow in ("credit", "onoff"):
            other = Fabric(topo, queues=QueuePolicy(capacity=12,
                                                    flow=flow),
                           engine="ring")
            assert other.compile(spec, warm=False).bucket == cf.bucket
            other.run(spec)
        assert cf.cache_size() == size0


class TestEventDrivenAdaptation:
    def _cfg(self, **kw):
        base = dict(policy="min_backlog", epochs=3, alpha=4.0, ema=0.5)
        base.update(kw)
        return AdaptiveRouting(**base)

    def test_trigger_validation(self):
        with pytest.raises(ValueError, match="trigger"):
            self._cfg(trigger="load_spike")
        with pytest.raises(ValueError, match="threshold"):
            self._cfg(trigger="backlog_burst", threshold=-1.0)

    def test_huge_threshold_never_rebuilds(self):
        """An unreachable burst threshold keeps the static tables for
        every epoch — the run IS the static epoched run, and the
        per-epoch report says why (rebuilt=False throughout)."""
        topo, spec = ring_topology(8), _hot(8, 8, 24)
        queues = QueuePolicy(capacity=24)
        fab = Fabric(topo, routing=self._cfg(trigger="backlog_burst",
                                             threshold=1e9),
                     queues=queues)
        res = fab.run(spec)
        assert [r.rebuilt for r in fab.last_report.records[:-1]] == \
            [False, False]
        static = Fabric(topo, queues=queues)
        EQ(res, static.run_epochs(spec, epochs=3), "never-rebuild")

    def test_zero_threshold_is_every_epoch(self):
        """threshold=0 fires on any nonzero congestion signal: on a
        congested workload it reproduces the unconditional per-epoch
        rebuild bit-for-bit."""
        topo, spec = ring_topology(8), _hot(9, 8, 24)
        queues = QueuePolicy(capacity=24)
        burst = Fabric(topo, routing=self._cfg(trigger="backlog_burst",
                                               threshold=0.0),
                       queues=queues)
        res_b = burst.run(spec)
        every = Fabric(topo, routing=self._cfg(), queues=queues)
        EQ(res_b, every.run(spec), "zero-threshold-vs-epoch")
        assert all(r.rebuilt for r in burst.last_report.records[:-1])
        # the last epoch has no successor to rebuild for
        assert burst.last_report.records[-1].rebuilt is False


class TestTimeShiftInvariance:
    @given(dt=st.integers(min_value=0, max_value=10**6))
    @settings(max_examples=20, deadline=None)
    def test_credit_latencies_shift_invariant(self, dt):
        """Shifting every injection by a constant shifts every stall and
        delivery by the same constant: latencies, drops and stall
        telemetry are unchanged."""
        topo = ring_topology(4)
        spec = _hot(10, 4, 8)
        shifted = tr.TrafficSpec(src=spec.src, t=spec.t + jnp.int32(dt),
                                 dest=spec.dest)
        a = _run(topo, spec, "credit", capacity=4)
        b = _run(topo, shifted, "credit", capacity=4)
        np.testing.assert_array_equal(
            np.asarray(net.delivered_latencies(a)),
            np.asarray(net.delivered_latencies(b)))
        assert int(a.drops) == int(b.drops) == 0
        np.testing.assert_array_equal(
            np.asarray(a.telemetry.stall_steps),
            np.asarray(b.telemetry.stall_steps))
