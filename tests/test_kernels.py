"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle across
shape/dtype sweeps + hypothesis property tests on the compression invariants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.aer_decode import aer_decode_pallas
from repro.kernels.aer_encode import aer_encode_pallas
from repro.kernels.lif_step import lif_step_pallas


def rand(shape, dtype, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


ENC_SHAPES = [
    (4, 256, 32), (8, 1024, 128), (16, 512, 64), (4, 2048, 256),
    (2, 128, 128),   # budget == block
    (12, 384, 48),   # non-128-aligned block (interpret; TPU would pad)
]


class TestAerEncode:
    @pytest.mark.parametrize("nb,block,budget", ENC_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, nb, block, budget, dtype):
        x = rand((nb, block), dtype, seed=nb * block)
        tau = ops.tau_from_fraction(x, 0.05)
        rpb = 4 if nb % 4 == 0 else (2 if nb % 2 == 0 else 1)
        idx_k, val_k, cnt_k, want_k = aer_encode_pallas(
            x, tau, budget, rows_per_block=rpb, interpret=True)
        idx_r, val_r, cnt_r, want_r = ref.aer_encode(x, tau, budget)
        np.testing.assert_array_equal(np.array(idx_k), np.array(idx_r))
        np.testing.assert_allclose(np.array(val_k, np.float32),
                                   np.array(val_r, np.float32),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.array(cnt_k), np.array(cnt_r))
        np.testing.assert_array_equal(np.array(want_k), np.array(want_r))

    def test_budget_overflow_keeps_first_in_index_order(self):
        x = jnp.ones((1, 64), jnp.float32)
        idx, val, cnt, want = ref.aer_encode(x, jnp.array([0.5]), 8)
        assert int(cnt[0]) == 8 and int(want[0]) == 64
        np.testing.assert_array_equal(np.array(idx[0]), np.arange(8))

    def test_void_slots_are_minus_one(self):
        x = jnp.zeros((1, 128), jnp.float32).at[0, 5].set(3.0)
        idx, val, cnt, _ = ref.aer_encode(x, jnp.array([1.0]), 16)
        assert int(cnt[0]) == 1
        assert int(idx[0, 0]) == 5
        assert (np.array(idx[0, 1:]) == -1).all()

    def test_zero_threshold_selects_everything_up_to_budget(self):
        x = rand((2, 256), jnp.float32, 3) + 10.0
        idx, val, cnt, want = ref.aer_encode(x, jnp.zeros(2), 64)
        assert (np.array(cnt) == 64).all() and (np.array(want) == 256).all()


class TestAerDecode:
    @pytest.mark.parametrize("nb,block,budget", ENC_SHAPES)
    def test_roundtrip_reconstructs_selected(self, nb, block, budget):
        x = rand((nb, block), jnp.float32, seed=7)
        tau = ops.tau_from_fraction(x, min(0.9 * budget / block, 0.05))
        evb = ops.aer_compress(x, tau, budget, interpret=True)
        dense = ops.aer_decompress(evb, block, interpret=True)
        dense_r = ref.aer_decode(evb.idx, evb.val, block)
        np.testing.assert_allclose(np.array(dense), np.array(dense_r),
                                   rtol=1e-6, atol=1e-6)
        # every emitted event is reconstructed exactly at its address
        idx = np.array(evb.idx)
        val = np.array(evb.val)
        d = np.array(dense)
        for r in range(nb):
            for e in range(budget):
                if idx[r, e] >= 0:
                    assert d[r, idx[r, e]] == pytest.approx(val[r, e], abs=1e-6)

    def test_duplicate_addresses_accumulate(self):
        idx = jnp.array([[3, 3, -1, -1]], jnp.int32)
        val = jnp.array([[1.5, 2.0, 9.0, 9.0]], jnp.float32)
        dense = aer_decode_pallas(idx, val, 8, rows_per_block=1, interpret=True)
        assert float(dense[0, 3]) == pytest.approx(3.5)
        assert float(jnp.sum(jnp.abs(dense))) == pytest.approx(3.5)


class TestLif:
    @pytest.mark.parametrize("rows,lanes", [(8, 128), (32, 256), (8, 384),
                                            (64, 128)])
    @pytest.mark.parametrize("decay,v_th", [(0.9, 1.0), (0.5, 0.3)])
    def test_matches_oracle(self, rows, lanes, decay, v_th):
        v = rand((rows, lanes), jnp.float32, 1)
        i = rand((rows, lanes), jnp.float32, 2)
        vk, sk = lif_step_pallas(v, i, decay=decay, v_th=v_th, v_reset=0.0,
                                 block_rows=8, interpret=True)
        vr, sr = ref.lif_step(v, i, decay, v_th, 0.0)
        np.testing.assert_allclose(np.array(vk), np.array(vr), atol=1e-6)
        np.testing.assert_array_equal(np.array(sk), np.array(sr))

    def test_spike_resets_membrane(self):
        v = jnp.full((8, 128), 2.0, jnp.float32)
        i = jnp.zeros((8, 128), jnp.float32)
        vk, sk = ops.lif_step(v, i, decay=1.0, v_th=1.0, v_reset=-0.2)
        assert (np.array(sk) == 1.0).all()
        assert np.allclose(np.array(vk), -0.2)


class TestErrorFeedback:
    def test_feedback_conserves_mass(self):
        """compressed + residual == input (+ prior residual), exactly."""
        x = rand((1, 4096), jnp.float32, 11).reshape(-1)
        res0 = jnp.zeros_like(x)
        evb, res1, n = ops.compress_with_feedback(x, res0, frac=0.03)
        dec = ops.unpad_from_blocks(
            ops.aer_decompress(evb, ops.DEFAULT_BLOCK), n, x.shape)
        np.testing.assert_allclose(np.array(dec + res1), np.array(x),
                                   rtol=1e-6, atol=1e-6)

    def test_residual_drains_over_steps(self):
        """A one-shot signal fully transmits over repeated steps: feed x
        once, then zeros; the error-feedback residual drains to nothing."""
        x = rand((1, 2048), jnp.float32, 5).reshape(-1)
        res = jnp.zeros_like(x)
        total = jnp.zeros_like(x)
        inp = x
        for _ in range(60):
            # ~20% of entries ship per step -> residual decays as 0.8^k
            evb, res, n = ops.compress_with_feedback(inp, res, frac=0.2,
                                                     budget=256)
            total = total + ops.unpad_from_blocks(
                ops.aer_decompress(evb, ops.DEFAULT_BLOCK), n, x.shape)
            inp = jnp.zeros_like(x)
        np.testing.assert_allclose(np.array(total), np.array(x), atol=1e-3)
        assert float(jnp.max(jnp.abs(res))) < 1e-3


@settings(max_examples=25, deadline=None)
@given(nb=st.sampled_from([1, 2, 4]), block=st.sampled_from([128, 256, 512]),
       budget=st.sampled_from([16, 64, 128]), frac=st.floats(0.01, 0.5),
       seed=st.integers(0, 2**16))
def test_property_encode_invariants(nb, block, budget, frac, seed):
    """Invariants: counts bounded by budget; emitted indices strictly
    increasing per block; every emitted value is over threshold."""
    x = rand((nb, block), jnp.float32, seed)
    tau = ops.tau_from_fraction(x, frac)
    idx, val, cnt, want = ref.aer_encode(x, tau, budget)
    idx, val, cnt, want = map(np.array, (idx, val, cnt, want))
    assert (cnt <= budget).all() and (cnt <= want).all()
    for r in range(nb):
        v = idx[r, :cnt[r]]
        assert (np.diff(v) > 0).all()          # strictly increasing addresses
        assert (v >= 0).all()
        assert (np.abs(val[r, :cnt[r]]) >= np.array(tau)[r] - 1e-6).all()
        assert (idx[r, cnt[r]:] == -1).all()   # void slots after count


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), frac=st.floats(0.01, 0.2))
def test_property_pallas_equals_ref(seed, frac):
    x = rand((4, 512), jnp.float32, seed)
    tau = ops.tau_from_fraction(x, frac)
    k = aer_encode_pallas(x, tau, 64, rows_per_block=4, interpret=True)
    r = ref.aer_encode(x, tau, 64)
    for a, b in zip(k, r):
        np.testing.assert_allclose(np.array(a, np.float32),
                                   np.array(b, np.float32), atol=1e-6)
