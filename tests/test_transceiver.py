"""Unit tests for the SW_Control FSM — Table I rows + the three mode-switch
guards of paper §II."""

import jax.numpy as jnp
import pytest

from repro.core.transceiver import RX, TX, XcvrState, reset_state, step


def mk(mode, sw_ack, rx_p=1, burst=0):
    return XcvrState(mode=jnp.int32(mode), sw_ack=jnp.int32(sw_ack),
                     rx_p=jnp.int32(rx_p), burst=jnp.int32(burst))


class TestReset:
    def test_tx_block_holds_bus(self):
        s = reset_state(TX)
        assert int(s.mode) == TX and int(s.sw_ack) == 1

    def test_rx_block_gets_probe_exemption(self):
        # "except that this block is initially reset to RX mode for a
        #  chip-level global reset" — rx_p starts at 1 so it may request
        # before ever receiving.
        s = reset_state(RX)
        assert int(s.mode) == RX and int(s.rx_p) == 1 and int(s.sw_ack) == 0


class TestTableI:
    """Mode resolution for each (sw_ack, sw_req) row of Table I."""

    def test_row_tx_steady(self):
        # sw_ack=1, sw_req=0 -> TX
        s, _ = step(mk(TX, 1), sw_req=0, tx_pending=3, rx_strobe=0)
        assert int(s.mode) == TX

    def test_row_rx_steady(self):
        # sw_ack=0, sw_req=1 -> RX
        s, _ = step(mk(RX, 0, rx_p=0), sw_req=1, tx_pending=0, rx_strobe=0)
        assert int(s.mode) == RX

    def test_row_contended_holds(self):
        # (1,1): switch pending — current TX holds the bus
        s, _ = step(mk(TX, 1), sw_req=1, tx_pending=5, rx_strobe=0)
        assert int(s.mode) == TX

    def test_row_rx_requesting_holds_until_grant(self):
        # RX side requesting while TX still busy: stays RX
        s, _ = step(mk(RX, 1), sw_req=1, tx_pending=2, rx_strobe=0)
        assert int(s.mode) == RX

    def test_grant_edge_switches_requester_to_tx(self):
        # peer deasserted (sw_req 1->0) while we request -> we take TX
        s, out = step(mk(RX, 1), sw_req=0, tx_pending=2, rx_strobe=0)
        assert int(s.mode) == TX and int(out.switched) == 1

    def test_request_edge_switches_granter_to_rx(self):
        # we granted (ack->0) and peer requests -> we drop to RX
        s, out = step(mk(TX, 1), sw_req=1, tx_pending=0, rx_strobe=0)
        assert int(s.mode) == RX and int(out.switched) == 1


class TestRequestGuards:
    """RX→TX request iff: in RX ∧ received ≥1 event in RX ∧ events pending."""

    def test_requests_when_all_guards_met(self):
        s, _ = step(mk(RX, 0, rx_p=1), sw_req=1, tx_pending=4, rx_strobe=0)
        assert int(s.sw_ack) == 1

    def test_no_request_without_rx_probe(self):
        s, _ = step(mk(RX, 0, rx_p=0), sw_req=1, tx_pending=4, rx_strobe=0)
        assert int(s.sw_ack) == 0

    def test_no_request_without_pending_events(self):
        s, _ = step(mk(RX, 0, rx_p=1), sw_req=1, tx_pending=0, rx_strobe=0)
        assert int(s.sw_ack) == 0

    def test_rx_strobe_sets_probe_then_enables_request(self):
        s, _ = step(mk(RX, 0, rx_p=0), sw_req=1, tx_pending=4, rx_strobe=1)
        assert int(s.rx_p) == 1 and int(s.sw_ack) == 1

    def test_probe_clears_on_entering_rx(self):
        # TX that grants away enters RX with a cleared probe
        s, _ = step(mk(TX, 1, rx_p=1), sw_req=1, tx_pending=0, rx_strobe=0)
        assert int(s.mode) == RX and int(s.rx_p) == 0


class TestGrantGuards:
    """TX→RX grant iff: in TX ∧ peer requests ∧ nothing left to send."""

    def test_grants_when_drained_and_requested(self):
        s, _ = step(mk(TX, 1), sw_req=1, tx_pending=0, rx_strobe=0)
        assert int(s.sw_ack) == 0

    def test_no_grant_while_events_pending(self):
        s, _ = step(mk(TX, 1), sw_req=1, tx_pending=1, rx_strobe=0)
        assert int(s.sw_ack) == 1

    def test_no_grant_without_request(self):
        s, _ = step(mk(TX, 1), sw_req=0, tx_pending=0, rx_strobe=0)
        assert int(s.sw_ack) == 1  # idle TX holds the bus

    def test_bounded_burst_grants_early(self):
        # beyond-paper fairness: grant after max_burst even if not drained
        s, _ = step(mk(TX, 1, burst=2), sw_req=1, tx_pending=9, rx_strobe=0,
                    max_burst=2)
        assert int(s.sw_ack) == 0 and int(s.mode) == RX

    def test_bounded_burst_inactive_without_request(self):
        s, _ = step(mk(TX, 1, burst=5), sw_req=0, tx_pending=9, rx_strobe=0,
                    max_burst=2)
        assert int(s.mode) == TX and int(s.sw_ack) == 1


class TestEnables:
    def test_tx_rx_en_complementary(self):
        for mode in (TX, RX):
            for req in (0, 1):
                s, out = step(mk(mode, mode), sw_req=req, tx_pending=1,
                              rx_strobe=0)
                assert int(out.tx_en) + int(out.rx_en) == 1
