"""Per-architecture smoke tests (reduced same-family configs on CPU):
forward/loss finiteness + shape, gradient flow, and serving consistency —
token-by-token decode must reproduce the teacher-forced forward logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config, shapes_for
from repro.models.model import build_model

B, S = 2, 32


def make_batch(cfg, key, b=B, s=S):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.modality == "audio_frames":
        batch["frames"] = jax.random.normal(ks[0], (b, s, cfg.d_frontend),
                                            jnp.float32)
        batch["labels"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab)
        batch["mask"] = jnp.ones((b, s), jnp.int32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (b, s), 0, cfg.vocab)
        batch["labels"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab)
    if cfg.modality == "image+text":
        batch["img_embed"] = jax.random.normal(
            ks[2], (b, cfg.n_img_tokens, cfg.d_frontend), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = get_smoke_config(arch).with_(
        compute_dtype=jnp.float32)  # f32 for tight decode-vs-forward checks
    if cfg.moe is not None:
        # drop-free capacity so routing is identical across sequence lengths
        # (capacity dropping is load-dependent by design — Switch semantics)
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    return arch, cfg, model, params, batch


class TestSmoke:
    def test_loss_finite(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        loss, metrics = jax.jit(model.loss)(params, batch)
        assert np.isfinite(float(loss))
        assert float(metrics["nll"]) > 0

    def test_logits_shape_and_finite(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        from repro.models.layers import padded_vocab
        logits, _ = jax.jit(model.forward)(params, batch)
        assert logits.shape == (B, S, padded_vocab(cfg.vocab))
        live = np.asarray(logits, np.float32)[..., :cfg.vocab]
        assert np.isfinite(live).all()
        # padded ids can never win an argmax
        assert (np.asarray(jnp.argmax(logits, -1)) < cfg.vocab).all()

    def test_gradients_finite_and_nonzero(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        g = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
        leaves = jax.tree.leaves(g)
        assert all(np.isfinite(np.asarray(x, np.float32)).all()
                   for x in leaves)
        gnorm = np.sqrt(sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
                            for x in leaves))
        assert gnorm > 1e-4

    def test_full_config_importable(self, arch_setup):
        arch, *_ = arch_setup
        cfg = get_config(arch)
        assert cfg.n_layers >= 32
        assert len(shapes_for(cfg)) >= 2


class TestServingConsistency:
    """prefill(x[:, :t]) + decode(x[:, t]) must equal forward(x) logits."""

    def test_decode_matches_forward(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        if not cfg.causal:
            pytest.skip("encoder-only: no decode path")
        t0 = S // 2
        pre_batch = dict(batch)
        if "tokens" in batch:
            pre_batch["tokens"] = batch["tokens"][:, :t0]
        full_logits, _ = jax.jit(model.forward)(params, batch)

        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=S))(params, pre_batch)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t0 - 1], np.float32),
            rtol=2e-4, atol=2e-4)

        decode = jax.jit(model.decode_step)
        for t in range(t0, S):
            tok = batch["tokens"][:, t:t + 1]
            pos = jnp.full((B,), t, jnp.int32)
            logits, cache = decode(params, cache, tok, pos)
            np.testing.assert_allclose(
                np.asarray(logits[:, 0], np.float32),
                np.asarray(full_logits[:, t], np.float32),
                rtol=2e-4, atol=2e-4,
                err_msg=f"{arch}: decode step t={t} diverges from forward")

    def test_determinism(self, arch_setup):
        arch, cfg, model, params, batch = arch_setup
        l1, _ = jax.jit(model.loss)(params, batch)
        l2, _ = jax.jit(model.loss)(params, batch)
        assert float(l1) == float(l2)
