"""Closed-loop co-simulation tests: placement compilation, transport
bit-exactness across engines, per-tick conservation, the open-loop ==
standalone-rollout contract, and congestion-coupled feedback."""

import jax
import numpy as np
import pytest

from repro.core import events as ev
from repro.core import network as net
from repro.core.fabric import Fabric, QueuePolicy
from repro.core.link import SERIAL_LVDS_TIMING
from repro.core.router import AddressSpec, line_topology, ring_topology
from repro.cosim import (CosimConfig, CosimEngine, Population, Projection,
                         place, reference_rollout)
from repro.cosim.traffic_bridge import SNN_PATTERNS, spike_traffic
from repro.models import snn

KEY = jax.random.PRNGKey(3)


def ring_recurrent(n_chips=4, neurons=128, addr=AddressSpec()):
    pops = [Population(f"p{i}", neurons) for i in range(n_chips)]
    projs = []
    for i in range(n_chips):
        projs.append(Projection(i, ((i + 1) % n_chips,), 0.4))
        projs.append(Projection(i, ((i - 1) % n_chips,), 0.4))
        projs.append(Projection(i, (i,), 0.3))
    return place(pops, projs, ring_topology(n_chips), addr=addr)


class TestPlacement:
    def test_compile_ring(self):
        pl = ring_recurrent(4)
        assert pl.n_pops == 4 and pl.neurons == 128
        assert len(pl.local) == 4          # the self-projections
        assert len(pl.cross) == 8          # fwd + back, all unicast
        assert all(r.tag == -1 and r.fanout == 1 for r in pl.cross)
        for r in pl.cross:                 # unicast word unpacks to chip
            assert not pl.addr.is_multicast(r.dest_word)
            chip, _ = pl.addr.unpack(r.dest_word)
            assert int(chip) == r.chips[0]
        # every cross route's delivery chip maps back to its posts
        for r in pl.cross:
            posts = pl.posts_on[(r.proj, r.chips[0])]
            assert posts == (pl.projections[r.proj].posts[0],)

    def test_multicast_fanout(self):
        pops = [Population(f"p{i}") for i in range(4)]
        projs = [Projection(0, (1, 2, 3), 0.4)]
        pl = place(pops, projs, ring_topology(4), addr=AddressSpec())
        (r,) = pl.cross
        assert r.tag == 0 and r.chips == (1, 2, 3) and r.fanout == 3
        assert pl.mcast is not None and pl.mcast.members.shape == (1, 4)
        assert list(np.flatnonzero(pl.mcast.members[0])) == [1, 2, 3]
        fab = pl.fabric()                  # auto-attaches the in_fabric
        assert fab.mcast is not None       # multicast table

    def test_strategies_and_pins(self):
        pops = [Population(f"p{i}") for i in range(4)]
        projs = [Projection(0, (1,))]
        topo = ring_topology(2)
        rr = place(pops, projs, topo)
        assert list(rr.chip_of) == [0, 1, 0, 1]
        blk = place(pops, projs, topo, strategy="block")
        assert list(blk.chip_of) == [0, 0, 1, 1]
        pin = place(pops, projs, topo, chips=[1, 1, 0, 0])
        assert list(pin.chip_of) == [1, 1, 0, 0]
        assert len(blk.cross) == 0 and len(blk.local) == 1  # co-located

    @pytest.mark.parametrize("bad", [
        lambda: place([], [], ring_topology(2)),
        lambda: place([Population("a", 100)], [], ring_topology(2)),
        lambda: place([Population("a"), Population("b", 256)], [],
                      ring_topology(2)),
        lambda: place([Population("a")], [], ring_topology(2),
                      chips=[5]),
        lambda: place([Population("a")], [], ring_topology(2),
                      chips=[0, 1]),
        lambda: place([Population("a")], [], ring_topology(2),
                      strategy="scatter"),
        lambda: place([Population("a"), Population("b")],
                      [Projection(0, ())], ring_topology(2)),
        lambda: place([Population("a"), Population("b")],
                      [Projection(2, (0,))], ring_topology(2)),
        lambda: place([Population("a"), Population("b")],
                      [Projection(0, (9,))], ring_topology(2)),
        # fan-out without an AddressSpec: no mcast bit to set
        lambda: place([Population(f"p{i}") for i in range(3)],
                      [Projection(0, (1, 2))], ring_topology(3),
                      addr=None),
        # more chips than the word's chip field can name
        lambda: place([Population("a")], [], ring_topology(8),
                      addr=AddressSpec(chip_bits=2)),
    ])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            bad()


class TestEngineContracts:
    def test_open_loop_matches_reference(self):
        pl = ring_recurrent(4)
        eng = CosimEngine(pl, CosimConfig(feedback="none"), key=KEY)
        ref = reference_rollout(eng, 12, record_state=True)
        opn = eng.run(12, record_state=True)
        assert np.array_equal(ref.v, opn.v)
        assert np.array_equal(ref.raster, opn.raster)
        assert np.array_equal(ref.spikes, opn.spikes)
        assert opn.total_spikes > 0

    def test_closed_loop_needs_fabric(self):
        pl = ring_recurrent(4)
        eng = CosimEngine(pl, CosimConfig(feedback="next_tick"), key=KEY)
        with pytest.raises(ValueError, match="needs a fabric"):
            eng.run(2)
        with pytest.raises(ValueError, match="feedback"):
            CosimEngine(pl, CosimConfig(feedback="sometimes"), key=KEY)

    def test_mismatched_fabric_rejected(self):
        pl = ring_recurrent(4)
        with pytest.raises(ValueError, match="topology"):
            CosimEngine(pl, fabric=Fabric(ring_topology(6)), key=KEY)
        pops = [Population(f"p{i}") for i in range(4)]
        mc = place(pops, [Projection(0, (1, 2, 3))], ring_topology(4),
                   addr=AddressSpec())
        with pytest.raises(ValueError, match="multicast"):
            CosimEngine(mc, fabric=Fabric(ring_topology(4),
                                          addr=AddressSpec()), key=KEY)

    def test_conservation_credit_lossless(self):
        pl = ring_recurrent(4)
        fab = pl.fabric(queues=QueuePolicy(capacity=128, flow="credit"))
        res = CosimEngine(pl, fabric=fab, key=KEY).run(10)
        assert res.conservation_exact
        assert int(res.drops.sum()) == 0
        assert int(res.delivered.sum()) == int(res.injected.sum()) > 0

    def test_conservation_under_drops(self):
        """A many-to-one funnel on bounded drop-mode queues overflows
        at the hot chip; the dropped events must still balance the
        books and must never feed back."""
        pops = [Population(f"p{i}") for i in range(8)]
        projs = [Projection(i, (0,), 0.4) for i in range(1, 8)]
        projs += [Projection(i, (i,), 0.3) for i in range(8)]
        pl = place(pops, projs, line_topology(8), addr=AddressSpec())
        # every source's events converge on chip 0's last link (~7x one
        # population's spikes) while each endpoint's own injections stay
        # well under capacity — through-traffic, not backlog, overflows
        fab = pl.fabric(queues=QueuePolicy(capacity=32, flow="drop"))
        res = CosimEngine(pl, CosimConfig(input_rate=0.1,
                                          feedback_scale=0.0),
                          fabric=fab, key=KEY).run(10)
        assert int(res.drops.sum()) > 0     # the funnel overflows
        assert res.conservation_exact       # and is still accounted

    def test_closed_diverges_from_open(self):
        pl = ring_recurrent(4)
        eng_o = CosimEngine(pl, CosimConfig(feedback="none"), key=KEY)
        fab = pl.fabric(queues=QueuePolicy(capacity=128, flow="credit"))
        eng_c = CosimEngine(pl, fabric=fab, key=KEY)
        opn, cls = eng_o.run(12), eng_c.run(12)
        assert int(np.abs(cls.spikes - opn.spikes).sum()) > 0

    def test_measured_feedback_diverges_from_next_tick(self):
        """Slow serial links delay deliveries past tick boundaries; the
        late current must change the dynamics vs idealized delivery."""
        pl = ring_recurrent(4)
        qp = QueuePolicy(capacity=128, flow="credit")
        runs = {}
        for mode in ("measured", "next_tick"):
            fab = pl.fabric(timing=SERIAL_LVDS_TIMING, queues=qp)
            cfg = CosimConfig(feedback=mode, tick_dt_ns=600)
            runs[mode] = CosimEngine(pl, cfg, fabric=fab, key=KEY).run(16)
        assert int((runs["measured"].latency_ns >= 600).sum()) > 0
        assert runs["measured"].conservation_exact
        gap = np.abs(runs["measured"].spikes
                     - runs["next_tick"].spikes).sum()
        assert int(gap) > 0

    def test_tick_budget_guard(self):
        pl = ring_recurrent(4)
        fab = pl.fabric(queues=QueuePolicy(capacity=128, flow="credit"))
        eng = CosimEngine(pl, CosimConfig(input_rate=1.0, tick_dt_ns=60),
                          fabric=fab, key=KEY)
        with pytest.raises(ValueError, match="unique-timestamp budget"):
            eng.run(2)

    def test_aer_word_roundtrip(self):
        """EventSpec payload words are 26-bit AER (projection, neuron)
        pairs in the core/events layout, exactly recoverable."""
        pl = ring_recurrent(4)
        eng = CosimEngine(pl, CosimConfig(feedback="none"), key=KEY)
        res = eng.run(6, collect_events=True)
        assert res.events, "no spikes crossed chips in 6 ticks"
        for e in res.events:
            core, neuron = ev.unpack_aer_address(e.words)
            assert np.array_equal(np.asarray(core), e.proj)
            assert np.array_equal(np.asarray(neuron), e.neuron)
            assert int(e.words.max()) <= ev.AER_ADDR_MASK


class TestCrossEngine:
    def test_engines_bit_exact(self):
        """The SAME closed-loop co-simulation on ring / reference /
        pallas transports: every per-tick FabricResult and the spike
        trajectory must agree bit for bit."""
        pl = ring_recurrent(4)
        runs = {}
        for engine in ("ring", "reference", "pallas"):
            fab = pl.fabric(engine=engine,
                            queues=QueuePolicy(capacity=128,
                                               flow="credit"))
            runs[engine] = CosimEngine(pl, fabric=fab, key=KEY).run(
                8, record_fabric=True)
        base = runs["ring"]
        for other in ("reference", "pallas"):
            r = runs[other]
            assert np.array_equal(base.spikes, r.spikes)
            assert np.array_equal(base.delivered, r.delivered)
            assert len(base.fabric_results) == len(r.fabric_results)
            for (ta, fa), (tb, fb) in zip(base.fabric_results,
                                          r.fabric_results):
                assert ta == tb
                net.assert_results_equal(fa, fb, f"ring vs {other} @ {ta}")

    def test_multicast_closed_loop(self):
        """A fanout-3 projection through in-fabric multicast trees:
        injected = fanout x offered, and every delivery lands on a
        member chip."""
        pops = [Population(f"p{i}") for i in range(4)]
        projs = [Projection(0, (1, 2, 3), 0.5), Projection(0, (0,), 0.3),
                 Projection(1, (0,), 0.4)]
        pl = place(pops, projs, ring_topology(4), addr=AddressSpec())
        fab = pl.fabric(queues=QueuePolicy(capacity=128, flow="credit"))
        res = CosimEngine(pl, CosimConfig(input_rate=0.08),
                          fabric=fab, key=KEY).run(
            8, collect_events=True, record_fabric=True)
        assert res.conservation_exact and int(res.drops.sum()) == 0
        by_tick = {e.tick: e for e in res.events}
        for tick, fr in res.fabric_results:
            e = by_tick[tick]
            n_mc = int((e.proj == 0).sum())     # the fanout-3 route
            n_uc = e.n_events - n_mc
            assert int(fr.injected) == 3 * n_mc + n_uc
            dest = np.asarray(fr.log_dest)[:int(fr.delivered)]
            assert set(np.unique(dest)) <= {0, 1, 2, 3}
            # member chips 1,2,3 each see every multicast event once;
            # chip 0 sees exactly the unicast 1 -> 0 events
            for c in (1, 2, 3):
                assert int((dest == c).sum()) == n_mc
            assert int((dest == 0).sum()) == n_uc


class TestTrafficBridge:
    def test_deterministic_and_sized(self):
        k = jax.random.PRNGKey(11)
        a = spike_traffic(k, 8, 16)
        b = spike_traffic(k, 8, 16)
        assert a.src.shape == (128,)
        for f in ("src", "t", "dest"):
            assert np.array_equal(np.asarray(getattr(a, f)),
                                  np.asarray(getattr(b, f)))

    @pytest.mark.parametrize("name", sorted(SNN_PATTERNS))
    def test_patterns_fabric_ready(self, name):
        spec = SNN_PATTERNS[name](jax.random.PRNGKey(5), 8, 12)
        src = np.asarray(spec.src)
        dest = np.asarray(spec.dest)
        t = np.asarray(spec.t)
        assert np.all(src != dest)          # fabric refuses self-routes
        assert np.all((dest >= 0) & (dest < 8))  # bare chip ids
        for s in range(8):                  # per-source nondecreasing
            ts = t[src == s]
            assert np.all(np.diff(ts) >= 0)
        # and a plain fabric consumes it whole, conservatively
        res = Fabric(ring_topology(8)).run(spec)
        assert int(res.delivered) + int(res.drops) == int(res.injected)

    def test_underrun_and_bad_mode(self):
        with pytest.raises(ValueError, match="underran"):
            spike_traffic(jax.random.PRNGKey(0), 8, 10_000, max_ticks=3)
        with pytest.raises(ValueError, match="mode"):
            spike_traffic(jax.random.PRNGKey(0), 8, 4, mode="chaotic")


class TestFabricReport:
    def test_report_measures_the_run(self):
        pl = ring_recurrent(4)
        fab = pl.fabric(queues=QueuePolicy(capacity=128, flow="credit"))
        res = CosimEngine(pl, fabric=fab, key=KEY).run(10)
        rep = snn.fabric_report(res, 10, tick_dt_us=10.0)
        assert rep["events_total"] == float(res.delivered.sum())
        # energy bills per link traversal through the ONE shared model
        assert rep["energy_uj"] == pytest.approx(
            net.link_energy_pj(res.sent) * 1e-6)
        assert rep["energy_uj"] == pytest.approx(
            float(res.sent.sum()) * 11.0 * 1e-6)
        assert 0.0 <= rep["bus_busy_frac"] <= 1.0
        assert rep["max_link_busy_frac"] >= rep["bus_busy_frac"] > 0.0
        assert rep["traversals"] == int(res.sent.sum())
        assert rep["dual_bus_wires_per_link"] == \
            2 * rep["shared_bus_wires_per_link"]

    def test_link_report_same_energy_model(self):
        """The legacy estimator and the fabric path charge the same
        model: N events -> N * e_event_pj, exactly."""
        ticks = {"ew_events_lr": np.asarray([3.0, 2.0]),
                 "ew_events_rl": np.asarray([1.0, 0.0]),
                 "ns_events": np.asarray([4.0, 2.0])}
        rep = snn.link_report(ticks)
        assert rep["energy_uj"] == net.link_energy_pj(
            np.asarray([12.0])) * 1e-6
