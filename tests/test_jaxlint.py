"""JAX-pitfall lint: ``repro.analysis.jaxlint``.

Contracts under test:

* each rule fires on a seeded violation — JX001 traced-value branch,
  JX002 integer-valued float literal against a jnp expression, JX003
  jit static arg naming a dynamic-operand quantity (argnames and
  argnums spellings, decorator and call forms);
* each rule stays quiet on the idiomatic fix (jnp.where, int literal /
  explicit float dtype, dynamic operand);
* pragma suppression (`# jaxlint: disable=...`, bare disable,
  skip-file) and the CLI contract (exit 1 with findings, 0 without);
* the shipped tree is clean: zero findings over src/ and benchmarks/
  — the CI analysis lane's gate.
"""

from pathlib import Path

from repro.analysis.jaxlint import RULES, lint_paths, lint_source, main

REPO = Path(__file__).resolve().parents[1]


def rules_of(findings):
    return [f.rule for f in findings]


class TestJX001TracedBranch:
    def test_if_on_jnp_call(self):
        src = "if jnp.any(q == cap):\n    stall()\n"
        assert rules_of(lint_source(src)) == ["JX001"]

    def test_while_on_lax_call(self):
        src = "while lax.lt(i, n):\n    i = step(i)\n"
        assert rules_of(lint_source(src)) == ["JX001"]

    def test_conditional_expression(self):
        src = "x = a if jnp.all(mask) else b\n"
        assert rules_of(lint_source(src)) == ["JX001"]

    def test_python_value_branch_clean(self):
        src = "if flow != 'drop':\n    check()\n"
        assert lint_source(src) == []

    def test_jnp_where_clean(self):
        src = "x = jnp.where(q == cap, BIG, q)\n"
        assert lint_source(src) == []


class TestJX002FloatPromotion:
    def test_integer_valued_float_literal(self):
        src = "t = jnp.minimum(t, cap) * 2.0\n"
        assert rules_of(lint_source(src)) == ["JX002"]

    def test_literal_on_left(self):
        src = "t = 1.0 + jnp.asarray(q)\n"
        assert rules_of(lint_source(src)) == ["JX002"]

    def test_int_literal_clean(self):
        src = "t = jnp.minimum(t, cap) * 2\n"
        assert lint_source(src) == []

    def test_fractional_literal_assumed_intentional(self):
        src = "t = jnp.asarray(x) * 0.5\n"
        assert lint_source(src) == []

    def test_explicit_float_dtype_clean(self):
        """Arithmetic on an expression that names a float dtype is the
        author opting into float — the kernels' MXU iota idiom."""
        src = "i = jnp.arange(n, dtype=jnp.float32) + 1.0\n"
        assert lint_source(src) == []
        src = "i = jax.lax.broadcasted_iota(jnp.float32, (1, b), 1) " \
              "+ 1.0\n"
        assert lint_source(src) == []

    def test_division_not_flagged(self):
        # true division is float anyway; only int-preserving ops flag
        src = "t = jnp.sum(x) / 2.0\n"
        assert lint_source(src) == []


class TestJX003JitBucketHazard:
    def test_static_argnames_decorator(self):
        src = ("@partial(jax.jit, static_argnames=('capacity',))\n"
               "def step(q, capacity):\n    return q\n")
        assert rules_of(lint_source(src)) == ["JX003"]

    def test_static_argnums_resolved_through_signature(self):
        src = ("@partial(jax.jit, static_argnums=(1,))\n"
               "def step(q, max_steps):\n    return q\n")
        assert rules_of(lint_source(src)) == ["JX003"]

    def test_call_form_argnames(self):
        src = "f = jax.jit(step, static_argnames=['flow'])\n"
        assert rules_of(lint_source(src)) == ["JX003"]

    def test_genuinely_static_args_clean(self):
        src = ("@partial(jax.jit, static_argnames=('block', 'budget', "
               "'interpret'))\n"
               "def step(q, block, budget, interpret):\n    return q\n")
        assert lint_source(src) == []

    def test_call_form_argnums_unresolvable_stays_quiet(self):
        # without the signature, positions cannot be mapped to names
        src = "f = jax.jit(step, static_argnums=(0,))\n"
        assert lint_source(src) == []


class TestSuppression:
    def test_pragma_single_rule(self):
        src = "t = jnp.asarray(q) * 2.0  # jaxlint: disable=JX002\n"
        assert lint_source(src) == []

    def test_pragma_wrong_rule_does_not_suppress(self):
        src = "t = jnp.asarray(q) * 2.0  # jaxlint: disable=JX001\n"
        assert rules_of(lint_source(src)) == ["JX002"]

    def test_bare_disable_suppresses_all(self):
        src = "if jnp.any(jnp.asarray(q) * 2.0):  # jaxlint: disable\n" \
              "    pass\n"
        assert lint_source(src) == []

    def test_skip_file(self):
        src = "# jaxlint: skip-file\nt = jnp.asarray(q) * 2.0\n"
        assert lint_source(src) == []

    def test_syntax_error_reported_not_raised(self):
        findings = lint_source("def broken(:\n", "x.py")
        assert rules_of(findings) == ["JX000"]


class TestCLI:
    def test_seeded_file_exits_nonzero(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(
            "import jax.numpy as jnp\n"
            "from functools import partial\n"
            "import jax\n"
            "if jnp.any(x):\n    pass\n"                       # JX001
            "y = jnp.asarray(q) * 2.0\n"                       # JX002
            "@partial(jax.jit, static_argnames=('capacity',))\n"
            "def f(q, capacity):\n    return q\n")             # JX003
        assert main([str(bad)]) == 1
        out = capsys.readouterr().out
        for rule in ("JX001", "JX002", "JX003"):
            assert rule in out
        assert "3 finding(s)" in out

    def test_clean_file_exits_zero(self, tmp_path, capsys):
        good = tmp_path / "good.py"
        good.write_text("x = 1\n")
        assert main([str(good)]) == 0
        assert "0 finding(s)" in capsys.readouterr().out

    def test_rule_table_documented(self):
        assert set(RULES) == {"JX001", "JX002", "JX003"}


class TestShippedTreeClean:
    def test_zero_findings_on_src_and_benchmarks(self):
        findings = lint_paths([REPO / "src", REPO / "benchmarks"])
        assert findings == [], "\n".join(map(str, findings))
