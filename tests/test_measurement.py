"""Unit coverage for the measurement helpers against hand-computed
Table II / pin-saving values (paper §IV)."""

import jax.numpy as jnp
import pytest

from repro.core import protocol_sim as ps
from repro.core.halfduplex import wire_bytes_per_direction
from repro.core.link import PAPER_TIMING, LinkTiming


def _result(sent_l, sent_r, t_end, n_switches=0):
    """Hand-built SimResult (trace unused by the helpers)."""
    return ps.SimResult(trace=None,
                        sent_l=jnp.int32(sent_l), sent_r=jnp.int32(sent_r),
                        t_end=jnp.int32(t_end),
                        n_switches=jnp.int32(n_switches))


class TestThroughput:
    def test_hand_computed_rate(self):
        # 100 events in 3100 ns = 100 / 3.1 us = 32.258... MEvents/s,
        # the paper's Fig. 7 steady-state 1/31 ns rate.
        res = _result(100, 0, 100 * 31)
        assert float(ps.throughput_mev_s(res)) == pytest.approx(1e3 / 31,
                                                                rel=1e-6)

    def test_bidirectional_sum(self):
        # both directions count: 60 + 40 events in 3.5 us
        res = _result(60, 40, 3500)
        assert float(ps.throughput_mev_s(res)) == pytest.approx(100 / 3.5,
                                                                rel=1e-6)

    def test_t_end_zero_guard(self):
        """No elapsed time -> 0 MEvents/s, not a NaN/inf division."""
        res = _result(5, 5, 0)
        thr = float(ps.throughput_mev_s(res))
        assert thr == 0.0

    def test_table_ii_rates_from_timing(self):
        assert PAPER_TIMING.onedir_throughput_mev_s() == pytest.approx(
            1e3 / 31)  # 32.26 MEvents/s
        assert PAPER_TIMING.bidir_throughput_mev_s() == pytest.approx(
            1e3 / 35)  # 28.57 MEvents/s


class TestEnergy:
    def test_hand_computed(self):
        # Table II: 11 pJ per delivered event
        res = _result(30, 12, 10_000)
        assert float(ps.energy_pj(res)) == pytest.approx(11.0 * 42)

    def test_custom_timing(self):
        res = _result(10, 0, 1_000)
        t = LinkTiming(e_event_pj=7.5)
        assert float(ps.energy_pj(res, timing=t)) == pytest.approx(75.0)

    def test_energy_nj_matches_pj(self):
        assert PAPER_TIMING.energy_nj(1000) == pytest.approx(11.0)


class TestWireBytes:
    """halfduplex.wire_bytes_per_direction — the pin-saving argument in
    byte units: ring all-reduce ships 2(n-1)/n of the payload; the
    bi-directional schedule halves the per-direction share."""

    def test_unidirectional_hand_value(self):
        # n=4, payload 1024 B: 2*(3/4)*1024 = 1536 B on one direction
        assert wire_bytes_per_direction(1024, 4, False) == pytest.approx(
            1536.0)

    def test_bidirectional_halves(self):
        assert wire_bytes_per_direction(1024, 4, True) == pytest.approx(768.0)
        for n in (2, 3, 8, 16):
            uni = wire_bytes_per_direction(4096, n, False)
            assert wire_bytes_per_direction(4096, n, True) == pytest.approx(
                uni / 2)

    def test_two_devices(self):
        # n=2: each device ships exactly the payload once (2*(1/2)*B)
        assert wire_bytes_per_direction(512, 2, False) == pytest.approx(512.0)


class TestPinSavings:
    def test_paper_quoted_100_ios(self):
        # 4 borders x (26-bit shared bus - 1 extra SW wire) = 100
        assert PAPER_TIMING.io_pins_saved(n_links=4) == 100

    def test_scales_with_links(self):
        assert PAPER_TIMING.io_pins_saved(n_links=1) == 25
        assert LinkTiming(word_bits=13).io_pins_saved(n_links=4) == 48
