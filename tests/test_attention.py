"""Block-skipping flash attention vs naive softmax reference, across
causal/window/cross, GQA grouping, chunk shapes, and padding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.models.layers import decode_attention, flash_attention


def naive(q, k, v, causal, window, q_offset=0):
    B, Sq, K, G, dh = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q * dh ** -0.5, k).astype(jnp.float32)
    qi = q_offset + jnp.arange(Sq)
    ki = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qi[:, None] >= ki[None, :]
    if window > 0:
        mask &= (qi[:, None] - ki[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def rand_qkv(B, Sq, Skv, K, G, dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, K, G, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, K, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, K, dh), jnp.float32)
    return q, k, v


CASES = [
    # (Sq, Skv, qc, kc, causal, window)
    (128, 128, 32, 32, True, 0),      # multi-tile causal
    (128, 128, 32, 32, False, 0),     # encoder
    (128, 128, 32, 32, True, 48),     # SWA crossing tile edges
    (96, 96, 32, 32, True, 32),       # window == tile
    (100, 100, 32, 32, True, 0),      # padding both axes
    (64, 160, 32, 32, False, 0),      # cross-attention (Skv > Sq)
    (128, 128, 128, 128, True, 0),    # single tile
    (64, 64, 16, 64, True, 0),        # qc != kc
]


@pytest.mark.parametrize("Sq,Skv,qc,kc,causal,window", CASES)
def test_flash_matches_naive(Sq, Skv, qc, kc, causal, window):
    q, k, v = rand_qkv(2, Sq, Skv, 2, 3, 16, seed=Sq + Skv + qc)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=qc, kv_chunk=kc)
    want = naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_q_offset_matches_suffix_of_full():
    """Chunked prefill: q positioned at offset inside the kv stream."""
    q, k, v = rand_qkv(1, 96, 96, 2, 2, 8, seed=5)
    full = flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    tail = flash_attention(q[:, 64:], k, v, causal=True, q_offset=64,
                           q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, 64:]),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_naive_last_row():
    q, k, v = rand_qkv(2, 1, 64, 2, 4, 16, seed=9)
    valid = jnp.arange(64)[None, :] < jnp.array([[40], [64]])
    got = decode_attention(q, k, v, valid)
    # reference: mask then softmax
    s = jnp.einsum("bokgd,bskd->bkgos", q * 16 ** -0.5, k)
    s = jnp.where(valid[:, None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), -1)
    want = jnp.einsum("bkgos,bskd->bokgd", p.astype(v.dtype), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(sq=st.sampled_from([48, 64, 96]), kc=st.sampled_from([16, 32, 48]),
       causal=st.booleans(), window=st.sampled_from([0, 16, 40]),
       seed=st.integers(0, 1000))
def test_property_flash_equals_naive(sq, kc, causal, window, seed):
    q, k, v = rand_qkv(1, sq, sq, 1, 2, 8, seed=seed)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=16, kv_chunk=kc)
    want = naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
