"""The declarative ``Fabric`` front-end: policy composition, the explicit
compile/run lifecycle, per-link timing heterogeneity, and the contract
that the ``simulate_fabric`` compatibility wrapper is the new API
bit-for-bit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import network as net
from repro.core import traffic as tr
from repro.core.fabric import (CompiledFabric, EngineSpec, Fabric,
                               PrebuiltRouting, QueuePolicy,
                               StaticShortestPath)
from repro.core.link import (PAPER_TIMING, SERIAL_LVDS_TIMING, LinkTiming,
                             link_timing_arrays, per_link_timing)
from repro.core.router import RoutingTable, line_topology, ring_topology

assert_bit_exact = net.assert_results_equal


def _spec(key=3, n=8, epc=24):
    return tr.poisson(jax.random.PRNGKey(key), n, epc)


def _mixed_timing(n_links, slow=(0,)):
    cls = [0] * n_links
    for l in slow:
        cls[l] = 1
    return per_link_timing([PAPER_TIMING, SERIAL_LVDS_TIMING], cls)


class TestWrapperEquivalence:
    """``simulate_fabric`` must be ``Fabric.run`` bit-exactly — it IS the
    same code path, and this pins the contract."""

    @pytest.mark.parametrize("engine", sorted(net.ENGINES))
    def test_wrapper_is_fabric_run(self, engine):
        topo = ring_topology(4)
        spec = _spec(13, 4, 24)
        a = net.simulate_fabric(topo, spec, engine=engine, max_burst=1)
        fab = Fabric(topo, queues=QueuePolicy(max_burst=1),
                     engine=EngineSpec(name=engine))
        assert_bit_exact(a, fab.run(spec), f"wrapper/{engine}")

    def test_wrapper_with_prebuilt_routing(self):
        topo = ring_topology(6)
        rt = RoutingTable.build(topo)
        spec = _spec(5, 6, 16)
        a = net.simulate_fabric(topo, spec, routing=rt)
        b = Fabric(topo, routing=rt).run(spec)
        assert_bit_exact(a, b, "prebuilt-routing")

    def test_paper_anchor_through_fabric_api(self):
        """The N=2 Fig. 8 anchor (28.6 MEv/s) must hold through the new
        front door, not just the wrapper."""
        fab = Fabric(ring_topology(2), queues=QueuePolicy(max_burst=1))
        res = fab.run(tr.ping_pong(2, 1024))
        thr = float(net.fabric_throughput_mev_s(res))
        assert thr == pytest.approx(28.6, rel=1e-3)


class TestCompileRunLifecycle:
    def test_compile_returns_bound_bucket(self):
        fab = Fabric(ring_topology(4))
        spec = _spec(1, 4, 16)
        cf = fab.compile(spec, warm=False)
        assert isinstance(cf, CompiledFabric)
        assert cf.bucket[0] == "ring"
        assert cf.bucket in fab.compiled_buckets

    def test_second_run_same_bucket_zero_recompiles(self):
        """The headline cache contract: after a warm compile, further
        runs on the bucket add NO jit cache entries — even with
        different traffic, capacity or burst settings (all dynamic)."""
        fab = Fabric(ring_topology(4))
        cf = fab.compile(_spec(1, 4, 16))        # warm=True
        n0 = cf.cache_size()
        assert n0 >= 1
        cf.run(_spec(1, 4, 16))
        cf.run(_spec(2, 4, 20))                  # same bucket, new traffic
        Fabric(ring_topology(4),
               queues=QueuePolicy(max_burst=3)).run(_spec(3, 4, 16))
        assert cf.cache_size() == n0

    def test_warm_compile_then_run_bit_exact(self):
        topo = ring_topology(4)
        spec = _spec(7, 4, 24)
        fab = Fabric(topo)
        cf = fab.compile(spec)
        assert_bit_exact(net.simulate_fabric(topo, spec), cf.run(spec),
                         "warm-compile")

    def test_compiled_rejects_foreign_bucket(self):
        """CompiledFabric.run refuses a spec outside its bucket instead
        of silently recompiling."""
        fab = Fabric(line_topology(3),
                     engine=EngineSpec(name="reference"))
        cf = fab.compile(_spec(1, 3, 8), warm=False)
        with pytest.raises(ValueError, match="shape bucket"):
            cf.run(_spec(1, 3, 12))  # different E -> different slot bucket

    def test_fabric_run_routes_buckets_automatically(self):
        """Fabric.run (unlike CompiledFabric.run) accepts any spec and
        compiles/reuses buckets as needed."""
        fab = Fabric(line_topology(3), engine=EngineSpec(name="reference"))
        fab.run(_spec(1, 3, 8))
        fab.run(_spec(1, 3, 12))
        assert len(fab.compiled_buckets) == 2

    def test_run_many_amortises_and_matches(self):
        topo = ring_topology(4)
        specs = [_spec(k, 4, 24) for k in range(4)]
        fab = Fabric(topo)
        results = fab.run_many(specs)
        assert len(fab.compiled_buckets) == 1  # one bucket, one compile
        for s, r in zip(specs, results):
            assert_bit_exact(net.simulate_fabric(topo, s), r, "run_many")

    def test_sweep_returns_timed_cells(self):
        fab = Fabric(ring_topology(4))
        cells = fab.sweep([_spec(k, 4, 16) for k in range(3)])
        assert len(cells) == 3
        for c in cells:
            assert c.us_per_call > 0
            assert int(c.result.delivered) == c.result.injected


class TestPolicyValidation:
    def test_unknown_engine(self):
        with pytest.raises(ValueError, match="unknown engine"):
            EngineSpec(name="warp")

    def test_bad_chunk(self):
        with pytest.raises(ValueError, match="chunk_size"):
            EngineSpec(chunk_size=0)

    def test_bad_queue_policy(self):
        with pytest.raises(ValueError, match="capacity"):
            QueuePolicy(capacity=0)
        with pytest.raises(ValueError, match="max_burst"):
            QueuePolicy(max_burst=-1)

    def test_bad_routing_type(self):
        with pytest.raises(TypeError, match="RoutingPolicy"):
            Fabric(ring_topology(4), routing=42)

    def test_bad_timing_shape(self):
        bad = LinkTiming(t_req2req_ns=np.array([31, 31, 31], np.int32))
        with pytest.raises(ValueError, match="per-link"):
            Fabric(ring_topology(4), timing=bad)  # 4 links, 3 entries

    def test_timing_invariants(self):
        with pytest.raises(ValueError, match="t_bidir"):
            link_timing_arrays(LinkTiming(t_bidir_ns=30), 2)
        with pytest.raises(ValueError, match="positive"):
            link_timing_arrays(LinkTiming(t_req2req_ns=0), 2)

    def test_timing_int32_overflow_rejected(self):
        """Costs at/above the int32 BIG_NS sentinel must be refused
        before the int32 cast, not silently wrapped."""
        huge = 3_000_000_000
        with pytest.raises(ValueError, match="BIG_NS"):
            link_timing_arrays(LinkTiming(t_req2req_ns=huge,
                                          t_bidir_ns=huge + 4), 2)


class TestRoutingPolicy:
    def test_static_shortest_path_matches_default(self):
        topo = ring_topology(6)
        spec = _spec(9, 6, 16)
        a = Fabric(topo).run(spec)
        b = Fabric(topo, routing=StaticShortestPath()).run(spec)
        assert_bit_exact(a, b, "explicit-policy")

    def test_table_override_hook_changes_routes(self):
        """The adaptive-routing landing pad: an override that forces the
        long way around a ring is honoured (more hops -> more sent)."""
        topo = ring_topology(4)
        spec = tr.TrafficSpec(src=jnp.zeros(8, jnp.int32),
                              t=jnp.arange(8, dtype=jnp.int32) * 200,
                              dest=jnp.ones(8, jnp.int32))

        def long_way(topo_, rt):
            # dest 1 from chip 0: force the 3-hop detour 0 -(l3)-> 3
            # -(l2)-> 2 -(l1)-> 1 instead of the direct 0-1 link (the
            # override owns consistency of every hop it bends)
            nl = rt.next_link.copy()
            os = rt.out_side.copy()
            hops = rt.hops.copy()
            nl[0, 1], os[0, 1], hops[0, 1] = 3, 1, 3
            nl[3, 1], os[3, 1], hops[3, 1] = 2, 1, 2
            return RoutingTable(next_link=nl, out_side=os, hops=hops)

        direct = Fabric(topo).run(spec)
        detour = Fabric(
            topo, routing=StaticShortestPath(table_override=long_way)
        ).run(spec)
        assert int(detour.delivered) == 8
        assert int(np.asarray(detour.sent).sum()) == 3 * 8
        assert int(np.asarray(direct.sent).sum()) == 8

    def test_override_validated(self):
        def bad(topo_, rt):
            return RoutingTable(next_link=rt.next_link[:2, :2],
                                out_side=rt.out_side, hops=rt.hops)
        with pytest.raises(ValueError, match="routing table"):
            Fabric(ring_topology(4),
                   routing=StaticShortestPath(table_override=bad))

    def test_prebuilt_adapter(self):
        topo = ring_topology(4)
        pol = PrebuiltRouting(RoutingTable.build(topo))
        assert_bit_exact(Fabric(topo).run(_spec(2, 4, 12)),
                         Fabric(topo, routing=pol).run(_spec(2, 4, 12)),
                         "prebuilt-adapter")


class TestPerLinkTiming:
    """The headline capability: per-link heterogeneous LinkTiming on all
    three engines, with the uniform array bit-exactly the scalar."""

    @pytest.mark.parametrize("engine", sorted(net.ENGINES))
    def test_uniform_array_equals_scalar(self, engine):
        topo = ring_topology(4)
        spec = _spec(13, 4, 24)
        a = net.simulate_fabric(topo, spec, engine=engine,
                                timing=PAPER_TIMING)
        b = net.simulate_fabric(topo, spec, engine=engine,
                                timing=PAPER_TIMING.for_links(topo.n_links))
        assert_bit_exact(a, b, f"uniform/{engine}")

    @pytest.mark.parametrize("engine", sorted(net.ENGINES))
    def test_uniform_subword_array_equals_scalar(self, engine):
        """Same check on a non-default contract (subword serialisation)."""
        topo = line_topology(3)
        t = PAPER_TIMING.subword(2)
        spec = _spec(5, 3, 16)
        a = net.simulate_fabric(topo, spec, engine=engine, timing=t)
        b = net.simulate_fabric(topo, spec, engine=engine,
                                timing=t.for_links(topo.n_links))
        assert_bit_exact(a, b, f"uniform-subword/{engine}")

    def test_heterogeneous_cross_engine_bit_exact(self):
        topo = ring_topology(8)
        spec = _spec(3, 8, 24)
        mixed = _mixed_timing(topo.n_links, slow=(7,))
        res = {e: net.simulate_fabric(topo, spec, engine=e, timing=mixed)
               for e in net.ENGINES}
        assert int(res["ring"].delivered) == res["ring"].injected
        assert_bit_exact(res["reference"], res["ring"], "het/ring")
        assert_bit_exact(res["reference"], res["pallas"], "het/pallas")

    def test_heterogeneous_with_bursts(self):
        """Heterogeneity composes with the bounded-burst fairness
        extension identically on both scan engines."""
        topo = ring_topology(6)
        spec = tr.ping_pong(6, 24)
        mixed = _mixed_timing(topo.n_links, slow=(0, 3))
        kw = dict(timing=mixed, max_burst=1)
        a = net.simulate_fabric(topo, spec, engine="reference", **kw)
        b = net.simulate_fabric(topo, spec, engine="ring", **kw)
        assert_bit_exact(a, b, "het/burst")

    def test_heterogeneous_with_drops(self):
        """...and with the capacity/drop path: a convergecast through a
        slow relay link drops identically on both engines."""
        from repro.core.router import Topology
        topo = Topology(4, np.array([(0, 2), (1, 2), (2, 3)], np.int32))
        n = 64
        spec = tr.TrafficSpec(
            src=jnp.concatenate([jnp.zeros(n, jnp.int32),
                                 jnp.ones(n, jnp.int32)]),
            t=jnp.zeros(2 * n, jnp.int32),
            dest=jnp.full((2 * n,), 3, jnp.int32))
        mixed = _mixed_timing(topo.n_links, slow=(2,))  # slow drain link
        kw = dict(timing=mixed, queue_capacity=n)
        a = net.simulate_fabric(topo, spec, engine="reference", **kw)
        b = net.simulate_fabric(topo, spec, engine="ring", **kw)
        assert int(a.drops) > 0
        assert int(a.delivered) + int(a.drops) == 2 * n
        assert_bit_exact(a, b, "het/drop")

    def test_slow_link_slows_only_its_traffic(self):
        """Physics check: a slow LVDS class on one ring link stretches
        latencies crossing it; traffic avoiding it keeps paper latency."""
        topo = ring_topology(8)
        n = 16
        # chip 2 -> 3: never touches link 7 (the 7-0 edge); chip 7 -> 0
        # rides it directly
        spec = tr.TrafficSpec(
            src=jnp.concatenate([jnp.full((n,), 2, jnp.int32),
                                 jnp.full((n,), 7, jnp.int32)]),
            t=jnp.tile(jnp.arange(n, dtype=jnp.int32) * 1500, 2),
            dest=jnp.concatenate([jnp.full((n,), 3, jnp.int32),
                                  jnp.zeros(n, jnp.int32)]))
        mixed = _mixed_timing(topo.n_links, slow=(7,))
        res = net.simulate_fabric(topo, spec, timing=mixed)
        m = int(res.delivered)
        assert m == 2 * n
        lat = net.delivered_latencies(res)
        dst = np.asarray(res.log_dest)[:m]
        assert lat[dst == 3].max() == PAPER_TIMING.t_req2req_ns
        assert lat[dst == 0].min() >= SERIAL_LVDS_TIMING.t_req2req_ns

    def test_heterogeneous_energy_rollup(self):
        """Per-link e_event_pj weights each hop by its link's energy."""
        topo = line_topology(3)
        cheap = LinkTiming(e_event_pj=1.0)
        dear = LinkTiming(e_event_pj=100.0)
        mixed = per_link_timing([cheap, dear], [0, 1])
        n = 8
        spec = tr.TrafficSpec(src=jnp.zeros(n, jnp.int32),
                              t=jnp.arange(n, dtype=jnp.int32) * 100,
                              dest=jnp.full((n,), 2, jnp.int32))
        res = net.simulate_fabric(topo, spec, timing=mixed)
        assert float(net.fabric_energy_pj(res, mixed)) == pytest.approx(
            n * 1.0 + n * 100.0)

    def test_shared_bucket_across_timing(self):
        """Timing travels as dynamic vectors: fabrics that differ ONLY in
        timing share one ring-engine shape bucket (and so one compile)."""
        topo = ring_topology(4)
        spec = _spec(1, 4, 16)
        f1 = Fabric(topo)
        f2 = Fabric(topo, timing=_mixed_timing(topo.n_links))
        b1 = f1.compile(spec, warm=False).bucket
        b2 = f2.compile(spec, warm=False).bucket
        assert b1 == b2
