"""Kernel-dispatch accounting: count Pallas launches in a traced program.

The per-step pallas fabric engine dispatches TWO kernels per
micro-transaction (queue scan + slot update), so a run costs
``2 * max_steps`` launches with the full packed state round-tripping
through XLA between every pair.  The multi-step kernel fuses ``chunk``
micro-transactions per launch (carry resident across steps), cutting the
count to ``ceil(max_steps / chunk)``.  This module makes that claim
*checkable*: walk the jaxpr of an engine call and count how many
``pallas_call`` equations execute, loop trip counts included.

Counting rules (static program counts, not a runtime profiler):

* ``scan``  — body count times the static trip count (``length``).
* ``while`` — condition + body counted ONCE each (a conservative lower
  bound: the true count multiplies by a data-dependent trip count).
* ``cond``  — the maximum over branches (exactly one branch runs).
* ``pjit`` / closed calls / custom derivatives — descend transparently.
* ``pallas_call`` — counts 1; its kernel jaxpr is the launch body, not
  further dispatches, so it is NOT descended.

Used by the ``fabric_ring16_pallas_multistep`` smoke gate to assert the
fused kernel issues strictly fewer launches than the per-step path, and
by the roofline report to annotate measured cells with their dispatch
economy.
"""

from __future__ import annotations

import jax

__all__ = ["count_pallas_calls", "pallas_dispatches"]


def _is_closed_jaxpr(v) -> bool:
    # duck-typed: jax.core.ClosedJaxpr moves between jax versions
    return hasattr(v, "jaxpr") and hasattr(getattr(v, "jaxpr"), "eqns")


def _count_param(v) -> int:
    """Pallas launches inside an arbitrary eqn param value."""
    if _is_closed_jaxpr(v):
        return _count(v.jaxpr)
    if hasattr(v, "eqns"):  # open Jaxpr
        return _count(v)
    if isinstance(v, (tuple, list)):
        return sum(_count_param(x) for x in v)
    return 0


def _count(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "pallas_call":
            n += 1  # the kernel body is the launch, not more launches
        elif prim == "scan":
            n += int(eqn.params["length"]) * _count_param(
                eqn.params["jaxpr"])
        elif prim == "while":
            n += _count_param(eqn.params["cond_jaxpr"])
            n += _count_param(eqn.params["body_jaxpr"])
        elif prim == "cond":
            n += max((_count_param(b) for b in eqn.params["branches"]),
                     default=0)
        else:
            for v in eqn.params.values():
                n += _count_param(v)
    return n


def count_pallas_calls(jaxpr) -> int:
    """Pallas launches in a (closed or open) jaxpr, trip counts applied."""
    if _is_closed_jaxpr(jaxpr):
        jaxpr = jaxpr.jaxpr
    return _count(jaxpr)


def pallas_dispatches(fn, *args, **kwargs) -> int:
    """Trace ``fn(*args, **kwargs)`` and count its Pallas launches.

    ``fn`` may be plain or jitted (``pjit`` bodies are descended).  The
    args only need the right shapes/dtypes — tracing is abstract, no
    kernel actually runs.
    """
    return count_pallas_calls(jax.make_jaxpr(fn)(*args, **kwargs))
