"""Static analysis: machine-checked correctness arguments.

Two halves, both pure setup-time code (numpy + ast, nothing traced):

* :mod:`repro.analysis.verify` — the fabric pre-flight verifier.
  Builds the channel-dependency graph (CDG) over the fabric's
  (link, endpoint) channels from the unicast routes and multicast-tree
  branchings, runs Dally–Seitz cycle detection on it, checks route
  termination / reachability / replication-table completeness, and
  bounds the worst-case clock against the ``BIG_NS`` sentinel —
  everything ``Fabric.verify(spec)`` reports before a single engine
  step runs.

* :mod:`repro.analysis.jaxlint` — an AST lint for the JAX pitfalls
  this repo keeps hand-auditing: Python-level branches on traced
  values, jit static args that should be dynamic operands (the
  zero-new-buckets contract), and bare float literals that promote the
  int32 hot path.  Runnable as ``python -m repro.analysis.jaxlint
  src/ benchmarks/ examples/`` (the CI analysis lane).

Plus one trace-time probe:

* :mod:`repro.analysis.dispatch` — counts ``pallas_call`` launches in a
  traced program (loop trip counts applied), the evidence behind the
  multi-step kernel's fewer-dispatches claim.
"""

from .dispatch import count_pallas_calls, pallas_dispatches  # noqa: F401
from .verify import (ChannelGraph, Finding, VerifyReport,  # noqa: F401
                     channel_graph, describe_channel, verify_fabric)

__all__ = ["ChannelGraph", "Finding", "VerifyReport", "channel_graph",
           "count_pallas_calls", "describe_channel", "pallas_dispatches",
           "verify_fabric"]
