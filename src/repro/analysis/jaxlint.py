"""AST lint for the JAX pitfalls this repo keeps hand-auditing.

Three rules, each encoding an invariant PRs 2–7 enforce by review and
cross-engine bit-exactness tests — here turned into machine checks:

``JX001`` **traced-branch** — a Python ``if`` / ``while`` / conditional
    expression whose condition calls into ``jnp`` / ``jax.numpy`` /
    ``lax`` / ``jax.random``.  Inside ``jit`` this raises
    ``TracerBoolConversionError``; outside it silently forces a device
    sync per evaluation.  Engine step functions must use ``jnp.where``
    / ``lax.cond`` / ``lax.while_loop`` instead (every branch in the
    fabric engines is data-flow, which is what keeps drop/credit/onoff
    a *dynamic operand* rather than a retrace).

``JX002`` **float-literal promotion** — an integer-valued float literal
    (``2.0``, ``1.``) combined arithmetically with a ``jnp``-rooted
    expression.  The fabric hot path is int32 end-to-end (the
    ``BIG_NS`` sentinel, release times, queue slots); a bare float
    literal promotes the whole expression to float32/float64 and the
    sentinel comparison silently loses exactness.  Write the int
    literal, or an explicit ``jnp.float32`` cast where float is meant.
    Literals with fractional parts (``0.5``, ``1e-3``) are assumed
    intentionally float and are not flagged, and neither is arithmetic
    on an expression that explicitly names a float dtype
    (``jnp.arange(n, dtype=jnp.float32) + 1.0``) — the author already
    opted into float there.

``JX003`` **jit-bucket hazard** — ``jax.jit`` ``static_argnums`` /
    ``static_argnames`` naming a quantity the repo's zero-new-buckets
    contract says must be a dynamic operand (capacity, flow mode, xon,
    burst bound, step bound, seeds/keys, injection times).  Marking one
    static recompiles per value — exactly the bucket explosion PRs 3–7
    eliminated.  Genuinely static shape/config args (``block``,
    ``budget``, ``interpret``, ...) are fine.

Suppression: trailing ``# jaxlint: disable=JX001`` (comma-separate for
several, bare ``disable`` for all) on the flagged line, or
``# jaxlint: skip-file`` anywhere in the file.

CLI (the CI analysis lane)::

    python -m repro.analysis.jaxlint src/ benchmarks/

exits 1 when any finding survives suppression.  Pure stdlib ``ast`` —
nothing is imported or executed, so linting broken or GPU-only code is
safe.
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable

__all__ = ["LintFinding", "RULES", "lint_source", "lint_paths", "main"]

RULES = {
    "JX001": "Python-level branch on a traced (jnp/lax) value",
    "JX002": "integer-valued float literal promotes an int32 jnp "
             "expression",
    "JX003": "jit static arg that the zero-new-buckets contract says "
             "must be a dynamic operand",
}

#: module roots whose call results are traced values under jit
_TRACED_ROOTS = ("jnp.", "jax.numpy.", "lax.", "jax.lax.", "jax.random.")

#: quantities that must travel as dynamic operands (the repo's
#: zero-new-buckets contract: sweeping any of these must not add a
#: compilation bucket).  Names, not positions — JX003 resolves argnums
#: through the decorated function's signature.
DYNAMIC_OPERAND_NAMES = frozenset({
    "capacity", "cap", "xon", "fc", "fc_mode", "flow", "max_burst",
    "max_steps", "seed", "key", "keys", "t", "t_max", "n_events",
})

_PRAGMA = re.compile(r"#\s*jaxlint:\s*disable(?:=([A-Z0-9,\s]+))?")
_SKIP_FILE = re.compile(r"#\s*jaxlint:\s*skip-file")


@dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chains as a dotted string (None otherwise)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _is_traced_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    d = _dotted(node.func)
    return d is not None and any(d.startswith(r) for r in _TRACED_ROOTS)


def _contains_traced_call(node: ast.AST) -> ast.Call | None:
    for sub in ast.walk(node):
        if _is_traced_call(sub):
            return sub
    return None


def _int_valued_float(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, float)
            and node.value == int(node.value))


_FLOAT_DTYPES = {"float16", "bfloat16", "float32", "float64"}


def _names_float_dtype(node: ast.AST) -> bool:
    """True when the expression explicitly names a float dtype
    (``jnp.float32`` / ``dtype=jnp.float32`` / ``.astype(jnp.float32)``)
    — the author opted into float, so a float literal next to it is
    intentional, not an int32 promotion bug."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _FLOAT_DTYPES:
            return True
        if isinstance(sub, ast.Name) and sub.id in _FLOAT_DTYPES:
            return True
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: list[LintFinding] = []
        # decorator jit calls checked with the signature in hand; the
        # generic Call visit must not re-report them
        self._decorator_jits: set[int] = set()

    def _add(self, node: ast.AST, rule: str, message: str):
        self.findings.append(LintFinding(
            self.path, getattr(node, "lineno", 0),
            getattr(node, "col_offset", 0), rule, message))

    # ---- JX001: traced branch -----------------------------------------

    def _check_branch(self, node, test, kind: str):
        hit = _contains_traced_call(test)
        if hit is not None:
            name = _dotted(hit.func) or "jnp call"
            self._add(node, "JX001",
                      f"{kind} condition calls {name}(...): branching "
                      f"on a traced value raises under jit (use "
                      f"jnp.where / lax.cond, or hoist to setup time)")

    def visit_If(self, node: ast.If):
        self._check_branch(node, node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        self._check_branch(node, node.test, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp):
        self._check_branch(node, node.test, "conditional-expression")
        self.generic_visit(node)

    # ---- JX002: float-literal promotion -------------------------------

    def visit_BinOp(self, node: ast.BinOp):
        if isinstance(node.op, (ast.Add, ast.Sub, ast.Mult,
                                ast.FloorDiv, ast.Mod)):
            for lit, other in ((node.left, node.right),
                               (node.right, node.left)):
                if _int_valued_float(lit) \
                        and _contains_traced_call(other) is not None \
                        and not _names_float_dtype(other):
                    self._add(node, "JX002",
                              f"float literal {lit.value!r} promotes "
                              f"the jnp operand out of int32; write "
                              f"{int(lit.value)} (or an explicit float "
                              f"cast if float is meant)")
                    break
        self.generic_visit(node)

    # ---- JX003: jit-bucket hazard -------------------------------------

    def _jit_call(self, node: ast.AST) -> ast.Call | None:
        """``jax.jit(...)`` or ``functools.partial(jax.jit, ...)``."""
        if not isinstance(node, ast.Call):
            return None
        d = _dotted(node.func)
        if d in ("jax.jit", "jit"):
            return node
        if d in ("functools.partial", "partial") and node.args:
            if _dotted(node.args[0]) in ("jax.jit", "jit"):
                return node
        return None

    def _static_names(self, call: ast.Call,
                      params: list[str] | None) -> list[tuple[str, ast.AST]]:
        out: list[tuple[str, ast.AST]] = []
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant) and isinstance(v.value,
                                                                  str):
                        out.append((v.value, kw.value))
            elif kw.arg == "static_argnums" and params is not None:
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant) \
                            and isinstance(v.value, int) \
                            and 0 <= v.value < len(params):
                        out.append((params[v.value], kw.value))
        return out

    def _check_jit(self, call: ast.Call, params: list[str] | None):
        for name, where in self._static_names(call, params):
            if name in DYNAMIC_OPERAND_NAMES:
                self._add(where, "JX003",
                          f"static arg {name!r} must be a dynamic "
                          f"operand (zero-new-buckets contract): "
                          f"marking it static recompiles per value")

    def visit_FunctionDef(self, node: ast.FunctionDef):
        params = [a.arg for a in (node.args.posonlyargs
                                  + node.args.args)]
        for dec in node.decorator_list:
            call = self._jit_call(dec)
            if call is not None:
                self._decorator_jits.add(id(call))
                self._check_jit(call, params)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call):
        # non-decorator uses: jax.jit(f, static_argnames=...) — argnums
        # cannot be resolved to names here, argnames still can
        call = self._jit_call(node)
        if call is not None and id(call) not in self._decorator_jits:
            self._check_jit(call, None)
        self.generic_visit(node)


def _suppressed(finding: LintFinding, lines: list[str]) -> bool:
    if not 1 <= finding.line <= len(lines):
        return False
    m = _PRAGMA.search(lines[finding.line - 1])
    if m is None:
        return False
    if m.group(1) is None:
        return True  # bare "disable": all rules
    codes = {c.strip() for c in m.group(1).split(",")}
    return finding.rule in codes


def lint_source(source: str, path: str = "<string>") -> list[LintFinding]:
    """Lint one source string; returns findings after pragma filtering."""
    if _SKIP_FILE.search(source):
        return []
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as err:
        return [LintFinding(path, err.lineno or 0, err.offset or 0,
                            "JX000", f"syntax error: {err.msg}")]
    visitor = _Visitor(path)
    visitor.visit(tree)
    lines = source.splitlines()
    out = [f for f in visitor.findings if not _suppressed(f, lines)]
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def lint_paths(paths: Iterable[str | Path]) -> list[LintFinding]:
    """Lint every ``*.py`` under the given files/directories."""
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    findings: list[LintFinding] = []
    for f in files:
        findings.extend(lint_source(f.read_text(encoding="utf-8"), str(f)))
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.jaxlint",
        description="JAX-pitfall lint (JX001 traced-branch, JX002 "
                    "float-literal promotion, JX003 jit-bucket hazard)")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to lint")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the summary line")
    args = ap.parse_args(argv)
    findings = lint_paths(args.paths)
    for f in findings:
        print(f)
    if not args.quiet:
        print(f"jaxlint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
