"""Fabric pre-flight verifier: static proofs before any engine step.

The paper's two-chip handshake is deadlock-free by construction; an
N-chip fabric with credit/on-off backpressure (PR 6) is not — a pop
stalls while its downstream queue is full, so stall chains follow the
*channel-dependency graph* (CDG) of the route set.  PR 7 side-stepped
the question by refusing ANY table with a broken next-hop walk under
lossless flow, which over-refuses: a route graph may be cyclic as a
walk (one broken (chip, dest) pair) while the channels its *terminating*
routes use depend on each other acyclically — such a fabric cannot
deadlock as long as traffic avoids the broken pairs.

This module applies the classical Dally–Seitz criterion statically:

* **Channels** are the engines' flat endpoint queues, ``link * 2 +
  out_side`` (2L of them) — the exact queue ids ``network._prefill`` /
  the replication tables use.
* **CDG edges** ``q1 -> q2`` exist when an event popped from ``q1``
  forwards into ``q2``: consecutive channels of every terminating
  unicast route, plus parent-edge -> child-edge pairs of every
  in-fabric multicast tree branching.
* **Acyclic CDG ⇒ deadlock-free** for the stall modes: every wait
  chain descends a DAG and bottoms out at a delivery-only pop (which is
  never gated — sinks always drain).
* A cyclic CDG is a deadlock *hazard*, not a certainty: a cycle can
  only lock up if every channel on it is simultaneously full, so a
  cycle crossing a channel whose worst-case insertions (prefill +
  forwards, statically known from the routes) stay below the queue
  capacity can never engage.  With a traffic spec in hand the verifier
  grades cycles by this *saturability*: all-saturable cycle = error
  (refused), otherwise a warning-level hazard with the slack named.

``verify_fabric`` (surfaced as ``Fabric.verify(spec)``) bundles the CDG
verdict with the rest of the pre-flight: route termination (unicast
walks and multicast replication, via the shared
``router.route_step_tables`` traversal), reachability of the spec's
destinations, replication-table completeness (one in-edge per tree
node, subtree weights that sum), drop-mode prefill overflow, and the
int32 clock budget versus the ``BIG_NS`` sentinel (per-link
heterogeneous timing, tight routed bound).  Everything is numpy at
setup time — nothing compiles, nothing traces.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.network import (_BIG, _clock_bound, _expand, _first_hop_queues,
                            _route_link_tx)
from ..core.router import (RoutingTable, Topology, find_route_cycles,
                           route_step_tables)

__all__ = ["ChannelGraph", "Finding", "VerifyReport", "channel_graph",
           "describe_channel", "verify_fabric"]


def describe_channel(topo: Topology, q: int) -> str:
    """Human name of flat endpoint-queue ``q``: ``L<link>:<from>-><to>``."""
    link, side = int(q) // 2, int(q) % 2
    a = int(topo.links[link, side])
    b = int(topo.links[link, 1 - side])
    return f"L{link}:{a}->{b}"


@dataclass(frozen=True)
class ChannelGraph:
    """The channel-dependency graph over ``2 * n_links`` flat queues.

    ``edges[(m, 2)]`` — directed dependencies ``q1 -> q2`` (an event
    popped from ``q1`` appends into ``q2``), deduplicated and sorted so
    the graph (and every verdict derived from it) is deterministic.
    """
    topo: Topology
    edges: np.ndarray  # (m, 2) int32

    @property
    def n_channels(self) -> int:
        return 2 * self.topo.n_links

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    def restrict(self, keep: np.ndarray) -> "ChannelGraph":
        """Subgraph induced on the channels where ``keep`` is True."""
        keep = np.asarray(keep, bool)
        if not len(self.edges):
            return self
        m = keep[self.edges[:, 0]] & keep[self.edges[:, 1]]
        return ChannelGraph(self.topo, self.edges[m])

    def find_cycle(self) -> list[int] | None:
        """One explicit channel cycle, or ``None`` when the CDG is
        acyclic (the Dally–Seitz certificate).

        Kahn's algorithm peels nodes with no remaining in-edges; what
        survives is exactly the set of channels on or downstream of a
        cycle.  A DFS inside the survivor subgraph then recovers one
        concrete cycle to *name* in refusals — the deterministic
        lowest-id back edge, so error messages are stable across runs.
        """
        if not len(self.edges):
            return None
        n = self.n_channels
        e = self.edges
        indeg = np.bincount(e[:, 1], minlength=n)
        alive = np.ones(n, bool)
        frontier = list(np.flatnonzero(indeg == 0))
        while frontier:
            u = frontier.pop()
            alive[u] = False
            for v in e[e[:, 0] == u, 1]:
                indeg[v] -= 1
                if indeg[v] == 0 and alive[v]:
                    frontier.append(int(v))
        if not alive.any():
            return None
        # adjacency restricted to surviving nodes, sorted for determinism
        adj: dict[int, list[int]] = {}
        for q1, q2 in e[alive[e[:, 0]] & alive[e[:, 1]]].tolist():
            adj.setdefault(q1, []).append(q2)
        for lst in adj.values():
            lst.sort()
        color = {}  # 0 = on stack, 1 = done
        for start in sorted(adj):
            if start in color:
                continue
            stack = [(start, iter(adj.get(start, ())))]
            color[start] = 0
            path = [start]
            while stack:
                u, it = stack[-1]
                nxt = next(it, None)
                if nxt is None:
                    stack.pop()
                    path.pop()
                    color[u] = 1
                    continue
                if color.get(nxt) == 0:       # back edge: cycle found
                    return path[path.index(nxt):]
                if nxt not in color:
                    color[nxt] = 0
                    path.append(nxt)
                    stack.append((nxt, iter(adj.get(nxt, ()))))
        return None  # pragma: no cover - Kahn said a cycle exists

    def describe_cycle(self, cycle: list[int]) -> str:
        names = [describe_channel(self.topo, q) for q in cycle]
        return " -> ".join(names + [names[0]]) if names else ""


def channel_graph(topo: Topology, rt: RoutingTable, trees=(),
                  exclude_pairs: np.ndarray | None = None) -> ChannelGraph:
    """Build the CDG from the routes (and tree branchings) themselves.

    Walks all (chip, dest) unicast pairs at once over the shared
    ``router.route_step_tables`` traversal, collecting every
    consecutive-channel pair; ``exclude_pairs`` (an ``(n, 2)`` array of
    (chip, dest)) removes non-terminating walks — their channels are
    quarantined, not dependencies.  Each in-fabric multicast tree adds
    one edge per non-root branching (parent edge's channel -> child
    edge's channel); root edges are injection prefill, which consumes no
    upstream pop and therefore adds no dependency.
    """
    n = topo.n_chips
    step_to, step_q = route_step_tables(topo, rt)
    dest = np.broadcast_to(np.arange(n)[None, :], (n, n))
    pos = np.broadcast_to(np.arange(n)[:, None], (n, n)).copy()
    active = (np.asarray(rt.next_link) >= 0) & (pos != dest)
    if exclude_pairs is not None and len(exclude_pairs):
        ex = np.asarray(exclude_pairs).reshape(-1, 2)
        uni = ex[ex[:, 1] < n]  # tree route ids have no (chip, dest) cell
        active[uni[:, 0], uni[:, 1]] = False
    prev_q = np.full((n, n), -1, np.int64)
    parts = []
    for _ in range(max(n - 1, 0)):
        if not active.any():
            break
        q = np.where(active, step_q[pos, dest], -1)
        dep = active & (prev_q >= 0) & (q >= 0)
        if dep.any():
            parts.append(np.stack([prev_q[dep], q[dep]], 1))
        prev_q = np.where(active, q, prev_q)
        nxt = step_to[pos, dest]
        pos = np.where(active & (nxt >= 0), nxt, pos)
        active = active & (pos != dest)
    for tree in trees:
        par = np.asarray(tree.parent)
        ed = np.asarray(tree.edges).reshape(-1, 4)
        nz = par >= 0
        if nz.any():
            child_q = ed[nz, 1] * 2 + ed[nz, 2]
            parent_q = ed[par[nz], 1] * 2 + ed[par[nz], 2]
            parts.append(np.stack([parent_q, child_q], 1).astype(np.int64))
    if parts:
        edges = np.unique(np.concatenate(parts, 0), axis=0)
    else:
        edges = np.zeros((0, 2), np.int64)
    return ChannelGraph(topo, edges.astype(np.int32))


# -----------------------------------------------------------------------
# Report structure
# -----------------------------------------------------------------------

@dataclass(frozen=True)
class Finding:
    """One verifier observation.  ``severity`` is ``"error"`` (the
    config is refused — ``VerifyReport.ok`` is False), ``"warning"``
    (admitted, but a hazard the caller should know about) or ``"info"``
    (context)."""
    severity: str
    check: str
    message: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.check}: {self.message}"


@dataclass(frozen=True)
class VerifyReport:
    """Everything ``Fabric.verify(spec)`` can prove without running.

    ``ok``               — no error-severity findings: the config is
                           admitted.
    ``deadlock_free``    — True when a static certificate exists (see
                           ``certificate``); False means "not proven",
                           which is an error only if the hazard is
                           saturable under the spec.
    ``certificate``      — why deadlock cannot happen: ``"acyclic-cdg"``
                           (Dally–Seitz), ``"capacity-slack"`` (every
                           CDG cycle crosses a channel whose worst-case
                           insertions stay below capacity),
                           ``"drop-mode"`` (no backpressure gating) —
                           or ``""`` when unproven.
    ``findings``         — graded observations, errors first.
    ``cdg_nodes/edges``  — CDG size (channels with any dependency).
    ``cdg_cycle``        — one named channel cycle of the full CDG
                           (``None`` when acyclic).
    ``route_cycles``     — (chip, route) pairs whose walk never reaches
                           delivery (route >= n_chips = multicast tree).
    ``clock_bound_ns``   — worst-case end time under the tight per-link
                           budget (``None`` without a spec).
    ``clock_headroom_ns``— ``BIG_NS - clock_bound_ns`` (negative =
                           refused; ``None`` without a spec).
    ``n_trees``          — multicast trees covered by the analysis.
    """
    ok: bool
    deadlock_free: bool
    certificate: str
    findings: tuple[Finding, ...]
    cdg_nodes: int
    cdg_edges: int
    cdg_cycle: tuple[str, ...] | None
    route_cycles: np.ndarray = field(repr=False)
    clock_bound_ns: int | None
    clock_headroom_ns: int | None
    n_trees: int

    @property
    def errors(self) -> tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == "error")

    def raise_if_failed(self) -> "VerifyReport":
        """Raise ``ValueError`` listing every error finding (the CI
        precision gate's refusal path); return self when ok."""
        if not self.ok:
            raise ValueError(
                "fabric pre-flight verification failed:\n"
                + "\n".join(str(f) for f in self.errors))
        return self

    def summary(self) -> str:
        head = ("OK" if self.ok else "REFUSED") + (
            f" deadlock_free={self.deadlock_free}"
            f" certificate={self.certificate or 'none'!r}"
            f" cdg={self.cdg_nodes}ch/{self.cdg_edges}dep")
        if self.clock_headroom_ns is not None:
            head += f" clock_headroom={self.clock_headroom_ns}ns"
        lines = [head] + [str(f) for f in self.findings]
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.summary()


# -----------------------------------------------------------------------
# The verifier
# -----------------------------------------------------------------------

def _spec_routes(fab, spec, findings: list[Finding]):
    """Expand ``spec`` to unicast (src, dest) streams + multicast trees
    exactly the way planning does, downgrading hard errors to findings
    so the report can carry several at once."""
    src = np.asarray(spec.src, np.int32).reshape(-1)
    t = np.asarray(spec.t, np.int32).reshape(-1)
    dest = np.asarray(spec.dest, np.int32).reshape(-1)
    trees: list = []
    tree_counts = np.zeros(0, np.int64)
    if fab.mcast_policy.mode == "in_fabric" and fab.addr is not None:
        is_mc = np.asarray(fab.addr.is_multicast(dest))
        chip_or_tag, _ = fab.addr.unpack(dest)
        u_src, u_dest = src[~is_mc], chip_or_tag[~is_mc]
        m_src, m_tag = src[is_mc], chip_or_tag[is_mc]
        if len(m_src):
            if fab.mcast_policy.table is None:
                findings.append(Finding(
                    "error", "multicast-table",
                    "traffic carries multicast tags but the fabric "
                    "declares no MulticastTable"))
            else:
                pairs = np.unique(np.stack([m_src, m_tag], 1), axis=0)
                counts = []
                for s, g in pairs:
                    try:
                        trees.append(fab._tree(int(s), int(g)))
                        counts.append(int(np.sum((m_src == s)
                                                 & (m_tag == g))))
                    except ValueError as err:
                        findings.append(Finding(
                            "error", "multicast-members", str(err)))
                tree_counts = np.asarray(counts, np.int64)
    else:
        try:
            u_src, t, u_dest = _expand(spec, fab.addr, fab.mcast)
        except ValueError as err:
            findings.append(Finding("error", "multicast-table", str(err)))
            u_src = u_dest = np.zeros(0, np.int32)
    return u_src, u_dest, t, trees, tree_counts


def verify_fabric(fab, spec=None, *, max_steps: int | None = None
                  ) -> VerifyReport:
    """Statically verify a ``Fabric`` (and optionally one traffic spec).

    See the module docstring for the criteria; ``Fabric.verify``
    delegates here.  Without a spec only the structural checks run
    (route termination, CDG, replication completeness) and cyclic-CDG
    hazards cannot be graded by demand, so they surface as warnings for
    the stall modes.  With a spec the report adds reachability, used-
    route termination, drop-mode prefill overflow, the tight clock
    budget, and the saturability grading that turns an engaged deadlock
    hazard into an error.
    """
    topo, rt = fab.topo, fab.routing_table
    n, L = topo.n_chips, topo.n_links
    flow = fab.queues.flow
    cap = fab.queues.capacity
    findings: list[Finding] = []

    u_src = u_dest = None
    trees: list = []
    tree_counts = np.zeros(0, np.int64)
    t_arr = np.zeros(0, np.int32)
    if spec is not None:
        u_src, u_dest, t_arr, trees, tree_counts = _spec_routes(
            fab, spec, findings)

    # ---- route termination (shared traversal, trees included) ---------
    bad = find_route_cycles(topo, rt, trees)
    nonterm = np.zeros((n, n), bool)
    if len(bad):
        uni = bad[bad[:, 1] < n]
        nonterm[uni[:, 0], uni[:, 1]] = True
        shown = ", ".join(f"{c}->{r}" for c, r in bad[:4].tolist())
        # tree routes (route id >= n_chips) exist only because the spec
        # rides them — a cycle there is engaged, not latent
        if np.any(bad[:, 1] >= n):
            sev = "error"
        else:
            sev = "warning" if flow != "drop" else "info"
        findings.append(Finding(
            sev, "route-termination",
            f"{len(bad)} (chip, route) pair(s) never reach delivery "
            f"(next-hop cycle or dead-end), e.g. {shown}; traffic "
            f"addressing them is refused at plan time under "
            f"flow={flow!r} and truncates at the step bound in drop "
            f"mode"))

    # ---- replication-table completeness -------------------------------
    for i, tree in enumerate(trees):
        r = n + i
        ed = np.asarray(tree.edges).reshape(-1, 4)
        par = np.asarray(tree.parent).reshape(-1)
        deliver = np.asarray(tree.deliver, bool)
        sub = np.asarray(tree.subtree, np.int64).reshape(-1)
        if not len(ed):
            continue
        v = ed[:, 3]
        dup = np.flatnonzero(np.bincount(v, minlength=n) > 1)
        if len(dup):
            findings.append(Finding(
                "error", "replication-in-edges",
                f"tree route {r}: chip(s) {dup.tolist()} have more than "
                f"one in-edge — events would be delivered/replicated "
                f"more than once"))
        if np.any(v == tree.src):
            findings.append(Finding(
                "error", "replication-in-edges",
                f"tree route {r}: an edge delivers back into the "
                f"source chip {tree.src}"))
        root_ok = np.all(ed[par < 0, 0] == tree.src)
        chain_ok = np.all(ed[par[par >= 0], 3] == ed[par >= 0, 0])
        if not (root_ok and chain_ok):
            findings.append(Finding(
                "error", "replication-parents",
                f"tree route {r}: parent pointers are inconsistent "
                f"(an edge's source chip is not its parent edge's "
                f"target)"))
        # subtree weights must sum: own delivery + children's subtrees
        want = deliver[v].astype(np.int64)
        np.add.at(want, par[par >= 0], sub[par >= 0])
        if not np.array_equal(want, sub):
            off = np.flatnonzero(want != sub)[:4]
            findings.append(Finding(
                "error", "replication-weights",
                f"tree route {r}: subtree drop-weights do not sum "
                f"(edge(s) {off.tolist()}: stored "
                f"{sub[off].tolist()}, recomputed {want[off].tolist()})"
                f" — drop accounting would break "
                f"delivered + drops == injected"))
        if bool(deliver[tree.src]):
            findings.append(Finding(
                "error", "replication-deliver",
                f"tree route {r}: the source chip {tree.src} is marked "
                f"for delivery (sources never receive their own copy)"))

    # ---- spec checks ---------------------------------------------------
    clock_bound = clock_headroom = None
    demand = None
    if spec is not None and u_src is not None:
        if np.any(u_src == u_dest):
            ex = np.flatnonzero(u_src == u_dest)[:4]
            findings.append(Finding(
                "error", "self-addressed",
                f"event(s) {ex.tolist()} have src == dest"))
        ok_pairs = u_src != u_dest
        first = rt.next_link[u_src, u_dest]
        unreach = ok_pairs & (first < 0)
        if np.any(unreach):
            ex = np.flatnonzero(unreach)[:4]
            findings.append(Finding(
                "error", "reachability",
                f"unreachable destinations, e.g. events {ex.tolist()}: "
                f"src={u_src[unreach][:4].tolist()} "
                f"dest={u_dest[unreach][:4].tolist()}"))
        used_bad = ok_pairs & ~unreach & nonterm[u_src, u_dest]
        if np.any(used_bad):
            pairs = np.unique(np.stack([u_src[used_bad],
                                        u_dest[used_bad]], 1), axis=0)
            shown = ", ".join(f"{c}->{d}" for c, d in pairs[:4].tolist())
            findings.append(Finding(
                "error", "route-termination",
                f"traffic addresses non-terminating route pair(s) "
                f"{shown}: those events are never delivered "
                f"({'the stall chain deadlocks' if flow != 'drop' else 'the run truncates at the step bound'})"))

        # worst-case insertions per flat endpoint queue: prefill +
        # forwards (occupancy can never exceed total insertions, so
        # demand < capacity certifies "this queue can never be full")
        walkable = ok_pairs & ~unreach & ~nonterm[u_src, u_dest]
        demand = np.zeros(2 * L, np.int64)
        if np.any(walkable):
            ws, wd = u_src[walkable], u_dest[walkable]
            np.add.at(demand, _first_hop_queues(rt, ws, wd), 1)
            step_to, step_q = route_step_tables(topo, rt)
            c = ws.astype(np.int64)
            c = step_to[c, wd].astype(np.int64)
            live = c != wd
            for _ in range(max(n - 1, 0)):
                if not live.any():
                    break
                q = step_q[c, wd]
                np.add.at(demand, q[live], 1)
                c = np.where(live, step_to[c, wd], c)
                live = live & (c != wd)
        for tree, cnt in zip(trees, tree_counts):
            ed = np.asarray(tree.edges).reshape(-1, 4)
            if len(ed):
                np.add.at(demand, ed[:, 1] * 2 + ed[:, 2], int(cnt))

        # drop-mode prefill overflow: the logical budget binds the
        # initial backlog too (the stall modes legitimately buffer
        # above capacity at the source)
        if flow == "drop" and cap is not None and np.any(walkable):
            backlog = np.bincount(
                _first_hop_queues(rt, u_src[walkable], u_dest[walkable]),
                minlength=2 * L)
            for tree, cnt in zip(trees, tree_counts):
                ed = np.asarray(tree.edges).reshape(-1, 4)
                roots = ed[np.asarray(tree.parent) < 0]
                if len(roots):
                    np.add.at(backlog, roots[:, 1] * 2 + roots[:, 2],
                              int(cnt))
            worst = int(backlog.max(initial=0))
            if worst > int(cap):
                findings.append(Finding(
                    "error", "prefill-overflow",
                    f"queue capacity {cap} < initial backlog {worst}; "
                    f"raise queue_capacity"))

        # tight int32 clock budget vs the BIG_NS sentinel
        tc, tv, ti = fab.timing_arrays
        link_cost = tc.astype(np.int64) + np.maximum(tv, ti)
        link_tx, walk_ok = _route_link_tx(
            rt, topo.links, u_src[walkable], u_dest[walkable], L, n)
        for tree, cnt in zip(trees, tree_counts):
            ed = np.asarray(tree.edges).reshape(-1, 4)
            if len(ed):
                np.add.at(link_tx, ed[:, 1], int(cnt))
        t_max = int(np.asarray(t_arr).max(initial=0))
        clock_bound = _clock_bound(t_max, link_tx, link_cost)
        clock_headroom = int(_BIG) - clock_bound
        if clock_headroom <= 0:
            findings.append(Finding(
                "error", "clock-overflow",
                f"worst-case end time {clock_bound} ns reaches the "
                f"BIG_NS sentinel ({int(_BIG)} ns); rebase injection "
                f"times or split the simulation"))

    # ---- channel-dependency graph (Dally–Seitz) ------------------------
    g = cdg = channel_graph(topo, rt, trees, exclude_pairs=bad)
    cycle = g.find_cycle()
    cycle_names = tuple(describe_channel(topo, q)
                        for q in cycle) if cycle else None
    deadlock_free = False
    certificate = ""
    if flow == "drop":
        deadlock_free = True
        certificate = "drop-mode"
        if cycle is not None:
            findings.append(Finding(
                "info", "cdg-cycle",
                f"channel-dependency cycle {g.describe_cycle(cycle)} — "
                f"harmless in drop mode (overflowing forwards drop, "
                f"pops are never gated), but this route set would be a "
                f"deadlock hazard under flow='credit'/'onoff'"))
    elif cycle is None:
        deadlock_free = True
        certificate = "acyclic-cdg"
    else:
        sat_cycle = None
        if demand is not None and cap is not None:
            saturable = demand >= int(cap)
            sat_cycle = g.restrict(saturable).find_cycle()
            if sat_cycle is None:
                deadlock_free = True
                certificate = "capacity-slack"
                findings.append(Finding(
                    "info", "cdg-cycle",
                    f"channel-dependency cycle "
                    f"{g.describe_cycle(cycle)} cannot engage: every "
                    f"such cycle crosses a channel whose worst-case "
                    f"insertions stay below capacity {cap} (a queue "
                    f"that is never full never gates its upstream "
                    f"pop)"))
            else:
                findings.append(Finding(
                    "error", "cdg-cycle",
                    f"deadlock hazard: channel-dependency cycle "
                    f"{g.describe_cycle(sat_cycle)} with every channel "
                    f"saturable (worst-case insertions >= capacity "
                    f"{cap}) under flow={flow!r} — the stall chain can "
                    f"lock up; re-route, raise capacity, or use "
                    f"flow='drop'"))
        else:
            findings.append(Finding(
                "warning", "cdg-cycle",
                f"channel-dependency cycle {g.describe_cycle(cycle)} "
                f"under flow={flow!r}: deadlock possible if every "
                f"channel on a cycle can fill to capacity — pass a "
                f"traffic spec to verify() to grade the hazard by "
                f"static demand"))

    if max_steps is not None and spec is not None and u_src is not None:
        # the plan's own default bound is safe whenever routes
        # terminate; a smaller explicit bound may truncate
        hops = rt.hops[u_src, u_dest]
        total_tx = int(hops[hops > 0].sum()) + int(
            sum(tr.n_edges * int(c) for tr, c in zip(trees, tree_counts)))
        default = 4 * total_tx + 2 * max(len(u_src), 1) \
            + 64 * (rt.diameter + 2)
        if int(max_steps) < default:
            findings.append(Finding(
                "warning", "step-bound",
                f"max_steps={max_steps} is below the safe default "
                f"bound {default}; a binding bound truncates delivery"))

    order = {"error": 0, "warning": 1, "info": 2}
    findings.sort(key=lambda f: order.get(f.severity, 3))
    used = np.zeros(2 * L, bool)
    if len(cdg.edges):
        used[cdg.edges[:, 0]] = True
        used[cdg.edges[:, 1]] = True
    return VerifyReport(
        ok=not any(f.severity == "error" for f in findings),
        deadlock_free=deadlock_free,
        certificate=certificate,
        findings=tuple(findings),
        cdg_nodes=int(used.sum()),
        cdg_edges=cdg.n_edges,
        cdg_cycle=cycle_names,
        route_cycles=bad,
        clock_bound_ns=clock_bound,
        clock_headroom_ns=clock_headroom,
        n_trees=len(trees))
