"""Epoch-based adaptive routing: the fabric's congestion control plane.

Static shortest-path routing serves uniform traffic well, but skewed
(hot-spot / convergecast) workloads saturate a few contended links while
parallel links idle — the congestion ceiling DYNAPs (Moradi et al. 2017)
and the core-interface optimization work (Su et al. 2023) identify as
the real limit of multi-core AER throughput.  This module closes the
loop over the telemetry plane (:mod:`repro.core.telemetry`):

1. A run is split into **epochs** — contiguous injection-time slices of
   the workload (:func:`partition_epochs`).  Each epoch simulates on the
   routing tables chosen *before* it started; between epochs the fabric
   drains (quasi-static reconfiguration, the standard model for updating
   neuromorphic routing fabrics in operation).
2. After an epoch, its per-link :class:`~repro.core.telemetry.LinkLoad`
   becomes a congestion signal, and the next epoch's tables are rebuilt
   by **congestion-weighted shortest paths**
   (``RoutingTable.build_weighted``: integer edge costs
   ``base + alpha * load``, deterministic tie-breaks) — including the
   per-``(source, tag)`` ``MulticastTree`` Steiner branchings, which are
   regrown on the new tables through the same replication-table operands
   the engines already consume.
3. Routing tables travel as *dynamic operands* through the engines'
   shape-bucketed jit cache, so every epoch of a run reuses ONE XLA
   compilation (``AdaptiveReport.cache_size == 1``; asserted in tests).

Contracts (all tested):

* epoch 0 is bit-exact with static routing on the same slice (the base
  tables ARE the static tables);
* ``alpha = 0`` (or a zero load signal) rebuilds tables bit-identical to
  BFS, so an adaptive run degenerates to ``Fabric.run_epochs`` under
  ``StaticShortestPath`` exactly;
* telemetry counters merge additively, and the merged result keeps
  ``delivered + drops == injected``.

Batched execution (``Fabric.run_batch`` / ``fabric.run_batch``) refuses
adaptive policies by design: the epoch loop is a *sequential feedback
control loop* — epoch ``k``'s telemetry re-weights epoch ``k + 1``'s
tables — so B adaptive instances cannot fuse into one feed-forward
computation without changing semantics.  Batch the static baseline
(``StaticShortestPath`` or prebuilt tables) instead, or run adaptive
specs through ``Fabric.run`` / ``run_epochs`` one at a time; the epoch
slices of those runs still share one compilation via the shape-bucketed
jit cache.

Policies (`AdaptiveRouting.policy`):

``"min_backlog"``
    Signal = normalized backlog-step integral + normalized weighted
    drops per link.  Reacts to *queueing* — prefer it for bursty or
    capacity-limited fabrics where drops and standing backlog mark the
    contended links.
``"weighted_bfs"``
    Signal = link traversal counts.  Reacts to *utilization* — prefer
    it for steady skewed load where you want flows spread by volume
    before queues ever build.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

from .network import FabricResult
from .router import RoutingTable, Topology
from .telemetry import LinkLoad, link_load, merge_telemetry
from .traffic import TrafficSpec

__all__ = ["AdaptiveRouting", "AdaptiveReport", "EpochRecord",
           "partition_epochs", "merge_results", "run_epoched",
           "shared_max_steps"]

#: Integer quantisation of congestion-weighted edge costs: a base cost
#: of _COST_SCALE per link plus up to ``alpha * _COST_SCALE`` of
#: congestion penalty, rounded — reproducible across platforms, and a
#: zero penalty is *exactly* uniform (BFS-degenerate).
_COST_SCALE = 1024


@dataclass(frozen=True)
class AdaptiveRouting:
    """Congestion-adaptive routing policy (a ``fabric.RoutingPolicy``).

    ``policy`` — congestion signal: ``"min_backlog"`` (backlog + drops)
    or ``"weighted_bfs"`` (traversals); see the module docstring for
    when to prefer which.
    ``epochs`` — number of injection-time slices the run is split into;
    tables are recomputed between consecutive epochs.  ``epochs=1``
    never adapts (identical to static routing).
    ``alpha``  — congestion weight: next-epoch edge cost is
    ``1 + alpha * load / max(load)`` (quantised).  ``alpha=0`` is
    bit-exact static routing; ``alpha < 1`` only re-balances among
    equal-hop alternatives (a detour can never pay); larger values buy
    longer detours around contended links (a detour of ``k`` extra hops
    pays off once the contended link's normalized load exceeds
    ``k / alpha``).
    ``ema``    — congestion-signal smoothing in (0, 1]: the signal fed
    to the table rebuild is ``ema * this_epoch + (1 - ema) * previous
    signal``.  1.0 reacts instantly but can flip-flop all flows between
    alternatives epoch over epoch (the classic stale-signal
    oscillation); smaller values damp the swing and settle on a split.
    ``trigger`` — when tables are rebuilt between epochs.  ``"epoch"``
    (default): after every epoch, unconditionally.  ``"backlog_burst"``:
    event-driven — only when one link's congestion (backlog + stall +
    drop integral) bursts past ``threshold ×`` the fabric mean;
    quiescent or evenly-loaded epochs keep their tables, so a fabric
    under benign load never churns routes (and never pays the
    tree-regrow setup) while a hot-spot burst still reroutes within one
    epoch.  The EMA signal keeps folding every epoch either way, so a
    slow-building burst is judged on its full history when it crosses.
    ``threshold`` — the burst factor for ``trigger="backlog_burst"``;
    ``0`` rebuilds whenever any congestion exists at all.
    """
    policy: str = "min_backlog"
    epochs: int = 4
    alpha: float = 2.0
    ema: float = 0.5
    trigger: str = "epoch"
    threshold: float = 4.0

    POLICIES = ("min_backlog", "weighted_bfs")
    TRIGGERS = ("epoch", "backlog_burst")

    def __post_init__(self):
        if self.policy not in self.POLICIES:
            raise ValueError(f"unknown adaptive policy {self.policy!r}; "
                             f"expected one of {self.POLICIES}")
        if int(self.epochs) < 1:
            raise ValueError(f"epochs must be >= 1, got {self.epochs}")
        if float(self.alpha) < 0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if not 0.0 < float(self.ema) <= 1.0:
            raise ValueError(f"ema must be in (0, 1], got {self.ema}")
        if self.trigger not in self.TRIGGERS:
            raise ValueError(f"unknown trigger {self.trigger!r}; "
                             f"expected one of {self.TRIGGERS}")
        if float(self.threshold) < 0:
            raise ValueError(f"threshold must be >= 0, got "
                             f"{self.threshold}")

    # --- RoutingPolicy protocol: epoch-0 tables ARE the static tables --
    def build(self, topo: Topology) -> RoutingTable:
        return RoutingTable.build(topo)

    # --- the control loop's two pure functions -------------------------
    def load_signal(self, result: FabricResult) -> np.ndarray:
        """(L,) float congestion signal from one epoch's telemetry."""
        ll = link_load(result)
        if self.policy == "weighted_bfs":
            return ll.traversals.astype(np.float64)
        backlog = ll.backlog_steps.astype(np.float64)
        drops = ll.drops.astype(np.float64)
        stalls = ll.stalls.astype(np.float64)
        if backlog.max(initial=0) > 0:
            backlog = backlog / backlog.max()
        if drops.max(initial=0) > 0:
            drops = drops / drops.max()
        # flow-control stalls mark the links the lossless modes throttle
        # on — the congestion drops used to flag; zero in drop mode, so
        # historical drop-mode signals are untouched
        if stalls.max(initial=0) > 0:
            stalls = stalls / stalls.max()
        return backlog + drops + stalls

    def should_rebuild(self, load: LinkLoad) -> bool:
        """Event-driven rebuild gate: does this epoch's telemetry warrant
        new tables?  Always true under ``trigger="epoch"``; under
        ``"backlog_burst"``, true only when the hottest link's congestion
        integral bursts past ``threshold ×`` the fabric-wide mean."""
        if self.trigger == "epoch":
            return True
        hot = (load.backlog_steps.astype(np.float64)
               + load.stalls.astype(np.float64)
               + load.drops.astype(np.float64))
        mx = float(hot.max(initial=0.0))
        return mx > 0.0 and mx > float(self.threshold) * float(hot.mean())

    def next_table(self, topo: Topology, load: np.ndarray) -> RoutingTable:
        """Congestion-weighted shortest-path tables for the next epoch."""
        load = np.asarray(load, np.float64)
        mx = load.max(initial=0.0)
        if mx <= 0 or float(self.alpha) == 0.0:
            cost = np.full(topo.n_links, _COST_SCALE, np.int64)
        else:
            cost = np.rint(_COST_SCALE
                           * (1.0 + float(self.alpha) * load / mx)
                           ).astype(np.int64)
        return RoutingTable.build_weighted(topo, cost)


class EpochRecord(NamedTuple):
    """One epoch of an epoched run, as the report exposes it."""
    result: FabricResult        # the epoch's own FabricResult
    table: RoutingTable         # tables the epoch ran on
    load: LinkLoad              # the epoch's telemetry roll-up
    bucket: tuple               # engine shape bucket the epoch used
    cache_size: int             # jit entries in that bucket's engine
    rebuilt: bool = True        # tables rebuilt AFTER this epoch?


class AdaptiveReport(NamedTuple):
    """Side-channel record of one epoched run (``Fabric.last_report``).

    ``buckets`` is the ordered set of engine shape buckets the epochs
    used and ``cache_size`` the final jit-cache entry count of the
    shared engine.  The zero-recompile contract is :attr:`recompiled`
    ``== False``: one bucket, and the entry count flat from the first
    epoch on (epoch 0 pays the one compilation; in a fresh process the
    count is exactly 1, but an engine function can be shared by sibling
    buckets — e.g. a multicast-capable fabric of the same size — so
    *flatness*, not the absolute count, is the invariant).
    """
    records: tuple[EpochRecord, ...]
    buckets: tuple[tuple, ...]
    cache_size: int
    result: FabricResult

    @property
    def n_epochs(self) -> int:
        return len(self.records)

    @property
    def recompiled(self) -> bool:
        """True if any epoch after the first compiled anything new."""
        sizes = [r.cache_size for r in self.records]
        return len(self.buckets) != 1 or any(s != sizes[0] for s in sizes)


def partition_epochs(spec: TrafficSpec, epochs: int) -> list[TrafficSpec]:
    """Split a workload into ``epochs`` contiguous injection-time slices.

    Events are ranked by ``(t, original index)`` (stable) and cut into
    near-equal count slices (``i * n // epochs`` boundaries — exactly
    equal when ``n`` divides, which also keeps the slot engines on one
    shape bucket).  Within a slice the original event order is kept.
    Empty slices (more epochs than events) are omitted.
    """
    if int(epochs) < 1:
        raise ValueError(f"epochs must be >= 1, got {epochs}")
    t = np.asarray(spec.t)
    n = len(t)
    order = np.argsort(t, kind="stable")
    parts = []
    for i in range(int(epochs)):
        sel = order[i * n // epochs:(i + 1) * n // epochs]
        if not len(sel):
            continue
        idx = np.sort(sel)
        parts.append(TrafficSpec(src=spec.src[idx], t=spec.t[idx],
                                 dest=spec.dest[idx]))
    return parts


def merge_results(results: list[FabricResult], *,
                  offered: int) -> FabricResult:
    """Fold per-epoch results into one workload-level ``FabricResult``.

    Counters are extensive (summed); delivery logs concatenate in epoch
    order (each trimmed to its own ``delivered``); clocks take the
    elementwise maximum — injection times are absolute across the whole
    run, so the last epoch's clocks ARE the end of the run and
    latency/throughput roll-ups stay exact.
    """
    if not results:
        raise ValueError("no epoch results to merge")
    ns = [int(r.delivered) for r in results]
    cat = {f: np.concatenate([np.asarray(getattr(r, f))[:k]
                              for r, k in zip(results, ns)])
           for f in ("log_inj", "log_del", "log_dest")}
    return FabricResult(
        delivered=np.int32(sum(ns)),
        injected=sum(r.injected for r in results),
        log_inj=cat["log_inj"], log_del=cat["log_del"],
        log_dest=cat["log_dest"],
        sent=sum(np.asarray(r.sent, np.int64) for r in results),
        n_switches=sum(np.asarray(r.n_switches, np.int64)
                       for r in results),
        t_link=np.maximum.reduce([np.asarray(r.t_link) for r in results]),
        t_end=np.int32(max(int(r.t_end) for r in results)),
        drops=np.int64(sum(int(r.drops) for r in results)),
        offered=offered,
        telemetry=merge_telemetry([r.telemetry for r in results]))


def shared_max_steps(fabric, parts: list[TrafficSpec], *,
                     detour_factor: float = 1.0) -> int:
    """One step bound for every epoch, scaled for detour headroom.

    A congestion-weighted route can be longer than the static shortest
    path: a contended link costs up to ``(1 + alpha)`` base units while
    every hop costs at least one, so weighted path length is bounded by
    ``(1 + alpha) *`` static hops — and, since weighted routes are
    loop-free, by ``n_chips - 1`` hops absolutely.  The caller passes
    ``detour_factor = 1 + alpha`` (floored at 2 for legacy headroom) and
    the per-slice transmission estimate is scaled by it under the
    absolute hop cap, so an auto-computed bound can never bind on a
    completed adaptive epoch.  A single static value keeps the slot
    engines (which bake the scan length into their shape bucket) on ONE
    compilation across epochs.

    Unicast/source-expand slices use a direct estimate (the same
    ``4 * total_tx + 2 * E + 64 * (diameter + 2)`` formula ``_plan_impl``
    defaults to) so the full plan — prefill, stream-quota path walk — is
    built exactly once per slice, at run time; only in-fabric multicast
    slices need the tree-building plan to know their bound."""
    from .network import _expand
    rt = fabric.routing_table
    f = max(2.0, float(detour_factor))
    N = fabric.topo.n_chips
    ms = 0
    for p in parts:
        if fabric.mcast_policy.mode == "in_fabric":
            ms = max(ms, int(np.ceil(
                f * fabric._plan_impl(p, None).max_steps)))
            continue
        src, _t, dest = _expand(p, fabric.addr, fabric.mcast)
        total_tx = min(int(np.ceil(f * int(rt.hops[src, dest].sum()))),
                       len(src) * max(N - 1, 1))
        ms = max(ms, 4 * total_tx + 2 * len(src)
                 + 64 * (rt.diameter + 2))
    return ms


def run_epoched(fabric, spec: TrafficSpec, *, epochs: int,
                max_steps: int | None = None,
                policy: AdaptiveRouting | None = None) -> FabricResult:
    """Run ``spec`` in injection-time epochs on ``fabric``.

    With ``policy=None`` the fabric's own (static) tables serve every
    epoch — the fair A/B baseline for adaptive runs, sharing this exact
    partition/merge path.  With an :class:`AdaptiveRouting` policy, each
    epoch's telemetry re-weights the next epoch's tables (unicast AND
    multicast trees — the per-epoch fabric rebuilds its Steiner
    branchings from the new tables).  The merged ``FabricResult`` comes
    back; the per-epoch breakdown lands on ``fabric.last_report``.
    """
    parts = partition_epochs(spec, epochs)
    if not parts:
        raise ValueError("workload has no events")
    auto_bound = max_steps is None
    shared_ms = (int(max_steps) if max_steps is not None
                 else shared_max_steps(
                     fabric, parts,
                     detour_factor=1.0 + float(policy.alpha)
                     if policy is not None else 1.0))
    records: list[EpochRecord] = []
    results: list[FabricResult] = []
    epoch_fab = fabric
    table = fabric.routing_table
    signal = None  # EMA-smoothed congestion signal across epochs
    for e, part in enumerate(parts):
        res = epoch_fab._run_single(part, max_steps=shared_ms)
        if auto_bound and \
                int(res.delivered) + int(res.drops) != res.injected:
            # the auto bound must never bind: raising beats silently
            # under-reporting drops/latency (an EXPLICIT max_steps is
            # the caller's business and may truncate, as the engines
            # document)
            raise RuntimeError(
                f"epoch {e} truncated at the auto step bound "
                f"{shared_ms} ({int(res.delivered)} + {int(res.drops)} "
                f"of {res.injected} accounted); pass max_steps "
                f"explicitly to run_epochs/run")
        bucket = epoch_fab._plan(part, shared_ms).bucket
        cf = epoch_fab._get_compiled(bucket)
        load = link_load(res)
        rebuild = (policy is not None and e + 1 < len(parts)
                   and policy.should_rebuild(load))
        records.append(EpochRecord(result=res, table=table, load=load,
                                   bucket=bucket,
                                   cache_size=cf.cache_size(),
                                   rebuilt=rebuild))
        results.append(res)
        if policy is not None and e + 1 < len(parts):
            # the EMA signal folds every epoch (a slow-building burst is
            # judged on its history); the table rebuild itself waits for
            # the policy's trigger
            raw = policy.load_signal(res)
            signal = raw if signal is None else (
                float(policy.ema) * raw
                + (1.0 - float(policy.ema)) * signal)
            if rebuild:
                table = policy.next_table(fabric.topo, signal)
                epoch_fab = fabric._with_routing(table)
    merged = merge_results(results, offered=spec.n_events)
    fabric.last_report = AdaptiveReport(
        records=tuple(records),
        buckets=tuple(dict.fromkeys(r.bucket for r in records)),
        cache_size=records[-1].cache_size,
        result=merged)
    return merged
