"""Hierarchical AER addressing + routing for multi-chip transceiver fabrics.

The paper validates one bi-directional link; its stated purpose is
large-scale multi-chip systems.  This module supplies the addressing layer
that scales the link into a fabric, following the hierarchy used by
DYNAPs-style boards (Moradi et al. 2017) and the tag-expansion multicast of
Su et al. 2024:

* ``AddressSpec`` — carves the paper's 26-bit parallel AER word into
  ``[mcast flag | chip id | core/neuron tag]`` fields.  Unicast events carry
  an explicit destination chip; multicast events carry a *tag* that each
  expansion point resolves through a ``MulticastTable``.
* ``Topology`` — chips + bi-directional links (each link is one instance of
  the paper's transceiver pair sharing one AER bus).  Builders for line,
  ring and 2-D mesh fabrics.
* ``RoutingTable`` — deterministic BFS shortest-path next-hop tables
  (``next_link`` / ``out_side`` / ``hops``), precomputed in numpy at build
  time so the in-scan forwarding step is a pure table gather.

Everything here is *setup-time* code (plain numpy, no tracing); the hot
per-micro-transaction path lives in ``network.py``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "AddressSpec", "Topology", "RoutingTable", "MulticastTable",
    "MulticastTree", "find_route_cycles", "route_step_tables",
    "find_tree_cycles", "line_topology", "ring_topology",
    "mesh2d_topology",
]


# -----------------------------------------------------------------------
# Hierarchical addressing over the 26-bit AER word
# -----------------------------------------------------------------------

@dataclass(frozen=True)
class AddressSpec:
    """Bit layout of one AER word: ``[mcast | chip | core]`` (MSB first).

    The paper's bus is ``word_bits`` = 26 wires.  One bit flags multicast;
    ``chip_bits`` name the destination chip (or the multicast tag when the
    flag is set); the rest is the on-chip core/neuron address that the
    fabric transports opaquely.
    """
    word_bits: int = 26
    chip_bits: int = 8

    @property
    def core_bits(self) -> int:
        return self.word_bits - self.chip_bits - 1

    @property
    def max_chips(self) -> int:
        return 1 << self.chip_bits

    @property
    def _mcast_bit(self) -> int:
        return 1 << (self.word_bits - 1)

    def pack(self, chip: np.ndarray, core: np.ndarray = 0) -> np.ndarray:
        chip = np.asarray(chip, np.int64)
        core = np.asarray(core, np.int64)
        if np.any(chip >= self.max_chips) or np.any(chip < 0):
            raise ValueError(f"chip id out of range for {self.chip_bits} bits")
        if np.any(core >= (1 << self.core_bits)) or np.any(core < 0):
            raise ValueError(f"core tag out of range for {self.core_bits} bits")
        return ((chip << self.core_bits) | core).astype(np.int32)

    def pack_multicast(self, tag: np.ndarray, core: np.ndarray = 0):
        return (self.pack(tag, core) | self._mcast_bit).astype(np.int32)

    def is_multicast(self, word: np.ndarray) -> np.ndarray:
        return (np.asarray(word, np.int64) & self._mcast_bit) != 0

    def unpack(self, word: np.ndarray):
        """Return ``(chip_or_tag, core)`` — check ``is_multicast`` first."""
        w = np.asarray(word, np.int64) & ~self._mcast_bit
        return ((w >> self.core_bits).astype(np.int32),
                (w & ((1 << self.core_bits) - 1)).astype(np.int32))


# -----------------------------------------------------------------------
# Topologies
# -----------------------------------------------------------------------

@dataclass(frozen=True)
class Topology:
    """``n_chips`` chips joined by bi-directional AER links.

    ``links[l] = (a, b)`` — link ``l`` connects chip ``a`` (the link's L
    side, side 0) to chip ``b`` (the R side, side 1).  Each link is one
    shared parallel bus with a transceiver block on both ends, exactly the
    paper's Fig. 1 pair.
    """
    n_chips: int
    links: np.ndarray  # (L, 2) int32
    name: str = "custom"

    def __post_init__(self):
        links = np.asarray(self.links, np.int32).reshape(-1, 2)
        object.__setattr__(self, "links", links)
        if len(links) and (links.min() < 0 or links.max() >= self.n_chips):
            raise ValueError("link endpoint out of range")
        if np.any(links[:, 0] == links[:, 1]):
            raise ValueError("self-loop link")

    @property
    def n_links(self) -> int:
        return len(self.links)


def line_topology(n_chips: int) -> Topology:
    links = [(i, i + 1) for i in range(n_chips - 1)]
    return Topology(n_chips, np.asarray(links, np.int32), name=f"line{n_chips}")


def ring_topology(n_chips: int) -> Topology:
    """Ring of n chips.  ``n == 2`` degenerates to a single link (the
    paper's measured configuration) rather than a doubled bus."""
    if n_chips < 2:
        raise ValueError("ring needs >= 2 chips")
    if n_chips == 2:
        return Topology(2, np.asarray([(0, 1)], np.int32), name="ring2")
    links = [(i, (i + 1) % n_chips) for i in range(n_chips)]
    return Topology(n_chips, np.asarray(links, np.int32),
                    name=f"ring{n_chips}")


def mesh2d_topology(rows: int, cols: int) -> Topology:
    """2-D mesh (the four-border chip floorplan of the paper's prototype
    scaled out): chip (r, c) has id ``r * cols + c``."""
    links = []
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            if c + 1 < cols:
                links.append((i, i + 1))
            if r + 1 < rows:
                links.append((i, i + cols))
    return Topology(rows * cols, np.asarray(links, np.int32),
                    name=f"mesh{rows}x{cols}")


# -----------------------------------------------------------------------
# Deterministic shortest-path routing
# -----------------------------------------------------------------------

@dataclass(frozen=True)
class RoutingTable:
    """Next-hop tables: at chip ``c`` an event for chip ``d`` departs on
    link ``next_link[c, d]`` from that link's side ``out_side[c, d]``
    (0 = the link's L endpoint, 1 = R).  ``hops[c, d]`` is the path length.
    Diagonals and unreachable pairs hold -1.
    """
    next_link: np.ndarray  # (N, N) int32
    out_side: np.ndarray   # (N, N) int32
    hops: np.ndarray       # (N, N) int32

    @staticmethod
    def build(topo: Topology) -> "RoutingTable":
        """BFS from every destination, ties broken by lowest (chip, link)
        so the tables are reproducible across runs."""
        n, links = topo.n_chips, topo.links
        # adjacency: chip -> sorted [(neighbor, link, my_side)]
        adj: list[list[tuple[int, int, int]]] = [[] for _ in range(n)]
        for l, (a, b) in enumerate(links):
            adj[a].append((b, l, 0))
            adj[b].append((a, l, 1))
        for lst in adj:
            lst.sort()

        next_link = np.full((n, n), -1, np.int32)
        out_side = np.full((n, n), -1, np.int32)
        hops = np.full((n, n), -1, np.int32)
        for dst in range(n):
            hops[dst, dst] = 0
            frontier = [dst]
            while frontier:
                nxt = []
                for u in frontier:
                    for v, l, side_of_u in adj[u]:
                        if hops[v, dst] == -1:
                            hops[v, dst] = hops[u, dst] + 1
                            # v forwards toward dst over link l; v sits on
                            # the opposite side from u.
                            next_link[v, dst] = l
                            out_side[v, dst] = 1 - side_of_u
                            nxt.append(v)
                frontier = sorted(nxt)
        return RoutingTable(next_link=next_link, out_side=out_side, hops=hops)

    @property
    def diameter(self) -> int:
        reach = self.hops[self.hops >= 0]
        return int(reach.max()) if reach.size else 0

    @staticmethod
    def build_weighted(topo: Topology,
                       link_cost: np.ndarray) -> "RoutingTable":
        """Shortest-path tables over positive per-link costs (Dijkstra
        from every destination) — the congestion-weighted generalisation
        of :meth:`build` the adaptive control plane recomputes per epoch.

        ``link_cost`` is an (L,) array of integer costs >= 1 (integer so
        route selection is exactly reproducible across platforms — the
        adaptive policies quantise their congestion weights before
        calling in).  Next hops minimise the total path cost; ties break
        to the lowest (predecessor chip, link) pair, which makes the
        choice deterministic AND makes uniform costs reproduce
        :meth:`build`'s BFS tables bit-exactly (tested) — so a zero
        congestion weight degenerates to static shortest-path routing.

        ``hops`` still counts *links traversed* along the chosen route
        (not cost): the step-bound and stream-quota estimators consume
        path lengths.  Next hops strictly decrease the remaining cost,
        so weighted routes can never cycle.
        """
        import heapq
        cost = np.asarray(link_cost)
        if cost.shape != (topo.n_links,):
            raise ValueError(f"link_cost must have shape "
                             f"({topo.n_links},), got {cost.shape}")
        if cost.size and (np.any(cost < 1)
                          or np.any(cost != np.floor(cost))):
            raise ValueError("link costs must be integers >= 1")
        cost = cost.astype(np.int64)
        n = topo.n_chips
        adj: list[list[tuple[int, int, int]]] = [[] for _ in range(n)]
        for l, (a, b) in enumerate(topo.links):
            adj[a].append((b, l, 0))
            adj[b].append((a, l, 1))
        for lst in adj:
            lst.sort()

        next_link = np.full((n, n), -1, np.int32)
        out_side = np.full((n, n), -1, np.int32)
        hops = np.full((n, n), -1, np.int32)
        inf = np.iinfo(np.int64).max
        for dst in range(n):
            dist = np.full(n, inf, np.int64)
            dist[dst] = 0
            heap = [(0, dst)]
            done = np.zeros(n, bool)
            order = []
            while heap:
                d, u = heapq.heappop(heap)
                if done[u]:
                    continue
                done[u] = True
                order.append(u)
                for v, l, _side_u in adj[u]:
                    nd = d + cost[l]
                    if nd < dist[v]:
                        dist[v] = nd
                        heapq.heappush(heap, (nd, v))
            hops[dst, dst] = 0
            # settle next hops in ascending (dist, chip) order: the
            # chosen predecessor always has strictly smaller dist, so
            # its hop count is final when we read it.  adj[v] entries
            # are (neighbor u, link l, v's side of l), sorted — the min
            # below is the deterministic (cost, chip, link) tie-break.
            for v in order[1:]:
                best = min((dist[u] + cost[l], u, l, side_v)
                           for u, l, side_v in adj[v]
                           if dist[u] < inf)
                _, u, l, side_v = best
                next_link[v, dst] = l
                out_side[v, dst] = side_v
                hops[v, dst] = hops[u, dst] + 1
        return RoutingTable(next_link=next_link, out_side=out_side,
                            hops=hops)


def route_step_tables(topo: Topology, rt: RoutingTable):
    """One-step traversal tables of the unicast functional route graph.

    ``step_to[c, d]`` is the chip an event at ``c`` bound for ``d``
    forwards to (the far endpoint of the chosen link) and
    ``step_q[c, d]`` the flat endpoint-queue id it transmits from
    (``link * 2 + out_side`` — the engines' queue encoding); both are
    -1 where no route exists.  This is THE definition of "the route an
    event takes": :func:`find_route_cycles` and the static verifier
    (``repro.analysis.verify``) walk the same tables, so the
    termination check and the channel-dependency graph can never
    disagree about a path.
    """
    links = topo.links
    nl = np.asarray(rt.next_link)
    os_ = np.asarray(rt.out_side)
    step_to = np.where(nl >= 0,
                       links[np.maximum(nl, 0), 1 - np.maximum(os_, 0)],
                       -1).astype(np.int32)
    step_q = np.where(nl >= 0, nl * 2 + np.maximum(os_, 0),
                      -1).astype(np.int32)
    return step_to, step_q


def find_tree_cycles(topo: Topology, trees) -> np.ndarray:
    """Chips whose in-fabric replication never terminates, per tree.

    A :class:`MulticastTree` route is the multicast analogue of a
    unicast ``next_link`` column: an event arriving at chip ``u`` on
    tree route ``N + i`` replicates along the tree's out-edges of
    ``u``.  Trees built by :meth:`MulticastTree.build` are rooted
    forests by construction, but hand-built trees (or corrupted
    replication tables) can carry an edge cycle — an event riding one
    replicates forever, exactly the failure mode a cyclic unicast
    column has.  For each tree the edge graph ``u -> v`` is reduced to
    a fixpoint of "all of my out-edges terminate"; chips that never
    reach it (they lie on, or feed into, an edge cycle) are reported
    as ``(chip, n_chips + i)`` pairs — the same (chip, route-id)
    coordinates the engines' replication tables use.
    """
    n = topo.n_chips
    bad: list[tuple[int, int]] = []
    for i, tree in enumerate(trees):
        edges = np.asarray(tree.edges, np.int64).reshape(-1, 4)
        if not len(edges):
            continue
        terminated = np.ones(n, bool)
        has_out = np.zeros(n, bool)
        has_out[edges[:, 0]] = True
        terminated[has_out] = False
        for _ in range(n):
            ok = terminated.copy()
            # a chip terminates once every chip it replicates to does
            nxt_ok = np.ones(n, bool)
            np.logical_and.at(nxt_ok, edges[:, 0], terminated[edges[:, 3]])
            ok |= nxt_ok & has_out
            if np.array_equal(ok, terminated):
                break
            terminated = ok
        touched = np.zeros(n, bool)
        touched[edges[:, 0]] = True
        touched[edges[:, 3]] = True
        for c in np.flatnonzero(touched & ~terminated):
            bad.append((int(c), n + i))
    return np.asarray(bad, np.int32).reshape(-1, 2)


def find_route_cycles(topo: Topology, rt: RoutingTable,
                      trees=()) -> np.ndarray:
    """All ``(chip, route)`` pairs whose forwarding walk never reaches
    delivery — i.e. the pairs caught on (or feeding into) a next-hop
    cycle of a hand-built / overridden table.

    For each destination the ``next_link`` column is a functional graph
    on chips; a walk from every chip either reaches the destination
    within ``n_chips - 1`` hops or is provably cyclic.  The walk is
    vectorised over all (chip, dest) pairs at once (numpy, setup-time)
    over the shared :func:`route_step_tables` traversal.  Pairs with no
    route at all (``next_link < 0`` off-diagonal) are *unreachable*,
    not cyclic, and are not reported — ``Fabric`` rejects those
    separately when traffic actually addresses them.

    ``trees`` extends the check to in-fabric multicast replication
    (route id ``n_chips + i`` for ``trees[i]``): chips whose
    replication walk cycles are reported in the same (chip, route)
    coordinates — see :func:`find_tree_cycles`.

    Tables built by :meth:`RoutingTable.build` (BFS) or
    :meth:`RoutingTable.build_weighted` (Dijkstra — next hops strictly
    decrease the remaining cost) are acyclic by construction; this check
    exists for ``table_override`` hooks and prebuilt tables, where a
    cycle would otherwise silently truncate at the step bound (drop
    mode) or deadlock the lossless flow-control modes.  Routes that
    dead-end mid-path (an intermediate chip with no next hop) are
    reported too — the walk never arrives either way.  Returns an
    ``(n_bad, 2)`` int32 array of ``(chip, route)`` pairs.
    """
    n = topo.n_chips
    step_to, _step_q = route_step_tables(topo, rt)
    dest = np.broadcast_to(np.arange(n)[None, :], (n, n))
    pos = np.broadcast_to(np.arange(n)[:, None], (n, n)).copy()
    routed = (np.asarray(rt.next_link) >= 0) & (pos != dest)
    for _ in range(max(n - 1, 0)):
        at_dest = pos == dest
        nxt = step_to[pos, dest]
        # walk only pairs that still have a route and haven't arrived
        pos = np.where(~at_dest & routed & (nxt >= 0), nxt, pos)
    cyclic = routed & (pos != dest)
    out = np.argwhere(cyclic).astype(np.int32)
    if len(trees):
        out = np.concatenate(
            [out.reshape(-1, 2), find_tree_cycles(topo, trees)], 0)
    return out.astype(np.int32)


# -----------------------------------------------------------------------
# Multicast (Su et al.-style tag expansion)
# -----------------------------------------------------------------------

@dataclass(frozen=True)
class MulticastTable:
    """Tag → member-chip sets.  ``members[tag, chip]`` is True when the
    chip subscribes to the tag.  Expansion replicates a tagged event into
    one unicast copy per member (the source never receives its own copy),
    which is how the Su et al. scheme resolves tags at expansion nodes.
    """
    members: np.ndarray  # (n_tags, n_chips) bool

    def __post_init__(self):
        object.__setattr__(self, "members",
                           np.asarray(self.members, bool).reshape(
                               len(self.members), -1))

    @property
    def n_tags(self) -> int:
        return self.members.shape[0]

    def expand(self, tag: int, src: int | None = None) -> np.ndarray:
        """Member chips of ``tag`` (excluding ``src`` when given)."""
        chips = np.flatnonzero(self.members[tag])
        if src is not None:
            chips = chips[chips != src]
        return chips.astype(np.int32)

    def expand_stream(self, src, t, tag):
        """Vector expansion of a tagged event stream into unicast triples.

        Returns ``(src', t', dest')`` where each input event is replicated
        once per member chip of its tag, source excluded.  Fully
        vectorized: one boolean gather + ``np.nonzero`` (row-major, so
        copies appear in event order and, within an event, in ascending
        member-chip order — exactly the order ``expand`` yields).
        """
        src = np.asarray(src, np.int32).reshape(-1)
        t = np.asarray(t, np.int32).reshape(-1)
        tag = np.asarray(tag, np.int32).reshape(-1)
        mask = self.members[tag].copy()          # (E, n_chips)
        if len(src):
            mask[np.arange(len(src)), src] = False   # source never receives
        ev, chips = np.nonzero(mask)
        return (src[ev].astype(np.int32), t[ev].astype(np.int32),
                chips.astype(np.int32))


# -----------------------------------------------------------------------
# In-fabric multicast replication trees
# -----------------------------------------------------------------------

@dataclass(frozen=True)
class MulticastTree:
    """Replication tree of one ``(source, tag)`` pair.

    The Steiner-branching of the per-destination BFS shortest paths:
    member paths are grafted onto the growing tree at their last shared
    node (members processed in ascending chip order, so the tree is
    deterministic), which guarantees every tree node has exactly ONE
    in-edge — an event replicated along the tree reaches each member
    exactly once.  A tagged event traverses each tree edge once instead
    of once per downstream member, which is where in-fabric replication
    saves link occupancy and energy over source expansion.

    ``edges[e] = (u, link, out_side, v)`` — the copy leaves chip ``u`` on
    ``link`` (from the link's ``out_side`` endpoint) toward ``v``.
    ``parent[e]`` is the edge index delivering into ``u`` (-1 for edges
    leaving the source — those become queue prefill, not in-fabric
    forwards).  ``deliver[c]`` marks member chips (source excluded);
    ``subtree[e]`` counts the final deliveries at or below ``v`` — the
    number of deliveries lost if the copy on edge ``e`` is dropped, the
    weight the engines' drop accounting uses to keep
    ``delivered + drops == expected`` exact.
    """
    src: int
    edges: np.ndarray    # (n_edges, 4) int32 [u, link, out_side, v]
    parent: np.ndarray   # (n_edges,) int32, -1 = source out-edge
    deliver: np.ndarray  # (n_chips,) bool
    subtree: np.ndarray  # (n_edges,) int32

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    @property
    def fanout(self) -> int:
        """Final deliveries per injected event on this tree."""
        return int(self.deliver.sum())

    @property
    def max_out_degree(self) -> int:
        """Largest *in-fabric* replication factor: the max out-degree
        over non-source nodes (the engines' K lane bound).  Source
        out-edges are prefill — one injected copy per root edge, never
        a mid-flight replication — so they do not widen K."""
        non_root = self.edges[self.parent >= 0]
        if not len(non_root):
            return 0
        return int(np.bincount(non_root[:, 0]).max())

    @staticmethod
    def build(topo: Topology, rt: RoutingTable, src: int,
              members: np.ndarray) -> "MulticastTree":
        """Graft each member's shortest path onto the tree at the last
        on-path node already covered (ascending member order)."""
        deliver = np.zeros(topo.n_chips, bool)
        in_edge: dict[int, int] = {int(src): -1}
        edges: list[tuple[int, int, int, int]] = []
        parent: list[int] = []
        for d in sorted(int(m) for m in np.asarray(members).reshape(-1)):
            if d == src:
                continue
            if rt.hops[src, d] < 0:
                raise ValueError(f"multicast member chip {d} unreachable "
                                 f"from source {src}")
            deliver[d] = True
            path = []
            c = int(src)
            while c != d:
                l = int(rt.next_link[c, d])
                s = int(rt.out_side[c, d])
                v = int(topo.links[l][1 - s])
                path.append((c, l, s, v))
                c = v
            nodes = [int(src)] + [st[3] for st in path]
            graft = max(i for i, nd in enumerate(nodes) if nd in in_edge)
            for (u, l, s, v) in path[graft:]:
                parent.append(in_edge[u])
                in_edge[v] = len(edges)
                edges.append((u, l, s, v))
        edges_a = np.asarray(edges, np.int32).reshape(-1, 4)
        parent_a = np.asarray(parent, np.int32).reshape(-1)
        subtree = deliver[edges_a[:, 3]].astype(np.int32) \
            if len(edges) else np.zeros(0, np.int32)
        for e in range(len(edges) - 1, -1, -1):
            if parent_a[e] >= 0:
                subtree[parent_a[e]] += subtree[e]
        return MulticastTree(src=int(src), edges=edges_a, parent=parent_a,
                             deliver=deliver, subtree=subtree)
