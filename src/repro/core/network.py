"""N-chip AER fabric simulator: the paper's link pair, scaled out.

The paper measures ONE bi-directional transceiver pair on one shared AER
bus.  This module composes many such pairs into a multi-chip fabric
(line / ring / 2-D mesh — ``router.Topology``): every link of the fabric
is one paper-faithful ``protocol_sim.LinkState`` micro-transaction unit,
and one global ``lax.scan`` step advances **all** links simultaneously via
``jax.vmap(link_step)`` — the LinkSim unit batches across links.

Event transport
---------------
Each link endpoint owns a fixed-capacity queue of
``(release_time, dest_chip, inject_time)`` entries.  Injected traffic
(``traffic.TrafficSpec``) is routed to its first-hop queue at setup time
(numpy, sorted by time).  When a link delivers an event to a chip that is
not its destination, the event is re-queued on that chip's next-hop link
(``router.RoutingTable`` gather) with release time equal to its delivery
time — multi-hop latency accumulates exactly.

An entry only *enters* the physical FIFO at its release time, so service
order is release-time order (FIFO among equal times): a forward that has
already arrived is never blocked behind a pre-routed injection that has
not happened yet.  Slots are one-shot (consumed entries are not reused),
so ``queue_capacity`` bounds the total events *through* an endpoint, not
its instantaneous depth; the lossless default (= expanded event count)
can never drop.

Clocks are link-local, exactly as in ``protocol_sim.simulate``: a link
whose queues are empty *parks* (its clock holds) and wakes when a forward
lands.  Cross-link causality is kept by conservative lookahead against
the fabric-wide lower bound on future event releases (min over links of
"clock if work is pending, else own next arrival", plus one event cycle
for the insert bound): idle links never jump past it, and a busy link
pops an entry only once no future forward can precede it — so queues
serve in true release order and end-to-end latencies are exact.

The degenerate 2-chip fabric runs the identical ``link_step`` code path
with the identical pending/next-arrival semantics as
``protocol_sim.simulate`` and therefore reproduces its event departure
times, switch counts and ``t_end`` bit-exactly (tested in
``tests/test_fabric.py``).

Measurements: per-event latency log, per-link/direction transmission
counts, direction-switch counts, energy roll-up (every hop is one paper
event: ``e_event_pj``), aggregate + per-link throughput.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .link import LinkTiming, PAPER_TIMING
from .protocol_sim import BIG_NS, LinkState, link_step, reset_link
from .router import AddressSpec, MulticastTable, RoutingTable, Topology
from .traffic import TrafficSpec

__all__ = ["FabricResult", "simulate_fabric", "reset_links",
           "fabric_throughput_mev_s", "fabric_energy_pj",
           "per_link_throughput_mev_s", "delivered_latencies",
           "latency_stats"]

_BIG = BIG_NS  # one sentinel shared with link_step's park/wake contract


class FabricState(NamedTuple):
    link: LinkState         # (L,)-leaved LinkSim batch
    q_time: jnp.ndarray     # (L, 2, C) release times; BIG_NS = empty/consumed
    q_dest: jnp.ndarray     # (L, 2, C) destination chip
    q_inj: jnp.ndarray      # (L, 2, C) original injection time
    n_ins: jnp.ndarray      # (L, 2) entries ever inserted (next free slot)
    sent: jnp.ndarray       # (L, 2) transmissions per direction (0: L->R)
    prev_mode_l: jnp.ndarray  # (L,) for switch counting
    n_sw: jnp.ndarray       # (L,) mode_l transitions (excl. reset step)
    log_inj: jnp.ndarray    # (E,) delivery log: injection time
    log_del: jnp.ndarray    # (E,) delivery log: delivery time
    log_dest: jnp.ndarray   # (E,) delivery log: destination chip
    log_n: jnp.ndarray      # scalar: deliveries so far
    drops: jnp.ndarray      # scalar: forwards lost to a full queue


class FabricResult(NamedTuple):
    delivered: jnp.ndarray   # scalar int32
    injected: int            # static: expanded events offered
    log_inj: jnp.ndarray     # (E,) valid up to ``delivered``
    log_del: jnp.ndarray
    log_dest: jnp.ndarray
    sent: jnp.ndarray        # (L, 2) per-link/direction transmissions
    n_switches: jnp.ndarray  # (L,) direction switches per link
    t_link: jnp.ndarray      # (L,) final link-local clocks
    t_end: jnp.ndarray       # scalar: max over links
    drops: jnp.ndarray       # scalar


def reset_links(initial_tx: np.ndarray) -> LinkState:
    """Batched ``protocol_sim.reset_link``: leaf shape (L,)."""
    return jax.vmap(reset_link)(jnp.asarray(initial_tx, jnp.int32))


def _prefill(topo: Topology, rt: RoutingTable, src, t, dest, capacity: int):
    """Route every injected event to its first-hop queue (numpy, setup)."""
    L = topo.n_links
    first_link = rt.next_link[src, dest]
    first_side = rt.out_side[src, dest]
    if np.any(first_link < 0):
        bad = np.flatnonzero(first_link < 0)[:4]
        raise ValueError(f"unreachable destinations, e.g. events {bad}: "
                         f"src={src[bad]} dest={dest[bad]}")
    grp = first_link * 2 + first_side
    order = np.lexsort((np.arange(len(t)), t, grp))  # stable time order
    grp_s, t_s, dest_s, inj_s = grp[order], t[order], dest[order], t[order]

    sizes = np.bincount(grp, minlength=2 * L).astype(np.int32)
    if sizes.max(initial=0) > capacity:
        raise ValueError(f"queue capacity {capacity} < initial backlog "
                         f"{sizes.max()}; raise queue_capacity")
    # within-queue slot = position since the queue's first event
    starts = np.zeros(2 * L + 1, np.int64)
    np.cumsum(sizes, out=starts[1:2 * L + 1])
    slot = np.arange(len(t)) - starts[grp_s]

    # empty slots hold the BIG_NS sentinel: "never released"
    q_time = np.full((2 * L, capacity), int(_BIG), np.int32)
    q_dest = np.zeros((2 * L, capacity), np.int32)
    q_inj = np.zeros((2 * L, capacity), np.int32)
    q_time[grp_s, slot] = t_s
    q_dest[grp_s, slot] = dest_s
    q_inj[grp_s, slot] = inj_s
    return (q_time.reshape(L, 2, capacity), q_dest.reshape(L, 2, capacity),
            q_inj.reshape(L, 2, capacity), sizes.reshape(L, 2))


def _expand(spec: TrafficSpec, addr: AddressSpec | None,
            mcast: MulticastTable | None):
    """Resolve packed/multicast destinations into unicast chip triples."""
    src = np.asarray(spec.src, np.int32)
    t = np.asarray(spec.t, np.int32)
    dest = np.asarray(spec.dest, np.int32)
    if addr is None:
        return src, t, dest
    is_mc = addr.is_multicast(dest)
    chip_or_tag, _ = addr.unpack(dest)
    out_s = [src[~is_mc]]
    out_t = [t[~is_mc]]
    out_d = [chip_or_tag[~is_mc]]
    if np.any(is_mc):
        if mcast is None:
            raise ValueError("multicast events but no MulticastTable")
        ms, mt, md = mcast.expand_stream(src[is_mc], t[is_mc],
                                         chip_or_tag[is_mc])
        out_s.append(ms)
        out_t.append(mt)
        out_d.append(md)
    return (np.concatenate(out_s), np.concatenate(out_t),
            np.concatenate(out_d))


def simulate_fabric(topo: Topology,
                    spec: TrafficSpec,
                    *,
                    routing: RoutingTable | None = None,
                    addr: AddressSpec | None = None,
                    mcast: MulticastTable | None = None,
                    timing: LinkTiming = PAPER_TIMING,
                    max_burst: int = 0,
                    initial_tx: int | np.ndarray = 1,
                    max_steps: int | None = None,
                    queue_capacity: int | None = None) -> FabricResult:
    """Simulate an N-chip fabric of bi-directional AER links.

    Args:
      topo:        fabric topology (``router.line/ring/mesh2d_topology``).
      spec:        injected traffic.  With ``addr`` given, ``spec.dest``
                   holds packed 26-bit AER words (multicast tags expanded
                   through ``mcast``); otherwise plain destination chip ids.
      routing:     prebuilt table (rebuilt from ``topo`` when omitted).
      timing:      per-link timing contract (shared by all links).
      max_burst:   0 = paper-faithful grant rule, B > 0 = bounded burst.
      initial_tx:  scalar or (L,) — which side of each link resets into TX.
      max_steps:   global micro-transaction count; default scales with the
                   total hop-transmissions the traffic needs.
      queue_capacity: per-endpoint slot budget — slots are one-shot, so
                   this bounds the total events routed *through* an
                   endpoint, not instantaneous depth.  Defaults to the
                   expanded event count (lossless).  Smaller values may
                   drop forwards, counted in ``FabricResult.drops``.
    """
    rt = routing if routing is not None else RoutingTable.build(topo)
    src, t, dest = _expand(spec, addr, mcast)
    if np.any(src == dest):
        raise ValueError("self-addressed events (src == dest)")
    E = len(src)
    L = topo.n_links
    if L == 0 or E == 0:
        raise ValueError("need at least one link and one event")

    C = int(queue_capacity) if queue_capacity is not None else max(E, 1)
    if max_steps is None:
        total_tx = int(rt.hops[src, dest].sum())
        max_steps = 4 * total_tx + 2 * E + 64 * (rt.diameter + 2)

    qt, qd, qi, sizes = _prefill(topo, rt, src, t, dest, C)
    init_tx = np.broadcast_to(np.asarray(initial_tx, np.int32), (L,))

    links_j = jnp.asarray(topo.links, jnp.int32)          # (L, 2)
    next_link_j = jnp.asarray(rt.next_link, jnp.int32)    # (N, N)
    out_side_j = jnp.asarray(rt.out_side, jnp.int32)
    t_cycle = jnp.int32(timing.t_req2req_ns)              # min delivery gap

    step_v = jax.vmap(
        lambda s, pl, pr, na: link_step(s, pl, pr, na,
                                        timing=timing, max_burst=max_burst))

    link0 = reset_links(init_tx)
    init = FabricState(
        link=link0,
        q_time=jnp.asarray(qt), q_dest=jnp.asarray(qd), q_inj=jnp.asarray(qi),
        n_ins=jnp.asarray(sizes),
        sent=jnp.zeros((L, 2), jnp.int32),
        prev_mode_l=link0.xl.mode,
        n_sw=jnp.zeros((L,), jnp.int32),
        log_inj=jnp.zeros((E,), jnp.int32),
        log_del=jnp.zeros((E,), jnp.int32),
        log_dest=jnp.zeros((E,), jnp.int32),
        log_n=jnp.zeros((), jnp.int32),
        drops=jnp.zeros((), jnp.int32),
    )

    lidx = jnp.arange(L)

    def body(s: FabricState, step_i):
        t_now = s.link.t  # (L,)

        # --- pending & next-arrival per endpoint queue ------------------
        # An entry is *in* the FIFO once its release time has passed;
        # empty/consumed slots hold BIG_NS and never match.  Service order
        # is release-time order (argmin; ties resolve to the lowest slot,
        # i.e. FIFO among simultaneous arrivals), which for the sorted
        # single-hop prefill is exactly simulate()'s searchsorted count.
        released = s.q_time <= t_now[:, None, None]              # (L,2,C)
        pend = jnp.sum(released.astype(jnp.int32), axis=2)       # (L,2)
        nxt = jnp.min(jnp.where(released, _BIG, s.q_time), axis=2)
        t_next = jnp.min(nxt, axis=1)                            # (L,)

        # --- conservative clock synchronization -------------------------
        # A link acts no earlier than its clock (work pending) or its own
        # next arrival: ``na``.  Any *future* forward is released at some
        # link's next delivery, i.e. no earlier than min(na) + t_cycle.
        # Two consequences keep every queue in true release order:
        #   * idle links never jump past min(na), so a parked clock never
        #     overtakes a forward still in flight;
        #   * a busy link may pop its earliest released entry only if its
        #     release precedes every possible future insert (release <=
        #     min(na) + t_cycle) — otherwise it stalls until the rest of
        #     the fabric catches up (classic conservative lookahead).
        # With one link both guards are vacuous (its own bound is always
        # the loosest), so simulate() semantics are preserved bit-exactly.
        pend_any = (pend[:, 0] + pend[:, 1]) > 0
        na = jnp.where(pend_any, t_now, t_next)
        horizon = jnp.min(na)
        t_next_eff = jnp.minimum(t_next, jnp.maximum(horizon, t_now))
        r_min = jnp.min(jnp.where(released, s.q_time, _BIG), axis=2)
        safe = r_min <= horizon + t_cycle                         # (L,2)
        pend_safe = jnp.where(safe, pend, 0)

        # --- one micro-transaction on every link, batched ---------------
        link, out = step_v(s.link, pend_safe[:, 0], pend_safe[:, 1],
                           t_next_eff)

        did = (out.tx_l + out.tx_r) > 0                          # (L,) bool
        did32 = did.astype(jnp.int32)
        send_side = jnp.where(out.tx_l == 1, 0, 1)               # (L,)
        q_sel = s.q_time[lidx, send_side]                        # (L, C)
        pop_slot = jnp.argmin(
            jnp.where(q_sel <= t_now[:, None], q_sel, _BIG), axis=1)
        ev_dest = s.q_dest[lidx, send_side, pop_slot]
        ev_inj = s.q_inj[lidx, send_side, pop_slot]
        # consume the popped slot (one-shot slots; no reuse)
        popped_t = jnp.where(did, _BIG, q_sel[lidx, pop_slot])
        q_time = s.q_time.at[lidx, send_side, pop_slot].set(popped_t)
        sent = s.sent.at[lidx, send_side].add(did32)

        # --- deliver or forward ----------------------------------------
        rx_chip = jnp.where(out.tx_l == 1, links_j[:, 1], links_j[:, 0])
        deliver = did & (ev_dest == rx_chip)
        forward = did & ~deliver

        d32 = deliver.astype(jnp.int32)
        log_slot = jnp.where(deliver, s.log_n + jnp.cumsum(d32) - d32, E)
        log_inj = s.log_inj.at[log_slot].set(ev_inj, mode="drop")
        log_del = s.log_del.at[log_slot].set(link.t, mode="drop")
        log_dest = s.log_dest.at[log_slot].set(ev_dest, mode="drop")
        log_n = s.log_n + jnp.sum(d32)

        nl = next_link_j[rx_chip, ev_dest]
        nside = out_side_j[rx_chip, ev_dest]
        fq = nl * 2 + nside                                      # (L,)
        fq_m = jnp.where(forward, fq, 2 * L)   # sentinel for non-forwards
        # simultaneous forwards into one queue: order by link index
        before = (fq_m[None, :] == fq_m[:, None]) \
            & (lidx[None, :] < lidx[:, None]) & forward[None, :]
        offs = jnp.sum(before.astype(jnp.int32), axis=1)
        fq_g = jnp.where(forward, fq, 0)
        n_ins_f = s.n_ins.reshape(-1)
        slot = n_ins_f[fq_g] + offs            # next free slot
        cap_ok = slot < C
        app = forward & cap_ok
        fq_s = jnp.where(app, fq_g, 2 * L)     # drop non-appends
        q_time = q_time.reshape(2 * L, C) \
            .at[fq_s, slot].set(link.t, mode="drop").reshape(L, 2, C)
        q_dest = s.q_dest.reshape(2 * L, C) \
            .at[fq_s, slot].set(ev_dest, mode="drop").reshape(L, 2, C)
        q_inj = s.q_inj.reshape(2 * L, C) \
            .at[fq_s, slot].set(ev_inj, mode="drop").reshape(L, 2, C)
        n_ins = n_ins_f.at[fq_s].add(1, mode="drop").reshape(L, 2)
        drops = s.drops + jnp.sum((forward & ~cap_ok).astype(jnp.int32))

        # --- switch counting (matches SimResult.n_switches: mode_l
        # transitions between consecutive steps, reset step excluded) ----
        n_sw = s.n_sw + jnp.where(
            step_i > 0, (link.xl.mode != s.prev_mode_l).astype(jnp.int32), 0)

        ns = FabricState(
            link=link, q_time=q_time, q_dest=q_dest, q_inj=q_inj,
            n_ins=n_ins, sent=sent,
            prev_mode_l=link.xl.mode, n_sw=n_sw,
            log_inj=log_inj, log_del=log_del, log_dest=log_dest,
            log_n=log_n, drops=drops)
        return ns, None

    final, _ = jax.lax.scan(body, init, jnp.arange(max_steps))
    return FabricResult(
        delivered=final.log_n, injected=E,
        log_inj=final.log_inj, log_del=final.log_del,
        log_dest=final.log_dest,
        sent=final.sent, n_switches=final.n_sw,
        t_link=final.link.t, t_end=jnp.max(final.link.t),
        drops=final.drops)


# -----------------------------------------------------------------------
# Measurement roll-ups
# -----------------------------------------------------------------------

def fabric_throughput_mev_s(res: FabricResult) -> jnp.ndarray:
    """Delivered events per second across the fabric, MEvents/s."""
    return jnp.where(res.t_end > 0, 1e3 * res.delivered / res.t_end, 0.0)


def per_link_throughput_mev_s(res: FabricResult) -> jnp.ndarray:
    """(L,) per-link transmissions/s (both directions), MEvents/s."""
    n = jnp.sum(res.sent, axis=1)
    return jnp.where(res.t_link > 0, 1e3 * n / res.t_link, 0.0)


def fabric_energy_pj(res: FabricResult,
                     timing: LinkTiming = PAPER_TIMING) -> jnp.ndarray:
    """Total link energy: every hop moves one ``e_event_pj`` event."""
    return jnp.sum(res.sent) * timing.e_event_pj


def delivered_latencies(res: FabricResult) -> np.ndarray:
    """End-to-end ns latencies of the delivered events (numpy)."""
    n = int(res.delivered)
    inj = np.asarray(res.log_inj)[:n]
    dlv = np.asarray(res.log_del)[:n]
    return (dlv - inj).astype(np.int64)


def latency_stats(res: FabricResult) -> dict:
    """p50/p90/p99/max end-to-end latency plus delivery counters."""
    lat = delivered_latencies(res)
    if lat.size == 0:
        return {"delivered": 0, "injected": res.injected,
                "p50_ns": 0.0, "p90_ns": 0.0, "p99_ns": 0.0, "max_ns": 0}
    return {
        "delivered": int(res.delivered),
        "injected": res.injected,
        "p50_ns": float(np.percentile(lat, 50)),
        "p90_ns": float(np.percentile(lat, 90)),
        "p99_ns": float(np.percentile(lat, 99)),
        "max_ns": int(lat.max()),
    }
