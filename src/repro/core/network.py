"""N-chip AER fabric simulator: the paper's link pair, scaled out.

The paper measures ONE bi-directional transceiver pair on one shared AER
bus.  This module composes many such pairs into a multi-chip fabric
(line / ring / 2-D mesh — ``router.Topology``): every link of the fabric
is one paper-faithful ``protocol_sim.LinkState`` micro-transaction unit,
and one global step advances **all** links simultaneously via
``protocol_sim.link_step_batch`` — the LinkSim unit batches across links.

Event transport
---------------
Each link endpoint owns a fixed-capacity queue of
``(release_time, route_id, inject_time)`` entries.  Injected traffic
(``traffic.TrafficSpec``) is routed to its first-hop queue(s) at setup
time (numpy, sorted by time).  A *route id* is either a destination chip
(unicast: ``r < n_chips``) or a multicast replication tree
(``r = n_chips + tree``, in-fabric multicast — see below).  When a link
delivers an event, the receiving chip consults its *replication table*
row ``(chip, route)``: a local-deliver bit plus up to ``K`` out-queues
to copy the event onto.  For unicast routes the table degenerates to the
classic next-hop gather (one out-link everywhere, deliver exactly at the
destination); forwarded copies re-queue with release time equal to their
delivery time — multi-hop latency accumulates exactly.

Multicast events can travel in two modes (``fabric.MulticastPolicy``):

``source_expand`` (default, PR 1 semantics)
    A tag with fanout F becomes F independent unicast copies at the
    source — F traversals of every shared link.

``in_fabric``
    The tagged event carries its route id through the fabric and is
    replicated only where the per-``(source, tag)`` Steiner-branching
    tree (``router.MulticastTree``) diverges: one traversal per tree
    edge.  A replication step can deliver locally AND spawn several
    child events from one pop; drops are weighted by the subtree's
    delivery count so ``delivered + drops == expected`` stays exact.

An entry only *enters* the physical FIFO at its release time, so service
order is release-time order (FIFO among equal times): a forward that has
already arrived is never blocked behind a pre-routed injection that has
not happened yet.  Slots are one-shot (consumed entries are not reused),
so in the default ``"drop"`` flow mode ``queue_capacity`` bounds the
total events *through* an endpoint, not its instantaneous depth; the
lossless default (= expanded event count) can never drop.

Flow control (``fabric.QueuePolicy(flow=...)``)
-----------------------------------------------
The paper's four-phase req/ack handshake is inherently lossless — a
sender stalls until the receiver acks, it never silently discards an
event.  Three flow modes reproduce the design space (all three are a
*dynamic* scalar operand, so they share one compilation per shape):

``"drop"`` (default)
    Today's semantics: a forward into a full queue is discarded and
    counted (``FabricResult.drops``), weighted by the forfeited
    deliveries under in-fabric multicast.

``"credit"``
    Per-link credit counters: every endpoint queue tracks its occupancy
    ``n_ins - n_pop``; a pop whose head would forward into a queue at or
    above ``capacity`` *stalls in place* (the event stays at the stream
    head / slot, backlog telemetry keeps accruing, head-of-line blocking
    is modeled) until a downstream pop returns a credit.  Delivery-only
    pops (all replication targets local) are never gated, so
    convergecast sinks always drain and an acyclic route set cannot
    deadlock.  ``delivered == injected`` with ``drops == 0``.

``"onoff"``
    Threshold xon/xoff: the queue raises ``xoff`` when occupancy
    reaches ``capacity`` and clears it when occupancy falls back to
    ``xon`` (hysteresis) — senders gate on the latched bit rather than
    the instantaneous count.  ``xon = capacity - 1`` degenerates to
    credit mode exactly.

Because several upstream links can pop into one queue in the same
micro-transaction, instantaneous occupancy may transiently overshoot
``capacity`` by at most the chip in-degree; the overshoot is
deterministic and bit-exact across engines.  A *stalled* link is
excluded from the conservative horizon (its next insert is causally
gated on a downstream pop, which the downstream link's own ``na`` term
already bounds) and its parked clock rides the fabric-wide floor
upward, so the eventual transmit time — and therefore the event's
end-to-end latency — includes the full backpressure wait.  Cyclic
route dependency chains (e.g. all-clockwise ring traffic with tiny
capacities) can genuinely deadlock, exactly like real credit-based
fabrics; the step bound then binds and the run reports
``delivered + drops < injected`` instead of hanging.

Clocks are link-local, exactly as in ``protocol_sim.simulate``: a link
whose queues are empty *parks* (its clock holds) and wakes when a forward
lands.  Cross-link causality is kept by conservative lookahead against
the fabric-wide lower bound on future event releases (min over links of
"clock if work is pending, else own next arrival", plus one event cycle
for the insert bound): idle links never jump past it, and a busy link
pops an entry only once no future forward can precede it — so queues
serve in true release order and end-to-end latencies are exact.

Engines
-------
``simulate_fabric`` ships three interchangeable, bit-exact event-transport
engines (select with ``engine=``):

``"ring"`` (default)
    The O(1)-per-step hot path.  Each endpoint queue is decomposed into
    release-time-sorted streams — the static prefill (sorted at setup)
    plus one FIFO stream per in-edge of the chip (a link's delivery clock
    is monotone, so forwards from one link arrive in release order; this
    replaces the tail-insert + local-sift design with something strictly
    stronger: no sift is ever needed).  The per-step pending /
    next-arrival / pop computation then reads only the stream *heads* —
    O(deg) ≈ O(1) slots per endpoint instead of scanning all ``C`` — and
    pops compare ``(release, insertion_key)`` so service order matches
    the flat-slot argmin of the reference engine exactly.  The
    micro-transaction scan runs as chunked ``lax.scan`` inside
    ``lax.while_loop`` and exits within one chunk of
    ``delivered + drops == injected`` instead of padding to
    ``max_steps``, and the whole simulation is compiled once per shape
    signature through a jit cache with buffer donation (stream widths
    are bucketed to powers of two so sweep cells share compilations).

``"reference"``
    The flat one-shot slot-array engine (PR 1): every step re-scans all
    ``L x 2 x C`` slots.  O(max_steps · L · C) — kept verbatim as the
    semantics oracle; every other engine must reproduce its
    ``FabricResult`` bit-exactly.

``"pallas"``
    The reference slot layout with the per-step O(C) queue scan
    (released-count / min-release / next-arrival / argmin-pop) and the
    pop-consume + forward-append scatter fused into the Pallas kernels
    of ``kernels/fabric_queue.py`` (scatter-as-matmul, MXU-shaped; runs
    in interpret mode off-TPU).

When the step bound binds before delivery completes, the chunked ring
engine clamps its final chunk to the steps remaining, so it executes
exactly ``max_steps`` micro-transactions — bit-exact against a
reference scan of the same length (regression-tested in
``tests/test_fabric_engines.py``).

All engines take the timing contract as *dynamic* per-link (L,) cost
vectors (``link.link_timing_arrays``): a scalar ``LinkTiming`` broadcasts
uniformly (bit-exactly equal to the historical static-scalar path), and a
structure-of-arrays ``LinkTiming`` gives every link its own class — e.g.
fast on-board parallel buses next to slow bit-serial LVDS inter-board
links.  The conservative insert bound generalises to
``min(na + t_cycle)`` per link, which degenerates to the uniform
``min(na) + t_cycle`` exactly.

The declarative front door — composable routing/timing/queue/engine
policies with an explicit ``compile``/``run``/``run_many`` lifecycle —
lives in :mod:`repro.core.fabric`; ``simulate_fabric`` below is its
one-shot convenience wrapper.

The degenerate 2-chip fabric runs the identical ``link_step`` code path
with the identical pending/next-arrival semantics as
``protocol_sim.simulate`` and therefore reproduces its event departure
times, switch counts and ``t_end`` bit-exactly (tested in
``tests/test_fabric.py``).

Measurements: per-event latency log, per-link/direction transmission
counts, direction-switch counts, energy roll-up (every hop is one paper
event: ``e_event_pj``), aggregate + per-link throughput — plus the
congestion telemetry plane (:mod:`repro.core.telemetry`): per-link
``busy_ns`` / ``busy_steps`` / ``q_drops`` counters accumulated as scan
carry state inside every engine (bit-exact across engines, zero extra
compilation buckets), surfaced as ``FabricResult.telemetry`` and
consumed by the epoch-based adaptive routing control plane
(:mod:`repro.core.adaptive`).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .link import LinkTiming, PAPER_TIMING
from .protocol_sim import BIG_NS, LinkState, link_step_batch, reset_link
from .transceiver import XcvrState
from .router import (AddressSpec, MulticastTable, MulticastTree,
                     RoutingTable, Topology)
from .telemetry import Telemetry
from .traffic import TrafficSpec

__all__ = ["FabricResult", "FabricBatchResult", "simulate_fabric",
           "reset_links",
           "fabric_throughput_mev_s", "fabric_energy_pj",
           "link_energy_pj",
           "per_link_throughput_mev_s", "delivered_latencies",
           "delivery_multiset", "latency_stats", "batch_latency_stats",
           "batch_throughput_mev_s", "ENGINES",
           "DEFAULT_CHUNK_SIZE", "RESULT_FIELDS", "assert_results_equal"]

_BIG = BIG_NS  # one sentinel shared with link_step's park/wake contract

#: Event-transport engines accepted by ``simulate_fabric(engine=...)``.
ENGINES = ("ring", "reference", "pallas")

#: Micro-transactions per ``lax.scan`` chunk of the ring engine.
DEFAULT_CHUNK_SIZE = 128

# Ring-engine shape buckets.  Every array dimension that would otherwise
# vary cell-to-cell in a sweep (links, events, chip count, queue widths,
# chip degree) is padded up to a floored power of two, and the logical
# event/capacity counts travel as *dynamic* scalars — so one XLA
# compilation serves every (topology, pattern) cell that fits the bucket,
# and the jit cache turns a 19-cell sweep into ~2 compiles.  Padding is
# semantically inert: dummy links have empty queues (they park forever
# and never constrain the conservative horizon), dummy queue slots hold
# the BIG_NS sentinel, and results are trimmed to the real sizes.
_RING_L_FLOOR = 32        # links
_RING_N_FLOOR = 64        # chips (routing-table side)
_RING_D_FLOOR = 4         # chip degree (forward streams per endpoint)
_RING_E_FLOOR = 2048      # expected deliveries (delivery-log length)
_RING_PREFILL_FLOOR = 2048  # prefill queue width
_RING_STREAM_FLOOR = 512  # forward-stream width
_RING_R_FLOOR = 64        # route ids (chips + multicast trees)
_RING_K_FLOOR = 4         # replication branch bound (out-copies per pop)


class FabricResult(NamedTuple):
    delivered: jnp.ndarray   # scalar int32
    injected: int            # static: expected deliveries (post-fanout)
    log_inj: jnp.ndarray     # (E,) valid up to ``delivered``
    log_del: jnp.ndarray
    log_dest: jnp.ndarray
    sent: jnp.ndarray        # (L, 2) per-link/direction transmissions
    n_switches: jnp.ndarray  # (L,) direction switches per link
    t_link: jnp.ndarray      # (L,) final link-local clocks
    t_end: jnp.ndarray       # scalar: max over links
    drops: jnp.ndarray       # scalar (subtree-weighted for in-fabric
    #                          multicast: delivered + drops == injected)
    offered: int = -1        # static: events offered pre-fanout (-1 =
    #                          legacy result without the field)
    telemetry: Telemetry | None = None  # per-link congestion counters
    #                          (accumulated as engine carry state; None
    #                          only on legacy hand-built results)

    @property
    def traversals(self) -> int:
        """Actual link traversals (sum of per-link transmissions) — the
        quantity in-fabric multicast replication minimizes."""
        return int(np.asarray(self.sent).sum())

    @property
    def fanout(self) -> float:
        """Expected deliveries per offered event (1.0 = pure unicast)."""
        if self.offered <= 0:
            return 1.0
        return float(self.injected) / float(self.offered)


#: FabricResult fields the engines must agree on bit-for-bit (log arrays
#: compared up to ``delivered`` — beyond it is scratch space).
RESULT_FIELDS = ("delivered", "log_inj", "log_del", "log_dest", "sent",
                 "n_switches", "t_link", "t_end", "drops")


def assert_results_equal(a: FabricResult, b: FabricResult, ctx: str = ""):
    """The engines' bit-exactness contract, shared by tests and the CI
    bench smoke so the checked field list cannot drift apart."""
    assert a.injected == b.injected, ctx
    assert a.offered == b.offered, ctx
    n = int(a.delivered)
    for f in RESULT_FIELDS:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if f.startswith("log"):
            x, y = x[:n], y[:n]
        if not np.array_equal(x, y):
            raise AssertionError(f"{ctx}: engines disagree on field {f}: "
                                 f"{x!r} != {y!r}")
    # the telemetry plane is part of the contract too: when both results
    # carry counters (every engine run does), they must agree bit-for-bit
    if a.telemetry is not None and b.telemetry is not None:
        for f in Telemetry._fields:
            x = np.asarray(getattr(a.telemetry, f))
            y = np.asarray(getattr(b.telemetry, f))
            if not np.array_equal(x, y):
                raise AssertionError(
                    f"{ctx}: engines disagree on telemetry field {f}: "
                    f"{x!r} != {y!r}")


class FabricBatchResult(NamedTuple):
    """Results of B fabric instances executed as ONE batched computation.

    Every array field is the solo :class:`FabricResult` field with a
    leading ``(B,)`` instance axis (telemetry leaves included); the
    static per-instance counters (``injected`` / ``offered``) become
    (B,) numpy vectors.  ``instance(i)`` materialises instance ``i`` as
    an ordinary :class:`FabricResult` — bit-exact with the same spec run
    solo on the same engine (the contract ``Fabric.run_batch`` tests and
    the CI batch gate enforce), so every existing roll-up
    (``latency_stats``, ``link_load``, ``fabric_throughput_mev_s``, ...)
    applies per instance unchanged.  Conservation holds per instance:
    ``delivered[i] + drops[i] == injected[i]``.
    """
    delivered: jnp.ndarray   # (B,) int32
    injected: np.ndarray     # (B,) static: expected deliveries/instance
    log_inj: jnp.ndarray     # (B, E) valid up to ``delivered[i]``
    log_del: jnp.ndarray     # (B, E)
    log_dest: jnp.ndarray    # (B, E)
    sent: jnp.ndarray        # (B, L, 2)
    n_switches: jnp.ndarray  # (B, L)
    t_link: jnp.ndarray      # (B, L)
    t_end: jnp.ndarray       # (B,)
    drops: jnp.ndarray       # (B,)
    offered: np.ndarray      # (B,) static: pre-fanout events/instance
    telemetry: Telemetry     # (B,)-leading leaves

    @property
    def n_instances(self) -> int:
        return int(self.injected.shape[0])

    def instance(self, i: int) -> FabricResult:
        """Instance ``i`` as a solo-shaped :class:`FabricResult` (log
        arrays trimmed to the instance's own expected delivery count)."""
        e = int(self.injected[i])
        return FabricResult(
            delivered=self.delivered[i], injected=e,
            log_inj=self.log_inj[i, :e], log_del=self.log_del[i, :e],
            log_dest=self.log_dest[i, :e],
            sent=self.sent[i], n_switches=self.n_switches[i],
            t_link=self.t_link[i], t_end=self.t_end[i],
            drops=self.drops[i], offered=int(self.offered[i]),
            telemetry=Telemetry(*(getattr(self.telemetry, f)[i]
                                  for f in Telemetry._fields)))

    def results(self) -> list[FabricResult]:
        """All instances as solo-shaped results, batch order."""
        return [self.instance(i) for i in range(self.n_instances)]


def batch_throughput_mev_s(batch: FabricBatchResult) -> jnp.ndarray:
    """(B,) delivered events per second per instance, MEvents/s."""
    return jnp.where(batch.t_end > 0,
                     1e3 * batch.delivered / batch.t_end, 0.0)


def batch_latency_stats(batch: FabricBatchResult) -> list[dict]:
    """Per-instance ``latency_stats`` dicts, batch order — the Monte-
    Carlo view: the spread of p50/p99 across seeds of one scenario."""
    return [latency_stats(r) for r in batch.results()]


def reset_links(initial_tx: np.ndarray) -> LinkState:
    """Batched ``protocol_sim.reset_link``: leaf shape (L,)."""
    return jax.vmap(reset_link)(jnp.asarray(initial_tx, jnp.int32))


# -----------------------------------------------------------------------
# Setup-time helpers (plain numpy)
# -----------------------------------------------------------------------

def _pow2ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _check_reachable(rt: RoutingTable, src: np.ndarray, dest: np.ndarray):
    first_link = rt.next_link[src, dest]
    if np.any(first_link < 0):
        bad = np.flatnonzero(first_link < 0)[:4]
        raise ValueError(f"unreachable destinations, e.g. events {bad}: "
                         f"src={src[bad]} dest={dest[bad]}")


def _prefill(L: int, grp, t, route, inj,
             capacity: int, width: int | str | None = None):
    """Place injected copies into their first-hop queues (numpy, setup).

    ``grp`` is the flat first-hop queue id (``link * 2 + side``) of each
    copy, ``route`` its route id (destination chip or multicast tree)
    and ``inj`` the original injection time the delivery log reports.
    ``capacity`` is the logical per-endpoint budget (raises on overflow);
    ``width`` is the allocated column count of the returned arrays —
    ``None`` = ``capacity`` (the reference slot layout), ``"auto"`` = the
    max initial backlog bucketed to a power of two plus one
    always-empty pad column (the ring engine's prefill-only layout).
    """
    grp = np.asarray(grp, np.int64)
    t = np.asarray(t, np.int32)
    route = np.asarray(route, np.int32)
    inj = np.asarray(inj, np.int32)
    order = np.lexsort((np.arange(len(t)), t, grp))  # stable time order
    grp_s, t_s, route_s, inj_s = (grp[order], t[order], route[order],
                                  inj[order])

    sizes = np.bincount(grp, minlength=2 * L).astype(np.int32)
    if sizes.max(initial=0) > capacity:
        raise ValueError(f"queue capacity {capacity} < initial backlog "
                         f"{sizes.max()}; raise queue_capacity")
    if width == "auto":
        width = _pow2ceil(max(int(sizes.max(initial=1)),
                              _RING_PREFILL_FLOOR)) + 1
    elif width is None:
        width = capacity
    # within-queue slot = position since the queue's first event
    starts = np.zeros(2 * L + 1, np.int64)
    np.cumsum(sizes, out=starts[1:2 * L + 1])
    slot = np.arange(len(t)) - starts[grp_s]

    # empty slots hold the BIG_NS sentinel: "never released"
    q_time = np.full((2 * L, width), int(_BIG), np.int32)
    q_dest = np.zeros((2 * L, width), np.int32)
    q_inj = np.zeros((2 * L, width), np.int32)
    q_time[grp_s, slot] = t_s
    q_dest[grp_s, slot] = route_s
    q_inj[grp_s, slot] = inj_s
    return (q_time.reshape(L, 2, width), q_dest.reshape(L, 2, width),
            q_inj.reshape(L, 2, width), sizes.reshape(L, 2))


def _first_hop_queues(rt: RoutingTable, src, dest) -> np.ndarray:
    """Flat first-hop queue ids of unicast events (validated upstream)."""
    return rt.next_link[src, dest] * 2 + rt.out_side[src, dest]


# -----------------------------------------------------------------------
# Replication tables: one (node, route) -> out-copies/deliver contract
# shared by every engine.  Route id r < N is "unicast to chip r"; route
# id N + i is multicast tree i (router.MulticastTree).
# -----------------------------------------------------------------------

def _unicast_routes(topo: Topology, rt: RoutingTable):
    """(N, N, 1) out-queue / (N, N) deliver / (N, N, 1) drop-weight
    tables for the unicast route ids.  ``out_q`` holds the flat next-hop
    queue (``link * 2 + side``) or -1 (deliver here / unreachable);
    ``deliver`` is the identity (a unicast route delivers exactly at its
    destination chip); every forward carries drop weight 1."""
    nl, os_ = rt.next_link, rt.out_side
    out_q = np.where(nl >= 0, nl * 2 + os_, -1).astype(np.int32)[:, :, None]
    deliver = np.eye(topo.n_chips, dtype=np.int32)
    weight = (out_q >= 0).astype(np.int32)
    return out_q, deliver, weight


def _routes_with_trees(topo: Topology, rt: RoutingTable,
                       trees: list[MulticastTree]):
    """Stack the unicast tables with one route per multicast tree.

    Returns ``(out_q (N, R, K), deliver (N, R), weight (N, R, K))`` with
    ``R = n_chips + len(trees)`` and ``K`` the largest replication
    branch factor.  ``weight[c, r, k]`` is the number of final
    deliveries in the subtree fed by that out-copy — what a capacity
    drop at that point forfeits."""
    N = topo.n_chips
    uq, ud, uw = _unicast_routes(topo, rt)
    K = max([1] + [t.max_out_degree for t in trees])
    R = N + len(trees)
    out_q = np.full((N, R, K), -1, np.int32)
    deliver = np.zeros((N, R), np.int32)
    weight = np.zeros((N, R, K), np.int32)
    out_q[:, :N, :1] = uq
    deliver[:, :N] = ud
    weight[:, :N, :1] = uw
    for i, t in enumerate(trees):
        r = N + i
        deliver[:, r] = t.deliver
        k_next = np.zeros(N, np.int64)
        for e in range(t.n_edges):
            if t.parent[e] < 0:
                continue   # root edges are prefill, not replication (no
                #            copy ever arrives at the source on its own
                #            tree route — the source row stays empty)
            u, l, s, _v = (int(x) for x in t.edges[e])
            out_q[u, r, k_next[u]] = l * 2 + s
            weight[u, r, k_next[u]] = t.subtree[e]
            k_next[u] += 1
    return out_q, deliver, weight


def _expand(spec: TrafficSpec, addr: AddressSpec | None,
            mcast: MulticastTable | None):
    """Resolve packed/multicast destinations into unicast chip triples."""
    src = np.asarray(spec.src, np.int32)
    t = np.asarray(spec.t, np.int32)
    dest = np.asarray(spec.dest, np.int32)
    if addr is None:
        return src, t, dest
    is_mc = addr.is_multicast(dest)
    chip_or_tag, _ = addr.unpack(dest)
    out_s = [src[~is_mc]]
    out_t = [t[~is_mc]]
    out_d = [chip_or_tag[~is_mc]]
    if np.any(is_mc):
        if mcast is None:
            raise ValueError("multicast events but no MulticastTable")
        ms, mt, md = mcast.expand_stream(src[is_mc], t[is_mc],
                                         chip_or_tag[is_mc])
        out_s.append(ms)
        out_t.append(mt)
        out_d.append(md)
    return (np.concatenate(out_s), np.concatenate(out_t),
            np.concatenate(out_d))


def _in_edge_ranks(topo: Topology):
    """Per-chip enumeration of delivering links.

    ``rank[l, side]`` is the index of link ``l`` among the links incident
    to chip ``topo.links[l, side]`` (id order) — the forward-stream slot
    an event delivered over ``l`` into that chip appends to.  Returns
    ``(rank (L, 2) int32, D)`` with ``D`` the maximum chip degree.
    """
    L = topo.n_links
    rank = np.zeros((L, 2), np.int32)
    deg = np.zeros(topo.n_chips, np.int32)
    for l, (a, b) in enumerate(topo.links):
        rank[l, 0] = deg[a]
        deg[a] += 1
        rank[l, 1] = deg[b]
        deg[b] += 1
    return rank, max(int(deg.max(initial=1)), 1)


def _stream_quota(rt: RoutingTable, links: np.ndarray, in_rank: np.ndarray,
                  src: np.ndarray, dest: np.ndarray, L: int, D: int):
    """Static per-(queue, in-edge) forward-count upper bound.

    Routing is deterministic, so every event's full path is known at
    setup; walking all paths counts how many forwards each stream can
    ever receive (drops only shorten paths, so the no-drop count is an
    upper bound).  O(E · diameter) in numpy, off the hot path.
    """
    counts = np.zeros((2 * L, D), np.int64)
    c = src.astype(np.int64).copy()
    prev_l = np.full(len(src), -1, np.int64)
    prev_rx_side = np.zeros(len(src), np.int64)
    active = c != dest
    while active.any():
        l = np.where(active, rt.next_link[c, dest], 0)
        s = np.where(active, rt.out_side[c, dest], 0)
        m = active & (prev_l >= 0)
        if m.any():
            d = in_rank[prev_l[m], prev_rx_side[m]]
            np.add.at(counts, (l[m] * 2 + s[m], d), 1)
        prev_l = np.where(active, l, prev_l)
        prev_rx_side = np.where(active, 1 - s, prev_rx_side)
        c = np.where(active, links[l, 1 - s], c)
        active = c != dest
    return counts


def _tree_stream_quota(trees: list[MulticastTree], tree_counts,
                       in_rank: np.ndarray, L: int, D: int):
    """Static per-(queue, in-edge) forward-count bound for tree routes.

    Every non-root tree edge is one in-fabric forward: the copy arrives
    at ``u`` over the parent edge's link and is appended to the edge's
    out-queue on the parent link's in-edge stream — once per event
    riding the tree (``tree_counts``).  Root edges are prefill, not
    stream appends."""
    counts = np.zeros((2 * L, D), np.int64)
    for tree, n in zip(trees, tree_counts):
        for e in range(tree.n_edges):
            p = int(tree.parent[e])
            if p < 0:
                continue
            _u, l, s, _v = (int(x) for x in tree.edges[e])
            lp, sp = int(tree.edges[p][1]), int(tree.edges[p][2])
            d = int(in_rank[lp, 1 - sp])
            counts[l * 2 + s, d] += int(n)
    return counts


def _pad_to(a: np.ndarray, shape: tuple, fill) -> np.ndarray:
    """Embed ``a`` in a ``fill``-initialized array of ``shape``."""
    out = np.full(shape, fill, a.dtype)
    out[tuple(slice(n) for n in a.shape)] = a
    return out


def _overflow_guard(t_max: int, total_tx: int, worst_cost: int):
    """Refuse traffic that could push a clock past the ``BIG_NS`` sentinel.

    Empty queue slots hold ``BIG_NS`` ("never released"); once any
    link-local clock reaches it, empty slots would look released and the
    queue state would corrupt silently.  The clock only advances by
    jumping to an arrival (<= ``t_max``) or by paying one transmission
    cost, so ``t_max + total_tx * worst_cost`` bounds every clock (and
    the ``min(na + t_cycle)`` insert bound stays below int32 overflow a
    fortiori).  ``worst_cost`` is the maximum single-transmission cost
    over all links (per-link heterogeneous timing maximises over the
    fabric).

    This is the *global* bound — the documented fallback when per-route
    tables are unavailable or broken (a cyclic/dead-end override walks
    forever, so its per-link transmission counts are undefined).  When
    the routes do terminate, :func:`_overflow_guard_routed` charges each
    transmission its own link's cost instead of the fabric-wide worst —
    a strictly tighter bound on heterogeneous fabrics (slow LVDS links
    no longer tax traffic that never crosses them), so fewer false
    refusals.
    """
    bound = int(t_max) + int(total_tx) * int(worst_cost)
    if bound >= int(_BIG):
        raise ValueError(
            f"clock overflow risk: worst-case end time {bound} ns reaches "
            f"the BIG_NS sentinel ({int(_BIG)} ns). Long-running "
            f"simulations must keep max(t) + total_hops * "
            f"{worst_cost} ns below it; rebase injection times or split "
            f"the simulation.")


def _route_link_tx(rt: RoutingTable, links: np.ndarray, src: np.ndarray,
                   dest: np.ndarray, L: int, n_chips: int):
    """Per-link transmission counts along the actual unicast routes.

    Walks every event's deterministic path (the same O(E · diameter)
    numpy pattern as ``_stream_quota``, collapsed to links) and counts
    how many transmissions each link carries.  Returns ``(counts (L,)
    int64, ok)``; ``ok`` is False when some walk failed to terminate
    within ``n_chips - 1`` hops — a cyclic or dead-end override table,
    whose per-link counts are undefined (the caller falls back to the
    global :func:`_overflow_guard` bound).
    """
    counts = np.zeros(L, np.int64)
    c = np.asarray(src, np.int64).copy()
    dest = np.asarray(dest, np.int64)
    active = c != dest
    for _ in range(max(n_chips - 1, 0)):
        if not active.any():
            break
        l = np.where(active, rt.next_link[c, dest], -1)
        has = active & (l >= 0)
        l_g = np.maximum(l, 0)
        s_g = np.clip(np.where(has, rt.out_side[c, dest], 0), 0, 1)
        np.add.at(counts, l_g[has], 1)
        c = np.where(has, links[l_g, 1 - s_g], c)
        active = has & (c != dest)
    return counts, not bool(active.any())


def _clock_bound(t_max: int, link_tx: np.ndarray,
                 link_cost: np.ndarray) -> int:
    """Worst-case end-time bound with per-link transmission costs:
    ``t_max + sum_l link_tx[l] * link_cost[l]`` — each transmission pays
    its own link's worst single-transmission cost rather than the
    fabric-wide maximum."""
    return int(t_max) + int((np.asarray(link_tx, np.int64)
                             * np.asarray(link_cost, np.int64)).sum())


def _overflow_guard_routed(t_max: int, link_tx: np.ndarray,
                           link_cost: np.ndarray):
    """Route-aware ``BIG_NS`` guard: the tight per-link clock budget.

    Same refusal contract as :func:`_overflow_guard` (see there for why
    the sentinel must stay unreachable), but the bound charges each
    link only the transmissions that actually cross it under the
    routing tables — on fabrics mixing fast parallel and slow serial
    links this admits workloads the global worst-cost bound falsely
    refused.
    """
    bound = _clock_bound(t_max, link_tx, link_cost)
    if bound >= int(_BIG):
        worst = int(np.asarray(link_cost).max(initial=1))
        raise ValueError(
            f"clock overflow risk: worst-case end time {bound} ns "
            f"(routed per-link bound) reaches the BIG_NS sentinel "
            f"({int(_BIG)} ns). Long-running simulations must keep "
            f"max(t) + sum over links of transmissions * per-link cost "
            f"(<= {worst} ns each) below it; rebase injection times or "
            f"split the simulation.")


def _jit_cached(fn, donate_argnums=()):
    """jit with buffer donation where the backend supports it (donation
    on CPU is a no-op warning in current JAX, so skip it there)."""
    if donate_argnums and jax.default_backend() != "cpu":
        return jax.jit(fn, donate_argnums=donate_argnums)
    return jax.jit(fn)


# -----------------------------------------------------------------------
# Per-step pieces shared verbatim by every engine body (the bit-exactness
# contract lives here: one implementation of delivery logging and of the
# simultaneous-forwards insertion ordering)
# -----------------------------------------------------------------------

def _log_deliveries(log_inj, log_del, log_dest, log_n,
                    deliver, ev_inj, t_del, ev_dest, n_slots: int):
    """Append this step's deliveries to the packed log (order: link id)."""
    d32 = deliver.astype(jnp.int32)
    slot = jnp.where(deliver, log_n + jnp.cumsum(d32) - d32, n_slots)
    return (log_inj.at[slot].set(ev_inj, mode="drop"),
            log_del.at[slot].set(t_del, mode="drop"),
            log_dest.at[slot].set(ev_dest, mode="drop"),
            log_n + jnp.sum(d32))


def _forward_slots(forward, fq, n_ins_flat, cap, n_queues: int):
    """Insertion slots for this step's forward copies.

    ``forward`` / ``fq`` are flat (M,) candidate arrays in priority
    order — link-major, replica-minor (M = L for unicast, L·K with
    in-fabric replication) — so simultaneous appends into one queue are
    ordered by (link index, replica index).  The returned ``key`` is the
    queue's insertion index (the reference slot id and pop tie-break
    key).  Returns ``(fq_g, key, app, dropped)`` where ``app`` masks
    copies that fit under ``cap`` and ``dropped`` the ones that did not
    (the caller weighs them — an in-fabric multicast copy carries its
    whole subtree's deliveries).
    """
    idx = jnp.arange(forward.shape[0])
    fq_m = jnp.where(forward, fq, n_queues)   # sentinel for non-forwards
    before = (fq_m[None, :] == fq_m[:, None]) \
        & (idx[None, :] < idx[:, None]) & forward[None, :]
    offs = jnp.sum(before.astype(jnp.int32), axis=1)
    fq_g = jnp.where(forward, fq, 0)
    key = n_ins_flat[fq_g] + offs             # next free slot
    cap_ok = key < cap
    app = forward & cap_ok
    return fq_g, key, app, forward & ~cap_ok


def _replicate(route_out_j, route_wt_j, rx_chip, ev_route, did):
    """Gather one step's forward copies from the replication tables.

    Returns flat (L·K,) ``(forward mask, queue id, drop weight)`` in the
    link-major / replica-minor priority order ``_forward_slots``
    expects.  With unicast-only tables (K = 1) this is exactly the
    historical single next-hop gather."""
    out_qk = route_out_j[rx_chip, ev_route]              # (L, K)
    wt_k = route_wt_j[rx_chip, ev_route]                 # (L, K)
    fwd = (did[:, None] & (out_qk >= 0)).reshape(-1)
    return fwd, jnp.maximum(out_qk, 0).reshape(-1), wt_k.reshape(-1)


def _flow_gate(fc_mode, cap, xon, occ, xoff, cand_route, rx_chip_cand,
               route_out_j):
    """Flow-control admission gate for one micro-transaction.

    For every endpoint queue, looks up the downstream queues its head
    event would replicate onto (``route_out_j[rx_chip, route]``) and
    decides whether a pop must stall: in credit mode when any real
    target's occupancy ``n_ins - n_pop`` has reached ``cap``, in on/off
    mode when any real target has its latched ``xoff`` bit raised.
    Delivery-only heads (all targets -1) are never gated — destination
    sinks always drain, so acyclic route sets cannot deadlock.  The
    xon/xoff hysteresis state advances first (set at ``occ >= cap``,
    cleared at ``occ <= xon``) so both engines latch from the identical
    start-of-step occupancy.

    ``fc_mode`` / ``cap`` / ``xon`` are *dynamic* int32 scalars (0 =
    drop, 1 = credit, 2 = onoff) — the gate adds no compilation
    buckets, and in drop mode it is the constant ``False`` mask, which
    keeps the PR 5 semantics bit-exact.

    Shapes: ``occ`` / ``xoff`` / ``cand_route`` / ``rx_chip_cand`` are
    (L, 2); returns ``(blocked (L, 2) bool, xoff' (L, 2) int32)``.
    """
    xoff2 = jnp.where(occ >= cap, jnp.int32(1),
                      jnp.where(occ <= xon, jnp.int32(0), xoff))
    tgt = route_out_j[rx_chip_cand, cand_route]          # (L, 2, K)
    real = tgt >= 0
    tgt_g = jnp.maximum(tgt, 0)
    occ_t = occ.reshape(-1)[tgt_g]
    xoff_t = xoff2.reshape(-1)[tgt_g]
    full = jnp.any(real & (occ_t >= cap), axis=2)
    off = jnp.any(real & (xoff_t > 0), axis=2)
    blocked = jnp.where(fc_mode == 1, full,
                        jnp.where(fc_mode == 2, off, False))
    return blocked, xoff2


# -----------------------------------------------------------------------
# Slot engines ("reference" and "pallas"): flat one-shot (Q, C) arrays
# -----------------------------------------------------------------------

class _SlotState(NamedTuple):
    link: LinkState         # (L,)-leaved LinkSim batch
    q_time: jnp.ndarray     # (Q, C) release times; BIG_NS = empty/consumed
    q_dest: jnp.ndarray     # (Q, C) route id (dest chip | multicast tree)
    q_inj: jnp.ndarray      # (Q, C) original injection time
    n_ins: jnp.ndarray      # (L, 2) entries ever inserted (next free slot)
    sent: jnp.ndarray       # (L, 2) transmissions per direction (0: L->R)
    prev_mode_l: jnp.ndarray  # (L,) for switch counting
    n_sw: jnp.ndarray       # (L,) mode_l transitions (excl. reset step)
    log_inj: jnp.ndarray    # (E,) delivery log: injection time
    log_del: jnp.ndarray    # (E,) delivery log: delivery time
    log_dest: jnp.ndarray   # (E,) delivery log: destination chip
    log_n: jnp.ndarray      # scalar: deliveries so far
    drops: jnp.ndarray      # scalar: forwards lost to a full queue
    busy_ns: jnp.ndarray    # (L,) telemetry: ns spent transmitting
    busy_steps: jnp.ndarray  # (L, 2) telemetry: steps with backlog
    q_drops: jnp.ndarray    # (L, 2) telemetry: weighted drops per queue
    n_pop: jnp.ndarray      # (L, 2) entries ever popped (credit returns)
    xoff: jnp.ndarray       # (L, 2) latched on/off backpressure bit
    in_stall: jnp.ndarray   # (L, 2) stalled last step (episode edges)
    stall_steps: jnp.ndarray  # (L, 2) telemetry: flow-control stalls
    credit_waits: jnp.ndarray  # (L, 2) telemetry: stall episodes


def _slot_init(L: int, E: int, q_time, q_dest, q_inj, sizes,
               init_tx) -> _SlotState:
    """Reset-time slot-engine carry (shared by the per-step scan and the
    multi-step kernel path, so both start from the identical state)."""
    link0 = reset_links(init_tx)
    return _SlotState(
        link=link0,
        q_time=q_time, q_dest=q_dest, q_inj=q_inj,
        n_ins=sizes,
        sent=jnp.zeros((L, 2), jnp.int32),
        prev_mode_l=link0.xl.mode,
        n_sw=jnp.zeros((L,), jnp.int32),
        log_inj=jnp.zeros((E,), jnp.int32),
        log_del=jnp.zeros((E,), jnp.int32),
        log_dest=jnp.zeros((E,), jnp.int32),
        log_n=jnp.zeros((), jnp.int32),
        drops=jnp.zeros((), jnp.int32),
        busy_ns=jnp.zeros((L,), jnp.int32),
        busy_steps=jnp.zeros((L, 2), jnp.int32),
        q_drops=jnp.zeros((L, 2), jnp.int32),
        n_pop=jnp.zeros((L, 2), jnp.int32),
        xoff=jnp.zeros((L, 2), jnp.int32),
        in_stall=jnp.zeros((L, 2), jnp.int32),
        stall_steps=jnp.zeros((L, 2), jnp.int32),
        credit_waits=jnp.zeros((L, 2), jnp.int32),
    )


def _slot_results(final: _SlotState):
    """The engine's 14-tuple result, read off the final carry."""
    return (final.log_n, final.log_inj, final.log_del, final.log_dest,
            final.sent, final.n_sw, final.link.t,
            jnp.max(final.link.t), final.drops,
            final.busy_ns, final.busy_steps, final.q_drops,
            final.stall_steps, final.credit_waits)


def _slot_step_body(L: int, E: int, C: int, max_burst: int,
                    scan_fn, update_fn,
                    links_j, route_out_j, route_del_j, route_wt_j,
                    t_cycle_v, t_rev_v, t_idle_v, cap, fc_mode, xon):
    """Build the per-micro-transaction physics ``body(s, step_i) -> s'``.

    ONE implementation of the slot-engine step, closed over the dynamic
    operands, consumed by three callers: the reference engine
    (``scan_fn``/``update_fn`` = the pure-jnp oracles), the per-step
    pallas engine (= the jitted kernel wrappers), and the multi-step
    kernel body / its oracle (= the value-level kernel math) — which is
    what makes ``kernel="multistep"`` bit-exact by construction rather
    than by parallel maintenance.
    """
    Q = 2 * L
    lidx = jnp.arange(L)
    K = route_out_j.shape[2]
    # the chip a pop over (link, side) would deliver into — the gate
    # needs it for both sides before the FSM picks a direction
    rx_chip_cand = jnp.stack([links_j[:, 1], links_j[:, 0]], axis=1)

    def body(s: _SlotState, step_i) -> _SlotState:
            t_now = s.link.t  # (L,)

            # --- pending & next-arrival per endpoint queue --------------
            # An entry is *in* the FIFO once its release time has passed;
            # empty/consumed slots hold BIG_NS and never match.  Service
            # order is release-time order (argmin; ties resolve to the
            # lowest slot, i.e. FIFO among simultaneous arrivals), which
            # for the sorted single-hop prefill is exactly simulate()'s
            # searchsorted count.
            t_q = jnp.repeat(t_now, 2)                           # (Q,)
            pend_q, r_min_q, nxt_q, amin_q, busy_q, route_q = scan_fn(
                s.q_time, s.q_dest, t_q)
            pend = pend_q.reshape(L, 2)
            # telemetry: backlog-present integral per endpoint queue
            busy_steps = s.busy_steps + busy_q.reshape(L, 2)
            r_min = r_min_q.reshape(L, 2)
            nxt2 = nxt_q.reshape(L, 2)                           # (L, 2)

            # --- flow-control admission gate ----------------------------
            # Would this queue's head pop into a backpressured queue?
            # Gated BEFORE the FSM step so a stalled head simply presents
            # no pending work (the event stays in its slot, the link
            # idles — the 4-phase "receiver withholds ack" behaviour).
            occ = s.n_ins - s.n_pop
            cand_route = route_q.reshape(L, 2)
            blocked, xoff = _flow_gate(fc_mode, cap, xon, occ, s.xoff,
                                       cand_route, rx_chip_cand,
                                       route_out_j)
            stalled = (pend > 0) & blocked
            stall_steps = s.stall_steps + stalled.astype(jnp.int32)
            credit_waits = s.credit_waits + (
                stalled & (s.in_stall == 0)).astype(jnp.int32)

            # --- conservative clock synchronization ---------------------
            # A link acts no earlier than its clock (work pending) or its
            # own next arrival: ``na``.  Any *future* forward is released
            # at some link's next delivery — link ``l``'s next
            # transmission completes no earlier than ``na[l] +
            # t_cycle[l]`` (every transmit cost is >= its event cycle), so
            # ``min(na + t_cycle)`` lower-bounds every possible future
            # insert even under per-link heterogeneous timing (with
            # uniform timing it is exactly the old ``min(na) + t_cycle``).
            # Two consequences keep every queue in true release order:
            #   * idle links never jump past min(na), so a parked clock
            #     never overtakes a forward still in flight;
            #   * a busy link may pop its earliest released entry only if
            #     its release precedes every possible future insert
            #     (release <= min(na + t_cycle)) — otherwise it stalls
            #     until the rest of the fabric catches up (classic
            #     conservative lookahead).
            # With one link both guards are vacuous (its own bound is
            # always the loosest), so simulate() semantics are preserved
            # bit-exactly.
            #
            # Flow control refines the ``na`` term, per SIDE: a side with
            # ANY released entry is head-of-line gated by its earliest
            # released head (a shadowed later arrival can never act
            # before the head pops), so its next-action bound is the
            # clock when the head may pop — and when the head is *gated*,
            # the downstream chain instead: the stall only breaks after a
            # downstream pop, which that link's own ``na`` already
            # bounds, so the stalled side is excluded from the horizon
            # (else its parked clock would pin the fabric and a deep
            # stall chain could false-deadlock).  Its clock then rides
            # the fabric floor upward via the idle jump, so the eventual
            # post-stall transmit time (and the event's latency) includes
            # the backpressure wait.  Only sides with NO released work
            # contribute their future-arrival minimum — which is why the
            # idle-jump target ``t_next_g`` masks released sides too:
            # behind a released head the engines legitimately disagree on
            # shadowed arrival times (the ring engine sees only stream
            # heads), and head-of-line gating makes those times
            # irrelevant anyway.  In drop mode ``blocked`` is constant
            # False and every expression below collapses bit-exactly to
            # the historical link-level form.
            pend_b = pend > 0                                    # (L, 2)
            na_side = jnp.where(
                pend_b, jnp.where(blocked, _BIG, t_now[:, None]), nxt2)
            na = jnp.min(na_side, axis=1)                        # (L,)
            t_next_g = jnp.min(jnp.where(pend_b, _BIG, nxt2), axis=1)
            horizon = jnp.min(na)
            t_next_eff = jnp.minimum(t_next_g,
                                     jnp.maximum(horizon, t_now))
            safe = r_min <= jnp.min(na + t_cycle_v)              # (L,2)
            pend_safe = jnp.where(safe & ~blocked, pend, 0)

            # --- one micro-transaction on every link, batched -----------
            link, out = link_step_batch(
                s.link, pend_safe[:, 0], pend_safe[:, 1], t_next_eff,
                max_burst=max_burst,
                timing_arrays=(t_cycle_v, t_rev_v, t_idle_v))

            did = (out.tx_l + out.tx_r) > 0                      # (L,) bool
            did32 = did.astype(jnp.int32)
            # telemetry: a transmitting link's clock advances by exactly
            # the transmission cost, so the gated delta is bus-busy time
            busy_ns = s.busy_ns + jnp.where(did, link.t - t_now, 0)
            send_side = jnp.where(out.tx_l == 1, 0, 1)           # (L,)
            qid = lidx * 2 + send_side                           # (L,)
            pop_slot = amin_q[qid]
            ev_route = cand_route[lidx, send_side]  # == q_dest[qid, slot]
            ev_inj = s.q_inj[qid, pop_slot]
            # consume the popped slot (one-shot slots; no reuse) and
            # return its credit (occupancy = n_ins - n_pop drops by one)
            pop_q = jnp.where(did, qid, Q)
            sent = s.sent.at[lidx, send_side].add(did32)
            n_pop = s.n_pop.at[lidx, send_side].add(did32)

            # --- deliver and/or replicate -------------------------------
            # The receiving chip's replication-table row decides both: a
            # branch node of a multicast tree can deliver locally AND
            # spawn several child copies from this one pop.
            rx_chip = jnp.where(out.tx_l == 1, links_j[:, 1], links_j[:, 0])
            deliver = did & (route_del_j[rx_chip, ev_route] > 0)

            log_inj, log_del, log_dest, log_n = _log_deliveries(
                s.log_inj, s.log_del, s.log_dest, s.log_n,
                deliver, ev_inj, link.t, rx_chip, E)

            fwd_f, fqk_f, wt_f = _replicate(route_out_j, route_wt_j,
                                            rx_chip, ev_route, did)
            n_ins_f = s.n_ins.reshape(-1)
            # drop mode enforces the logical budget at append time (the
            # historical one-shot total-through bound); the stall modes
            # never discard — physical width C always fits (cap == C in
            # the unbounded default, so this is bit-exactly PR 5 there)
            app_cap = jnp.where(fc_mode == 0, jnp.minimum(cap, C), C)
            fq_g, slot, app, dropped = _forward_slots(
                fwd_f, fqk_f, n_ins_f, app_cap, Q)
            fq_s = jnp.where(app, fq_g, Q)         # drop non-appends
            q_time, q_dest, q_inj = update_fn(
                s.q_time, s.q_dest, s.q_inj, pop_q, pop_slot,
                fq_s, slot, jnp.repeat(link.t, K),
                jnp.repeat(ev_route, K), jnp.repeat(ev_inj, K))
            n_ins = n_ins_f.at[fq_s].add(1, mode="drop").reshape(L, 2)
            drop_wt = jnp.where(dropped, wt_f, 0)
            drops = s.drops + jnp.sum(drop_wt)
            # telemetry: charge each weighted drop to its target queue
            q_drops = s.q_drops.reshape(-1).at[
                jnp.where(dropped, fq_g, Q)].add(
                drop_wt, mode="drop").reshape(L, 2)

            # --- switch counting (matches SimResult.n_switches: mode_l
            # transitions between consecutive steps, reset excluded) -----
            n_sw = s.n_sw + jnp.where(
                step_i > 0,
                (link.xl.mode != s.prev_mode_l).astype(jnp.int32), 0)

            ns = _SlotState(
                link=link, q_time=q_time, q_dest=q_dest, q_inj=q_inj,
                n_ins=n_ins, sent=sent,
                prev_mode_l=link.xl.mode, n_sw=n_sw,
                log_inj=log_inj, log_del=log_del, log_dest=log_dest,
                log_n=log_n, drops=drops,
                busy_ns=busy_ns, busy_steps=busy_steps, q_drops=q_drops,
                n_pop=n_pop, xoff=xoff,
                in_stall=stalled.astype(jnp.int32),
                stall_steps=stall_steps, credit_waits=credit_waits)
            return ns

    return body


def _slot_run(L: int, E: int, C: int, max_steps: int,
              max_burst: int, use_kernels: bool):
    """Build the slot-scan ``run`` function for one static shape signature
    (uncompiled — ``_slot_engine`` jits it solo, ``_slot_engine_batch``
    vmaps it over a ``(B,)`` leading instance axis).

    Timing arrives as *dynamic* (L,) cost vectors (``t_cycle_v`` /
    ``t_rev_v`` / ``t_idle_v`` — see ``link.link_timing_arrays``), so one
    compilation serves every timing contract, uniform or per-link
    heterogeneous.  Routing arrives as the replication tables
    ``route_out/route_del/route_wt`` ((N, R, K) / (N, R) / (N, R, K)):
    one pop can deliver locally AND spawn up to K child copies, which
    for unicast-only tables (K = 1, identity deliver) reproduces the
    historical next-hop gather bit-exactly.

    ``C`` is the *physical* slot width (the expanded event count — every
    queue can always hold everything ever routed through it); the
    logical per-endpoint budget arrives as the dynamic scalar ``cap``
    together with the flow-control mode ``fc_mode`` and on/off low-water
    mark ``xon``, so drop, credit and on/off runs of every capacity
    share ONE compilation per shape signature.
    """
    from ..kernels import ops as kops
    from ..kernels import ref as kref
    if use_kernels:
        scan_fn = kops.fabric_queue_scan
        update_fn = kops.fabric_queue_update
    else:
        scan_fn = kref.fabric_queue_scan
        update_fn = kref.fabric_queue_update

    def run(q_time, q_dest, q_inj, sizes, init_tx,
            links_j, route_out_j, route_del_j, route_wt_j,
            t_cycle_v, t_rev_v, t_idle_v, cap, fc_mode, xon):
        init = _slot_init(L, E, q_time, q_dest, q_inj, sizes, init_tx)
        body = _slot_step_body(
            L, E, C, max_burst, scan_fn, update_fn,
            links_j, route_out_j, route_del_j, route_wt_j,
            t_cycle_v, t_rev_v, t_idle_v, cap, fc_mode, xon)

        def scan_body(s, step_i):
            return body(s, step_i), None

        final, _ = jax.lax.scan(scan_body, init, jnp.arange(max_steps))
        return _slot_results(final)

    return run


# -----------------------------------------------------------------------
# Multi-step slot engine (``kernel="multistep"``): the whole
# micro-transaction loop fused into chunked Pallas launches
# -----------------------------------------------------------------------

#: packed-lane channel order of the multi-step carry, (16, L) int32:
#: the link FSM pair + per-link engine bookkeeping.
_MS_LANES = ("t", "last_dir", "bus_busy", "prev_tx_l", "prev_tx_r",
             "xl.mode", "xl.sw_ack", "xl.rx_p", "xl.burst",
             "xr.mode", "xr.sw_ack", "xr.rx_p", "xr.burst",
             "prev_mode_l", "n_sw", "busy_ns")
#: packed per-endpoint-side channel order, (9, L, 2) int32.
_MS_SIDES = ("n_ins", "sent", "n_pop", "xoff", "in_stall",
             "stall_steps", "credit_waits", "busy_steps", "q_drops")


def _pack_slot_state(s: _SlotState):
    """``_SlotState`` -> the multi-step kernel's packed int32 carry.

    Seven arrays: the three (Q, C) slot planes, a (16, L) lane plane
    (``_MS_LANES``), a (9, L, 2) side plane (``_MS_SIDES``), a (3, E)
    delivery-log plane and a (2,) counter vector ``[log_n, drops]``.
    The packing is what the roofline model meters: bytes/step on the
    per-step path = this carry round-tripped through HBM twice per
    micro-transaction."""
    lk = s.link
    lanes = jnp.stack([
        lk.t, lk.last_dir, lk.bus_busy, lk.prev_tx_l, lk.prev_tx_r,
        lk.xl.mode, lk.xl.sw_ack, lk.xl.rx_p, lk.xl.burst,
        lk.xr.mode, lk.xr.sw_ack, lk.xr.rx_p, lk.xr.burst,
        s.prev_mode_l, s.n_sw, s.busy_ns])
    sides = jnp.stack([s.n_ins, s.sent, s.n_pop, s.xoff, s.in_stall,
                       s.stall_steps, s.credit_waits, s.busy_steps,
                       s.q_drops])
    logs = jnp.stack([s.log_inj, s.log_del, s.log_dest])
    counters = jnp.stack([s.log_n, s.drops])
    return (s.q_time, s.q_dest, s.q_inj, lanes, sides, logs, counters)


def _unpack_slot_state(carry) -> _SlotState:
    q_time, q_dest, q_inj, lanes, sides, logs, counters = carry
    link = LinkState(
        t=lanes[0], last_dir=lanes[1], bus_busy=lanes[2],
        prev_tx_l=lanes[3], prev_tx_r=lanes[4],
        xl=XcvrState(mode=lanes[5], sw_ack=lanes[6], rx_p=lanes[7],
                     burst=lanes[8]),
        xr=XcvrState(mode=lanes[9], sw_ack=lanes[10], rx_p=lanes[11],
                     burst=lanes[12]))
    return _SlotState(
        link=link, q_time=q_time, q_dest=q_dest, q_inj=q_inj,
        n_ins=sides[0], sent=sides[1],
        prev_mode_l=lanes[13], n_sw=lanes[14],
        log_inj=logs[0], log_del=logs[1], log_dest=logs[2],
        log_n=counters[0], drops=counters[1],
        busy_ns=lanes[15], busy_steps=sides[7], q_drops=sides[8],
        n_pop=sides[2], xoff=sides[3], in_stall=sides[4],
        stall_steps=sides[5], credit_waits=sides[6])


def slot_carry_bytes(L: int, E: int, C: int) -> int:
    """Bytes of the packed multi-step carry (the roofline traffic unit).

    ``3·(2L·C) + 16·L + 9·2L + 3·E + 2`` int32 words — exactly what the
    per-step engine round-trips through XLA/HBM per micro-transaction
    and the multi-step kernel keeps resident for ``chunk`` steps."""
    q = 2 * L
    words = 3 * q * C + len(_MS_LANES) * L + len(_MS_SIDES) * q + 3 * E + 2
    return 4 * words


def _slot_run_multistep(L: int, E: int, C: int, max_steps: int,
                        max_burst: int, chunk: int):
    """Multi-step variant of :func:`_slot_run`: same operand contract,
    same 14-tuple result, but the scan over micro-transactions runs
    ``chunk`` steps at a time INSIDE one Pallas launch
    (``fabric_queue_multistep_pallas``) with the packed carry resident
    across steps, instead of dispatching two kernels + a full state
    round-trip per step.  The queue scan / pop / append inside the
    kernel body is the value-level scatter-as-matmul math
    (``scan_math`` / ``update_math``) — the same tile code the per-step
    kernels execute, now fused with the FSM/flow physics of
    :func:`_slot_step_body`.

    The final chunk's in-kernel loop bound is
    ``min(chunk, max_steps - base)``, so a binding ``max_steps`` is
    honoured exactly (post-bound steps never execute — they are not
    no-ops in general)."""
    from ..kernels import fabric_queue as fqk

    def run(q_time, q_dest, q_inj, sizes, init_tx,
            links_j, route_out_j, route_del_j, route_wt_j,
            t_cycle_v, t_rev_v, t_idle_v, cap, fc_mode, xon):
        init = _slot_init(L, E, q_time, q_dest, q_inj, sizes, init_tx)
        carry0 = _pack_slot_state(init)
        consts = (links_j, route_out_j, route_del_j, route_wt_j,
                  jnp.stack([t_cycle_v, t_rev_v, t_idle_v]),
                  jnp.stack([jnp.asarray(cap, jnp.int32),
                             jnp.asarray(fc_mode, jnp.int32),
                             jnp.asarray(xon, jnp.int32)]))

        def step_fn(car, con, step_i):
            links_c, rout_c, rdel_c, rwt_c, timing_c, par_c = con
            body = _slot_step_body(
                L, E, C, max_burst, fqk.scan_math, fqk.update_math,
                links_c, rout_c, rdel_c, rwt_c,
                timing_c[0], timing_c[1], timing_c[2],
                par_c[0], par_c[1], par_c[2])
            return _pack_slot_state(body(_unpack_slot_state(car), step_i))

        # base rides an array derived from a batched operand (sizes) so
        # that under jax.vmap every pallas operand carries the batch
        # axis — the batching rule then has no unbatched inputs to
        # special-case.  Solo, the added term is exactly zero.
        base0 = jnp.zeros((1,), jnp.int32) + 0 * sizes[0, 0]
        n_chunks = -(-max_steps // chunk) if max_steps > 0 else 0

        def chunk_body(state, _):
            car, b = state
            out = fqk.fabric_queue_multistep_pallas(
                car, consts, b, step_fn=step_fn,
                chunk=chunk, max_steps=max_steps)
            return (tuple(out), b + chunk), None

        carry = carry0
        if n_chunks > 0:
            (carry, _b), _ = jax.lax.scan(
                chunk_body, (carry0, base0), None, length=n_chunks)
        return _slot_results(_unpack_slot_state(carry))

    return run


@functools.lru_cache(maxsize=None)
def _slot_engine_multistep(L: int, E: int, C: int, max_steps: int,
                           max_burst: int, chunk: int):
    """Compile-once multi-step slot engine (``engine="pallas"`` with
    ``kernel="multistep"``): ceil(max_steps / chunk) fused kernel
    launches per run instead of 2·max_steps."""
    return _jit_cached(
        _slot_run_multistep(L, E, C, max_steps, max_burst, chunk),
        donate_argnums=(0, 1, 2))


@functools.lru_cache(maxsize=None)
def _slot_engine_multistep_batch(L: int, E: int, C: int, max_steps: int,
                                 max_burst: int, chunk: int,
                                 n_devices: int = 1):
    """Batched multi-step engine: ``jax.vmap`` over a ``(B,)`` instance
    axis; the fused kernel batches through ``pallas_call``'s batching
    rule (B independent carries per launch, interpret mode included)."""
    fn = jax.vmap(_slot_run_multistep(L, E, C, max_steps, max_burst,
                                      chunk))
    return _jit_cached(_shard_over_batch(fn, n_devices))


@functools.lru_cache(maxsize=None)
def _slot_engine(L: int, E: int, C: int, max_steps: int,
                 max_burst: int, use_kernels: bool):
    """Compile-once slot-scan simulation for one static shape signature.

    Timing arrives as *dynamic* (L,) cost vectors and routing as the
    per-plan replication tables, so one compilation serves every timing
    contract, routing table and flow-control setting that fits the shape
    signature — see :func:`_slot_run` for the full operand contract.
    """
    return _jit_cached(_slot_run(L, E, C, max_steps, max_burst,
                                 use_kernels), donate_argnums=(0, 1, 2))


def _shard_over_batch(fn, n_devices: int, n_args: int | None = None,
                      replicated: tuple = ()):
    """Split a batched engine's leading ``(B,)`` instance axis across
    devices via ``shard_map`` (through :mod:`repro.parallel.compat`, so
    old and new jax spellings both work).  Every operand and output
    carries the batch axis leading, so one ``PartitionSpec("batch")``
    covers the whole tree — except the positional args named in
    ``replicated`` (with ``n_args`` total), which are shared scalars
    (the ring batch's ``max_steps`` bound) and get the empty spec.
    Each shard runs its sub-batch independently — including the ring
    engine's early-exit ``while_loop``, which drains per-shard (a
    finished shard's devices idle instead of stepping the slowest
    instance globally).  ``n_devices <= 1`` is the identity."""
    if n_devices <= 1:
        return fn
    from jax.sharding import PartitionSpec

    from ..parallel import compat
    mesh = compat.make_mesh((int(n_devices),), ("batch",))
    spec = PartitionSpec("batch")
    in_specs = (spec if not replicated else
                tuple(PartitionSpec() if i in replicated else spec
                      for i in range(n_args)))
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=spec, check_vma=False)


@functools.lru_cache(maxsize=None)
def _slot_engine_batch(L: int, E: int, C: int, max_steps: int,
                       max_burst: int, use_kernels: bool,
                       n_devices: int = 1):
    """Batched slot engine: ONE compilation running B fabric instances.

    ``jax.vmap`` of :func:`_slot_run` over a leading ``(B,)`` instance
    axis on EVERY operand — traffic, routing/replication tables, timing
    vectors and the flow-control scalars are all per-instance, so a batch
    can mix seeds, tables and timing contracts freely within one shape
    signature.  The scan length is static (as in the solo engine), so all
    instances execute the same ``max_steps`` micro-transactions;
    post-completion steps are exact no-ops, keeping every instance
    bit-exact with its solo run.  The pallas variant batches through
    ``pallas_call``'s batching rule (interpret mode included).  With
    ``n_devices > 1`` the batch axis is additionally sharded across
    devices (see :func:`_shard_over_batch`)."""
    fn = jax.vmap(_slot_run(L, E, C, max_steps, max_burst, use_kernels))
    return _jit_cached(_shard_over_batch(fn, n_devices))


# -----------------------------------------------------------------------
# Ring engine: release-time-sorted per-endpoint streams, O(1) per step
# -----------------------------------------------------------------------

class _RingState(NamedTuple):
    link: LinkState           # (L,)-leaved LinkSim batch
    h0: jnp.ndarray           # (L, 2) prefill head (also the pop tie key)
    fh: jnp.ndarray           # (L, 2, D) forward-stream heads
    ftl: jnp.ndarray          # (L, 2, D) forward-stream tails
    fqs: jnp.ndarray          # (L, 2, D, Cf, 4) stream entries, packed
    #                           channels: 0 release time, 1 route id
    #                           (dest | mcast tree), 2 original injection
    #                           time, 3 reference-slot tie key.  One array
    #                           so each step is ONE head gather and ONE
    #                           tail scatter instead of four of each —
    #                           scatter/gather rows dominate the step on
    #                           CPU, and under vmap they serialize per
    #                           instance, so row count is the batch
    #                           throughput limit.
    n_ins: jnp.ndarray        # (L, 2) entries ever inserted (capacity/key)
    sent: jnp.ndarray         # (L, 2)
    prev_mode_l: jnp.ndarray  # (L,)
    n_sw: jnp.ndarray         # (L,)
    log_pk: jnp.ndarray       # (E + L, 3) delivery log, packed (inj,
    #                           t_del, dest).  Delivery slots are
    #                           CONSECUTIVE (log_n + per-step cumsum), so
    #                           the append is a dynamic_update_slice of
    #                           one compacted (L, 3) block — a dense copy,
    #                           not a scatter; the L-row slack holds each
    #                           step's zeroed overhang rows.
    log_n: jnp.ndarray        # scalar
    drops: jnp.ndarray        # scalar
    busy_ns: jnp.ndarray      # (L,) telemetry: ns spent transmitting
    busy_steps: jnp.ndarray   # (L, 2) telemetry: steps with backlog
    q_drops: jnp.ndarray      # (L, 2) telemetry: weighted drops per queue
    n_pop: jnp.ndarray        # (L, 2) entries ever popped (credit returns)
    xoff: jnp.ndarray         # (L, 2) latched on/off backpressure bit
    in_stall: jnp.ndarray     # (L, 2) stalled last step (episode edges)
    stall_steps: jnp.ndarray  # (L, 2) telemetry: flow-control stalls
    credit_waits: jnp.ndarray  # (L, 2) telemetry: stall episodes


def _ring_run(L: int, E: int, C0: int, D: int, Cf: int, chunk: int):
    """Build the ring-stream ``run`` function for one static shape
    signature (uncompiled — ``_ring_engine`` jits it solo,
    ``_ring_engine_batch`` vmaps it over a ``(B,)`` instance axis).

    All dimensions are the *bucketed* ones (``_RING_*_FLOOR`` pow2
    padding): ``L`` links, ``E`` delivery-log slots, ``C0``/``Cf``
    prefill/stream widths (each with one always-``BIG_NS`` pad column so
    head/tail gathers never need bounds checks), ``D`` streams per
    endpoint.  The logical capacity, event count, burst bound and flow
    control arrive as dynamic scalars (``cap``, ``real_e``,
    ``max_burst``, ``fc_mode``, ``xon`` — the FSM's burst guard and the
    admission gate are pure arithmetic) and the timing contract as dynamic
    (L,) cost vectors (``t_cycle_v`` / ``t_rev_v`` / ``t_idle_v``,
    padded with zeros on dummy links — which park forever, so their
    ``na + t_cycle`` term is the inert ``BIG_NS``), so every fabric that
    fits the buckets shares ONE compilation regardless of traffic,
    capacity, fairness setting or per-link timing assignment.
    """
    Q = 2 * L
    lidx = jnp.arange(L)
    no_key = jnp.int32(2 ** 31 - 1)  # tie-break sentinel (keys are < cap)

    def start(q0_time, q0_dest, q0_inj, sizes, init_tx,
              links_j, route_out_j, route_del_j, route_wt_j, in_rank_j,
              t_cycle_v, t_rev_v, t_idle_v,
              cap, max_burst, fc_mode, xon):
        """Build ``(init, body)`` from one instance's operands — shared
        by the solo loop below and the batched loop
        (:func:`_ring_run_batch`), which vmaps ``body`` ALONE so the
        chunk bookkeeping stays scalar."""
        K = route_out_j.shape[2]
        link0 = reset_links(init_tx)
        # per-(link, side) delivery chip, both sides — the flow gate
        # inspects both heads before the FSM picks a direction.  Dummy
        # padded links point at chip 0 with empty queues: inert.
        rx_chip_cand = jnp.stack([links_j[:, 1], links_j[:, 0]], axis=1)
        si2 = jnp.arange(2)[None, :]
        li2 = lidx[:, None]
        # pack the prefill columns once per trace: the per-step head read
        # becomes one gather of (time, route, inj) triples
        q0_all = jnp.stack([q0_time, q0_dest, q0_inj], axis=-1)
        didx = jnp.arange(D, dtype=jnp.int32)
        qid = jnp.arange(Q, dtype=jnp.int32)[None, :]
        init = _RingState(
            link=link0,
            h0=jnp.zeros((L, 2), jnp.int32),
            fh=jnp.zeros((L, 2, D), jnp.int32),
            ftl=jnp.zeros((L, 2, D), jnp.int32),
            fqs=jnp.stack(
                [jnp.full((L, 2, D, Cf), _BIG, jnp.int32),
                 jnp.zeros((L, 2, D, Cf), jnp.int32),
                 jnp.zeros((L, 2, D, Cf), jnp.int32),
                 jnp.zeros((L, 2, D, Cf), jnp.int32)], axis=-1),
            n_ins=sizes,
            sent=jnp.zeros((L, 2), jnp.int32),
            prev_mode_l=link0.xl.mode,
            n_sw=jnp.zeros((L,), jnp.int32),
            log_pk=jnp.zeros((E + L, 3), jnp.int32),
            log_n=jnp.zeros((), jnp.int32),
            drops=jnp.zeros((), jnp.int32),
            busy_ns=jnp.zeros((L,), jnp.int32),
            busy_steps=jnp.zeros((L, 2), jnp.int32),
            q_drops=jnp.zeros((L, 2), jnp.int32),
            n_pop=jnp.zeros((L, 2), jnp.int32),
            xoff=jnp.zeros((L, 2), jnp.int32),
            in_stall=jnp.zeros((L, 2), jnp.int32),
            stall_steps=jnp.zeros((L, 2), jnp.int32),
            credit_waits=jnp.zeros((L, 2), jnp.int32),
        )

        def body(s: _RingState, step_i):
            t_now = s.link.t  # (L,)

            # --- O(1) queue reads: stream heads only --------------------
            # Every stream is sorted by (release, insertion key): the
            # prefill by construction, each forward stream because its
            # source link's delivery clock is monotone.  So per endpoint,
            # "any released entry", the earliest released release and the
            # earliest future arrival are all properties of the 1 + D
            # heads — no O(C) slot scan.
            p_head = jnp.take_along_axis(
                q0_all, s.h0[:, :, None, None], axis=2)[:, :, 0]  # (L,2,3)
            f_head = jnp.take_along_axis(
                s.fqs, s.fh[..., None, None], axis=3)[:, :, :, 0]  # (L,2,D,4)
            p_t = p_head[..., 0]                                 # (L, 2)
            f_t = f_head[..., 0]                                 # (L, 2, D)
            p_rel = p_t <= t_now[:, None]
            f_rel = f_t <= t_now[:, None, None]
            pend_side = p_rel | jnp.any(f_rel, axis=2)           # (L, 2)
            r_min = jnp.minimum(
                jnp.where(p_rel, p_t, _BIG),
                jnp.min(jnp.where(f_rel, f_t, _BIG), axis=2))
            nxt = jnp.minimum(
                jnp.where(p_rel, _BIG, p_t),
                jnp.min(jnp.where(f_rel, _BIG, f_t), axis=2))    # (L, 2)

            # --- the earliest (release, key) head, BOTH sides -----------
            # (release, insertion_key) lexicographic minimum in two int32
            # stages (keys are unique reference slot ids per queue, so the
            # key argmin over release ties is exact and matches the
            # reference argmin's lowest-slot rule).  Computed before the
            # FSM step because the flow-control gate must inspect each
            # head's downstream targets; the send side's values are
            # gathered out after the FSM picks a direction — identical
            # math to a post-step send-side-only selection.
            fk = f_head[..., 3]                                  # (L, 2, D)
            cand_t = jnp.concatenate(
                [p_t[:, :, None], f_t], axis=2)                  # (L,2,1+D)
            cand_k = jnp.concatenate(
                [s.h0[:, :, None], fk], axis=2)
            rel_c = cand_t <= t_now[:, None, None]
            t_best = jnp.min(jnp.where(rel_c, cand_t, _BIG), axis=2)
            tie = rel_c & (cand_t == t_best[..., None])
            best = jnp.argmin(jnp.where(tie, cand_k, no_key),
                              axis=2).astype(jnp.int32)          # (L, 2)
            from_pre = best == 0
            d_best = jnp.maximum(best - 1, 0)
            # the winning forward stream's head entry IS f_head at d_best
            # (f_head gathers AT s.fh), so no second stream gather
            best_head = f_head[li2, si2, d_best]                 # (L, 2, 4)
            cand_route = jnp.where(
                from_pre, p_head[..., 1], best_head[..., 1])
            cand_inj = jnp.where(
                from_pre, p_head[..., 2], best_head[..., 2])

            # --- flow-control admission gate ----------------------------
            # Identical inputs and formulas to the slot engines: the
            # occupancy n_ins - n_pop is O(1) carry state, and the head
            # route is exactly the slot engines' q_dest[q, amin] gather.
            occ = s.n_ins - s.n_pop
            blocked, xoff = _flow_gate(fc_mode, cap, xon, occ, s.xoff,
                                       cand_route, rx_chip_cand,
                                       route_out_j)
            stalled = pend_side & blocked
            stall_steps = s.stall_steps + stalled.astype(jnp.int32)
            credit_waits = s.credit_waits + (
                stalled & (s.in_stall == 0)).astype(jnp.int32)

            # --- conservative clock synchronization ---------------------
            # Identical contract to the reference engine (see
            # _slot_engine, including the per-link ``min(na + t_cycle)``
            # insert bound and the per-side head-of-line/stall rules);
            # head releases are exact stand-ins: a side with work pending
            # contributes the clock (gated: excluded), and a side with
            # none has every head unreleased, so the head minimum IS the
            # stream minimum — the one state where arrival times behind
            # heads would be invisible here is exactly the state the
            # head-of-line rule makes them irrelevant in.
            na_side = jnp.where(
                pend_side, jnp.where(blocked, _BIG, t_now[:, None]), nxt)
            na = jnp.min(na_side, axis=1)                        # (L,)
            t_next_g = jnp.min(jnp.where(pend_side, _BIG, nxt), axis=1)
            horizon = jnp.min(na)
            t_next_eff = jnp.minimum(t_next_g,
                                     jnp.maximum(horizon, t_now))
            safe = r_min <= jnp.min(na + t_cycle_v)              # (L, 2)
            pend_safe = (pend_side & safe & ~blocked).astype(jnp.int32)

            # --- one micro-transaction on every link, batched -----------
            link, out = link_step_batch(
                s.link, pend_safe[:, 0], pend_safe[:, 1], t_next_eff,
                max_burst=max_burst,
                timing_arrays=(t_cycle_v, t_rev_v, t_idle_v))

            did = (out.tx_l + out.tx_r) > 0                      # (L,) bool
            did32 = did.astype(jnp.int32)
            # telemetry: backlog indicator + transmission-gated clock
            # delta — head properties only, so the O(1)-per-step contract
            # holds; bit-exact with the slot engines' (pend > 0) counter
            busy_steps = s.busy_steps + pend_side.astype(jnp.int32)
            busy_ns = s.busy_ns + jnp.where(did, link.t - t_now, 0)
            send_side = jnp.where(out.tx_l == 1, 0, 1)           # (L,)

            # --- pop the send side's head, return its credit ------------
            fp_s = from_pre[lidx, send_side]                     # (L,)
            db_s = d_best[lidx, send_side]
            ev_route = cand_route[lidx, send_side]
            ev_inj = cand_inj[lidx, send_side]
            # single update per link row -> dense one-hot adds, not
            # scatters (XLA lowers small scatters to a per-row loop; under
            # vmap that loop serializes across the batch too)
            oh_side = si2 == send_side[:, None]                  # (L, 2)
            h0 = s.h0 + jnp.where(
                oh_side, (did & fp_s).astype(jnp.int32)[:, None], 0)
            oh_d = oh_side[:, :, None] & (didx == db_s[:, None, None])
            fh = s.fh + jnp.where(
                oh_d, (did & ~fp_s).astype(jnp.int32)[:, None, None], 0)
            sent = s.sent + jnp.where(oh_side, did32[:, None], 0)
            n_pop = s.n_pop + jnp.where(oh_side, did32[:, None], 0)

            # --- deliver and/or replicate -------------------------------
            # The replication-table row of (rx_chip, route) decides both:
            # a multicast branch node can deliver locally AND spawn up to
            # K child copies from this one pop.
            rx_side = jnp.where(out.tx_l == 1, 1, 0)
            rx_chip = links_j[lidx, rx_side]
            deliver = did & (route_del_j[rx_chip, ev_route] > 0)

            # Delivery slots are consecutive from log_n (the same
            # log_n + cumsum slot rule as _log_deliveries), so instead of
            # three scatters the step compacts the delivering links to
            # the front — inv[p] is the (p+1)-th delivering link id,
            # counted densely — and writes ONE (L, 3) block with
            # dynamic_update_slice.  Rows at or past this step's delivery
            # count nd are forced to zero: the next step's block starts
            # exactly where this one's valid rows end, so overhang rows
            # are always overwritten by later valid rows, and the final
            # overhang leaves the same zeros an untouched buffer holds.
            # The buffer's L-row slack keeps the slice start (<= E) from
            # ever clamping.
            d32l = deliver.astype(jnp.int32)
            nd = jnp.sum(d32l)
            csum = jnp.cumsum(d32l)
            inv = jnp.minimum(jnp.sum(
                (csum[None, :] <= lidx[:, None]).astype(jnp.int32),
                axis=1), L - 1)                                  # (L,)
            blk = jnp.where(
                (lidx < nd)[:, None],
                jnp.stack([ev_inj[inv], link.t[inv], rx_chip[inv]],
                          axis=-1), 0)                           # (L, 3)
            log_pk = jax.lax.dynamic_update_slice(
                s.log_pk, blk, (s.log_n, jnp.int32(0)))
            log_n = s.log_n + nd

            # --- forward append: tails of the delivering link's streams -
            # All K copies of one pop land at the SAME chip on K distinct
            # out-queues, so every active (queue, in-edge) target below
            # is unique and the multi-scatter is race-free.
            fwd_f, fqk_f, wt_f = _replicate(route_out_j, route_wt_j,
                                            rx_chip, ev_route, did)
            n_ins_f = s.n_ins.reshape(-1)
            # ``key`` is the reference slot id: the pop tie-break key.
            # Only drop mode discards at append time; the stall modes
            # are lossless and the stream quotas already bound storage.
            app_cap = jnp.where(fc_mode == 0, cap, jnp.int32(_BIG))
            fq_g, key, app, dropped = _forward_slots(
                fwd_f, fqk_f, n_ins_f, app_cap, Q)
            d_ins = jnp.repeat(in_rank_j[lidx, rx_side], K)      # (L·K,)
            stream = fq_g * D + d_ins          # flat stream id
            stream_s = jnp.where(app, stream, Q * D)
            tail = s.ftl.reshape(-1)[stream]                     # (L·K,)
            # ONE packed append per step: all four channels of one entry
            # travel in a single (L·K, 4) scatter row
            upd = jnp.stack(
                [jnp.repeat(link.t, K), jnp.repeat(ev_route, K),
                 jnp.repeat(ev_inj, K), key], axis=-1)           # (L·K, 4)
            fqs = s.fqs.reshape(Q * D, Cf, 4) \
                .at[stream_s, tail].set(upd, mode="drop") \
                .reshape(L, 2, D, Cf, 4)
            # counter bumps as dense one-hot sums over the tiny (Q,) and
            # (D,) index spaces — masked rows contribute zero everywhere
            eq_q = fq_g[:, None] == qid                          # (L·K, Q)
            app_q = (eq_q & app[:, None]).astype(jnp.int32)
            n_ins = (n_ins_f + jnp.sum(app_q, axis=0)).reshape(L, 2)
            eq_d = (d_ins[:, None] == didx[None, :]).astype(jnp.int32)
            ftl = (s.ftl.reshape(Q, D) + jnp.einsum(
                'rq,rd->qd', app_q, eq_d)).reshape(L, 2, D)
            drop_wt = jnp.where(dropped, wt_f, 0)
            drops = s.drops + jnp.sum(drop_wt)
            # telemetry: charge each weighted drop to its target queue
            q_drops = (s.q_drops.reshape(-1) + jnp.sum(
                eq_q.astype(jnp.int32) * drop_wt[:, None], axis=0)
                ).reshape(L, 2)

            # --- switch counting (reset step excluded) ------------------
            n_sw = s.n_sw + jnp.where(
                step_i > 0,
                (link.xl.mode != s.prev_mode_l).astype(jnp.int32), 0)

            ns = _RingState(
                link=link, h0=h0, fh=fh, ftl=ftl,
                fqs=fqs, n_ins=n_ins, sent=sent,
                prev_mode_l=link.xl.mode, n_sw=n_sw,
                log_pk=log_pk, log_n=log_n, drops=drops,
                busy_ns=busy_ns, busy_steps=busy_steps, q_drops=q_drops,
                n_pop=n_pop, xoff=xoff,
                in_stall=stalled.astype(jnp.int32),
                stall_steps=stall_steps, credit_waits=credit_waits)
            return ns, None

        return init, body

    def run(q0_time, q0_dest, q0_inj, sizes, init_tx,
            links_j, route_out_j, route_del_j, route_wt_j, in_rank_j,
            t_cycle_v, t_rev_v, t_idle_v,
            cap, real_e, max_burst, max_steps, fc_mode, xon):
        init, body = start(q0_time, q0_dest, q0_inj, sizes, init_tx,
                           links_j, route_out_j, route_del_j, route_wt_j,
                           in_rank_j, t_cycle_v, t_rev_v, t_idle_v,
                           cap, max_burst, fc_mode, xon)

        # --- chunked steps inside while_loop: exit within one chunk of
        # delivered + drops == injected.  Post-completion steps are
        # no-ops (no pending, parked clocks, settled FSMs), so stopping
        # at a chunk boundary is bit-exact vs. the padded reference scan.
        # The inner trip count is clamped to the steps remaining under
        # ``max_steps`` (a dynamic fori_loop bound — same lowering as the
        # fixed-length scan, no per-step masking cost), so when the step
        # bound binds mid-chunk the simulation still executes EXACTLY
        # ``max_steps`` micro-transactions — bit-exact against a
        # reference scan of the same length.
        def chunk_body(carry):
            st, base = carry
            this_chunk = jnp.minimum(jnp.int32(chunk), max_steps - base)
            st2 = jax.lax.fori_loop(
                jnp.int32(0), this_chunk,
                lambda i, s: body(s, base + i)[0], st)
            return st2, base + jnp.int32(chunk)

        def cond(carry):
            st, base = carry
            return (st.log_n + st.drops < real_e) & (base < max_steps)

        final, _ = jax.lax.while_loop(cond, chunk_body,
                                      (init, jnp.int32(0)))
        return (final.log_n, final.log_pk[:E, 0], final.log_pk[:E, 1],
                final.log_pk[:E, 2],
                final.sent, final.n_sw, final.link.t, final.drops,
                final.busy_ns, final.busy_steps, final.q_drops,
                final.stall_steps, final.credit_waits)

    run._start = start   # the batched runner reuses (init, body)
    return run


def _ring_run_batch(L: int, E: int, C0: int, D: int, Cf: int, chunk: int):
    """Build the BATCHED ring ``run``: B instances, one computation.

    Not a blind ``jax.vmap`` of the solo runner — that would batch the
    loop bookkeeping too, and JAX's while/fori batching rules then pay
    for it twice per micro-transaction: a batched inner trip count
    turns the chunk ``fori_loop`` into a masked ``while_loop`` that
    re-selects EVERY carry leaf (the full queue state) on EVERY step,
    an ~8x per-instance slowdown on CPU.  Instead only the step
    ``body`` is vmapped (gathers/scatters batch cleanly into one kernel
    each); ``base``/``max_steps``/``chunk`` stay scalar, so the inner
    ``fori_loop`` keeps the solo lowering, and the early exit is one
    ``jnp.any`` over the per-instance delivery deficits: the loop runs
    until ALL instances drain, finished instances executing
    post-completion micro-transactions that are exact no-ops (the same
    property the solo early exit relies on at chunk granularity).
    Bit-exactness per instance is asserted by the batch tests and the
    CI batch gate.

    Signature matches the solo runner with every operand carrying a
    leading ``(B,)`` instance axis — including the dynamic scalars
    (``cap``/``real_e``/``max_burst``/``fc_mode``/``xon`` become (B,)
    vectors) — EXCEPT ``max_steps``, which is one shared scalar bound
    (``_plan_batch`` aligns the batch on it; a non-binding bound is
    invisible in the results).
    """
    start = _ring_run(L, E, C0, D, Cf, chunk)._start

    def run(q0_time, q0_dest, q0_inj, sizes, init_tx,
            links_j, route_out_j, route_del_j, route_wt_j, in_rank_j,
            t_cycle_v, t_rev_v, t_idle_v,
            cap, real_e, max_burst, max_steps, fc_mode, xon):
        ops = (q0_time, q0_dest, q0_inj, sizes, init_tx,
               links_j, route_out_j, route_del_j, route_wt_j, in_rank_j,
               t_cycle_v, t_rev_v, t_idle_v, cap, max_burst, fc_mode,
               xon)

        init = jax.vmap(lambda *o: start(*o)[0])(*ops)

        def body_of(ops_i, s, step_i):
            return start(*ops_i)[1](s, step_i)[0]

        vbody = jax.vmap(body_of, in_axes=(0, 0, None))

        def chunk_body(carry):
            st, base = carry
            this_chunk = jnp.minimum(jnp.int32(chunk), max_steps - base)
            st2 = jax.lax.fori_loop(
                jnp.int32(0), this_chunk,
                lambda i, s: vbody(ops, s, base + i), st)
            return st2, base + jnp.int32(chunk)

        def cond(carry):
            st, base = carry
            return (jnp.any(st.log_n + st.drops < real_e)
                    & (base < max_steps))

        final, _ = jax.lax.while_loop(cond, chunk_body,
                                      (init, jnp.int32(0)))
        return (final.log_n, final.log_pk[:, :E, 0],
                final.log_pk[:, :E, 1], final.log_pk[:, :E, 2],
                final.sent, final.n_sw, final.link.t, final.drops,
                final.busy_ns, final.busy_steps, final.q_drops,
                final.stall_steps, final.credit_waits)

    return run


@functools.lru_cache(maxsize=None)
def _ring_engine(L: int, E: int, C0: int, D: int, Cf: int, chunk: int):
    """Compile-once ring simulation for one static shape signature —
    :func:`_ring_run` jitted.  No donation: the prefill arrays are
    read-only gather sources here (no same-shaped output exists to alias
    them into)."""
    return _jit_cached(_ring_run(L, E, C0, D, Cf, chunk))


@functools.lru_cache(maxsize=None)
def _ring_engine_batch(L: int, E: int, C0: int, D: int, Cf: int,
                       chunk: int, n_devices: int = 1):
    """Batched ring engine: ONE compilation running B fabric instances.

    ``jax.vmap`` of :func:`_ring_run` with every operand carrying a
    leading ``(B,)`` instance axis — per-instance traffic, tables, timing
    vectors AND per-instance dynamic scalars (``cap`` / ``real_e`` /
    ``max_burst`` / ``fc_mode`` / ``xon`` become (B,) vectors;
    ``max_steps`` is the one shared scalar bound).  The early-exit
    ``while_loop`` is batch-aware by construction (see
    :func:`_ring_run_batch`): it continues while ANY instance still has
    a delivery/drop deficit — the max-over-instances exit the batch
    semantics require — and finished instances execute exact-no-op
    micro-transactions (the property the solo early exit already relies
    on), so every instance stays bit-exact with its solo run.  With
    ``n_devices > 1`` the batch axis is sharded across devices and each
    shard drains independently (see :func:`_shard_over_batch`)."""
    fn = _ring_run_batch(L, E, C0, D, Cf, chunk)
    return _jit_cached(_shard_over_batch(fn, n_devices, n_args=19,
                                         replicated=(16,)))


# -----------------------------------------------------------------------
# Public entry point
# -----------------------------------------------------------------------

def simulate_fabric(topo: Topology,
                    spec: TrafficSpec,
                    *,
                    routing: RoutingTable | None = None,
                    addr: AddressSpec | None = None,
                    mcast=None,
                    timing: LinkTiming = PAPER_TIMING,
                    max_burst: int = 0,
                    initial_tx: int | np.ndarray = 1,
                    max_steps: int | None = None,
                    queue_capacity: int | None = None,
                    flow_control: str = "drop",
                    xon: int | None = None,
                    engine: str = "auto",
                    chunk_size: int = DEFAULT_CHUNK_SIZE) -> FabricResult:
    """Simulate an N-chip fabric of bi-directional AER links.

    This is the stable *convenience wrapper* around the declarative
    :class:`repro.core.fabric.Fabric` object API: it folds the kwargs
    into the corresponding policy objects, builds a one-shot ``Fabric``
    and calls :meth:`Fabric.run`.  Code that reuses one fabric across
    many traffic specs (sweeps, serving loops) should hold a ``Fabric``
    and use its explicit ``compile``/``run``/``run_many`` lifecycle
    instead — the wrapper rebuilds routing tables every call and hides
    the shape-bucketed jit cache that makes repeat runs cheap.

    Args:
      topo:        fabric topology (``router.line/ring/mesh2d_topology``).
      spec:        injected traffic.  With ``addr`` given, ``spec.dest``
                   holds packed 26-bit AER words (multicast tags resolved
                   through ``mcast``); otherwise plain destination chip ids.
      routing:     prebuilt table (rebuilt from ``topo`` when omitted).
      mcast:       a ``MulticastTable`` (tags expanded at the source, the
                   historical default) or a ``fabric.MulticastPolicy``
                   selecting ``source_expand`` vs ``in_fabric``
                   replication.
      timing:      timing contract — one scalar ``LinkTiming`` shared by
                   all links, or a structure-of-arrays ``LinkTiming`` of
                   shape (L,) for per-link heterogeneity (see
                   ``link.per_link_timing``).
      max_burst:   0 = paper-faithful grant rule, B > 0 = bounded burst.
      initial_tx:  scalar or (L,) — which side of each link resets into TX.
      max_steps:   global micro-transaction count; default scales with the
                   total hop-transmissions the traffic needs.
      queue_capacity: per-endpoint budget.  In drop mode slots are
                   one-shot, so this bounds the total events routed
                   *through* an endpoint (defaults to the expanded event
                   count — lossless); smaller values may drop forwards,
                   counted in ``FabricResult.drops``.  In the stall
                   modes it bounds instantaneous occupancy instead.
      flow_control: ``"drop"`` (default, discard at full queues) |
                   ``"credit"`` (stall the upstream pop until occupancy
                   falls below ``queue_capacity``) | ``"onoff"``
                   (xon/xoff hysteresis on the latched threshold bit).
                   See the module docstring; the stall modes require a
                   finite ``queue_capacity`` and guarantee
                   ``drops == 0``.
      xon:         on/off low-water mark (``"onoff"`` only); defaults
                   to ``queue_capacity // 2``.
      engine:      ``"ring"`` (O(1)-per-step streams, early exit, the
                   default via ``"auto"``), ``"reference"`` (PR 1 flat
                   slot scan, the semantics oracle) or ``"pallas"``
                   (slot scan through the fused ``kernels/fabric_queue``
                   kernels).  All three are bit-exact.
      chunk_size:  ring engine only — micro-transactions per ``lax.scan``
                   chunk between early-exit checks.
    """
    from .fabric import EngineSpec, Fabric, QueuePolicy
    fab = Fabric(topo, routing=routing, timing=timing,
                 queues=QueuePolicy(capacity=queue_capacity,
                                    max_burst=max_burst,
                                    initial_tx=initial_tx,
                                    flow=flow_control, xon=xon),
                 engine=EngineSpec(name=engine, chunk_size=chunk_size),
                 addr=addr, mcast=mcast)
    return fab.run(spec, max_steps=max_steps)


# -----------------------------------------------------------------------
# Measurement roll-ups
# -----------------------------------------------------------------------

def fabric_throughput_mev_s(res: FabricResult) -> jnp.ndarray:
    """Delivered events per second across the fabric, MEvents/s."""
    return jnp.where(res.t_end > 0, 1e3 * res.delivered / res.t_end, 0.0)


def per_link_throughput_mev_s(res: FabricResult) -> jnp.ndarray:
    """(L,) per-link transmissions/s (both directions), MEvents/s."""
    n = jnp.sum(res.sent, axis=1)
    return jnp.where(res.t_link > 0, 1e3 * n / res.t_link, 0.0)


def link_energy_pj(sent, timing: LinkTiming = PAPER_TIMING) -> float:
    """THE link energy model: every transmission on link ``l`` moves one
    event at that link's ``e_event_pj`` (scalar timing: the paper's
    11 pJ everywhere; per-link timing: the link's own class figure).

    ``sent`` is per-link transmission counts — ``(L,)`` or ``(L, 2)``
    (trailing axes summed per link).  Shared by
    :func:`fabric_energy_pj` and the SNN report roll-ups
    (``models/snn.py``), so the fabric's billed energy and the
    application-level report can never drift apart."""
    sent = np.asarray(sent, np.float64)
    per_link = sent.sum(axis=tuple(range(1, sent.ndim)))
    e = np.broadcast_to(np.asarray(timing.e_event_pj, np.float64),
                        per_link.shape)
    return float((per_link * e).sum())


def fabric_energy_pj(res: FabricResult,
                     timing: LinkTiming = PAPER_TIMING) -> float:
    """Total link energy of one fabric run (see :func:`link_energy_pj`)."""
    return link_energy_pj(res.sent, timing)


def delivery_multiset(res: FabricResult) -> list:
    """Sorted (injection time, destination chip) pairs of all deliveries
    — the mode-independent multicast contract: ``source_expand`` and
    ``in_fabric`` transports of one workload must produce the identical
    multiset (asserted in tests and gated in the CI bench smoke)."""
    n = int(res.delivered)
    return sorted(zip(np.asarray(res.log_inj)[:n].tolist(),
                      np.asarray(res.log_dest)[:n].tolist()))


def delivered_latencies(res: FabricResult) -> np.ndarray:
    """End-to-end ns latencies of the delivered events (numpy)."""
    n = int(res.delivered)
    inj = np.asarray(res.log_inj)[:n]
    dlv = np.asarray(res.log_del)[:n]
    return (dlv - inj).astype(np.int64)


def latency_stats(res: FabricResult) -> dict:
    """p50/p90/p99/max end-to-end latency plus delivery counters.

    ``traversals`` counts actual link transmissions (the per-link
    weighted hop count energy is billed on) and ``fanout`` the expected
    deliveries per offered event — together they quantify what in-fabric
    multicast replication saves over source expansion."""
    lat = delivered_latencies(res)
    base = {
        "delivered": int(res.delivered),
        "injected": res.injected,
        "offered": res.offered,
        "fanout": res.fanout,
        "traversals": res.traversals,
    }
    if lat.size == 0:
        return {**base, "delivered": 0,
                "p50_ns": 0.0, "p90_ns": 0.0, "p99_ns": 0.0, "max_ns": 0}
    return {
        **base,
        "p50_ns": float(np.percentile(lat, 50)),
        "p90_ns": float(np.percentile(lat, 90)),
        "p99_ns": float(np.percentile(lat, 99)),
        "max_ns": int(lat.max()),
    }
