"""Half-duplex / bidirectional ring collectives (paper technique, layer 2).

The paper's transceiver shares ONE physical bus between two directions and
switches on demand; the measured lesson is that a reversal costs only
~4 ns against a 31 ns event cycle, so keeping a link busy in both
directions is nearly free.  On TPU the ICI links are physically
bidirectional, but a *unidirectional* ring schedule (the naive "two
parallel buses" design the paper argues against) drives each link in one
direction only and leaves half the aggregate wire bandwidth idle.

``bidirectional=True`` splits every payload in half and runs two
counter-rotating rings concurrently — both directions of every link carry
useful traffic, halving the wall-clock of the bandwidth term exactly like
the paper's shared bus halves the pin count.  These run inside
``shard_map`` over a DP axis via ``jax.lax.ppermute``.

All variants are numerically equivalent to ``jax.lax.psum`` (tested on 8
host devices) and are selectable as the gradient-reduction schedule in
``runtime/train_loop.py`` (``dp_reduce = ring | bidir_ring``).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..parallel.compat import axis_size


def _ring_perm(n, reverse=False):
    if reverse:
        return [(i, (i - 1) % n) for i in range(n)]
    return [(i, (i + 1) % n) for i in range(n)]


def _pad_to(x, mult):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % mult
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def ring_reduce_scatter(x, axis_name, *, reverse=False):
    """Unidirectional ring reduce-scatter over ``axis_name``.

    x: identical-shape local array per device. Returns this device's
    reduced chunk (flattened, 1/n of padded x).
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    flat, _ = _pad_to(x, n)
    chunks = flat.reshape(n, -1)
    perm = _ring_perm(n, reverse)
    sign = -1 if reverse else 1

    # step s: device i adds its local copy of chunk (i - sign*(s+1)) to the
    # accumulating partial and passes it on; after n-1 steps device i holds
    # the full sum of chunk i... shifted by ring direction.
    def body(s, acc):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        cid = (idx - sign * (s + 2)) % n
        return acc + chunks[cid]

    acc0 = chunks[(idx - sign) % n]
    acc = jax.lax.fori_loop(0, n - 1, body, acc0) if n > 1 else chunks[idx]
    return acc  # device i holds reduced chunk ((i - sign*(n)) % n == i)


def ring_all_gather(x, axis_name, *, reverse=False):
    """Unidirectional ring all-gather: local chunk -> (n * chunk) flat."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = _ring_perm(n, reverse)
    sign = -1 if reverse else 1
    out = jnp.zeros((n,) + x.shape, x.dtype).at[idx].set(x)

    def body(s, carry):
        out, buf = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        src = (idx - sign * (s + 1)) % n
        out = out.at[src].set(buf)
        return out, buf

    if n > 1:
        out, _ = jax.lax.fori_loop(0, n - 1, body, (out, x))
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def ring_allreduce(x, axis_name, *, bidirectional=False):
    """Ring all-reduce == psum(x, axis_name), as RS + AG.

    bidirectional=True: payload split in half, two counter-rotating rings —
    both ICI link directions utilized (the paper-adapted schedule).
    """
    shape, dtype = x.shape, x.dtype
    n = axis_size(axis_name)
    if n == 1:
        return x
    if not bidirectional:
        flat, pad = _pad_to(x, n)
        red = ring_reduce_scatter(x, axis_name)
        full = ring_all_gather(red, axis_name)
        if pad:
            full = full[:flat.shape[0] - pad]
        return full[:x.size].reshape(shape).astype(dtype)

    flat, pad = _pad_to(x, 2 * n)
    half = flat.reshape(2, -1)
    fwd, bwd = half[0], half[1]
    red_f = ring_reduce_scatter(fwd, axis_name, reverse=False)
    red_b = ring_reduce_scatter(bwd, axis_name, reverse=True)
    full_f = ring_all_gather(red_f, axis_name, reverse=False)
    full_b = ring_all_gather(red_b, axis_name, reverse=True)
    out = jnp.concatenate([full_f, full_b])
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


def wire_bytes_per_direction(n_bytes_payload: int, n_devices: int,
                             bidirectional: bool) -> float:
    """Ring all-reduce ships 2*(n-1)/n of the payload per device.  A
    unidirectional ring puts all of it on one link direction; the
    bidirectional schedule splits it across both — the per-direction (i.e.
    wall-clock-critical) traffic halves, the paper's pin-saving argument in
    byte units."""
    total = 2 * (n_devices - 1) / n_devices * n_bytes_payload
    return total / (2 if bidirectional else 1)
