"""Event-sparse collectives: the Address-Event Representation applied to
gradient synchronization (paper technique, layer 1).

AER's economy: transmit (address, value) only for *active* entries, so wire
traffic scales with activity, not tensor size.  ``aer_allreduce`` is the DP
gradient sync built on that idea:

  1. add the error-feedback residual to the local gradient shard;
  2. threshold-encode each (num_blocks × block) tile into fixed-budget
     event slots (Pallas kernel ``kernels/aer_encode``) — the threshold is
     the per-block |g| quantile for the target fraction;
  3. all-gather the event slots over the DP axis (the only cross-device
     traffic: ``budget/block`` of the dense payload);
  4. decode every peer's events (``kernels/aer_decode``) and sum into the
     dense result;
  5. keep what did not ship as the next step's residual (the FIFO
     back-pressure analogue — nothing is lost, only delayed).

Runs inside ``shard_map`` over the DP axis.  Also provides the dense
baselines and the wire-volume accounting used by benchmarks/tables.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops as K
from ..parallel.compat import axis_size
from . import halfduplex as hd


class AerState(NamedTuple):
    """Per-tensor error-feedback residual (same shape as the gradient)."""
    residual: jnp.ndarray

    @classmethod
    def init(cls, x):
        return cls(residual=jnp.zeros_like(x))


def aer_allreduce(x, state: AerState, axis_name, *, frac=0.02,
                  budget=K.DEFAULT_BUDGET, block=K.DEFAULT_BLOCK,
                  interpret=None):
    """Event-sparse all-*mean* of ``x`` over ``axis_name``.

    Returns (dense mean-reduced tensor — identical on all axis members,
    new AerState, wire_words_sent scalar).
    """
    n = axis_size(axis_name)
    y = x + state.residual
    tiles, size = K.pad_to_blocks(y, block)
    tau = K.tau_from_fraction(tiles, frac)
    ev = K.aer_compress(tiles, tau, budget, interpret=interpret)

    # the wire: fixed-width event slots, all-gathered over the DP axis
    all_idx = jax.lax.all_gather(ev.idx, axis_name)    # (n, nb, budget)
    all_val = jax.lax.all_gather(ev.val, axis_name)

    dec_all = jax.vmap(
        lambda i, v: K.aer_decompress(K.EventBlocks(i, v, ev.count,
                                                    ev.wanted),
                                      block, interpret=interpret)
    )(all_idx, all_val)                                # (n, nb, block)
    summed = dec_all.sum(axis=0) / n

    own_dec = dec_all[jax.lax.axis_index(axis_name)]
    new_residual = K.unpad_from_blocks(tiles - own_dec, size, x.shape)
    reduced = K.unpad_from_blocks(summed, size, x.shape)
    wire_words = jnp.sum(ev.count)
    return reduced, AerState(residual=new_residual), wire_words


def dense_allreduce(x, axis_name, *, schedule="psum"):
    """Dense mean baselines: psum | ring | bidir_ring."""
    n = axis_size(axis_name)
    if schedule == "psum":
        return jax.lax.psum(x, axis_name) / n
    return hd.ring_allreduce(
        x, axis_name, bidirectional=(schedule == "bidir_ring")) / n


def reduce_gradients(grads, aer_states, axis_name, *, mode="psum",
                     frac=0.02, budget=K.DEFAULT_BUDGET, interpret=None):
    """Tree-wise DP gradient reduction with selectable schedule.

    mode: psum | ring | bidir_ring | aer_topk.
    Returns (grads, new_aer_states, wire_words_total).
    """
    if mode in ("psum", "ring", "bidir_ring"):
        out = jax.tree.map(
            lambda g: dense_allreduce(g, axis_name, schedule=mode), grads)
        return out, aer_states, jnp.int32(0)

    assert mode == "aer_topk", mode
    leaves, treedef = jax.tree.flatten(grads)
    st_leaves = treedef.flatten_up_to(aer_states)
    outs, states, words = [], [], jnp.int32(0)
    for g, st in zip(leaves, st_leaves):
        r, ns, w = aer_allreduce(g, st, axis_name, frac=frac, budget=budget,
                                 interpret=interpret)
        outs.append(r)
        states.append(ns)
        words = words + w
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, states), words)


def init_aer_states(grads_or_params):
    return jax.tree.map(AerState.init, grads_or_params)


# ---------------------------------------------------------------------------
# Wire-volume accounting (benchmarks; the paper's "I/O saved" in bytes)
# ---------------------------------------------------------------------------

def dense_allreduce_bytes(n_params: int, n_devices: int, bytes_per=4,
                          bidirectional=False) -> float:
    return hd.wire_bytes_per_direction(n_params * bytes_per, n_devices,
                                       bidirectional)


def aer_allreduce_bytes(n_params: int, n_devices: int, frac: float,
                        budget: int = K.DEFAULT_BUDGET,
                        block: int = K.DEFAULT_BLOCK) -> float:
    """All-gather of event slots: each device ships nb*budget words once
    around the ring ((n-1)/n of it per link direction)."""
    nb = -(-n_params // block)
    shipped = min(budget, int(frac * block) + 1) * nb * 4
    return (n_devices - 1) / n_devices * shipped * n_devices / n_devices
