"""Address-Event word formats.

The paper transmits 26-bit parallel Address-Events (AEs) between chips.  Two
wire formats live here:

* the *protocol* format — a raw 26-bit address word, exactly as driven onto
  the shared AER bus by the transceiver block (used by the protocol
  simulator and the SNN chip-array example, where an event is "neuron X on
  core Y spiked");

* the *payload* format — the TPU-side adaptation, where an event is a sparse
  (address, value) pair produced by gradient/activation compression.  We pack
  a block-local 16-bit address together with a bfloat16 payload into one
  uint32 "wire word" so that event streams have a fixed, hardware-honest
  width (the analogue of the paper's fixed 26-bit bus).

Everything is pure jnp and jit/scan-friendly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

AER_ADDR_BITS = 26  # width of the paper's parallel AER bus
AER_ADDR_MASK = (1 << AER_ADDR_BITS) - 1

# Payload ("ML") event word: [31:16] block-local address, [15:0] bf16 bits.
EVENT_IDX_BITS = 16
EVENT_MAX_BLOCK = 1 << EVENT_IDX_BITS


# ---------------------------------------------------------------------------
# Protocol format: raw 26-bit addresses (fields: chip-local x/y/core/neuron).
# ---------------------------------------------------------------------------

def pack_aer_address(core: jnp.ndarray, neuron: jnp.ndarray,
                     neuron_bits: int = 16) -> jnp.ndarray:
    """Pack (core, neuron) into a 26-bit AER address word (uint32).

    The paper does not prescribe a field split; neuromorphic convention is a
    hierarchical (core, neuron) address.  ``neuron_bits`` low bits hold the
    neuron id, the remaining ``26 - neuron_bits`` hold the core id.
    """
    core = jnp.asarray(core, jnp.uint32)
    neuron = jnp.asarray(neuron, jnp.uint32)
    word = (core << neuron_bits) | (neuron & jnp.uint32((1 << neuron_bits) - 1))
    return word & jnp.uint32(AER_ADDR_MASK)


def unpack_aer_address(word: jnp.ndarray, neuron_bits: int = 16):
    word = jnp.asarray(word, jnp.uint32) & jnp.uint32(AER_ADDR_MASK)
    neuron = word & jnp.uint32((1 << neuron_bits) - 1)
    core = word >> neuron_bits
    return core, neuron


# ---------------------------------------------------------------------------
# Payload format: (idx:16 | bf16:16) -> uint32
# ---------------------------------------------------------------------------

def _f32_to_bf16_bits(val: jnp.ndarray) -> jnp.ndarray:
    """float32 -> uint16 holding the bf16 bit pattern (round-to-nearest-even
    via jnp cast, which is what the TPU datapath does)."""
    bf = val.astype(jnp.bfloat16)
    return jax.lax.bitcast_convert_type(bf, jnp.uint16)


def _bf16_bits_to_f32(bits: jnp.ndarray) -> jnp.ndarray:
    bf = jax.lax.bitcast_convert_type(bits.astype(jnp.uint16), jnp.bfloat16)
    return bf.astype(jnp.float32)


def pack_events(idx: jnp.ndarray, val: jnp.ndarray) -> jnp.ndarray:
    """Pack block-local indices (< 2**16) and float values into uint32 words.

    idx: int array, val: float array (same shape).  Returns uint32 words.
    Values are rounded to bf16 — the precision actually shipped on the wire.
    """
    idx16 = jnp.asarray(idx, jnp.uint32) & jnp.uint32(0xFFFF)
    vbits = _f32_to_bf16_bits(jnp.asarray(val, jnp.float32)).astype(jnp.uint32)
    return (idx16 << 16) | vbits


def unpack_events(words: jnp.ndarray):
    """uint32 words -> (idx int32, val float32 (bf16-precision))."""
    words = jnp.asarray(words, jnp.uint32)
    idx = (words >> 16).astype(jnp.int32)
    val = _bf16_bits_to_f32((words & jnp.uint32(0xFFFF)).astype(jnp.uint16))
    return idx, val


def event_bytes(n_events: int | jnp.ndarray, word_bytes: int = 4):
    """Wire bytes for an event stream (the 'pins -> bytes' accounting)."""
    return n_events * word_bytes


def roundtrip_error_bound() -> float:
    """Max relative error introduced by bf16 payload quantisation."""
    return 2.0 ** -8  # bf16 has 8 mantissa bits incl. implicit one
