"""JAX-native traffic generators for the multi-chip AER fabric.

Each generator returns a :class:`TrafficSpec` — flat ``(src, t, dest)``
int32 arrays describing the event *arrival process* every chip's cores
offer to the fabric.  Times are integer nanoseconds, nondecreasing per
source chip; destinations are chip ids (never the source itself).

The generators are built from ``jax.random`` primitives with static output
shapes, so a whole sweep of workloads can be sampled under ``jit``/``vmap``
before being handed to ``network.simulate_fabric`` (which consumes them at
setup time).

Patterns (the scenario axis of the benchmark sweep):

  poisson    independent exponential inter-arrival gaps per chip, uniform
             random destinations — the background-activity regime.
  bursty     Poisson burst *starts*, each burst a back-to-back train to a
             single destination — cortical-packet / population-code bursts.
  ping_pong  saturated pairwise exchange at t = 0 — the paper's Fig. 8
             worst case (every event reverses its bus), fabric-sized.
  hot_spot   poisson arrivals whose destinations concentrate on one chip —
             the congestion/convergecast regime.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TrafficSpec", "poisson", "bursty", "ping_pong", "hot_spot",
           "monte_carlo", "PATTERNS"]


class TrafficSpec(NamedTuple):
    """Flat event stream: event i enters the fabric at chip ``src[i]`` at
    time ``t[i]`` ns, addressed to chip ``dest[i]``."""
    src: jnp.ndarray   # (E,) int32
    t: jnp.ndarray     # (E,) int32, nondecreasing per src
    dest: jnp.ndarray  # (E,) int32

    @property
    def n_events(self) -> int:
        return int(self.src.shape[0])


def _flatten(times: jnp.ndarray, dests: jnp.ndarray) -> TrafficSpec:
    """(n_chips, E) per-chip arrays -> flat spec (chip-major order)."""
    n_chips, n_ev = times.shape
    src = jnp.repeat(jnp.arange(n_chips, dtype=jnp.int32), n_ev)
    return TrafficSpec(src=src,
                       t=times.reshape(-1).astype(jnp.int32),
                       dest=dests.reshape(-1).astype(jnp.int32))


def _uniform_other_chip(key, shape, n_chips: int, src_col: jnp.ndarray):
    """Uniform destination chip != source."""
    d = jax.random.randint(key, shape, 0, n_chips - 1, dtype=jnp.int32)
    return d + (d >= src_col).astype(jnp.int32)


def _src_col(n_chips: int, n_ev: int) -> jnp.ndarray:
    return jnp.broadcast_to(
        jnp.arange(n_chips, dtype=jnp.int32)[:, None], (n_chips, n_ev))


def poisson(key, n_chips: int, events_per_chip: int,
            mean_gap_ns: float = 200.0) -> TrafficSpec:
    """Independent Poisson processes: exponential gaps, uniform dests."""
    kt, kd = jax.random.split(key)
    gaps = jax.random.exponential(kt, (n_chips, events_per_chip)) * mean_gap_ns
    times = jnp.cumsum(gaps.astype(jnp.int32), axis=1)
    dests = _uniform_other_chip(kd, (n_chips, events_per_chip), n_chips,
                                _src_col(n_chips, events_per_chip))
    return _flatten(times, dests)


def bursty(key, n_chips: int, bursts_per_chip: int, burst_len: int = 8,
           mean_gap_ns: float = 2000.0) -> TrafficSpec:
    """Poisson burst starts; each burst is ``burst_len`` back-to-back
    events (same timestamp — the FIFO serialises them) to one dest."""
    kt, kd = jax.random.split(key)
    gaps = jax.random.exponential(
        kt, (n_chips, bursts_per_chip)) * mean_gap_ns
    starts = jnp.cumsum(gaps.astype(jnp.int32), axis=1)
    burst_dest = _uniform_other_chip(kd, (n_chips, bursts_per_chip), n_chips,
                                     _src_col(n_chips, bursts_per_chip))
    times = jnp.repeat(starts, burst_len, axis=1)
    dests = jnp.repeat(burst_dest, burst_len, axis=1)
    return _flatten(times, dests)


def ping_pong(n_chips: int, events_per_chip: int) -> TrafficSpec:
    """Saturated pairwise exchange: chips (2i, 2i+1) flood each other from
    t = 0.  With one link per pair this is exactly the paper's Fig. 8
    alternating-direction measurement on every pair at once.  An odd
    trailing chip stays silent."""
    n_active = (n_chips // 2) * 2
    src = jnp.arange(n_chips, dtype=jnp.int32)
    partner = jnp.where(src % 2 == 0, src + 1, src - 1)
    partner = jnp.where(src < n_active, partner, src)  # silent odd chip
    times = jnp.zeros((n_chips, events_per_chip), jnp.int32)
    dests = jnp.broadcast_to(partner[:, None], (n_chips, events_per_chip))
    spec = _flatten(times, dests)
    keep = spec.src < n_active
    # static shapes: an odd chip would self-address; drop its rows.
    if n_active < n_chips:
        idx = jnp.nonzero(keep, size=n_active * events_per_chip)[0]
        spec = TrafficSpec(src=spec.src[idx], t=spec.t[idx],
                           dest=spec.dest[idx])
    return spec


def hot_spot(key, n_chips: int, events_per_chip: int,
             mean_gap_ns: float = 200.0, hot_chip: int = 0,
             hot_frac: float = 0.75) -> TrafficSpec:
    """Poisson arrivals converging on one chip with probability
    ``hot_frac`` (uniform otherwise) — the congestion regime."""
    kt, kd, kh = jax.random.split(key, 3)
    gaps = jax.random.exponential(kt, (n_chips, events_per_chip)) * mean_gap_ns
    times = jnp.cumsum(gaps.astype(jnp.int32), axis=1)
    col = _src_col(n_chips, events_per_chip)
    uni = _uniform_other_chip(kd, (n_chips, events_per_chip), n_chips, col)
    hot = jax.random.uniform(kh, (n_chips, events_per_chip)) < hot_frac
    dests = jnp.where(hot & (col != hot_chip), jnp.int32(hot_chip), uni)
    return _flatten(times, dests)


def _poisson_default(key, n_chips, events_per_chip):
    return poisson(key, n_chips, events_per_chip)


def _bursty_default(key, n_chips, events_per_chip):
    burst_len = 8
    bursts = max(1, events_per_chip // burst_len)
    return bursty(key, n_chips, bursts, burst_len=burst_len)


def _ping_pong_default(key, n_chips, events_per_chip):
    del key
    return ping_pong(n_chips, events_per_chip)


def _hot_spot_default(key, n_chips, events_per_chip):
    return hot_spot(key, n_chips, events_per_chip)


def monte_carlo(pattern: str, key, batch: int, n_chips: int,
                events_per_chip: int) -> list[TrafficSpec]:
    """B independently-seeded instances of one traffic scenario.

    Splits ``key`` into ``batch`` subkeys and samples every instance in
    a single ``vmap`` of the pattern's default generator — ONE traced
    sampling computation regardless of B, matching the execution side
    (``Fabric.run_batch``) where the B instances then simulate as one
    compiled computation.  All instances share the static shape
    ``(n_chips, events_per_chip)``, so they land in one engine shape
    bucket by construction.  Returns the B specs in seed order (each an
    ordinary :class:`TrafficSpec` — instance ``i`` is bit-identical to
    ``PATTERNS[pattern](subkey_i, ...)`` sampled solo).
    """
    if pattern not in PATTERNS:
        raise ValueError(f"unknown pattern {pattern!r}; expected one of "
                         f"{sorted(PATTERNS)}")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    gen = PATTERNS[pattern]
    keys = jax.random.split(key, batch)
    stacked = jax.vmap(lambda k: gen(k, n_chips, events_per_chip))(keys)
    return [TrafficSpec(src=stacked.src[i], t=stacked.t[i],
                        dest=stacked.dest[i]) for i in range(batch)]


#: name -> generator(key, n_chips, events_per_chip) for sweeps/tests.
PATTERNS = {
    "poisson": _poisson_default,
    "bursty": _bursty_default,
    "ping_pong": _ping_pong_default,
    "hot_spot": _hot_spot_default,
}
