"""Declarative fabric front-end: composable policies + compile/run lifecycle.

``network.simulate_fabric`` grew one kwarg per feature; this module is the
redesigned front door.  A :class:`Fabric` is a *declaration* — topology
plus four orthogonal policies:

* ``routing`` — a :class:`RoutingPolicy`: ``StaticShortestPath`` (BFS
  tables + a ``table_override`` hook), a prebuilt ``RoutingTable``, or
  :class:`repro.core.adaptive.AdaptiveRouting` — the congestion control
  plane, which splits each ``run`` into epochs and re-weights the tables
  from per-link telemetry between them (``Fabric.run_epochs`` runs the
  same partition under static tables as the A/B baseline).
* ``timing``  — one scalar ``LinkTiming`` shared by every link, or a
  structure-of-arrays ``LinkTiming`` of shape (L,) mixing link classes
  (fast parallel on-board buses next to slow bit-serial LVDS inter-board
  links — see ``link.per_link_timing`` / ``link.SERIAL_LVDS_TIMING``).
* ``queues``  — :class:`QueuePolicy`: per-endpoint capacity, bounded-burst
  fairness, reset polarity.
* ``engine``  — :class:`EngineSpec`: which bit-exact event-transport
  engine runs the micro-transaction loop and its chunking.

Execution is an *explicit lifecycle*:

    fab = Fabric(ring_topology(8), timing=mixed, queues=QueuePolicy(max_burst=1))
    cf = fab.compile(spec)          # bind + pre-warm one shape bucket
    res = cf.run(spec)              # no compilation on this path
    results = fab.run_many(specs)   # one compile amortised over a sweep

``Fabric.compile`` makes the PR 2 shape-bucketed jit cache user-visible:
it returns a :class:`CompiledFabric` pinned to one bucket (the pow2-padded
static shape signature), whose ``warmup()`` populates the XLA cache with a
zero-event dummy run and whose ``cache_size()`` exposes the underlying jit
entry count — so tests and serving loops can *prove* a hot path never
recompiles.  ``Fabric.run`` routes each spec to the right bucket
automatically and caches ``CompiledFabric`` instances per bucket.

``simulate_fabric`` survives unchanged as a thin wrapper that builds a
one-shot ``Fabric`` and calls ``run`` — every historical call site keeps
working and stays bit-exact.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from .link import PAPER_TIMING, LinkTiming, link_timing_arrays
from .network import (DEFAULT_CHUNK_SIZE, ENGINES, FabricBatchResult,
                      FabricResult, _BIG,
                      _RING_D_FLOOR, _RING_E_FLOOR, _RING_K_FLOOR,
                      _RING_L_FLOOR, _RING_N_FLOOR, _RING_R_FLOOR,
                      _RING_STREAM_FLOOR, _check_reachable, _expand,
                      _first_hop_queues, _in_edge_ranks, _overflow_guard,
                      _overflow_guard_routed, _pad_to, _pow2ceil,
                      _prefill, _ring_engine, _route_link_tx,
                      _ring_engine_batch, _routes_with_trees, _slot_engine,
                      _slot_engine_batch, _slot_engine_multistep,
                      _slot_engine_multistep_batch, _stream_quota,
                      _tree_stream_quota, _unicast_routes)
from .router import (AddressSpec, MulticastTable, MulticastTree,
                     RoutingTable, Topology, find_route_cycles)
from .telemetry import Telemetry
from .traffic import TrafficSpec

__all__ = ["Fabric", "CompiledFabric", "QueuePolicy", "FLOW_MODES",
           "EngineSpec",
           "MulticastPolicy", "RoutingPolicy", "StaticShortestPath",
           "PrebuiltRouting", "SweepCell", "BatchSweepCell", "run_batch",
           "batch_cache_size"]


# -----------------------------------------------------------------------
# Policies
# -----------------------------------------------------------------------

#: flow-control modes, in engine encoding order (index = the dynamic
#: ``fc_mode`` scalar the engines receive)
FLOW_MODES = ("drop", "credit", "onoff")


@dataclass(frozen=True)
class QueuePolicy:
    """Per-endpoint queue behaviour of every link in the fabric.

    ``capacity``   — one-shot slot budget per endpoint (bounds the events
                     routed *through* an endpoint, not instantaneous
                     depth); ``None`` = lossless (the expanded event
                     count).  What happens when a forward would overflow
                     it is ``flow``'s call.
    ``max_burst``  — 0 = paper-faithful grant rule; B > 0 = bounded-burst
                     fairness (transmitter yields after B events when the
                     peer requests).
    ``initial_tx`` — scalar or (L,): which side of each link resets into
                     TX mode (the paper's chip-level global reset).
    ``flow``       — ``"drop"`` (default): overflowing forwards are
                     dropped and counted in ``FabricResult.drops``.
                     ``"credit"``: per-link credit counters — a sender
                     whose head would forward into a full downstream
                     queue *stalls in place* (no drop; credits return as
                     the downstream queue pops).  ``"onoff"``: threshold
                     xon/xoff — a queue crossing ``capacity`` asserts
                     xoff and releases it at ``xon``.  Both lossless
                     modes require ``capacity``; see the ``network``
                     module docstring for the exact gate semantics and
                     the cyclic-route deadlock caveat.
    ``xon``        — on/off mode's resume threshold (occupancy at or
                     below it deasserts xoff).  Default ``capacity // 2``;
                     ``xon = capacity - 1`` makes on/off coincide with
                     credit mode exactly.
    """
    capacity: int | None = None
    max_burst: int = 0
    initial_tx: int | np.ndarray = 1
    flow: str = "drop"
    xon: int | None = None

    def __post_init__(self):
        if self.capacity is not None and int(self.capacity) < 1:
            raise ValueError(f"queue capacity must be >= 1, got "
                             f"{self.capacity}")
        if int(self.max_burst) < 0:
            raise ValueError(f"max_burst must be >= 0, got {self.max_burst}")
        if self.flow not in FLOW_MODES:
            raise ValueError(f"unknown flow mode {self.flow!r}; expected "
                             f"one of {FLOW_MODES}")
        if self.flow != "drop" and self.capacity is None:
            raise ValueError(f"flow={self.flow!r} needs a finite queue "
                             f"capacity (capacity=None is already "
                             f"lossless)")
        if self.xon is not None:
            if self.flow != "onoff":
                raise ValueError("xon only applies to flow='onoff'")
            if not 0 <= int(self.xon) < int(self.capacity):
                raise ValueError(f"xon must satisfy 0 <= xon < capacity, "
                                 f"got xon={self.xon} with "
                                 f"capacity={self.capacity}")


@dataclass(frozen=True)
class EngineSpec:
    """Which bit-exact event-transport engine runs the simulation.

    ``name``       — ``"auto"`` (= ring), ``"ring"``, ``"reference"`` or
                     ``"pallas"`` (see ``network`` module docstring).
    ``chunk_size`` — ring engine: micro-transactions per ``lax.scan``
                     chunk between early-exit checks.  Pallas multi-step
                     kernel: micro-transactions fused per kernel launch.
    ``kernel``     — pallas engine only.  ``"step"`` (default) dispatches
                     the per-step scan/update kernel pair once per
                     micro-transaction; ``"multistep"`` runs the fused
                     multi-step kernel — ``chunk_size`` steps per launch
                     with the packed carry resident across steps, so a
                     run costs ``ceil(max_steps / chunk_size)`` dispatches
                     instead of ``2 * max_steps``.  Bit-exact with every
                     other engine; each kernel choice compiles its own
                     shape bucket (audited by ``cache_size()``).
    """
    name: str = "auto"
    chunk_size: int = DEFAULT_CHUNK_SIZE
    kernel: str = "step"

    KERNELS = ("step", "multistep")

    def __post_init__(self):
        resolved = "ring" if self.name == "auto" else self.name
        if resolved not in ENGINES:
            raise ValueError(f"unknown engine {self.name!r}; expected one "
                             f"of {ENGINES} (or 'auto')")
        if int(self.chunk_size) < 1:
            # a 0-step chunk would make the early-exit while_loop spin
            # forever
            raise ValueError(f"chunk_size must be >= 1, got "
                             f"{self.chunk_size}")
        if self.kernel not in self.KERNELS:
            raise ValueError(f"unknown kernel {self.kernel!r}; expected "
                             f"one of {self.KERNELS}")
        if self.kernel == "multistep" and resolved != "pallas":
            raise ValueError(
                f"kernel='multistep' is a pallas-engine knob (the fused "
                f"multi-step fabric kernel); engine {self.name!r} "
                f"resolves to {resolved!r}")

    @property
    def resolved(self) -> str:
        return "ring" if self.name == "auto" else self.name


@dataclass(frozen=True)
class MulticastPolicy:
    """How tagged (multicast) events traverse the fabric.

    ``mode``
        ``"source_expand"`` (default, the PR 1 semantics): a tag with
        fanout F becomes F independent unicast copies at the source —
        bit-exact with the historical behaviour, but F traversals of
        every shared link.

        ``"in_fabric"``: the event carries its tag through the fabric
        and is replicated only where the per-``(source, tag)``
        Steiner-branching tree diverges (``router.MulticastTree``) —
        one traversal per tree edge, the DYNAPs-style replication the
        paper's reserved multicast flag anticipates.

    ``table``
        The ``MulticastTable`` resolving tags to member-chip sets
        (required only when the traffic actually carries tagged events).

    Both modes deliver the identical destination multiset; ``in_fabric``
    strictly reduces link traversals whenever member paths share links.
    """
    mode: str = "source_expand"
    table: MulticastTable | None = None

    MODES = ("source_expand", "in_fabric")

    def __post_init__(self):
        if self.mode not in self.MODES:
            raise ValueError(f"unknown multicast mode {self.mode!r}; "
                             f"expected one of {self.MODES}")
        if self.table is not None and not isinstance(self.table,
                                                     MulticastTable):
            raise TypeError(f"table must be a MulticastTable, got "
                            f"{type(self.table).__name__}")


@runtime_checkable
class RoutingPolicy(Protocol):
    """Anything that turns a topology into next-hop tables."""

    def build(self, topo: Topology) -> RoutingTable: ...


def _validate_tables(topo: Topology, rt: RoutingTable) -> RoutingTable:
    n = topo.n_chips
    for name in ("next_link", "out_side", "hops"):
        a = np.asarray(getattr(rt, name))
        if a.shape != (n, n):
            raise ValueError(f"routing table {name} has shape {a.shape}, "
                             f"expected ({n}, {n})")
    nl = np.asarray(rt.next_link)
    if nl.max(initial=-1) >= topo.n_links:
        raise ValueError("routing table names a link id outside the "
                         "topology")
    return rt


@dataclass(frozen=True)
class StaticShortestPath:
    """Deterministic BFS shortest-path routing (the PR 1 tables).

    ``table_override`` — optional hook called with ``(topo, built_table)``
    returning a replacement ``RoutingTable``.  This is the landing pad
    for adaptive/congestion-aware routing policies: an override can bias
    next-hops off the shortest path (it is trusted to keep the tables
    consistent — every hop must make progress, or events cycle until the
    step bound binds).
    """
    table_override: Callable[[Topology, RoutingTable],
                             RoutingTable] | None = None

    def build(self, topo: Topology) -> RoutingTable:
        rt = RoutingTable.build(topo)
        if self.table_override is not None:
            rt = _validate_tables(topo, self.table_override(topo, rt))
        return rt


@dataclass(frozen=True)
class PrebuiltRouting:
    """Adapter: a ready-made ``RoutingTable`` as a ``RoutingPolicy``."""
    table: RoutingTable

    def build(self, topo: Topology) -> RoutingTable:
        return _validate_tables(topo, self.table)


# -----------------------------------------------------------------------
# Run planning (setup-time numpy; shared by compile and run)
# -----------------------------------------------------------------------

class _Plan(NamedTuple):
    """Everything one execution needs: routed traffic, prefilled
    queues, replication tables, dynamic scalars and the static shape
    bucket they fit.  ``E`` is the EXPECTED delivery count (fanout
    applied); ``offered`` the pre-fanout event count the ``fanout``
    metric reports against.  ``C`` is the *physical* slot width the
    engines allocate; ``cap``/``fc``/``xon`` the dynamic flow-control
    scalars (logical capacity, mode index into ``FLOW_MODES``, resume
    threshold) they receive as operands."""
    E: int
    C: int
    max_steps: int
    q_time: np.ndarray
    q_dest: np.ndarray      # route ids (dest chip | n_chips + tree)
    q_inj: np.ndarray
    sizes: np.ndarray
    route_out: np.ndarray   # (N, R, K) replication out-queues, -1 = none
    route_del: np.ndarray   # (N, R) local-deliver bits
    route_wt: np.ndarray    # (N, R, K) subtree delivery weights (drops)
    offered: int
    bucket: tuple
    cap: int = 1            # logical per-endpoint budget (dynamic scalar)
    fc: int = 0             # FLOW_MODES index (dynamic scalar)
    xon: int = 0            # on/off resume threshold (dynamic scalar)


class SweepCell(NamedTuple):
    result: FabricResult
    us_per_call: float
    bucket: tuple


class BatchSweepCell(NamedTuple):
    """Timing of one batched dispatch: ``us_per_call`` is the whole
    batch's wall-clock, ``us_per_instance`` the amortised per-fabric
    cost (the number the Monte-Carlo amortisation gate compares against
    sequential ``run``)."""
    result: FabricBatchResult
    us_per_call: float
    us_per_instance: float
    bucket: tuple


class Fabric:
    """A declarative N-chip AER fabric: topology + composable policies.

    See the module docstring for the lifecycle.  Construction resolves
    and validates every policy eagerly (routing tables are built once,
    timing is normalised to per-link cost vectors), so a ``Fabric`` held
    by a serving loop never re-runs setup-time numpy per call beyond the
    per-spec routing/prefill pass.
    """

    def __init__(self, topo: Topology, *,
                 routing: RoutingPolicy | RoutingTable | None = None,
                 timing: LinkTiming = PAPER_TIMING,
                 queues: QueuePolicy | None = None,
                 engine: EngineSpec | str | None = None,
                 addr: AddressSpec | None = None,
                 mcast: MulticastTable | MulticastPolicy | None = None):
        self.topo = topo
        if routing is None:
            policy: RoutingPolicy = StaticShortestPath()
        elif isinstance(routing, RoutingTable):
            policy = PrebuiltRouting(routing)
        elif isinstance(routing, RoutingPolicy):
            policy = routing
        else:
            raise TypeError(f"routing must be a RoutingPolicy or a "
                            f"RoutingTable, got {type(routing).__name__}")
        self.routing_policy = policy
        self.queues = queues if queues is not None else QueuePolicy()
        if engine is None:
            engine = EngineSpec()
        elif isinstance(engine, str):
            engine = EngineSpec(name=engine)
        self.engine = engine
        self.timing = timing
        self.addr = addr
        if mcast is None:
            self.mcast_policy = MulticastPolicy()
        elif isinstance(mcast, MulticastPolicy):
            self.mcast_policy = mcast
        elif isinstance(mcast, MulticastTable):
            self.mcast_policy = MulticastPolicy(table=mcast)
        else:
            raise TypeError(f"mcast must be a MulticastTable or a "
                            f"MulticastPolicy, got {type(mcast).__name__}")
        # legacy attribute: the bare table (what _expand consumes)
        self.mcast = self.mcast_policy.table

        L = topo.n_links
        # normalised per-link cost vectors: the engines' dynamic operands
        self.timing_arrays = link_timing_arrays(timing, L)
        tc, tv, ti = self.timing_arrays
        # per-link worst single-transmission cost (the tight routed
        # clock-budget guard) and its fabric-wide max (the documented
        # fallback bound when a broken table defeats the route walk)
        self._link_cost = tc.astype(np.int64) + np.maximum(tv, ti)
        self._worst_cost = int(self._link_cost.max(initial=1))
        self.routing_table = policy.build(topo)
        # Lossless flow control relies on every route making progress.
        # A next-hop cycle (possible only through table_override hooks
        # or prebuilt tables — BFS/Dijkstra tables are acyclic by
        # construction) breaks that for the pairs caught on it; PR 7
        # refused ANY such table outright.  The precise Dally–Seitz
        # criterion (repro.analysis.verify) is finer: what deadlocks a
        # stall chain is a cycle in the CHANNEL-DEPENDENCY graph of the
        # routes events actually ride.  So: when broken pairs exist but
        # the terminating routes' CDG is acyclic, the fabric is
        # admitted and the broken pairs are QUARANTINED — planning
        # refuses traffic that addresses them (see _plan_impl) while
        # everything else provably drains.  Only when the remaining
        # CDG itself carries a cycle is construction refused, with the
        # offending channel cycle named.  Drop mode keeps the
        # historical behaviour (events on a cyclic route are dropped
        # or truncated; pops are never gated, so no deadlock).  Note
        # a clean table (no broken pairs) may still have a cyclic CDG
        # (every ring >= 5 does) — that hazard is graded per-spec by
        # Fabric.verify(), which weighs channel demand against
        # capacity; it is not a construction error.
        self._nonterm_mask: np.ndarray | None = None
        if self.queues.flow != "drop":
            bad = find_route_cycles(topo, self.routing_table)
            if len(bad):
                from ..analysis.verify import channel_graph
                g = channel_graph(topo, self.routing_table,
                                  exclude_pairs=bad)
                cycle = g.find_cycle()
                shown = ", ".join(f"{c}->{d}" for c, d in bad[:4].tolist())
                if cycle is not None:
                    raise ValueError(
                        f"routing table has {len(bad)} (chip, dest) "
                        f"pair(s) whose route never reaches the "
                        f"destination (next-hop cycle or dead-end), "
                        f"e.g. {shown}, and the terminating routes' "
                        f"channel-dependency graph also carries a "
                        f"cycle ({g.describe_cycle(cycle)}); "
                        f"flow={self.queues.flow!r} would deadlock — "
                        f"fix the table or use flow='drop'")
                mask = np.zeros((topo.n_chips, topo.n_chips), bool)
                mask[bad[:, 0], bad[:, 1]] = True
                self._nonterm_mask = mask
        self._in_rank, self._D = _in_edge_ranks(topo)
        self._init_tx = np.broadcast_to(
            np.asarray(self.queues.initial_tx, np.int32), (L,))
        self._compiled: dict[tuple, "CompiledFabric"] = {}
        self._plan_memo: tuple | None = None  # (spec, max_steps, plan)
        #: per-epoch breakdown of the last epoched run (AdaptiveReport)
        self.last_report = None
        #: execution path the last ``run_many`` chose: "batch" | "loop"
        self.last_dispatch = None
        # in-fabric multicast setup caches: trees are a pure function of
        # (routing table, multicast table, src, tag) — all fixed per
        # Fabric — and the unicast replication tables of the routing
        # table alone
        self._tree_cache: dict[tuple[int, int], MulticastTree] = {}
        self._unicast_tables_np: tuple | None = None

    # --- declaration niceties ------------------------------------------

    @property
    def n_chips(self) -> int:
        return self.topo.n_chips

    @property
    def n_links(self) -> int:
        return self.topo.n_links

    @property
    def compiled_buckets(self) -> tuple[tuple, ...]:
        """Shape buckets this fabric has bound so far (compile order)."""
        return tuple(self._compiled)

    def __repr__(self) -> str:
        return (f"Fabric({self.topo.name}: {self.n_chips} chips, "
                f"{self.n_links} links, engine={self.engine.resolved!r}, "
                f"{len(self._compiled)} compiled bucket(s))")

    # --- lifecycle ------------------------------------------------------

    def verify(self, spec: TrafficSpec | None = None, *,
               max_steps: int | None = None):
        """Static pre-flight verification — prove properties, run nothing.

        Builds the channel-dependency graph of this fabric's routes
        (unicast + in-fabric multicast branchings), runs Dally–Seitz
        cycle detection, checks route termination / reachability /
        replication-table completeness, and bounds the worst-case int32
        clock against the ``BIG_NS`` sentinel (tight per-link budget).
        With ``spec`` the deadlock grading is demand-aware: a CDG cycle
        is an error only if every channel on some cycle can actually
        fill to capacity under the spec's routed traffic.

        Returns a :class:`repro.analysis.verify.VerifyReport`;
        ``report.raise_if_failed()`` turns error findings into the same
        ``ValueError`` refusal contract construction/planning uses.
        """
        from ..analysis.verify import verify_fabric
        return verify_fabric(self, spec, max_steps=max_steps)

    def compile(self, spec: TrafficSpec, *, max_steps: int | None = None,
                warm: bool = True) -> "CompiledFabric":
        """Bind the shape bucket that ``spec`` needs and return it.

        With ``warm=True`` (default) the bucket's XLA compilation is
        triggered immediately by a zero-event dummy run, so a subsequent
        ``run`` of any spec in the bucket pays zero compile time — the
        pre-warm hook a latency-sensitive serving loop wants.
        """
        plan = self._plan(spec, max_steps)
        cf = self._get_compiled(plan.bucket)
        if warm:
            cf.warmup()
        return cf

    def run(self, spec: TrafficSpec, *,
            max_steps: int | None = None) -> FabricResult:
        """Simulate one traffic spec (compiling its bucket on first use).

        Under an :class:`~repro.core.adaptive.AdaptiveRouting` policy the
        run is automatically split into the policy's epochs, telemetry
        re-weights the tables between them, and the merged result comes
        back (per-epoch breakdown on ``self.last_report``)."""
        from .adaptive import AdaptiveRouting, run_epoched
        if isinstance(self.routing_policy, AdaptiveRouting):
            return run_epoched(self, spec,
                               epochs=self.routing_policy.epochs,
                               max_steps=max_steps,
                               policy=self.routing_policy)
        return self._run_single(spec, max_steps=max_steps)

    def run_epochs(self, spec: TrafficSpec, *, epochs: int,
                   max_steps: int | None = None) -> FabricResult:
        """Epoch-partitioned run under this fabric's own routing policy.

        With a static policy every epoch reuses the same tables — the
        fair A/B baseline for adaptive runs (identical partitioning,
        per-epoch drain and merge; only the tables differ).  With an
        adaptive policy, ``epochs`` overrides the policy's own epoch
        count.  Per-epoch breakdown lands on ``self.last_report``."""
        from .adaptive import AdaptiveRouting, run_epoched
        pol = (self.routing_policy
               if isinstance(self.routing_policy, AdaptiveRouting)
               else None)
        return run_epoched(self, spec, epochs=epochs,
                           max_steps=max_steps, policy=pol)

    def _run_single(self, spec: TrafficSpec, *,
                    max_steps: int | None = None) -> FabricResult:
        """One un-epoched simulation (the epoch loop's inner call)."""
        plan = self._plan(spec, max_steps)
        return self._get_compiled(plan.bucket)._execute(plan)

    def _with_routing(self, table: RoutingTable) -> "Fabric":
        """Clone with prebuilt routing tables — the adaptive control
        plane's per-epoch rebuild path.  Unicast tables come straight
        from ``table``; in-fabric multicast Steiner branchings regrow on
        it too (the clone's tree cache starts empty).  Compilations are
        shared process-wide by engine shape bucket, so a clone never
        recompiles an engine the original already traced."""
        return Fabric(self.topo, routing=PrebuiltRouting(table),
                      timing=self.timing, queues=self.queues,
                      engine=self.engine, addr=self.addr,
                      mcast=self.mcast_policy)

    def run_many(self, specs, *,
                 max_steps: int | None = None) -> list[FabricResult]:
        """Run a sequence of specs, amortising work across them.

        Dispatch (recorded on ``self.last_dispatch``): when every spec
        lands in ONE shape bucket and the routing policy is static, the
        whole sequence executes as a single batched computation via
        :meth:`run_batch` — one compilation AND one dispatch for the
        entire sweep (``"batch"``).  Otherwise — mixed buckets, an
        adaptive policy (a sequential feedback loop), or a single spec —
        it falls back to the per-spec loop (``"loop"``), which still
        amortises compiles across specs that bucket alike.

        Batch-path caveat: with ``max_steps=None`` the batch shares the
        max of the per-spec default step bounds.  That is bit-exact with
        solo runs whenever each run drains (the bound does not bind) —
        the universal case, since lossless-mode traffic on broken
        routes is refused at plan time (cyclic-CDG tables already at
        construction) and drop-mode routes always terminate.  Pass an
        explicit ``max_steps`` to pin the bound.
        """
        from .adaptive import AdaptiveRouting
        specs = list(specs)
        if (len(specs) > 1
                and not isinstance(self.routing_policy, AdaptiveRouting)):
            plans = [self._plan(s, max_steps) for s in specs]
            if len(dict.fromkeys(p.bucket for p in plans)) == 1:
                self.last_dispatch = "batch"
                return self.run_batch(specs,
                                      max_steps=max_steps).results()
        self.last_dispatch = "loop"
        return [self.run(s, max_steps=max_steps) for s in specs]

    def run_batch(self, specs, *, max_steps: int | None = None,
                  devices: int | str | None = None) -> FabricBatchResult:
        """Run B traffic specs as ONE batched computation on this fabric.

        Every spec must land in the same shape bucket (same topology by
        construction — one ``Fabric`` — and pow2-compatible event
        counts); the batch compiles once per (bucket, B, devices) and
        executes as a single device dispatch, with every per-instance
        quantity (traffic, replication tables, capacity, flow mode, step
        bound) travelling as a ``(B,)``-leading operand.  Results are
        bit-exact with ``[self.run(s) for s in specs]`` per instance on
        every engine.  To batch across *fabrics* (per-instance routing
        tables / timing contracts on one topology), use the module-level
        :func:`run_batch`.

        ``devices`` shards the batch axis across local devices via
        ``shard_map``: an int (count), ``"all"``, or ``None`` (no
        sharding).  B must divide evenly.

        With ``max_steps=None`` all instances share the max of their
        default step bounds (the slot engines bake the bound into their
        scan); a non-binding bound is invisible in the results, keeping
        solo bit-exactness.  Adaptive routing policies are refused —
        their epoch loop is sequential feedback (see ``run_epochs``).
        """
        return run_batch(self, specs, max_steps=max_steps,
                         devices=devices)

    def sweep_batch(self, specs, *, max_steps: int | None = None,
                    warm: bool = True,
                    devices: int | str | None = None) -> BatchSweepCell:
        """:meth:`run_batch` with wall-clock: optionally pre-warms the
        batched engine with a zero-event dummy batch of the same size
        (so compile time stays out of the measurement), then times the
        single batched dispatch.  ``us_per_instance`` is the amortised
        per-fabric cost — the number to compare against a sequential
        ``sweep``'s ``us_per_call``."""
        specs = list(specs)
        fabs = [self] * len(specs)
        plans = _plan_batch(fabs, specs, max_steps)
        n_dev = _resolve_devices(devices, len(plans))
        if warm:
            zero = _zero_event_plan(self, plans[0].bucket)
            dummy = _execute_batch(fabs, [zero] * len(plans), n_dev)
            jax.block_until_ready(dummy.drops)
        t0 = time.perf_counter()
        res = _execute_batch(fabs, plans, n_dev)
        jax.block_until_ready(res.log_del)
        us = (time.perf_counter() - t0) * 1e6
        return BatchSweepCell(result=res, us_per_call=us,
                              us_per_instance=us / max(len(plans), 1),
                              bucket=plans[0].bucket)

    def sweep(self, specs, *, max_steps: int | None = None,
              warm: bool = True) -> list[SweepCell]:
        """``run_many`` with per-cell wall-clock: pre-warms every distinct
        bucket first (unless ``warm=False``), then times each run — the
        benchmark-sweep pattern where compile time must not pollute
        per-cell numbers."""
        from .adaptive import (AdaptiveRouting, partition_epochs,
                               shared_max_steps)
        if isinstance(self.routing_policy, AdaptiveRouting):
            # the epoch loop owns execution: time whole epoched runs
            # (merge already synchronises, so the clock is honest).
            # warm=True honours the no-compile-in-cell contract here
            # too: each spec's FIRST epoch slice is compiled untimed
            # under the SAME shared step bound the epoched run will use
            # (the slot engines key their bucket on max_steps), so the
            # warmed bucket is exactly the one every epoch hits.
            bounds = {}
            if warm:
                for i, s in enumerate(specs):
                    parts = partition_epochs(
                        s, self.routing_policy.epochs)
                    if parts:
                        bounds[i] = (max_steps if max_steps is not None
                                     else shared_max_steps(
                                         self, parts,
                                         detour_factor=1.0 + float(
                                             self.routing_policy.alpha)))
                        self.compile(parts[0], max_steps=bounds[i])
            cells = []
            for i, s in enumerate(specs):
                t0 = time.perf_counter()
                # reuse the warm pass's step bound so the epoch loop
                # does not recompute it (and provably runs the warmed
                # bucket)
                res = self.run(s, max_steps=bounds.get(i, max_steps))
                us = (time.perf_counter() - t0) * 1e6
                cells.append(SweepCell(
                    result=res, us_per_call=us,
                    bucket=self.last_report.buckets[0]))
            return cells
        plans = [self._plan(s, max_steps) for s in specs]
        if warm:
            for b in dict.fromkeys(p.bucket for p in plans):
                self._get_compiled(b).warmup()
        cells = []
        for p in plans:
            t0 = time.perf_counter()
            res = self._get_compiled(p.bucket)._execute(p)
            jax.block_until_ready(res.log_del)
            us = (time.perf_counter() - t0) * 1e6
            cells.append(SweepCell(result=res, us_per_call=us,
                                   bucket=p.bucket))
        return cells

    # --- internals ------------------------------------------------------

    def _get_compiled(self, bucket: tuple) -> "CompiledFabric":
        cf = self._compiled.get(bucket)
        if cf is None:
            cf = CompiledFabric(self, bucket)
            self._compiled[bucket] = cf
        return cf

    def _plan(self, spec: TrafficSpec, max_steps: int | None) -> _Plan:
        # memoize the last plan by spec identity: the documented
        # compile(spec) -> run(spec) lifecycle (and repeated runs of one
        # spec) pays the setup-time numpy (expansion, route walking,
        # prefill) once, not per call
        memo = self._plan_memo
        if memo is not None and memo[0] is spec and memo[1] == max_steps:
            return memo[2]
        plan = self._plan_impl(spec, max_steps)
        self._plan_memo = (spec, max_steps, plan)
        return plan

    def _unicast_tables(self):
        if self._unicast_tables_np is None:
            self._unicast_tables_np = _unicast_routes(self.topo,
                                                      self.routing_table)
        return self._unicast_tables_np

    def _tree(self, src: int, tag: int) -> MulticastTree:
        tree = self._tree_cache.get((src, tag))
        if tree is None:
            tree = MulticastTree.build(self.topo, self.routing_table, src,
                                       self.mcast_policy.table.expand(tag))
            self._tree_cache[(src, tag)] = tree
        return tree

    def _route_in_fabric(self, spec: TrafficSpec):
        """Setup for ``MulticastPolicy("in_fabric")``: split unicast from
        tagged events, build (and cache) one replication tree per unique
        ``(source, tag)`` pair, and emit the per-copy prefill stream —
        one copy per source out-edge of the tree — in original event
        order.  Returns everything ``_plan_impl`` needs."""
        topo, rt = self.topo, self.routing_table
        N = topo.n_chips
        src = np.asarray(spec.src, np.int32)
        t = np.asarray(spec.t, np.int32)
        dest = np.asarray(spec.dest, np.int32)
        if self.addr is not None:
            is_mc = np.asarray(self.addr.is_multicast(dest))
            chip_or_tag, _ = self.addr.unpack(dest)
        else:  # plain chip-id destinations: nothing to replicate
            is_mc = np.zeros(len(dest), bool)
            chip_or_tag = dest
        u_src, u_dest = src[~is_mc], chip_or_tag[~is_mc]
        if np.any(u_src == u_dest):
            raise ValueError("self-addressed events (src == dest)")
        _check_reachable(rt, u_src, u_dest)
        m_src, m_tag = src[is_mc], chip_or_tag[is_mc]
        if len(m_src) and self.mcast_policy.table is None:
            raise ValueError("multicast events but no MulticastTable")

        route_ev = chip_or_tag.astype(np.int64)   # unicast route = dest
        n_copies = np.ones(len(src), np.int64)    # prefill copies/event
        fanout_ev = np.ones(len(src), np.int64)   # deliveries/event
        if len(m_src):
            pairs, inv = np.unique(np.stack([m_src, m_tag], 1), axis=0,
                                   return_inverse=True)
            trees = [self._tree(int(s), int(g)) for s, g in pairs]
            tree_counts = np.bincount(inv, minlength=len(trees))
            roots = [tr.edges[tr.parent < 0] for tr in trees]
            root_qs = [(e[:, 1] * 2 + e[:, 2]).astype(np.int64)
                       for e in roots]
            route_ev[is_mc] = N + inv
            n_copies[is_mc] = np.array([len(q) for q in root_qs],
                                       np.int64)[inv]
            fanout_ev[is_mc] = np.array([tr.fanout for tr in trees],
                                        np.int64)[inv]
        else:
            trees, tree_counts, root_qs, inv = [], np.zeros(0, np.int64), \
                [], np.zeros(0, np.int64)

        # per-copy prefill stream, original event order (a tagged event's
        # source out-edges stay in tree-edge order)
        ev_idx = np.repeat(np.arange(len(src)), n_copies)
        is_mc_copy = is_mc[ev_idx]
        grp = np.empty(len(ev_idx), np.int64)
        grp[~is_mc_copy] = _first_hop_queues(rt, u_src, u_dest)
        if len(m_src):
            grp[is_mc_copy] = np.concatenate([root_qs[j] for j in inv])
        expected = int(fanout_ev.sum())   # 1/unicast + fanout/tagged
        total_tx = int(rt.hops[u_src, u_dest].sum()) + int(
            sum(tr.n_edges * int(c) for tr, c in zip(trees, tree_counts)))
        return (grp, t[ev_idx], route_ev[ev_idx].astype(np.int32),
                t[ev_idx], u_src, u_dest, trees, tree_counts,
                expected, total_tx)

    def _plan_impl(self, spec: TrafficSpec, max_steps: int | None) -> _Plan:
        topo, rt = self.topo, self.routing_table
        L = topo.n_links
        if self.mcast_policy.mode == "in_fabric":
            (grp, copy_t, copy_route, copy_inj, u_src, u_dest, trees,
             tree_counts, E, total_tx) = self._route_in_fabric(spec)
            route_out, route_del, route_wt = _routes_with_trees(
                topo, rt, trees)
        else:
            src, t, dest = _expand(spec, self.addr, self.mcast)
            if np.any(src == dest):
                raise ValueError("self-addressed events (src == dest)")
            # validate before route walking (_stream_quota follows paths)
            _check_reachable(rt, src, dest)
            route_out, route_del, route_wt = self._unicast_tables()
            grp = _first_hop_queues(rt, src, dest)
            copy_t = copy_inj = t
            copy_route = dest
            u_src, u_dest, trees, tree_counts = src, dest, [], []
            E = len(src)
            total_tx = int(rt.hops[src, dest].sum())
        if L == 0 or E == 0:
            raise ValueError("need at least one link and one event")
        # quarantined route pairs (broken walks admitted at construction
        # because the remaining CDG is acyclic): lossless flow refuses
        # traffic that would ride them — those events can never be
        # delivered, and their stall chain would wedge the run
        if self._nonterm_mask is not None:
            hit = self._nonterm_mask[u_src, u_dest]
            if np.any(hit):
                pairs = np.unique(np.stack([u_src[hit], u_dest[hit]], 1),
                                  axis=0)
                shown = ", ".join(f"{c}->{d}"
                                  for c, d in pairs[:4].tolist())
                raise ValueError(
                    f"traffic addresses quarantined route pair(s) "
                    f"{shown} whose walk never reaches the destination "
                    f"(next-hop cycle or dead-end); "
                    f"flow={self.queues.flow!r} would deadlock on them "
                    f"— re-route those events or use flow='drop'")

        # flow-control scalars: all dynamic operands, so switching between
        # drop/credit/onoff (or sweeping the capacity) NEVER adds a
        # compilation bucket for a fixed fabric shape
        cap_opt = self.queues.capacity
        cap = int(cap_opt) if cap_opt is not None else max(E, 1)
        fc = FLOW_MODES.index(self.queues.flow)
        xon = (int(self.queues.xon) if self.queues.xon is not None
               else (cap // 2 if fc == 2 else 0))
        # prefill overflow check: in drop mode the logical budget binds
        # the initial backlog too; the lossless modes legitimately buffer
        # above ``cap`` at the source (the gate throttles draining, not
        # buffering), so only the physical width binds there
        chk = cap if fc == 0 else max(E, 1)
        # physical slot width: always the expanded event count, so the
        # capacity stays OUT of the slot engines' shape bucket (extra
        # columns beyond the logical budget hold the BIG_NS sentinel —
        # semantically inert in drop mode, headroom in stall modes)
        C = max(E, 1)
        if max_steps is None:
            max_steps = 4 * total_tx + 2 * E + 64 * (rt.diameter + 2)
        # int32 clock budget vs the BIG_NS sentinel: charge each link
        # only the transmissions that actually cross it (tight bound —
        # slow links no longer tax traffic that avoids them); fall back
        # to the global worst-cost bound when a broken table defeats
        # the route walk (drop mode admits cyclic tables)
        t_max = int(copy_t.max(initial=0))
        link_tx, walk_ok = _route_link_tx(rt, topo.links, u_src, u_dest,
                                          L, topo.n_chips)
        if walk_ok:
            for tr, cnt in zip(trees, tree_counts):
                if tr.n_edges:
                    np.add.at(link_tx, tr.edges[:, 1], int(cnt))
            _overflow_guard_routed(t_max, link_tx, self._link_cost)
        else:
            _overflow_guard(t_max, total_tx, self._worst_cost)
        R, K = route_out.shape[1], route_out.shape[2]

        eng = self.engine.resolved
        if eng == "ring":
            quota = _stream_quota(rt, topo.links, self._in_rank, u_src,
                                  u_dest, L, self._D)
            if trees:
                quota = quota + _tree_stream_quota(trees, tree_counts,
                                                   self._in_rank, L,
                                                   self._D)
            qt, qd, qi, sizes = _prefill(L, grp, copy_t, copy_route,
                                         copy_inj, chk, width="auto")
            # Bucketed shapes (+1 = always-BIG_NS pad column for
            # head/tail gathers); logical E / C / max_burst / max_steps
            # and the timing vectors stay dynamic so cells share
            # compiles.  The replication-table dims (routes, branch
            # bound) are bucketed too, so ``source_expand`` (R = N,
            # K = 1) and a moderate ``in_fabric`` tree population land
            # in the SAME bucket and share one compilation.  The K
            # floor applies only to multicast-capable fabrics (a table
            # is declared): a pure-unicast fabric keeps the historical
            # single append lane per link on its hot path.
            k_floor = _RING_K_FLOOR if self.mcast_policy.table is not None \
                else 1
            C0 = qt.shape[2]
            Cf = _pow2ceil(max(int(quota.max(initial=1)),
                               _RING_STREAM_FLOOR)) + 1
            bucket = ("ring",
                      _pow2ceil(max(L, _RING_L_FLOOR)),
                      _pow2ceil(max(topo.n_chips, _RING_N_FLOOR)),
                      _pow2ceil(max(E, _RING_E_FLOOR)),
                      C0,
                      _pow2ceil(max(self._D, _RING_D_FLOOR)),
                      Cf,
                      _pow2ceil(max(R, _RING_R_FLOOR)),
                      _pow2ceil(max(K, k_floor)),
                      int(self.engine.chunk_size))
        else:
            qt, qd, qi, sizes = _prefill(L, grp, copy_t, copy_route,
                                         copy_inj, chk, width=C)
            # the slot engines bake max_steps/max_burst into the scan, so
            # they key the bucket too (R/K only shape the table operands).
            # The kernel choice is appended LAST so the positional
            # accesses above it stay stable; chunk keys the bucket only
            # for the multi-step kernel (it is baked into the fused
            # launch) — the per-step kernels ignore chunk_size, so
            # sweeping it never adds a step-kernel bucket.
            kern = self.engine.kernel if eng == "pallas" else "step"
            chunk = (int(self.engine.chunk_size) if kern == "multistep"
                     else 0)
            bucket = (eng, L, E, C, int(max_steps),
                      int(self.queues.max_burst), R, K, kern, chunk)
        return _Plan(E=E, C=C, max_steps=int(max_steps), q_time=qt,
                     q_dest=qd, q_inj=qi, sizes=sizes,
                     route_out=route_out, route_del=route_del,
                     route_wt=route_wt, offered=spec.n_events,
                     bucket=bucket, cap=cap, fc=fc, xon=xon)


class CompiledFabric:
    """A :class:`Fabric` bound to ONE engine shape bucket.

    The bucket is the static shape signature the engines compile for
    (pow2-padded link/event/queue dimensions for the ring engine; exact
    shapes plus the scan length for the slot engines).  Everything else —
    traffic, capacity, burst bound, step bound, per-link timing — travels
    as dynamic operands, so every ``run`` on the same bucket reuses one
    XLA executable.  ``cache_size()`` exposes the underlying jit entry
    count; a hot serving path can assert it stays flat.
    """

    def __init__(self, fabric: Fabric, bucket: tuple):
        self.fabric = fabric
        self.bucket = bucket
        self.n_runs = 0
        topo, rt = fabric.topo, fabric.routing_table
        L = topo.n_links
        tc, tv, ti = fabric.timing_arrays
        eng = bucket[0]
        if eng == "ring":
            _, Lp, Np, _Ep, C0, Dp, Cf, _Rp, _Kp, chunk = bucket
            self._fn = _ring_engine(Lp, _Ep, C0, Dp, Cf, chunk)
            # static gather tables + timing vectors, padded once per
            # bucket (dummy links park forever: empty queues, zero-cost
            # timing — semantically inert); the replication tables are
            # per-plan operands (they carry the spec's multicast trees)
            # and are padded in _execute
            self._tables = (
                jnp.asarray(_pad_to(fabric._init_tx, (Lp,), 1)),
                jnp.asarray(_pad_to(topo.links, (Lp, 2), 0), jnp.int32),
                jnp.asarray(_pad_to(fabric._in_rank, (Lp, 2), 0),
                            jnp.int32),
                jnp.asarray(_pad_to(tc, (Lp,), 0)),
                jnp.asarray(_pad_to(tv, (Lp,), 0)),
                jnp.asarray(_pad_to(ti, (Lp,), 0)),
            )
        else:
            _, _L, E, C, max_steps, mb, _R, _K, kern, chunk = bucket
            if kern == "multistep":
                self._fn = _slot_engine_multistep(L, E, C, max_steps, mb,
                                                  chunk)
            else:
                self._fn = _slot_engine(L, E, C, max_steps, mb,
                                        eng == "pallas")
            self._tables = (
                jnp.asarray(fabric._init_tx),
                jnp.asarray(topo.links, jnp.int32),
                jnp.asarray(tc), jnp.asarray(tv), jnp.asarray(ti),
            )
        self._warmed = False

    @property
    def engine_name(self) -> str:
        return self.bucket[0]

    def __repr__(self) -> str:
        return (f"CompiledFabric(engine={self.engine_name!r}, "
                f"bucket={self.bucket}, runs={self.n_runs})")

    def cache_size(self) -> int:
        """Entries in the underlying jit cache (-1 when unavailable).

        One entry per traced shape signature; a second ``run`` on this
        bucket must leave it unchanged — the no-recompile contract."""
        fn = self._fn
        try:
            return int(fn._cache_size())
        except AttributeError:  # pragma: no cover - older/newer jax
            return -1

    def run(self, spec: TrafficSpec, *,
            max_steps: int | None = None) -> FabricResult:
        """Run one spec, refusing specs that fall outside this bucket."""
        plan = self.fabric._plan(spec, max_steps)
        if plan.bucket != self.bucket:
            raise ValueError(
                f"spec needs shape bucket {plan.bucket} but this "
                f"CompiledFabric is bound to {self.bucket}; use "
                f"Fabric.run (auto-routes) or Fabric.compile the new "
                f"bucket")
        return self._execute(plan)

    def warmup(self) -> "CompiledFabric":
        """Trigger this bucket's XLA compilation with a zero-event run.

        The dummy run offers no traffic (all queue slots hold the
        ``BIG_NS`` sentinel, zero logical events).  On the ring engine —
        the hot path this hook exists for — the early-exit condition
        holds immediately, so the cost is one compilation plus
        microseconds of execution.  The slot engines have no early exit
        (``max_steps`` is baked into their scan), so their dummy run
        executes the full-length scan of settled no-op steps; compile
        time still dominates, but latency-critical slot-engine users may
        prefer ``warm=False``.  Idempotent."""
        if self._warmed:
            return self
        # a zero-event plan through the one real marshalling path
        # (_execute), so the engine call signature lives in one place
        L = self.fabric.topo.n_links
        N = self.fabric.topo.n_chips
        if self.bucket[0] == "ring":
            width = self.bucket[4]
            R, K = N, 1         # _execute pads to the bucket's (Rp, Kp)
        else:
            width = self.bucket[3]
            R, K = self.bucket[6], self.bucket[7]
        qt = np.full((L, 2, width), int(_BIG), np.int32)
        z = np.zeros((L, 2, width), np.int32)
        n_runs = self.n_runs
        res = self._execute(_Plan(
            E=0, C=width, max_steps=0, q_time=qt, q_dest=z, q_inj=z,
            sizes=np.zeros((L, 2), np.int32),
            route_out=np.full((N, R, K), -1, np.int32),
            route_del=np.zeros((N, R), np.int32),
            route_wt=np.zeros((N, R, K), np.int32),
            offered=0, bucket=self.bucket, cap=width, fc=0, xon=0))
        jax.block_until_ready(res.drops)
        self.n_runs = n_runs  # the dummy run is not a user run
        self._warmed = True
        return self

    def _execute(self, plan: _Plan) -> FabricResult:
        fab = self.fabric
        E, L = plan.E, fab.topo.n_links
        mb = int(fab.queues.max_burst)
        if self.bucket[0] == "ring":
            _, Lp, Np, Ep, C0, _Dp, _Cf, Rp, Kp, _chunk = self.bucket
            init_tx_j, links_j, in_rank_j, tc_j, tv_j, ti_j = self._tables
            out = self._fn(
                jnp.asarray(_pad_to(plan.q_time, (Lp, 2, C0), int(_BIG))),
                jnp.asarray(_pad_to(plan.q_dest, (Lp, 2, C0), 0)),
                jnp.asarray(_pad_to(plan.q_inj, (Lp, 2, C0), 0)),
                jnp.asarray(_pad_to(plan.sizes, (Lp, 2), 0)),
                init_tx_j, links_j,
                jnp.asarray(_pad_to(plan.route_out, (Np, Rp, Kp), -1)),
                jnp.asarray(_pad_to(plan.route_del, (Np, Rp), 0)),
                jnp.asarray(_pad_to(plan.route_wt, (Np, Rp, Kp), 0)),
                in_rank_j, tc_j, tv_j, ti_j,
                jnp.int32(plan.cap), jnp.int32(E), jnp.int32(mb),
                jnp.int32(plan.max_steps), jnp.int32(plan.fc),
                jnp.int32(plan.xon))
            (log_n, log_inj, log_del, log_dest, sent, n_sw, t_link,
             drops, busy_ns, busy_steps, q_drops, stall_steps,
             credit_waits) = out
            # trim the shape-bucket padding back to the real fabric
            log_inj, log_del, log_dest = (log_inj[:E], log_del[:E],
                                          log_dest[:E])
            sent, n_sw, t_link = sent[:L], n_sw[:L], t_link[:L]
            busy_ns, busy_steps, q_drops = (busy_ns[:L], busy_steps[:L],
                                            q_drops[:L])
            stall_steps, credit_waits = stall_steps[:L], credit_waits[:L]
            t_end = jnp.max(t_link)
        else:
            C = plan.C
            init_tx_j, links_j, tc_j, tv_j, ti_j = self._tables
            out = self._fn(jnp.asarray(plan.q_time).reshape(2 * L, C),
                           jnp.asarray(plan.q_dest).reshape(2 * L, C),
                           jnp.asarray(plan.q_inj).reshape(2 * L, C),
                           jnp.asarray(plan.sizes),
                           init_tx_j, links_j,
                           jnp.asarray(plan.route_out),
                           jnp.asarray(plan.route_del),
                           jnp.asarray(plan.route_wt),
                           tc_j, tv_j, ti_j,
                           jnp.int32(plan.cap), jnp.int32(plan.fc),
                           jnp.int32(plan.xon))
            (log_n, log_inj, log_del, log_dest, sent, n_sw, t_link, t_end,
             drops, busy_ns, busy_steps, q_drops, stall_steps,
             credit_waits) = out
        self.n_runs += 1
        self._warmed = True  # first real run compiles the bucket too
        return FabricResult(
            delivered=log_n, injected=E,
            log_inj=log_inj, log_del=log_del, log_dest=log_dest,
            sent=sent, n_switches=n_sw,
            t_link=t_link, t_end=t_end, drops=drops,
            offered=plan.offered,
            telemetry=Telemetry(busy_ns=busy_ns, busy_steps=busy_steps,
                                q_drops=q_drops, stall_steps=stall_steps,
                                credit_waits=credit_waits))


# -----------------------------------------------------------------------
# Batched execution: B fabric instances as ONE compiled computation
# -----------------------------------------------------------------------

def run_batch(fabrics, specs, *, max_steps: int | None = None,
              devices: int | str | None = None) -> FabricBatchResult:
    """Run B (fabric, spec) instances as one batched computation.

    ``fabrics`` is a single :class:`Fabric` (replicated across the
    batch — the Monte-Carlo-over-seeds case) or a sequence of B fabrics
    sharing one topology shape and shape bucket but free to differ in
    routing tables, timing contracts, queue policy scalars and initial
    polarity — every one of those is already a dynamic engine operand,
    so per-instance heterogeneity adds ZERO compilation buckets.  The
    batch compiles once per (bucket, B, devices) signature and runs as
    a single dispatch; each instance's result is bit-exact with its
    solo ``fabric.run(spec)``.

    ``devices``: shard the batch axis across this many local devices
    (``"all"`` = every local device) via ``shard_map``; ``None`` = no
    sharding.  B must be divisible by the device count.
    """
    specs = list(specs)
    if isinstance(fabrics, Fabric):
        fabs = [fabrics] * len(specs)
    else:
        fabs = list(fabrics)
    if len(fabs) != len(specs):
        raise ValueError(f"got {len(fabs)} fabrics for {len(specs)} "
                         f"specs; they must pair 1:1 (or pass a single "
                         f"Fabric to replicate)")
    plans = _plan_batch(fabs, specs, max_steps)
    return _execute_batch(fabs, plans,
                          _resolve_devices(devices, len(plans)))


def _plan_batch(fabs: list[Fabric], specs, max_steps: int | None):
    """Per-instance plans under one shared step bound and ONE bucket.

    With ``max_steps=None`` the shared bound is the max over the
    per-spec defaults: the slot engines bake ``max_steps`` into their
    static scan (it keys their bucket), and the ring engine's batch
    drains by early exit anyway — a non-binding bound never changes
    results, so solo bit-exactness survives the sharing.  Ring plans
    just take the shared bound (their bucket ignores it); slot plans
    with a different default are re-planned under it.
    """
    if not specs:
        raise ValueError("run_batch needs at least one instance")
    from .adaptive import AdaptiveRouting
    for f in fabs:
        if isinstance(f.routing_policy, AdaptiveRouting):
            raise NotImplementedError(
                "run_batch under AdaptiveRouting is refused: the epoch "
                "loop is sequential feedback (epoch k's telemetry "
                "re-weights epoch k+1's tables), so instances cannot "
                "fuse into one computation. Run adaptive specs through "
                "Fabric.run / run_epochs; batch the static baseline.")
    L = fabs[0].topo.n_links
    for f in fabs[1:]:
        if f.topo.n_links != L:
            raise ValueError(f"all fabrics in a batch must share the "
                             f"link count, got {f.topo.n_links} vs {L}")
    plans = [f._plan(s, max_steps) for f, s in zip(fabs, specs)]
    if max_steps is None:
        shared = max(p.max_steps for p in plans)
        plans = [p._replace(max_steps=shared) if p.bucket[0] == "ring"
                 else (p if p.max_steps == shared else f._plan(s, shared))
                 for f, s, p in zip(fabs, specs, plans)]
    buckets = dict.fromkeys(p.bucket for p in plans)
    if len(buckets) != 1:
        raise ValueError(
            f"run_batch needs every instance in ONE shape bucket, got "
            f"{list(buckets)}; Fabric.run_many loops mixed buckets")
    return plans


def _resolve_devices(devices: int | str | None, batch: int) -> int:
    """Device count for the batch axis; validates divisibility."""
    if devices is None:
        return 1
    n = jax.local_device_count() if devices == "all" else int(devices)
    if n < 1:
        raise ValueError(f"devices must be >= 1, got {devices!r}")
    if n > jax.local_device_count():
        raise ValueError(f"asked for {n} devices but only "
                         f"{jax.local_device_count()} are local")
    if batch % n:
        raise ValueError(f"batch size {batch} is not divisible by "
                         f"{n} devices (shard_map needs equal shards)")
    return n


def _zero_event_plan(fab: Fabric, bucket: tuple) -> _Plan:
    """The zero-event dummy plan ``warmup`` runs (every queue slot holds
    the ``BIG_NS`` sentinel, zero logical events) — here as a batch
    pre-warm instance."""
    L, N = fab.topo.n_links, fab.topo.n_chips
    if bucket[0] == "ring":
        width = bucket[4]
        R, K = N, 1         # _execute_batch pads to the bucket's (Rp, Kp)
    else:
        width = bucket[3]
        R, K = bucket[6], bucket[7]
    qt = np.full((L, 2, width), int(_BIG), np.int32)
    z = np.zeros((L, 2, width), np.int32)
    return _Plan(E=0, C=width, max_steps=0, q_time=qt, q_dest=z, q_inj=z,
                 sizes=np.zeros((L, 2), np.int32),
                 route_out=np.full((N, R, K), -1, np.int32),
                 route_del=np.zeros((N, R), np.int32),
                 route_wt=np.zeros((N, R, K), np.int32),
                 offered=0, bucket=bucket, cap=width, fc=0, xon=0)


def _batch_engine_for(bucket: tuple, n_devices: int):
    """The lru-cached batched engine bound to one shape bucket."""
    if bucket[0] == "ring":
        _, Lp, _Np, Ep, C0, Dp, Cf, _Rp, _Kp, chunk = bucket
        return _ring_engine_batch(Lp, Ep, C0, Dp, Cf, chunk, n_devices)
    eng, L, E, C, ms, mb, _R, _K, kern, chunk = bucket
    if kern == "multistep":
        return _slot_engine_multistep_batch(L, E, C, ms, mb, chunk,
                                            n_devices)
    return _slot_engine_batch(L, E, C, ms, mb, eng == "pallas", n_devices)


def batch_cache_size(bucket: tuple, n_devices: int = 1) -> int:
    """Entries in the batched engine's jit cache for ``bucket`` (-1 when
    unavailable) — the batch path's no-recompile audit: one entry per
    traced (B, operand-shape) signature, so a repeated same-size batch
    must leave it unchanged (asserted by tests and the CI batch gate)."""
    fn = _batch_engine_for(bucket, n_devices)
    try:
        return int(fn._cache_size())
    except AttributeError:  # pragma: no cover - older/newer jax
        return -1


def _execute_batch(fabs: list[Fabric], plans: list[_Plan],
                   n_devices: int) -> FabricBatchResult:
    """Marshal B plans into (B,)-leading operands and run the batched
    engine — the batch mirror of ``CompiledFabric._execute``.  Static
    per-bucket tables (polarity, link endpoints, in-edge ranks, timing
    vectors) come from each instance's ``CompiledFabric`` (reusing its
    padding work and keeping the solo and batch paths marshalling-
    identical); stacking them per instance is what lets one batch mix
    timing contracts and polarities across fabrics."""
    bucket = plans[0].bucket
    fn = _batch_engine_for(bucket, n_devices)
    L = fabs[0].topo.n_links
    tabs = [f._get_compiled(bucket)._tables for f in fabs]

    def stk(i):
        return jnp.stack([t[i] for t in tabs])

    def vec(xs):
        return jnp.asarray(np.asarray(list(xs), np.int32))

    if bucket[0] == "ring":
        _, Lp, Np, _Ep, C0, _Dp, _Cf, Rp, Kp, _chunk = bucket
        out = fn(
            jnp.stack([jnp.asarray(_pad_to(p.q_time, (Lp, 2, C0),
                                           int(_BIG))) for p in plans]),
            jnp.stack([jnp.asarray(_pad_to(p.q_dest, (Lp, 2, C0), 0))
                       for p in plans]),
            jnp.stack([jnp.asarray(_pad_to(p.q_inj, (Lp, 2, C0), 0))
                       for p in plans]),
            jnp.stack([jnp.asarray(_pad_to(p.sizes, (Lp, 2), 0))
                       for p in plans]),
            stk(0), stk(1),
            jnp.stack([jnp.asarray(_pad_to(p.route_out, (Np, Rp, Kp), -1))
                       for p in plans]),
            jnp.stack([jnp.asarray(_pad_to(p.route_del, (Np, Rp), 0))
                       for p in plans]),
            jnp.stack([jnp.asarray(_pad_to(p.route_wt, (Np, Rp, Kp), 0))
                       for p in plans]),
            stk(2), stk(3), stk(4), stk(5),
            vec(p.cap for p in plans), vec(p.E for p in plans),
            vec(int(f.queues.max_burst) for f in fabs),
            # shared scalar step bound (aligned by _plan_batch) — the
            # batched runner keeps its chunk bookkeeping unbatched
            jnp.int32(max(p.max_steps for p in plans)),
            vec(p.fc for p in plans), vec(p.xon for p in plans))
        (log_n, log_inj, log_del, log_dest, sent, n_sw, t_link, drops,
         busy_ns, busy_steps, q_drops, stall_steps, credit_waits) = out
        e_max = max((p.E for p in plans), default=0)
        log_inj, log_del, log_dest = (log_inj[:, :e_max],
                                      log_del[:, :e_max],
                                      log_dest[:, :e_max])
        sent, n_sw, t_link = sent[:, :L], n_sw[:, :L], t_link[:, :L]
        busy_ns, busy_steps = busy_ns[:, :L], busy_steps[:, :L]
        q_drops = q_drops[:, :L]
        stall_steps, credit_waits = (stall_steps[:, :L],
                                     credit_waits[:, :L])
        t_end = jnp.max(t_link, axis=1)
    else:
        C = plans[0].C
        out = fn(
            jnp.stack([jnp.asarray(p.q_time).reshape(2 * L, C)
                       for p in plans]),
            jnp.stack([jnp.asarray(p.q_dest).reshape(2 * L, C)
                       for p in plans]),
            jnp.stack([jnp.asarray(p.q_inj).reshape(2 * L, C)
                       for p in plans]),
            jnp.stack([jnp.asarray(p.sizes) for p in plans]),
            stk(0), stk(1),
            jnp.stack([jnp.asarray(p.route_out) for p in plans]),
            jnp.stack([jnp.asarray(p.route_del) for p in plans]),
            jnp.stack([jnp.asarray(p.route_wt) for p in plans]),
            stk(2), stk(3), stk(4),
            vec(p.cap for p in plans), vec(p.fc for p in plans),
            vec(p.xon for p in plans))
        (log_n, log_inj, log_del, log_dest, sent, n_sw, t_link, t_end,
         drops, busy_ns, busy_steps, q_drops, stall_steps,
         credit_waits) = out
    return FabricBatchResult(
        delivered=log_n,
        injected=np.asarray([p.E for p in plans], np.int64),
        log_inj=log_inj, log_del=log_del, log_dest=log_dest,
        sent=sent, n_switches=n_sw, t_link=t_link, t_end=t_end,
        drops=drops,
        offered=np.asarray([p.offered for p in plans], np.int64),
        telemetry=Telemetry(busy_ns=busy_ns, busy_steps=busy_steps,
                            q_drops=q_drops, stall_steps=stall_steps,
                            credit_waits=credit_waits))
