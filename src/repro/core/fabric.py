"""Declarative fabric front-end: composable policies + compile/run lifecycle.

``network.simulate_fabric`` grew one kwarg per feature; this module is the
redesigned front door.  A :class:`Fabric` is a *declaration* — topology
plus four orthogonal policies:

* ``routing`` — a :class:`RoutingPolicy` (``StaticShortestPath`` wraps the
  BFS table builder and exposes a ``table_override`` hook, the landing pad
  for adaptive/congestion-aware routing), or a prebuilt ``RoutingTable``.
* ``timing``  — one scalar ``LinkTiming`` shared by every link, or a
  structure-of-arrays ``LinkTiming`` of shape (L,) mixing link classes
  (fast parallel on-board buses next to slow bit-serial LVDS inter-board
  links — see ``link.per_link_timing`` / ``link.SERIAL_LVDS_TIMING``).
* ``queues``  — :class:`QueuePolicy`: per-endpoint capacity, bounded-burst
  fairness, reset polarity.
* ``engine``  — :class:`EngineSpec`: which bit-exact event-transport
  engine runs the micro-transaction loop and its chunking.

Execution is an *explicit lifecycle*:

    fab = Fabric(ring_topology(8), timing=mixed, queues=QueuePolicy(max_burst=1))
    cf = fab.compile(spec)          # bind + pre-warm one shape bucket
    res = cf.run(spec)              # no compilation on this path
    results = fab.run_many(specs)   # one compile amortised over a sweep

``Fabric.compile`` makes the PR 2 shape-bucketed jit cache user-visible:
it returns a :class:`CompiledFabric` pinned to one bucket (the pow2-padded
static shape signature), whose ``warmup()`` populates the XLA cache with a
zero-event dummy run and whose ``cache_size()`` exposes the underlying jit
entry count — so tests and serving loops can *prove* a hot path never
recompiles.  ``Fabric.run`` routes each spec to the right bucket
automatically and caches ``CompiledFabric`` instances per bucket.

``simulate_fabric`` survives unchanged as a thin wrapper that builds a
one-shot ``Fabric`` and calls ``run`` — every historical call site keeps
working and stays bit-exact.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from .link import PAPER_TIMING, LinkTiming, link_timing_arrays
from .network import (DEFAULT_CHUNK_SIZE, ENGINES, FabricResult, _BIG,
                      _RING_D_FLOOR, _RING_E_FLOOR, _RING_L_FLOOR,
                      _RING_N_FLOOR, _RING_STREAM_FLOOR, _check_reachable,
                      _expand, _in_edge_ranks, _overflow_guard, _pad_to,
                      _pow2ceil, _prefill, _ring_engine, _slot_engine,
                      _stream_quota)
from .router import AddressSpec, MulticastTable, RoutingTable, Topology
from .traffic import TrafficSpec

__all__ = ["Fabric", "CompiledFabric", "QueuePolicy", "EngineSpec",
           "RoutingPolicy", "StaticShortestPath", "PrebuiltRouting",
           "SweepCell"]


# -----------------------------------------------------------------------
# Policies
# -----------------------------------------------------------------------

@dataclass(frozen=True)
class QueuePolicy:
    """Per-endpoint queue behaviour of every link in the fabric.

    ``capacity``   — one-shot slot budget per endpoint (bounds the events
                     routed *through* an endpoint, not instantaneous
                     depth); ``None`` = lossless (the expanded event
                     count).  Overflowing forwards are dropped and
                     counted in ``FabricResult.drops``.
    ``max_burst``  — 0 = paper-faithful grant rule; B > 0 = bounded-burst
                     fairness (transmitter yields after B events when the
                     peer requests).
    ``initial_tx`` — scalar or (L,): which side of each link resets into
                     TX mode (the paper's chip-level global reset).
    """
    capacity: int | None = None
    max_burst: int = 0
    initial_tx: int | np.ndarray = 1

    def __post_init__(self):
        if self.capacity is not None and int(self.capacity) < 1:
            raise ValueError(f"queue capacity must be >= 1, got "
                             f"{self.capacity}")
        if int(self.max_burst) < 0:
            raise ValueError(f"max_burst must be >= 0, got {self.max_burst}")


@dataclass(frozen=True)
class EngineSpec:
    """Which bit-exact event-transport engine runs the simulation.

    ``name``       — ``"auto"`` (= ring), ``"ring"``, ``"reference"`` or
                     ``"pallas"`` (see ``network`` module docstring).
    ``chunk_size`` — ring engine only: micro-transactions per ``lax.scan``
                     chunk between early-exit checks.
    """
    name: str = "auto"
    chunk_size: int = DEFAULT_CHUNK_SIZE

    def __post_init__(self):
        resolved = "ring" if self.name == "auto" else self.name
        if resolved not in ENGINES:
            raise ValueError(f"unknown engine {self.name!r}; expected one "
                             f"of {ENGINES} (or 'auto')")
        if int(self.chunk_size) < 1:
            # a 0-step chunk would make the early-exit while_loop spin
            # forever
            raise ValueError(f"chunk_size must be >= 1, got "
                             f"{self.chunk_size}")

    @property
    def resolved(self) -> str:
        return "ring" if self.name == "auto" else self.name


@runtime_checkable
class RoutingPolicy(Protocol):
    """Anything that turns a topology into next-hop tables."""

    def build(self, topo: Topology) -> RoutingTable: ...


def _validate_tables(topo: Topology, rt: RoutingTable) -> RoutingTable:
    n = topo.n_chips
    for name in ("next_link", "out_side", "hops"):
        a = np.asarray(getattr(rt, name))
        if a.shape != (n, n):
            raise ValueError(f"routing table {name} has shape {a.shape}, "
                             f"expected ({n}, {n})")
    nl = np.asarray(rt.next_link)
    if nl.max(initial=-1) >= topo.n_links:
        raise ValueError("routing table names a link id outside the "
                         "topology")
    return rt


@dataclass(frozen=True)
class StaticShortestPath:
    """Deterministic BFS shortest-path routing (the PR 1 tables).

    ``table_override`` — optional hook called with ``(topo, built_table)``
    returning a replacement ``RoutingTable``.  This is the landing pad
    for adaptive/congestion-aware routing policies: an override can bias
    next-hops off the shortest path (it is trusted to keep the tables
    consistent — every hop must make progress, or events cycle until the
    step bound binds).
    """
    table_override: Callable[[Topology, RoutingTable],
                             RoutingTable] | None = None

    def build(self, topo: Topology) -> RoutingTable:
        rt = RoutingTable.build(topo)
        if self.table_override is not None:
            rt = _validate_tables(topo, self.table_override(topo, rt))
        return rt


@dataclass(frozen=True)
class PrebuiltRouting:
    """Adapter: a ready-made ``RoutingTable`` as a ``RoutingPolicy``."""
    table: RoutingTable

    def build(self, topo: Topology) -> RoutingTable:
        return _validate_tables(topo, self.table)


# -----------------------------------------------------------------------
# Run planning (setup-time numpy; shared by compile and run)
# -----------------------------------------------------------------------

class _Plan(NamedTuple):
    """Everything one execution needs: expanded traffic, prefilled
    queues, dynamic scalars and the static shape bucket they fit."""
    E: int
    C: int
    max_steps: int
    q_time: np.ndarray
    q_dest: np.ndarray
    q_inj: np.ndarray
    sizes: np.ndarray
    bucket: tuple


class SweepCell(NamedTuple):
    result: FabricResult
    us_per_call: float
    bucket: tuple


class Fabric:
    """A declarative N-chip AER fabric: topology + composable policies.

    See the module docstring for the lifecycle.  Construction resolves
    and validates every policy eagerly (routing tables are built once,
    timing is normalised to per-link cost vectors), so a ``Fabric`` held
    by a serving loop never re-runs setup-time numpy per call beyond the
    per-spec routing/prefill pass.
    """

    def __init__(self, topo: Topology, *,
                 routing: RoutingPolicy | RoutingTable | None = None,
                 timing: LinkTiming = PAPER_TIMING,
                 queues: QueuePolicy | None = None,
                 engine: EngineSpec | str | None = None,
                 addr: AddressSpec | None = None,
                 mcast: MulticastTable | None = None):
        self.topo = topo
        if routing is None:
            policy: RoutingPolicy = StaticShortestPath()
        elif isinstance(routing, RoutingTable):
            policy = PrebuiltRouting(routing)
        elif isinstance(routing, RoutingPolicy):
            policy = routing
        else:
            raise TypeError(f"routing must be a RoutingPolicy or a "
                            f"RoutingTable, got {type(routing).__name__}")
        self.routing_policy = policy
        self.queues = queues if queues is not None else QueuePolicy()
        if engine is None:
            engine = EngineSpec()
        elif isinstance(engine, str):
            engine = EngineSpec(name=engine)
        self.engine = engine
        self.timing = timing
        self.addr = addr
        self.mcast = mcast

        L = topo.n_links
        # normalised per-link cost vectors: the engines' dynamic operands
        self.timing_arrays = link_timing_arrays(timing, L)
        tc, tv, ti = self.timing_arrays
        self._worst_cost = int((tc.astype(np.int64)
                                + np.maximum(tv, ti)).max(initial=1))
        self.routing_table = policy.build(topo)
        self._in_rank, self._D = _in_edge_ranks(topo)
        self._init_tx = np.broadcast_to(
            np.asarray(self.queues.initial_tx, np.int32), (L,))
        self._compiled: dict[tuple, "CompiledFabric"] = {}
        self._plan_memo: tuple | None = None  # (spec, max_steps, plan)

    # --- declaration niceties ------------------------------------------

    @property
    def n_chips(self) -> int:
        return self.topo.n_chips

    @property
    def n_links(self) -> int:
        return self.topo.n_links

    @property
    def compiled_buckets(self) -> tuple[tuple, ...]:
        """Shape buckets this fabric has bound so far (compile order)."""
        return tuple(self._compiled)

    def __repr__(self) -> str:
        return (f"Fabric({self.topo.name}: {self.n_chips} chips, "
                f"{self.n_links} links, engine={self.engine.resolved!r}, "
                f"{len(self._compiled)} compiled bucket(s))")

    # --- lifecycle ------------------------------------------------------

    def compile(self, spec: TrafficSpec, *, max_steps: int | None = None,
                warm: bool = True) -> "CompiledFabric":
        """Bind the shape bucket that ``spec`` needs and return it.

        With ``warm=True`` (default) the bucket's XLA compilation is
        triggered immediately by a zero-event dummy run, so a subsequent
        ``run`` of any spec in the bucket pays zero compile time — the
        pre-warm hook a latency-sensitive serving loop wants.
        """
        plan = self._plan(spec, max_steps)
        cf = self._get_compiled(plan.bucket)
        if warm:
            cf.warmup()
        return cf

    def run(self, spec: TrafficSpec, *,
            max_steps: int | None = None) -> FabricResult:
        """Simulate one traffic spec (compiling its bucket on first use)."""
        plan = self._plan(spec, max_steps)
        return self._get_compiled(plan.bucket)._execute(plan)

    def run_many(self, specs, *,
                 max_steps: int | None = None) -> list[FabricResult]:
        """Run a sequence of specs, amortising compiles across buckets
        (specs that bucket alike share one compilation)."""
        return [self.run(s, max_steps=max_steps) for s in specs]

    def sweep(self, specs, *, max_steps: int | None = None,
              warm: bool = True) -> list[SweepCell]:
        """``run_many`` with per-cell wall-clock: pre-warms every distinct
        bucket first (unless ``warm=False``), then times each run — the
        benchmark-sweep pattern where compile time must not pollute
        per-cell numbers."""
        plans = [self._plan(s, max_steps) for s in specs]
        if warm:
            for b in dict.fromkeys(p.bucket for p in plans):
                self._get_compiled(b).warmup()
        cells = []
        for p in plans:
            t0 = time.perf_counter()
            res = self._get_compiled(p.bucket)._execute(p)
            jax.block_until_ready(res.log_del)
            us = (time.perf_counter() - t0) * 1e6
            cells.append(SweepCell(result=res, us_per_call=us,
                                   bucket=p.bucket))
        return cells

    # --- internals ------------------------------------------------------

    def _get_compiled(self, bucket: tuple) -> "CompiledFabric":
        cf = self._compiled.get(bucket)
        if cf is None:
            cf = CompiledFabric(self, bucket)
            self._compiled[bucket] = cf
        return cf

    def _plan(self, spec: TrafficSpec, max_steps: int | None) -> _Plan:
        # memoize the last plan by spec identity: the documented
        # compile(spec) -> run(spec) lifecycle (and repeated runs of one
        # spec) pays the setup-time numpy (expansion, route walking,
        # prefill) once, not per call
        memo = self._plan_memo
        if memo is not None and memo[0] is spec and memo[1] == max_steps:
            return memo[2]
        plan = self._plan_impl(spec, max_steps)
        self._plan_memo = (spec, max_steps, plan)
        return plan

    def _plan_impl(self, spec: TrafficSpec, max_steps: int | None) -> _Plan:
        topo, rt = self.topo, self.routing_table
        src, t, dest = _expand(spec, self.addr, self.mcast)
        if np.any(src == dest):
            raise ValueError("self-addressed events (src == dest)")
        E, L = len(src), topo.n_links
        if L == 0 or E == 0:
            raise ValueError("need at least one link and one event")
        # validate before any route walking (_stream_quota follows paths)
        _check_reachable(rt, src, dest)

        cap = self.queues.capacity
        C = int(cap) if cap is not None else max(E, 1)
        total_tx = int(rt.hops[src, dest].sum())
        if max_steps is None:
            max_steps = 4 * total_tx + 2 * E + 64 * (rt.diameter + 2)
        _overflow_guard(int(t.max(initial=0)), total_tx, self._worst_cost)

        eng = self.engine.resolved
        if eng == "ring":
            quota = _stream_quota(rt, topo.links, self._in_rank, src, dest,
                                  L, self._D)
            qt, qd, qi, sizes = _prefill(topo, rt, src, t, dest, C,
                                         width="auto")
            # Bucketed shapes (+1 = always-BIG_NS pad column for
            # head/tail gathers); logical E / C / max_burst / max_steps
            # and the timing vectors stay dynamic so cells share
            # compiles.
            C0 = qt.shape[2]
            Cf = _pow2ceil(max(int(quota.max(initial=1)),
                               _RING_STREAM_FLOOR)) + 1
            bucket = ("ring",
                      _pow2ceil(max(L, _RING_L_FLOOR)),
                      _pow2ceil(max(topo.n_chips, _RING_N_FLOOR)),
                      _pow2ceil(max(E, _RING_E_FLOOR)),
                      C0,
                      _pow2ceil(max(self._D, _RING_D_FLOOR)),
                      Cf,
                      int(self.engine.chunk_size))
        else:
            qt, qd, qi, sizes = _prefill(topo, rt, src, t, dest, C)
            # the slot engines bake max_steps/max_burst into the scan, so
            # they key the bucket too
            bucket = (eng, L, E, C, int(max_steps),
                      int(self.queues.max_burst))
        return _Plan(E=E, C=C, max_steps=int(max_steps), q_time=qt,
                     q_dest=qd, q_inj=qi, sizes=sizes, bucket=bucket)


class CompiledFabric:
    """A :class:`Fabric` bound to ONE engine shape bucket.

    The bucket is the static shape signature the engines compile for
    (pow2-padded link/event/queue dimensions for the ring engine; exact
    shapes plus the scan length for the slot engines).  Everything else —
    traffic, capacity, burst bound, step bound, per-link timing — travels
    as dynamic operands, so every ``run`` on the same bucket reuses one
    XLA executable.  ``cache_size()`` exposes the underlying jit entry
    count; a hot serving path can assert it stays flat.
    """

    def __init__(self, fabric: Fabric, bucket: tuple):
        self.fabric = fabric
        self.bucket = bucket
        self.n_runs = 0
        topo, rt = fabric.topo, fabric.routing_table
        L = topo.n_links
        tc, tv, ti = fabric.timing_arrays
        eng = bucket[0]
        if eng == "ring":
            _, Lp, Np, _Ep, C0, Dp, Cf, chunk = bucket
            self._fn = _ring_engine(Lp, _Ep, C0, Dp, Cf, chunk)
            # static gather tables + timing vectors, padded once per
            # bucket (dummy links park forever: empty queues, zero-cost
            # timing — semantically inert)
            self._tables = (
                jnp.asarray(_pad_to(fabric._init_tx, (Lp,), 1)),
                jnp.asarray(_pad_to(topo.links, (Lp, 2), 0), jnp.int32),
                jnp.asarray(_pad_to(rt.next_link, (Np, Np), 0), jnp.int32),
                jnp.asarray(_pad_to(rt.out_side, (Np, Np), 0), jnp.int32),
                jnp.asarray(_pad_to(fabric._in_rank, (Lp, 2), 0),
                            jnp.int32),
                jnp.asarray(_pad_to(tc, (Lp,), 0)),
                jnp.asarray(_pad_to(tv, (Lp,), 0)),
                jnp.asarray(_pad_to(ti, (Lp,), 0)),
            )
        else:
            _, _L, E, C, max_steps, mb = bucket
            self._fn = _slot_engine(L, E, C, max_steps, mb,
                                    eng == "pallas")
            self._tables = (
                jnp.asarray(fabric._init_tx),
                jnp.asarray(topo.links, jnp.int32),
                jnp.asarray(rt.next_link, jnp.int32),
                jnp.asarray(rt.out_side, jnp.int32),
                jnp.asarray(tc), jnp.asarray(tv), jnp.asarray(ti),
            )
        self._warmed = False

    @property
    def engine_name(self) -> str:
        return self.bucket[0]

    def __repr__(self) -> str:
        return (f"CompiledFabric(engine={self.engine_name!r}, "
                f"bucket={self.bucket}, runs={self.n_runs})")

    def cache_size(self) -> int:
        """Entries in the underlying jit cache (-1 when unavailable).

        One entry per traced shape signature; a second ``run`` on this
        bucket must leave it unchanged — the no-recompile contract."""
        fn = self._fn
        try:
            return int(fn._cache_size())
        except AttributeError:  # pragma: no cover - older/newer jax
            return -1

    def run(self, spec: TrafficSpec, *,
            max_steps: int | None = None) -> FabricResult:
        """Run one spec, refusing specs that fall outside this bucket."""
        plan = self.fabric._plan(spec, max_steps)
        if plan.bucket != self.bucket:
            raise ValueError(
                f"spec needs shape bucket {plan.bucket} but this "
                f"CompiledFabric is bound to {self.bucket}; use "
                f"Fabric.run (auto-routes) or Fabric.compile the new "
                f"bucket")
        return self._execute(plan)

    def warmup(self) -> "CompiledFabric":
        """Trigger this bucket's XLA compilation with a zero-event run.

        The dummy run offers no traffic (all queue slots hold the
        ``BIG_NS`` sentinel, zero logical events).  On the ring engine —
        the hot path this hook exists for — the early-exit condition
        holds immediately, so the cost is one compilation plus
        microseconds of execution.  The slot engines have no early exit
        (``max_steps`` is baked into their scan), so their dummy run
        executes the full-length scan of settled no-op steps; compile
        time still dominates, but latency-critical slot-engine users may
        prefer ``warm=False``.  Idempotent."""
        if self._warmed:
            return self
        # a zero-event plan through the one real marshalling path
        # (_execute), so the engine call signature lives in one place
        L = self.fabric.topo.n_links
        width = self.bucket[4] if self.bucket[0] == "ring" \
            else self.bucket[3]
        qt = np.full((L, 2, width), int(_BIG), np.int32)
        z = np.zeros((L, 2, width), np.int32)
        n_runs = self.n_runs
        res = self._execute(_Plan(
            E=0, C=width, max_steps=0, q_time=qt, q_dest=z, q_inj=z,
            sizes=np.zeros((L, 2), np.int32), bucket=self.bucket))
        jax.block_until_ready(res.drops)
        self.n_runs = n_runs  # the dummy run is not a user run
        self._warmed = True
        return self

    def _execute(self, plan: _Plan) -> FabricResult:
        fab = self.fabric
        E, L = plan.E, fab.topo.n_links
        mb = int(fab.queues.max_burst)
        if self.bucket[0] == "ring":
            _, Lp, _Np, Ep, C0, _Dp, _Cf, _chunk = self.bucket
            out = self._fn(
                jnp.asarray(_pad_to(plan.q_time, (Lp, 2, C0), int(_BIG))),
                jnp.asarray(_pad_to(plan.q_dest, (Lp, 2, C0), 0)),
                jnp.asarray(_pad_to(plan.q_inj, (Lp, 2, C0), 0)),
                jnp.asarray(_pad_to(plan.sizes, (Lp, 2), 0)),
                *self._tables,
                jnp.int32(plan.C), jnp.int32(E), jnp.int32(mb),
                jnp.int32(plan.max_steps))
            (log_n, log_inj, log_del, log_dest, sent, n_sw, t_link,
             drops) = out
            # trim the shape-bucket padding back to the real fabric
            log_inj, log_del, log_dest = (log_inj[:E], log_del[:E],
                                          log_dest[:E])
            sent, n_sw, t_link = sent[:L], n_sw[:L], t_link[:L]
            t_end = jnp.max(t_link)
        else:
            C = plan.C
            out = self._fn(jnp.asarray(plan.q_time).reshape(2 * L, C),
                           jnp.asarray(plan.q_dest).reshape(2 * L, C),
                           jnp.asarray(plan.q_inj).reshape(2 * L, C),
                           jnp.asarray(plan.sizes), *self._tables)
            (log_n, log_inj, log_del, log_dest, sent, n_sw, t_link, t_end,
             drops) = out
        self.n_runs += 1
        self._warmed = True  # first real run compiles the bucket too
        return FabricResult(
            delivered=log_n, injected=E,
            log_inj=log_inj, log_del=log_del, log_dest=log_dest,
            sent=sent, n_switches=n_sw,
            t_link=t_link, t_end=t_end, drops=drops)
