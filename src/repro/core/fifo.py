"""Bounded functional FIFO — the TX/RX FIFOs of Fig. 1, as a pytree.

A FIFO is a (buffer, head, count) triple manipulated by pure functions so it
can live inside ``lax.scan`` carries.  Overflow pushes are dropped and
reported (the hardware analogue: the 4-phase handshake would stall upstream;
the protocol simulator uses the reported flag to model back-pressure).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class Fifo(NamedTuple):
    buf: jnp.ndarray    # (capacity,) any dtype
    head: jnp.ndarray   # scalar int32 — index of oldest element
    count: jnp.ndarray  # scalar int32 — number of valid elements

    @property
    def capacity(self) -> int:
        return self.buf.shape[0]


def make_fifo(capacity: int, dtype=jnp.uint32) -> Fifo:
    return Fifo(
        buf=jnp.zeros((capacity,), dtype),
        head=jnp.zeros((), jnp.int32),
        count=jnp.zeros((), jnp.int32),
    )


def fifo_push(f: Fifo, value: jnp.ndarray, enable=True):
    """Push ``value`` if ``enable`` and not full.  Returns (fifo, ok)."""
    cap = f.capacity
    ok = jnp.logical_and(jnp.asarray(enable), f.count < cap)
    slot = (f.head + f.count) % cap
    newval = jnp.where(ok, jnp.asarray(value, f.buf.dtype), f.buf[slot])
    buf = f.buf.at[slot].set(newval)
    count = f.count + ok.astype(jnp.int32)
    return Fifo(buf, f.head, count), ok


def fifo_pop(f: Fifo, enable=True):
    """Pop oldest element if ``enable`` and non-empty.

    Returns (fifo, value, ok).  ``value`` is unspecified when not ok.
    """
    ok = jnp.logical_and(jnp.asarray(enable), f.count > 0)
    value = f.buf[f.head]
    head = jnp.where(ok, (f.head + 1) % f.capacity, f.head)
    count = f.count - ok.astype(jnp.int32)
    return Fifo(f.buf, head, count), value, ok


def fifo_peek(f: Fifo):
    """(value_at_head, non_empty)."""
    return f.buf[f.head], f.count > 0


def fifo_empty(f: Fifo):
    return f.count == 0


def fifo_full(f: Fifo):
    return f.count >= f.capacity
