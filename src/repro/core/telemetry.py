"""Per-link congestion telemetry: the fabric's measurement plane.

DYNAPs-scale systems (Moradi et al. 2017) and the core-interface
optimization line of work (Su et al. 2023) both locate the multi-core
throughput ceiling at *congestion on shared AER links*, not raw link
bandwidth.  Acting on congestion needs a measurement plane first: this
module defines the per-link counters every fabric engine accumulates
while it simulates, and the load summary the adaptive routing control
plane (:mod:`repro.core.adaptive`) feeds on.

Design constraints (and why the counters look the way they do):

* **Carry state, not shape state.**  Every counter is ordinary ``lax``
  carry alongside the queues and FSMs — shapes keyed on the link count
  already present in every engine's shape bucket — so telemetry adds
  ZERO compilation buckets: a fabric with telemetry compiles exactly as
  often as one without (asserted via ``cache_size()`` in the tests).
* **O(1)-compatible.**  The ring engine reads only stream *heads* per
  micro-transaction, so a counter may depend on "is there released work"
  (a head property) but never on "how many entries are released" (an
  O(C) scan).  ``busy_steps`` therefore counts *steps with backlog
  present*, the boolean integral both slot and ring engines compute
  identically.
* **Bit-exact across engines.**  The counters are part of the engines'
  equivalence contract (``network.assert_results_equal`` compares them
  field-for-field), so "reference", "ring" and "pallas" transports of
  one workload report the identical telemetry.

The counters:

``busy_ns (L,)``
    Nanoseconds each link's clock advanced *while transmitting* — the
    bus-driven time.  ``busy_ns / t_end`` is the link occupancy (a
    saturated link sits near 1.0).
``busy_steps (L, 2)``
    Micro-transactions during which the endpoint queue had released
    work pending (service backlog present) — the time-integral of
    queue pressure, per link direction.
``q_drops (L, 2)``
    Capacity drops charged to the *target* endpoint queue, weighted by
    the forfeited deliveries (an in-fabric multicast copy carries its
    whole subtree), so ``q_drops.sum() == FabricResult.drops`` exactly.
``stall_steps (L, 2)``
    Flow-control stalls: micro-transactions during which the endpoint
    queue had released work but was *gated* by a full (credit) or
    xoff'd (on/off) downstream queue.  Always zero in drop mode — the
    handshake never withholds an ack there.
``credit_waits (L, 2)``
    Number of distinct stall *episodes* (transitions into the stalled
    state) per endpoint queue — how often the sender had to park and
    wait for a credit return, as opposed to how long (``stall_steps``).

``LinkLoad`` is the per-link roll-up the routing policies consume.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

__all__ = ["Telemetry", "LinkLoad", "link_load", "link_load_batch",
           "merge_telemetry"]


class Telemetry(NamedTuple):
    """Per-link counters accumulated inside the engine scan (see module
    docstring for exact semantics).  All int32, trimmed to the real link
    count (shape-bucket padding removed)."""
    busy_ns: jnp.ndarray     # (L,)  ns the link spent transmitting
    busy_steps: jnp.ndarray  # (L, 2) steps with released backlog, per side
    q_drops: jnp.ndarray     # (L, 2) weighted drops per endpoint queue
    stall_steps: jnp.ndarray  # (L, 2) steps gated by flow control
    credit_waits: jnp.ndarray  # (L, 2) stall episodes (edges into stall)


def merge_telemetry(parts: list[Telemetry]) -> Telemetry:
    """Sum counters across sub-runs (the epoch merge: counters are
    extensive quantities, so a partitioned run's telemetry is the sum of
    its parts).  Generic over ``Telemetry._fields`` so a new counter can
    never be silently dropped from the merge."""
    return Telemetry(*(
        sum(np.asarray(getattr(p, f), np.int64) for p in parts)
        for f in Telemetry._fields))


class LinkLoad(NamedTuple):
    """Per-link load roll-up of one run — what a routing policy reads.

    ``traversals``    (L,) transmissions, both directions summed.
    ``occupancy``     (L,) fraction of the run's wall-clock (``t_end``)
                      the link bus was driven; ~1.0 = saturated.
    ``backlog_steps`` (L,) micro-transactions with released work waiting
                      behind either endpoint (queue-pressure integral).
    ``drops``         (L,) weighted capacity drops charged to the link's
                      endpoint queues.
    ``stalls``        (L,) flow-control stall steps charged to the
                      link's endpoint queues (zero in drop mode).
    """
    traversals: np.ndarray
    occupancy: np.ndarray
    backlog_steps: np.ndarray
    drops: np.ndarray
    stalls: np.ndarray

    def table(self, links: np.ndarray | None = None) -> str:
        """Human-readable per-link table (used by the examples)."""
        lines = [f"  {'link':<8}{'trav':>6}{'occ':>7}{'backlog':>9}"
                 f"{'drops':>7}{'stalls':>8}"]
        for l in range(len(self.traversals)):
            name = (f"{l}:{links[l][0]}-{links[l][1]}"
                    if links is not None else str(l))
            lines.append(f"  {name:<8}{int(self.traversals[l]):>6}"
                         f"{100.0 * self.occupancy[l]:>6.0f}%"
                         f"{int(self.backlog_steps[l]):>9}"
                         f"{int(self.drops[l]):>7}"
                         f"{int(self.stalls[l]):>8}")
        return "\n".join(lines)


def link_load(result) -> LinkLoad:
    """Roll one ``FabricResult``'s telemetry up to per-link loads.

    Requires ``result.telemetry`` (every engine attaches it); raises
    otherwise so a policy can never silently adapt on zeros.
    """
    tel = result.telemetry
    if tel is None:
        raise ValueError("FabricResult carries no telemetry (legacy "
                         "result?); adaptive policies need an engine run")
    traversals = np.asarray(result.sent, np.int64).sum(axis=1)
    # occupancy denominator: the run's ACTIVE span (first injection to
    # last clock), so an epoch slice whose events start late in absolute
    # time is not diluted by its offset from t = 0
    n = int(result.delivered)
    t0 = int(np.asarray(result.log_inj)[:n].min()) if n else 0
    span = max(int(result.t_end) - t0, 1)
    occupancy = np.asarray(tel.busy_ns, np.float64) / float(span)
    backlog = np.asarray(tel.busy_steps, np.int64).sum(axis=1)
    drops = np.asarray(tel.q_drops, np.int64).sum(axis=1)
    stalls = np.asarray(tel.stall_steps, np.int64).sum(axis=1)
    return LinkLoad(traversals=traversals, occupancy=occupancy,
                    backlog_steps=backlog, drops=drops, stalls=stalls)


def link_load_batch(batch) -> list[LinkLoad]:
    """Per-instance :class:`LinkLoad` roll-ups of one batched run.

    ``batch`` is a ``network.FabricBatchResult``: the engines accumulate
    the telemetry counters with a leading ``(B,)`` instance axis (one
    more vmapped carry dimension — still zero extra compilation
    buckets), and each instance's counters are bit-exact with its solo
    run, so the per-instance roll-up is just :func:`link_load` over the
    instance views.  Returns B loads in batch order — the Monte-Carlo
    congestion picture: the spread of per-link occupancy/backlog across
    seeds of one scenario.
    """
    return [link_load(batch.instance(i))
            for i in range(batch.n_instances)]
