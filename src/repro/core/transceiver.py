"""SW_Control FSM of the bi-directional AE transceiver block (paper §II–III).

Signal conventions (one block's point of view, matching Fig. 1 / Table I):

* ``sw_ack``  — the block's own state wire, driven out to the peer.
  Logic 1: "I need / hold the transmitter role" (events pending, or bus
  held).  Logic 0: "nothing to transmit — the bus may be yours".
* ``sw_req``  — the peer's ``sw_ack``, wired in (the two are swapped).
* ``mode``    — TX (1) or RX (0).  ``TX_EN = mode``, ``RX_EN = ~mode``; the
  paper generates these as complementary enables for the tri-state pads.
* ``rx_p``    — RX_Probe: has this block received ≥ 1 event since entering
  RX mode?  At global reset it is initialised to 1 for the block reset into
  RX mode ("except that this block is initially reset to RX mode for a
  chip-level global reset") and 0 for the TX block.
* ``tx_p``    — TX_Probe: are events pending in the TX FIFO?

Mode-switch guards (paper §II, verbatim):

  request RX→TX  (assert sw_ack ↑)  iff  mode == RX  ∧  rx_p == 1
                                        ∧  tx_pending > 0
  grant   TX→RX  (deassert sw_ack ↓) iff mode == TX  ∧  sw_req == 1
                                        ∧  tx_pending == 0

Mode resolution (Table I):

  (sw_ack, sw_req) = (1, 0) → TX        (request granted / steady TX)
  (sw_ack, sw_req) = (0, 1) → RX        (granted away / steady RX)
  (sw_ack, sw_req) = (1, 1) → hold      (switch pending: current TX holds)
  (sw_ack, sw_req) = (0, 0) → hold      (idle bus)

Beyond-paper extension: ``max_burst``.  The paper's grant rule only releases
the bus once the transmitter has fully drained, so two *saturated* sources
starve each other's reverse traffic (the paper's bidirectional measurement
alternates single events, sidestepping this).  ``max_burst = B`` makes a
transmitter voluntarily grant after B consecutive events whenever the peer
is requesting; ``B = 0`` disables the extension (paper-faithful).  The same
bounded-burst idea becomes the chunked bidirectional collective schedule in
``core/halfduplex.py``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

RX, TX = 0, 1


class XcvrState(NamedTuple):
    mode: jnp.ndarray    # int32: 0 = RX, 1 = TX
    sw_ack: jnp.ndarray  # int32: own state wire
    rx_p: jnp.ndarray    # int32: received >= 1 event since entering RX
    burst: jnp.ndarray   # int32: consecutive events sent in current TX tenure


def reset_state(initial_mode) -> XcvrState:
    """Chip-level global reset (PRst/SRst in Fig. 3).  ``initial_mode``
    may be a Python int or traced int32 scalar (vmap-friendly).

    Exactly one block of a linked pair must be reset into TX mode.  The RX
    block gets ``rx_p = 1`` (the paper's reset exemption) so it can claim the
    bus before ever receiving an event; the TX block starts with the bus.
    """
    mode = jnp.asarray(initial_mode, jnp.int32)
    return XcvrState(
        mode=mode,
        sw_ack=mode,                       # TX block holds the bus from reset
        rx_p=jnp.asarray(1 - initial_mode, jnp.int32),
        burst=jnp.zeros((), jnp.int32),
    )


class XcvrOut(NamedTuple):
    tx_en: jnp.ndarray
    rx_en: jnp.ndarray
    switched: jnp.ndarray  # 1 iff mode changed this step


def step(state: XcvrState,
         sw_req: jnp.ndarray,
         tx_pending: jnp.ndarray,
         rx_strobe: jnp.ndarray,
         max_burst: int = 0):
    """One FSM evaluation.

    Args:
      state:      current ``XcvrState``.
      sw_req:     the peer's ``sw_ack`` (already swapped, per Fig. 1).
      tx_pending: number of events in this block's TX FIFO (int).
      rx_strobe:  1 if an event was received by this block since last step.
      max_burst:  0 = paper-faithful; B > 0 = grant after B events if the
                  peer requests (fairness extension, see module docstring).

    Returns (new_state, XcvrOut).
    """
    sw_req = jnp.asarray(sw_req, jnp.int32)
    tx_pending = jnp.asarray(tx_pending, jnp.int32)
    rx_strobe = jnp.asarray(rx_strobe, jnp.int32)

    mode = state.mode
    tx_p = (tx_pending > 0).astype(jnp.int32)

    # RX_Probe latches on any receive while in RX mode.
    rx_p = jnp.where((mode == RX) & (rx_strobe == 1),
                     jnp.int32(1), state.rx_p)

    # --- request guard (Switch Controller NFET stack: TX_in_req·RX_EN·RX_P)
    want_request = (mode == RX) & (tx_p == 1) & (rx_p == 1)

    # --- grant guard (Switch Controller pFETs: SW_reqB + TX_P), plus the
    # bounded-burst fairness extension.  ``max_burst`` may be a Python int
    # or a traced int32 scalar (the fabric engines pass it dynamically so
    # every burst setting shares one compilation); B == 0 disables the
    # extension either way.
    mb = jnp.asarray(max_burst, jnp.int32)
    drained = (tx_p == 0) | ((mb > 0) & (state.burst >= mb))
    want_grant = (mode == TX) & (sw_req == 1) & drained

    sw_ack = jnp.where(mode == TX,
                       jnp.where(want_grant, jnp.int32(0), jnp.int32(1)),
                       jnp.where(want_request, jnp.int32(1), jnp.int32(0)))

    # --- Table I mode resolution
    new_mode = jnp.where((sw_ack == 1) & (sw_req == 0), jnp.int32(TX),
                jnp.where((sw_ack == 0) & (sw_req == 1), jnp.int32(RX),
                          mode))
    switched = (new_mode != mode).astype(jnp.int32)

    # Entering RX afresh clears the probe; burst counter clears on any switch.
    rx_p = jnp.where((switched == 1) & (new_mode == RX), jnp.int32(0), rx_p)
    burst = jnp.where(switched == 1, jnp.int32(0), state.burst)

    new_state = XcvrState(mode=new_mode, sw_ack=sw_ack, rx_p=rx_p, burst=burst)
    out = XcvrOut(
        tx_en=(new_mode == TX).astype(jnp.int32),
        rx_en=(new_mode == RX).astype(jnp.int32),
        switched=switched,
    )
    return new_state, out


def note_transmit(state: XcvrState) -> XcvrState:
    """Record one event sent in the current TX tenure (burst accounting)."""
    return state._replace(burst=state.burst + 1)
