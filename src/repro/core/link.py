"""Link timing / energy model — the measured contract of the fabricated block.

Constants are the chip measurements from paper §IV (28 nm FDSOI, 1 V):

  t_sw       ≈ 5 ns   direction-switch latency (TX/RX_EN flip)
  t_sw2req   ≈ 5 ns   switch-complete → first request asserted
  t_req2req  ≈ 31 ns  steady-state same-direction event cycle
                      → 1/31 ns = 32.3 MEvents/s (Fig. 7)
  t_bidir    ≈ 35 ns  per-event cycle when direction alternates every event
                      → 1/35 ns = 28.6 MEvents/s worst case (Fig. 8)
  e_event    ≈ 11 pJ  per delivered 26-bit event (excl. pad drivers)

The bidirectional cycle is NOT t_req2req + t_sw + t_sw2req (= 41 ns): the
grant/switch phases overlap the return-to-zero tail of the previous 4-phase
handshake.  We model the overlap explicitly: a reversal adds
``t_reverse_penalty = t_bidir - t_req2req = 4 ns`` on top of the steady
cycle, while a switch out of an *idle* bus pays the full, un-overlapped
t_sw + t_sw2req = 10 ns before the first request.

All times are integer nanoseconds so the discrete-event simulator is exact.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class LinkTiming:
    t_sw_ns: int = 5            # direction switch
    t_sw2req_ns: int = 5        # switch -> first request
    t_req2req_ns: int = 31      # same-direction event cycle
    t_bidir_ns: int = 35        # alternating-direction event cycle
    e_event_pj: float = 11.0    # energy per delivered event
    word_bits: int = 26         # parallel AER bus width

    @property
    def t_reverse_penalty_ns(self) -> int:
        """Extra cost of an event whose direction differs from the previous
        event on a busy bus (handshake-overlapped switch)."""
        return self.t_bidir_ns - self.t_req2req_ns

    @property
    def t_idle_switch_ns(self) -> int:
        """Cost of flipping an idle bus before the first request."""
        return self.t_sw_ns + self.t_sw2req_ns

    # --- derived figures of merit (Table II checks) ---------------------

    def onedir_throughput_mev_s(self) -> float:
        return 1e3 / self.t_req2req_ns  # events / us -> MEvents/s

    def bidir_throughput_mev_s(self) -> float:
        return 1e3 / self.t_bidir_ns

    def energy_nj(self, n_events: int) -> float:
        return self.e_event_pj * n_events * 1e-3

    def io_pins_saved(self, n_links: int = 4) -> int:
        """Pins saved vs. two unidirectional parallel buses per link.

        One link needs ``word_bits`` data + 2 handshake wires per direction;
        sharing the data bus saves ``word_bits`` pins per link (the SW wires
        replace one req/ack pair).  The paper reports 100 I/Os saved with
        transceivers on all four chip borders of a 180-I/O prototype.
        """
        return n_links * (self.word_bits - 1)  # 4*25 = 100, as measured

    # --- "sub-words" extension (paper §V conclusions) -------------------

    def subword(self, factor: int) -> "LinkTiming":
        """The paper's proposed combination with 'sub-words': serialize
        each ``word_bits`` event over ``factor`` bus beats of
        ``word_bits/factor`` wires.  Pins shrink by ~factor; the event
        cycle stretches by the extra beats (the matched-delay data phase
        repeats per beat while the 4-phase overhead is paid once), so
        throughput degrades sub-linearly — the paper's argument for why
        sub-words beat full bit-serial LVDS on latency.
        """
        assert self.word_bits % factor == 0, (self.word_bits, factor)
        # split the measured cycle into handshake overhead + data phase
        data_phase = 12  # ns of the 31 ns cycle that scales with beats
        overhead = self.t_req2req_ns - data_phase
        cyc = overhead + data_phase * factor
        return LinkTiming(
            t_sw_ns=self.t_sw_ns, t_sw2req_ns=self.t_sw2req_ns,
            t_req2req_ns=cyc,
            t_bidir_ns=cyc + self.t_reverse_penalty_ns,
            e_event_pj=self.e_event_pj,   # same charge moved, fewer wires
            word_bits=self.word_bits // factor)


PAPER_TIMING = LinkTiming()


@dataclass(frozen=True)
class TpuLink:
    """The target interconnect for the adapted technique (per-chip ICI)."""
    link_gb_s: float = 50.0      # per direction, per link
    hbm_gb_s: float = 819.0
    peak_bf16_tflops: float = 197.0
