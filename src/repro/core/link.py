"""Link timing / energy model — the measured contract of the fabricated block.

Constants are the chip measurements from paper §IV (28 nm FDSOI, 1 V):

  t_sw       ≈ 5 ns   direction-switch latency (TX/RX_EN flip)
  t_sw2req   ≈ 5 ns   switch-complete → first request asserted
  t_req2req  ≈ 31 ns  steady-state same-direction event cycle
                      → 1/31 ns = 32.3 MEvents/s (Fig. 7)
  t_bidir    ≈ 35 ns  per-event cycle when direction alternates every event
                      → 1/35 ns = 28.6 MEvents/s worst case (Fig. 8)
  e_event    ≈ 11 pJ  per delivered 26-bit event (excl. pad drivers)

The bidirectional cycle is NOT t_req2req + t_sw + t_sw2req (= 41 ns): the
grant/switch phases overlap the return-to-zero tail of the previous 4-phase
handshake.  We model the overlap explicitly: a reversal adds
``t_reverse_penalty = t_bidir - t_req2req = 4 ns`` on top of the steady
cycle, while a switch out of an *idle* bus pays the full, un-overlapped
t_sw + t_sw2req = 10 ns before the first request.

All times are integer nanoseconds so the discrete-event simulator is exact.

Per-link heterogeneity
----------------------
Real multi-chip AER systems mix link classes — fast parallel on-board
buses next to slow bit-serial LVDS inter-board links (Qiao & Indiveri
2019), hierarchical stages with different wire budgets (DYNAPs).  A
``LinkTiming`` therefore accepts *arrays* in every field: a
structure-of-arrays instance of shape ``(L,)`` gives link ``l`` the
timing contract ``timing[l]`` (see :func:`per_link_timing` /
:meth:`LinkTiming.for_links`).  A scalar instance means "every link
identical" — the fabric engines normalise both forms through
:func:`link_timing_arrays` and a uniform per-link array is bit-exactly
equivalent to the scalar it broadcasts.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class LinkTiming:
    t_sw_ns: int = 5            # direction switch
    t_sw2req_ns: int = 5        # switch -> first request
    t_req2req_ns: int = 31      # same-direction event cycle
    t_bidir_ns: int = 35        # alternating-direction event cycle
    e_event_pj: float = 11.0    # energy per delivered event
    word_bits: int = 26         # parallel AER bus width

    @property
    def t_reverse_penalty_ns(self) -> int:
        """Extra cost of an event whose direction differs from the previous
        event on a busy bus (handshake-overlapped switch)."""
        return self.t_bidir_ns - self.t_req2req_ns

    @property
    def t_idle_switch_ns(self) -> int:
        """Cost of flipping an idle bus before the first request."""
        return self.t_sw_ns + self.t_sw2req_ns

    # --- derived figures of merit (Table II checks) ---------------------

    def onedir_throughput_mev_s(self) -> float:
        return 1e3 / self.t_req2req_ns  # events / us -> MEvents/s

    def bidir_throughput_mev_s(self) -> float:
        return 1e3 / self.t_bidir_ns

    def energy_nj(self, n_events: int) -> float:
        return self.e_event_pj * n_events * 1e-3

    def io_pins_saved(self, n_links: int = 4) -> int:
        """Pins saved vs. two unidirectional parallel buses per link.

        One link needs ``word_bits`` data + 2 handshake wires per direction;
        sharing the data bus saves ``word_bits`` pins per link (the SW wires
        replace one req/ack pair).  The paper reports 100 I/Os saved with
        transceivers on all four chip borders of a 180-I/O prototype.
        """
        return n_links * (self.word_bits - 1)  # 4*25 = 100, as measured

    # --- "sub-words" extension (paper §V conclusions) -------------------

    def subword(self, factor: int) -> "LinkTiming":
        """The paper's proposed combination with 'sub-words': serialize
        each ``word_bits`` event over ``factor`` bus beats of
        ``word_bits/factor`` wires.  Pins shrink by ~factor; the event
        cycle stretches by the extra beats (the matched-delay data phase
        repeats per beat while the 4-phase overhead is paid once), so
        throughput degrades sub-linearly — the paper's argument for why
        sub-words beat full bit-serial LVDS on latency.
        """
        assert self.word_bits % factor == 0, (self.word_bits, factor)
        # split the measured cycle into handshake overhead + data phase
        data_phase = 12  # ns of the 31 ns cycle that scales with beats
        overhead = self.t_req2req_ns - data_phase
        cyc = overhead + data_phase * factor
        return LinkTiming(
            t_sw_ns=self.t_sw_ns, t_sw2req_ns=self.t_sw2req_ns,
            t_req2req_ns=cyc,
            t_bidir_ns=cyc + self.t_reverse_penalty_ns,
            e_event_pj=self.e_event_pj,   # same charge moved, fewer wires
            word_bits=self.word_bits // factor)

    # --- per-link heterogeneity ----------------------------------------

    @property
    def is_scalar(self) -> bool:
        """True when every field is a plain scalar (one shared contract)."""
        return all(np.ndim(getattr(self, f)) == 0 for f in _TIMING_FIELDS)

    def for_links(self, n_links: int) -> "LinkTiming":
        """Broadcast to an explicit structure-of-arrays of shape (L,)."""
        return LinkTiming(**{
            f: np.broadcast_to(np.asarray(getattr(self, f)),
                               (n_links,)).copy()
            for f in _TIMING_FIELDS})


_TIMING_FIELDS = ("t_sw_ns", "t_sw2req_ns", "t_req2req_ns", "t_bidir_ns",
                  "e_event_pj", "word_bits")


def per_link_timing(classes, assignment) -> LinkTiming:
    """Compose link classes into one structure-of-arrays ``LinkTiming``.

    ``classes`` is a sequence of scalar ``LinkTiming`` contracts (e.g. the
    paper's parallel bus next to a bit-serial LVDS class built with
    ``subword``); ``assignment[l]`` names the class of link ``l``.
    """
    idx = np.asarray(assignment, np.int64)
    if idx.ndim != 1:
        raise ValueError(f"assignment must be 1-D, got shape {idx.shape}")
    if idx.size and (idx.min() < 0 or idx.max() >= len(classes)):
        raise ValueError(f"assignment indexes {len(classes)} classes "
                         f"out of range: {idx.min()}..{idx.max()}")
    for c in classes:
        if not c.is_scalar:
            raise ValueError("per_link_timing classes must be scalar "
                             "LinkTiming instances")
    return LinkTiming(**{
        f: np.asarray([getattr(c, f) for c in classes])[idx]
        for f in _TIMING_FIELDS})


def link_timing_arrays(timing: LinkTiming, n_links: int):
    """Normalise scalar-or-per-link timing to the engine's (L,) vectors.

    Returns ``(t_cycle, t_rev, t_idle_sw)`` int32 arrays of shape (L,) —
    the three costs ``protocol_sim.link_step`` charges — after validating
    shape and the timing contract's invariants.  A scalar ``timing``
    broadcasts; the engines consume only these vectors, so the uniform
    broadcast is bit-exactly the scalar contract.
    """
    def vec(x, name):
        a = np.asarray(x)
        if a.ndim not in (0, 1) or (a.ndim == 1 and a.shape[0] != n_links):
            raise ValueError(f"per-link {name} must be scalar or shape "
                             f"({n_links},), got {a.shape}")
        return np.broadcast_to(a, (n_links,)).astype(np.int64)

    cyc = vec(timing.t_req2req_ns, "t_req2req_ns")
    bidir = vec(timing.t_bidir_ns, "t_bidir_ns")
    idle = vec(timing.t_sw_ns, "t_sw_ns") + vec(timing.t_sw2req_ns,
                                                "t_sw2req_ns")
    if np.any(cyc <= 0):
        raise ValueError("t_req2req_ns must be positive on every link")
    if np.any(bidir < cyc):
        raise ValueError("t_bidir_ns must be >= t_req2req_ns on every link")
    if np.any(idle < 0):
        raise ValueError("idle-switch latency must be >= 0 on every link")
    # the simulator's clocks are int32 ns with the BIG_NS = 2**30 "never
    # released" sentinel; costs at or above it would truncate/wrap after
    # the int32 cast and corrupt silently — refuse them while still on
    # int64 (validated BEFORE the cast)
    big = 1 << 30
    if np.any(bidir >= big) or np.any(idle >= big):
        raise ValueError(
            "per-link timing costs must stay below the int32 BIG_NS "
            f"sentinel ({big} ns); got max cycle {int(bidir.max())} ns, "
            f"max idle switch {int(idle.max())} ns")
    return (cyc.astype(np.int32), (bidir - cyc).astype(np.int32),
            idle.astype(np.int32))


PAPER_TIMING = LinkTiming()

#: The paper §V "sub-words" contract taken to bit-serial (26 beats of one
#: wire): the LVDS-like slow inter-board link class the heterogeneity
#: example and benchmarks mix with the on-board parallel bus.
SERIAL_LVDS_TIMING = PAPER_TIMING.subword(26)


@dataclass(frozen=True)
class TpuLink:
    """The target interconnect for the adapted technique (per-chip ICI)."""
    link_gb_s: float = 50.0      # per direction, per link
    hbm_gb_s: float = 819.0
    peak_bf16_tflops: float = 197.0
