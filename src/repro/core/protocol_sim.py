"""Discrete-event simulator of two linked AE transceiver blocks (Figs. 1–2).

Two blocks, L and R, share one parallel AER bus.  Their ``sw_ack`` wires are
swapped into each other's ``sw_req`` (Fig. 1).  Event *arrival processes*
(what the neuromorphic cores behind each block produce) are given as sorted
integer-nanosecond timestamp arrays; the simulator runs the SW_Control FSM
of both blocks and the measured link-timing contract (``link.LinkTiming``)
to produce exact event-departure times, mode-switch traces (Figs. 7–8), and
aggregate throughput / energy (Table II).

One ``lax.scan`` step is one *micro-transaction*: a simultaneous FSM
evaluation of both blocks followed by at most one bus action —

  TRANSMIT   the TX-mode block ships the oldest pending event;  the clock
             advances by t_req2req, plus t_reverse_penalty when the bus
             direction differs from the previous transmission in a busy
             stream, plus t_idle_switch when the bus had gone idle and the
             direction flipped while parked.
  HANDSHAKE  FSM wires settle (sw_ack/sw_req edges of Table I); no clock
             advance — its cost is exactly the reversal/idle penalty folded
             into the next TRANSMIT, matching how the paper measures t_sw
             *overlapped* with the 4-phase return-to-zero.
  IDLE       nothing pending anywhere: clock jumps to the next arrival.

The simulation is exact in integer nanoseconds and fully jittable.

The micro-transaction itself lives in ``link_step`` / ``LinkState`` — a
self-contained, ``jax.vmap``-able unit.  ``simulate`` wraps exactly one
such unit with static sorted-arrival pending counts; ``network.py`` maps
the same unit across every link of an N-chip fabric with queue-fed pending
counts, so the degenerate 2-chip fabric reproduces ``simulate`` bit-exactly
by construction.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .link import LinkTiming, PAPER_TIMING
from .transceiver import RX, TX, XcvrState, reset_state, step as fsm_step

# Trace action codes
A_IDLE, A_HANDSHAKE, A_TX_L, A_TX_R = 0, 1, 2, 3

# A plain Python int (NOT a jnp scalar): jnp scalars created at import
# time become captured constants inside any Pallas kernel body that
# closes over this module (rejected by pallas_call); a Python int stays
# a literal in every trace and promotes to int32 identically.
_BIG = 2**30
BIG_NS = _BIG  # exported: "no further arrival" sentinel for link_step


class LinkState(NamedTuple):
    """Carry of one bi-directional link: both FSMs plus the bus bookkeeping.

    This is the reusable LinkSim unit.  All leaves are scalar int32 (or
    scalar-leaved ``XcvrState``), so a fabric of L links is simply a
    ``LinkState`` with ``(L,)``-shaped leaves driven through
    ``jax.vmap(link_step)``.
    """
    t: jnp.ndarray          # int32 ns — link-local clock
    xl: XcvrState
    xr: XcvrState
    last_dir: jnp.ndarray   # direction of previous transmission (1 = L->R)
    bus_busy: jnp.ndarray   # 1 if previous step transmitted (stream alive)
    prev_tx_l: jnp.ndarray  # did L transmit last step (rx_strobe for R)
    prev_tx_r: jnp.ndarray


class LinkStepOut(NamedTuple):
    action: jnp.ndarray   # A_IDLE / A_HANDSHAKE / A_TX_L / A_TX_R
    tx_l: jnp.ndarray     # int32: 1 iff L shipped an event this step
    tx_r: jnp.ndarray     # int32: 1 iff R shipped an event this step


def reset_link(initial_tx=1) -> LinkState:
    """Chip-level global reset of one link pair (PRst/SRst in Fig. 3).

    ``initial_tx`` may be a Python int or a traced int32 scalar, so a
    fabric resets L links with ``jax.vmap(reset_link)`` — one source of
    truth for the reset semantics.
    """
    m = jnp.asarray(initial_tx, jnp.int32)
    return LinkState(
        t=jnp.zeros((), jnp.int32),
        xl=reset_state(m),
        xr=reset_state(1 - m),
        last_dir=m,
        bus_busy=jnp.zeros((), jnp.int32),
        prev_tx_l=jnp.zeros((), jnp.int32),
        prev_tx_r=jnp.zeros((), jnp.int32),
    )


def link_step(s: LinkState,
              pend_l: jnp.ndarray,
              pend_r: jnp.ndarray,
              t_next_arr: jnp.ndarray,
              *,
              timing: LinkTiming = PAPER_TIMING,
              max_burst: int = 0,
              t_cycle_ns=None,
              t_rev_ns=None,
              t_idle_sw_ns=None):
    """One micro-transaction of one link: FSM settling + at most one bus act.

    Args:
      s:          current ``LinkState``.
      pend_l/r:   events currently pending behind each block (``s.t``-gated;
                  the caller owns arrival bookkeeping).
      t_next_arr: earliest future arrival on either side, or ``BIG_NS`` when
                  none is scheduled — an idle link parks its clock instead
                  of jumping.
      timing:     link timing contract (static; closed over under vmap).
      max_burst:  0 = paper-faithful grant rule; B > 0 = bounded-burst.
      t_cycle_ns / t_rev_ns / t_idle_sw_ns: optional *dynamic* overrides of
                  the three costs ``timing`` would supply statically — the
                  per-link-heterogeneity path.  The fabric engines vmap
                  these as (L,) vectors so one compilation serves every
                  timing assignment; a uniform override is bit-exactly the
                  static contract (identical int32 arithmetic).

    Returns ``(new_state, LinkStepOut)``.
    """
    t_cycle = jnp.int32(timing.t_req2req_ns if t_cycle_ns is None
                        else t_cycle_ns)
    t_rev = jnp.int32(timing.t_reverse_penalty_ns if t_rev_ns is None
                      else t_rev_ns)
    t_idle_sw = jnp.int32(timing.t_idle_switch_ns if t_idle_sw_ns is None
                          else t_idle_sw_ns)

    # --- FSM evaluation with wire settling ------------------------------
    # The SW_req/SW_ack wires propagate in O(gate delay), far inside the
    # 31 ns event cycle, so within one micro-transaction the pair of FSMs
    # settles to a fixed point.  Two iterations suffice (one edge can
    # trigger at most one response edge); receive strobes are edges and
    # feed only the first iteration.
    xl, _ = fsm_step(s.xl, sw_req=s.xr.sw_ack, tx_pending=pend_l,
                     rx_strobe=s.prev_tx_r, max_burst=max_burst)
    xr, _ = fsm_step(s.xr, sw_req=s.xl.sw_ack, tx_pending=pend_r,
                     rx_strobe=s.prev_tx_l, max_burst=max_burst)
    xl2, _ = fsm_step(xl, sw_req=xr.sw_ack, tx_pending=pend_l,
                      rx_strobe=0, max_burst=max_burst)
    xr2, _ = fsm_step(xr, sw_req=xl.sw_ack, tx_pending=pend_r,
                      rx_strobe=0, max_burst=max_burst)
    xl, xr = xl2, xr2

    tx_l = (xl.mode == TX) & (xr.mode == RX) & (pend_l > 0)
    tx_r = (xr.mode == TX) & (xl.mode == RX) & (pend_r > 0)
    # exactly one side can transmit; prefer the (unique) TX-mode holder
    do_tx = tx_l | tx_r
    dir_now = jnp.where(tx_l, jnp.int32(1), jnp.int32(0))

    reversal = (dir_now != s.last_dir)
    cost = t_cycle \
        + jnp.where(reversal & (s.bus_busy == 1), t_rev, 0) \
        + jnp.where(reversal & (s.bus_busy == 0), t_idle_sw, 0)

    # handshake still settling? (any ack/mode changed or a grant pending)
    settling = (xl.sw_ack != s.xl.sw_ack) | (xr.sw_ack != s.xr.sw_ack) \
        | (xl.mode != s.xl.mode) | (xr.mode != s.xr.mode)

    # idle: nothing pending and nothing to settle -> jump the clock to the
    # next scheduled arrival; with none scheduled (t_next_arr == BIG_NS)
    # the clock parks, so a fabric link can be woken by a later forward.
    idle = (~do_tx) & (~settling)
    new_t = jnp.where(do_tx, s.t + cost,
             jnp.where(idle & (t_next_arr < _BIG), t_next_arr, s.t))

    # burst accounting for the fairness extension
    xl = xl._replace(burst=jnp.where(tx_l, xl.burst + 1, xl.burst))
    xr = xr._replace(burst=jnp.where(tx_r, xr.burst + 1, xr.burst))

    action = jnp.where(tx_l, jnp.int32(A_TX_L),
              jnp.where(tx_r, jnp.int32(A_TX_R),
               jnp.where(settling, jnp.int32(A_HANDSHAKE),
                         jnp.int32(A_IDLE))))

    # bus_busy = "a transmission stream is alive": it survives the
    # zero-time handshake micro-steps and clears only on a true idle,
    # so a reversal inside a busy stream costs t_reverse_penalty (the
    # overlapped switch) and not the full idle-switch latency.
    bus_busy = jnp.where(do_tx, jnp.int32(1),
                         jnp.where(idle, jnp.int32(0), s.bus_busy))
    ns = LinkState(
        t=new_t, xl=xl, xr=xr,
        last_dir=jnp.where(do_tx, dir_now, s.last_dir),
        bus_busy=bus_busy,
        prev_tx_l=(do_tx & tx_l).astype(jnp.int32),
        prev_tx_r=(do_tx & tx_r).astype(jnp.int32),
    )
    out = LinkStepOut(action=action,
                      tx_l=(do_tx & tx_l).astype(jnp.int32),
                      tx_r=(do_tx & tx_r).astype(jnp.int32))
    return ns, out


def link_step_batch(state: LinkState,
                    pend_l: jnp.ndarray,
                    pend_r: jnp.ndarray,
                    t_next_arr: jnp.ndarray,
                    *,
                    timing: LinkTiming = PAPER_TIMING,
                    max_burst: int = 0,
                    timing_arrays=None):
    """One micro-transaction on a whole batch of links at once.

    ``state`` is a ``LinkState`` with ``(L,)``-shaped leaves (see
    ``network.reset_links``); ``pend_l`` / ``pend_r`` / ``t_next_arr`` are
    ``(L,)`` int32.  This is the chunk-steppable LinkSim unit the fabric
    engines drive: a chunk of ``k`` fabric micro-transactions is ``k``
    calls of this function inside one ``lax.scan``, so callers can wrap it
    in ``lax.while_loop`` and stop as soon as their own termination
    condition (e.g. "all events delivered") holds instead of padding to a
    worst-case step count.

    ``timing_arrays`` — an optional ``(t_cycle, t_rev, t_idle_sw)`` triple
    of (L,) int32 vectors (see ``link.link_timing_arrays``) — switches the
    batch to per-link heterogeneous timing: link ``l`` pays link ``l``'s
    costs, and the vectors travel as *dynamic* operands, so one
    compilation serves every timing assignment.  When omitted, the static
    ``timing`` contract applies to every link, exactly as before.

    Returns ``(new_state, LinkStepOut)`` with ``(L,)``-shaped leaves.
    """
    if timing_arrays is None:
        step = jax.vmap(
            lambda s, pl, pr, na: link_step(s, pl, pr, na, timing=timing,
                                            max_burst=max_burst))
        return step(state, pend_l, pend_r, t_next_arr)
    t_cycle, t_rev, t_idle_sw = timing_arrays
    step = jax.vmap(
        lambda s, pl, pr, na, tc, tv, ti: link_step(
            s, pl, pr, na, timing=timing, max_burst=max_burst,
            t_cycle_ns=tc, t_rev_ns=tv, t_idle_sw_ns=ti))
    return step(state, pend_l, pend_r, t_next_arr,
                jnp.asarray(t_cycle, jnp.int32),
                jnp.asarray(t_rev, jnp.int32),
                jnp.asarray(t_idle_sw, jnp.int32))


class SimState(NamedTuple):
    link: LinkState
    sent_l: jnp.ndarray     # events shipped L->R
    sent_r: jnp.ndarray     # events shipped R->L


class SimTrace(NamedTuple):
    t: jnp.ndarray        # (steps,) time after the step
    action: jnp.ndarray   # (steps,) action code
    mode_l: jnp.ndarray
    mode_r: jnp.ndarray
    sw_ack_l: jnp.ndarray
    sw_ack_r: jnp.ndarray


class SimResult(NamedTuple):
    trace: SimTrace
    sent_l: jnp.ndarray
    sent_r: jnp.ndarray
    t_end: jnp.ndarray
    n_switches: jnp.ndarray


def _pending(arrivals: jnp.ndarray, t: jnp.ndarray, sent: jnp.ndarray):
    arrived = jnp.searchsorted(arrivals, t, side="right").astype(jnp.int32)
    return arrived - sent


def _next_arrival(arrivals: jnp.ndarray, t: jnp.ndarray):
    n = arrivals.shape[0]
    if n == 0:
        return _BIG
    i = jnp.searchsorted(arrivals, t, side="right")
    return jnp.where(i < n, arrivals[jnp.minimum(i, n - 1)], _BIG)


def simulate(arr_l: jnp.ndarray,
             arr_r: jnp.ndarray,
             *,
             timing: LinkTiming = PAPER_TIMING,
             initial_tx: int = 1,          # 1 → left starts as transmitter
             max_burst: int = 0,
             max_steps: int | None = None) -> SimResult:
    """Run the two-block simulation until all events deliver (or steps end).

    Args:
      arr_l / arr_r: sorted int32 ns arrival timestamps on each side.
      timing:        link timing contract (defaults to chip measurements).
      initial_tx:    1 → L reset into TX / R into RX; 0 → the converse.
      max_burst:     0 = paper-faithful grant rule; B > 0 = bounded-burst
                     fairness extension (see ``transceiver``).
      max_steps:     scan length; default 3·(n_l+n_r)+16.
    """
    arr_l = jnp.asarray(arr_l, jnp.int32)
    arr_r = jnp.asarray(arr_r, jnp.int32)
    n_l, n_r = arr_l.shape[0], arr_r.shape[0]
    if max_steps is None:
        max_steps = 3 * (n_l + n_r) + 16

    init = SimState(
        link=reset_link(initial_tx),
        sent_l=jnp.zeros((), jnp.int32),
        sent_r=jnp.zeros((), jnp.int32),
    )

    def body(s: SimState, _):
        t = s.link.t
        pend_l = _pending(arr_l, t, s.sent_l)
        pend_r = _pending(arr_r, t, s.sent_r)
        t_next_arr = jnp.minimum(_next_arrival(arr_l, t),
                                 _next_arrival(arr_r, t))
        link, out = link_step(s.link, pend_l, pend_r, t_next_arr,
                              timing=timing, max_burst=max_burst)
        ns = SimState(link=link,
                      sent_l=s.sent_l + out.tx_l,
                      sent_r=s.sent_r + out.tx_r)
        rec = (link.t, out.action, link.xl.mode, link.xr.mode,
               link.xl.sw_ack, link.xr.sw_ack)
        return ns, rec

    final, recs = jax.lax.scan(body, init, None, length=max_steps)
    trace = SimTrace(*recs)
    n_switches = jnp.sum(
        (trace.mode_l[1:] != trace.mode_l[:-1]).astype(jnp.int32))
    return SimResult(trace=trace, sent_l=final.sent_l, sent_r=final.sent_r,
                     t_end=final.link.t, n_switches=n_switches)


# -----------------------------------------------------------------------
# Measurement helpers (what benchmarks/bench_fig7/8 + Table II read out)
# -----------------------------------------------------------------------

def throughput_mev_s(res: SimResult) -> jnp.ndarray:
    """Delivered events per second, in MEvents/s."""
    n = res.sent_l + res.sent_r
    return jnp.where(res.t_end > 0, 1e3 * n / res.t_end, 0.0)


def energy_pj(res: SimResult, timing: LinkTiming = PAPER_TIMING):
    return (res.sent_l + res.sent_r) * timing.e_event_pj


def saturated_onedir(n_events: int = 4096, **kw) -> SimResult:
    """Fig. 7 condition: a saturated stream in one direction (plus the
    initial direction reversal the paper's trace starts with)."""
    arr_l = jnp.zeros((n_events,), jnp.int32)
    arr_r = jnp.zeros((0,), jnp.int32)
    return simulate(arr_l, arr_r, initial_tx=0, **kw)  # starts as RX -> must switch


def alternating_bidir(n_events_per_side: int = 2048, **kw) -> SimResult:
    """Fig. 8 worst case: every event reverses the bus (ping-pong load)."""
    # Saturate both sides but let the bounded-burst fairness grant after
    # every event — the measurement condition of the paper's Fig. 8.
    arr_l = jnp.zeros((n_events_per_side,), jnp.int32)
    arr_r = jnp.zeros((n_events_per_side,), jnp.int32)
    kw.setdefault("max_burst", 1)
    return simulate(arr_l, arr_r, initial_tx=1, **kw)
