"""Core: the paper's contribution — bi-directional AE transceiver protocol,
its timing/energy contract, the N-chip fabric built from it (routing,
traffic, network), and the TPU-scale adaptations (event-sparse collectives
+ half-duplex link scheduling)."""

from . import (events, fifo, link, network, protocol_sim, router,  # noqa: F401
               traffic, transceiver)
