"""Core: the paper's contribution — bi-directional AE transceiver protocol,
its timing/energy contract, the N-chip fabric built from it (routing,
traffic, network), and the TPU-scale adaptations (event-sparse collectives
+ half-duplex link scheduling)."""

from . import (events, fabric, fifo, link, network,  # noqa: F401
               protocol_sim, router, traffic, transceiver)
