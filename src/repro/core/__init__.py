"""Core: the paper's contribution — bi-directional AE transceiver protocol,
its timing/energy contract, the N-chip fabric built from it (routing,
traffic, network), the congestion control plane on top (telemetry +
epoch-based adaptive routing), and the TPU-scale adaptations
(event-sparse collectives + half-duplex link scheduling)."""

from . import (adaptive, events, fabric, fifo, link, network,  # noqa: F401
               protocol_sim, router, telemetry, traffic, transceiver)
