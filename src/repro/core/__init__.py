"""Core: the paper's contribution — bi-directional AE transceiver protocol,
its timing/energy contract, and the TPU-scale adaptations (event-sparse
collectives + half-duplex link scheduling)."""

from . import events, fifo, link, protocol_sim, transceiver  # noqa: F401
