"""AdamW with global-norm clipping, fp32 moments (distributed-safe: pure
pytree math — sharding follows the parameter shardings)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    def zeros(t):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state, grad_norm)."""
    if grad_clip and grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm


def warmup_cosine(step, *, base_lr, warmup_steps, total_steps,
                  final_frac=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
    t = jnp.clip((step - warmup_steps) /
                 jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, base_lr * cos)
