"""Optimizers + schedules."""
from . import adamw  # noqa: F401
