"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state; the 512-device dry-run forces the host platform
device count before first jax init, see dryrun.py)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ("pod", "data", "model") multi-pod / ("data", "model") single-pod.
    DP spans pod×data; TP/EP/SP span model.  More pods widen only the pure-
    DP outer axis — the design scales by adding pods, not by resharding.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
