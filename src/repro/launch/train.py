"""Training entry point.

CPU-scale (smoke configs) it actually trains; on a TPU fleet the same
driver runs under the production mesh.  Wires together: model, synthetic
data, AdamW, selectable DP-reduction schedule (the paper technique),
checkpoint/restart, failure injection drills, straggler monitoring.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --smoke \
      --steps 100 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --smoke \
      --steps 50 --dp-reduce bidir_ring --mesh-data 4   # 4-way manual DP
  PYTHONPATH=src python -m repro.launch.train --arch jamba_v01_52b --smoke \
      --steps 30 --fail-at 11,23 --checkpoint-every 10  # restart drill
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import Checkpointer
from ..configs.base import RunConfig, get_config, get_smoke_config
from ..data import SyntheticLM
from ..models.model import build_model, param_count
from ..parallel.sharding import make_rules
from ..runtime.fault import (FailureInjector, StragglerMonitor,
                             run_with_restarts)
from ..runtime.train_loop import init_state, make_train_step
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dp-reduce", default="psum",
                    choices=["psum", "ring", "bidir_ring", "aer_topk"])
    ap.add_argument("--aer-frac", type=float, default=0.05)
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="manual DP over N host devices (0 = single device)")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--fail-at", default="",
                    help="comma-separated steps for injected failures")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run_cfg = RunConfig(dp_reduce=args.dp_reduce, learning_rate=args.lr,
                        warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps, aer_frac=args.aer_frac,
                        checkpoint_every=args.checkpoint_every, fsdp=False)
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed,
                       modality=cfg.modality, d_frontend=cfg.d_frontend,
                       n_img_tokens=cfg.n_img_tokens)

    rules = None
    if args.mesh_data > 1:
        mesh = make_host_mesh(data=args.mesh_data, model=1)
        rules = make_rules(mesh, fsdp=False, kv_heads=cfg.n_kv_heads,
                           d_head=cfg.d_head)
        print(f"mesh: {dict(mesh.shape)} dp_reduce={args.dp_reduce}")

    state = init_state(model, jax.random.PRNGKey(args.seed), run_cfg)
    print(f"{cfg.name}: {param_count(state.params):,} params, "
          f"{args.steps} steps, reduce={args.dp_reduce}")
    step_fn = make_train_step(model, run_cfg, rules)

    ckpt = Checkpointer(args.checkpoint_dir, keep=3)
    injector = FailureInjector(frozenset(
        int(s) for s in args.fail_at.split(",") if s)) if args.fail_at \
        else None
    monitor = StragglerMonitor()

    class JaxData:
        def batch(self, s):
            return {k: jnp.asarray(v) for k, v in data.batch(s).items()}

    t0 = time.time()
    state, info = run_with_restarts(
        n_steps=args.steps, state=state, train_step=step_fn, data=JaxData(),
        ckpt=ckpt, checkpoint_every=args.checkpoint_every,
        injector=injector, monitor=monitor, log_every=args.log_every)
    dt = time.time() - t0
    print(f"done in {dt:.1f}s — restarts={info['restarts']} "
          f"stragglers={len(info['straggler_events'])}")
    return state


if __name__ == "__main__":
    main()
