"""Loop-aware cost analysis over post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
model built on ``lax.scan`` (every stack here) is undercounted by the layer
count.  This module re-derives the three roofline inputs from the HLO text
with loop trip-count multiplication:

  flops            — 2·M·N·K for every dot (+ convolutions), the matmul-
                     roofline convention (elementwise flops are noise for
                     these models);
  bytes_accessed   — fusion-boundary traffic: every top-level op counts its
                     operands + results once per execution (XLA's own
                     fusion-boundary memory model), × loop trip counts;
  collective bytes — per collective kind, result-shape bytes × trip counts
                     (per-device traffic proxy).

Trip counts come from the ``backend_config={"known_trip_count":{"n":...}}``
annotation XLA attaches to compiled while loops; loops without one count
once (reported in ``unknown_loops``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s+(?:ROOT )?(%[\w.\-]+) = ")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\{\s*$")


def _split_def(line: str):
    """'  %x = TYPE opcode(args...' -> (name, type_str, opcode, args_rest)
    robust to tuple types with /*index=N*/ comments and layouts."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rest[:i + 1]
        rest = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        rest = rest[sp + 1:]
    op_end = rest.find("(")
    if op_end <= 0:
        return None
    opcode = rest[:op_end]
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return name, type_str, opcode, rest[op_end + 1:]
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_DIMS_RE = re.compile(r"lhs_contracting_dims={([\d,]*)}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "while", "call", "conditional", "bitcast", "fusion-skip"}


def _shape_elems_bytes(type_str: str):
    elems, bts = 0, 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: list = field(default_factory=list)


def parse_hlo(text: str):
    """-> (computations: name -> [Op], shapes: op name -> type_str)."""
    comps, shapes = {}, {}
    cur = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = mc.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        md = _split_def(line) if cur is not None else None
        if md:
            name, type_str, opcode, inner = md
            depth, args = 1, ""
            for ch in inner:
                if ch == "(":
                    depth += 1
                if ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                args += ch
            operands = _OPERAND_RE.findall(args)
            op = Op(name=name, type_str=type_str, opcode=opcode, line=line,
                    operands=operands)
            comps[cur].append(op)
            shapes[name] = type_str
    return comps, shapes


def _dot_flops(op: Op, shapes: dict) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    m = _DIMS_RE.search(op.line)
    k = 1
    if m and op.operands:
        lhs_type = shapes.get(op.operands[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci:
                    i = int(ci)
                    if i < len(dims):
                        k *= dims[i]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, shapes: dict) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    rhs_type = shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
    rhs_elems, _ = _shape_elems_bytes(rhs_type)
    # per output element: 2 * (kernel elems / output channels); output
    # channel count ~ last minor dim of out — use feature_group heuristic:
    fg = 1
    mg = re.search(r"feature_group_count=(\d+)", op.line)
    if mg:
        fg = int(mg.group(1))
    return 2.0 * out_elems * max(rhs_elems / max(fg, 1), 1)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)
    unknown_loops: int = 0

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0) + v
        self.unknown_loops += o.unknown_loops
        return self

    def scaled(self, n):
        return Cost(self.flops * n, self.bytes * n,
                    {k: v * n for k, v in self.coll.items()},
                    self.unknown_loops)


def _sliced_params(comp_name, comps, shapes, cache):
    """Parameter indices of ``comp_name`` that are only read via
    dynamic-slice / gather inside the fused computation — XLA charges the
    slice size, not the full buffer (scan weight stacks!).  Returns
    {param_index: charged_bytes}."""
    if comp_name in cache:
        return cache[comp_name]
    ops = comps.get(comp_name, [])
    param_idx = {}      # op name -> parameter index
    for op in ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.line)
            if m:
                param_idx[op.name] = int(m.group(1))
    reads = {}          # param name -> list of (opcode, result bytes)
    for op in ops:
        for o in op.operands:
            if o in param_idx:
                _, rb = _shape_elems_bytes(op.type_str)
                reads.setdefault(o, []).append((op.opcode, rb))
    out = {}
    for pname, uses in reads.items():
        if uses and all(u[0] in ("dynamic-slice", "gather") for u in uses):
            out[param_idx[pname]] = sum(u[1] for u in uses)
    cache[comp_name] = out
    return out


def _comp_cost(name, comps, shapes, memo, inside_fusion=False):
    key = (name, inside_fusion)
    if key in memo:
        return memo[key]
    total = Cost()
    for op in comps.get(name, []):
        total += _op_cost(op, comps, shapes, memo, inside_fusion)
    memo[key] = total
    return total


def _op_cost(op: Op, comps, shapes, memo, inside_fusion):
    c = Cost()
    oc = op.opcode
    if oc == "dot":
        c.flops += _dot_flops(op, shapes)
    elif oc == "convolution":
        c.flops += _conv_flops(op, shapes)
    elif oc == "fusion":
        m = _CALLS_RE.search(op.line)
        if m:
            sub = _comp_cost(m.group(1), comps, shapes, memo,
                             inside_fusion=True)
            c.flops += sub.flops          # dots inside fusions still count
            for k, v in sub.coll.items():
                c.coll[k] = c.coll.get(k, 0) + v
    elif oc == "while":
        mb, mc_ = _BODY_RE.search(op.line), _COND_RE.search(op.line)
        mt = _TRIP_RE.search(op.line)
        n = int(mt.group(1)) if mt else 1
        if not mt:
            c.unknown_loops += 1
        if mb:
            c += _comp_cost(mb.group(1), comps, shapes, memo).scaled(n)
        if mc_:
            c += _comp_cost(mc_.group(1), comps, shapes, memo).scaled(n + 1)
    elif oc in ("call", "async-start"):
        m = _CALLS_RE.search(op.line) or re.search(
            r"to_apply=(%[\w.\-]+)", op.line)
        if m:
            c += _comp_cost(m.group(1), comps, shapes, memo)
    elif oc == "conditional":
        for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                             r"(?:true|false)_computation=(%[\w.\-]+))",
                             op.line):
            names = (m.group(1) or m.group(2) or "").split(",")
            for nm in names:
                nm = nm.strip()
                if nm:
                    c += _comp_cost(nm, comps, shapes, memo)

    base = oc.replace("-start", "")
    if base in COLLECTIVES:
        _, b = _shape_elems_bytes(op.type_str)
        c.coll[base] = c.coll.get(base, 0) + b
        c.coll[base + "_count"] = c.coll.get(base + "_count", 0) + 1

    # fusion-boundary bytes with in-place aliasing: when an operand has
    # exactly the result type (dynamic-update-slice fusions, in-place
    # elementwise, loop-carried copies), XLA aliases the buffer — traffic
    # is the *touched* region (≈ the other operands), not the whole buffer.
    # Operands consumed only via dynamic-slice/gather inside a fusion are
    # charged at the slice size (scan weight stacks are read one page per
    # iteration, not wholesale).
    if not inside_fusion and oc not in _SKIP_BYTES_OPS and oc != "while":
        sliced = {}
        if oc == "fusion":
            m = _CALLS_RE.search(op.line)
            if m:
                sliced = _sliced_params(m.group(1), comps, shapes,
                                        memo.setdefault("__sliced__", {}))
        _, ob = _shape_elems_bytes(op.type_str)
        if oc in ("dynamic-slice", "gather"):
            c.bytes += 2 * ob       # read slice + write result
            return c
        other, aliased = 0, False
        for i, o in enumerate(op.operands):
            t = shapes.get(o)
            if not t:
                continue
            _, b = _shape_elems_bytes(t)
            if i in sliced:
                other += min(b, sliced[i])
                continue
            if not aliased and t.split("{")[0] == op.type_str.split("{")[0]:
                aliased = True      # donated/aliased input: not re-read
                continue
            other += b
        c.bytes += other + (min(ob, other) if aliased else ob)
    return c


def analyze(hlo_text: str, entry: str | None = None) -> dict:
    comps, shapes = parse_hlo(hlo_text)
    if entry is None:
        m = re.search(r"^ENTRY (%[\w.\-]+)", hlo_text, re.M)
        entry = m.group(1) if m else max(
            comps, key=lambda k: len(comps[k]))
    memo = {}
    cost = _comp_cost(entry, comps, shapes, memo)
    coll_total = sum(v for k, v in cost.coll.items()
                     if not k.endswith("_count"))
    return {
        "flops": cost.flops,
        "bytes_accessed": cost.bytes,
        "collectives": cost.coll,
        "collective_bytes_total": coll_total,
        "unknown_trip_count_loops": cost.unknown_loops,
        "entry": entry,
        "n_computations": len(comps),
    }
