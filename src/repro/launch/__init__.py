"""Entry points: mesh construction, dry-run, train, serve."""
