"""Serving entry point: batched prefill + decode loop.

CPU-scale demo of the full serving path every decode-shape dry-run cell
lowers: prefill a batch of prompts, then step the KV/SSM caches token by
token with greedy sampling.  The same step functions are what the
``decode_32k`` / ``long_500k`` cells compile for the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x22b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.base import get_config, get_smoke_config
from ..data import SyntheticLM
from ..models.model import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.causal, f"{args.arch} is encoder-only — nothing to decode"
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))

    data = SyntheticLM(cfg.vocab, args.prompt_len, args.batch,
                       seed=args.seed, modality=cfg.modality,
                       d_frontend=cfg.d_frontend,
                       n_img_tokens=cfg.n_img_tokens)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()
             if k not in ("labels", "mask")}
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"{cfg.name}: prefill({args.batch}x{args.prompt_len}) "
          f"{t_prefill*1e3:.1f} ms; decode {args.gen - 1} steps "
          f"{t_decode*1e3:.1f} ms "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {list(map(int, gen[b][:12]))}")
    return gen


if __name__ == "__main__":
    main()
