import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell on the production meshes and extract the roofline terms.

MUST be run as its own process (the first two lines force 512 host
devices before jax initializes — never set that globally).

Per cell:
  train_*    -> full train_step (fwd+bwd+AdamW) under the paper-faithful
                psum DP reduction (the baseline; hillclimb variants via
                --dp-reduce / --remat / --sp / --no-fsdp);
  prefill_*  -> model.prefill (forward + cache build, last-token logits);
  decode_*   -> model.decode_step against a seq_len-deep cache;
  encoder prefill -> model.score (full-sequence logits).

Outputs per cell: memory_analysis, cost_analysis (FLOPs/bytes), and the
collective-bytes breakdown parsed from post-SPMD HLO — written as JSON to
experiments/dryrun/<cell>.json for benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron_8b \
      --shape train_4k --mesh pod           # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import (ALL_SHAPES, ARCH_IDS, RunConfig, get_config,
                            input_specs, shapes_for)
from ..models.model import build_model
from ..optim import adamw
from ..parallel.compat import set_mesh
from ..parallel.sharding import make_rules, partition_params, use_rules
from ..runtime.train_loop import TrainState, make_train_step
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# --------------------------------------------------------------------------
# Collective-bytes extraction from post-SPMD HLO
# --------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(\w[\w.-]*) = \S+ (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_shape_bytes(type_str: str) -> int:
    """Sum bytes over a possibly-tuple HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind OUTPUT bytes of every collective in the HLO module.

    Uses the op result type (for all-gather the gathered size, for
    reduce-scatter the scattered size...) as the per-device traffic proxy.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        lhs = line.split("=", 1)
        type_part = lhs[1].strip().split("(")[0]
        b = _parse_shape_bytes(type_part)
        out[kind] = out.get(kind, 0) + b
        out[kind + "_count"] = out.get(kind + "_count", 0) + 1
    return out


# --------------------------------------------------------------------------
# Cell construction
# --------------------------------------------------------------------------

def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_axis(mesh, b: int, rules=None):
    """Mesh axis (or axes tuple) for the batch dim, honoring rule
    overrides (act:batch=none for weight-stationary serving layouts)."""
    if rules is not None and "batch" in rules.act_map:
        ax = rules.act_map["batch"]
        if ax is None:
            return None
    dp = _dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    spec = dp if len(dp) > 1 else dp[0]
    return spec if b % dp_size == 0 else None


def _batch_sharding(mesh, b: int, rules=None):
    ax = _batch_axis(mesh, b, rules)
    return P(ax) if ax is not None else P()


def _abstract(tree, shardings):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        tree, shardings)


def build_cell(arch: str, shape_name: str, mesh, run_cfg: RunConfig,
               cfg_overrides: dict | None = None):
    """Returns (lower_thunk, meta). lower_thunk() -> jax.stages.Lowered."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    shape = ALL_SHAPES[shape_name]
    assert shape in shapes_for(cfg), f"{arch} skips {shape_name}"
    model = build_model(cfg)
    rules = make_rules(mesh, fsdp=run_cfg.fsdp,
                       seq_parallel=getattr(run_cfg, "seq_parallel", False),
                       kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                       overrides=dict(run_cfg.rules_overrides)
                       if run_cfg.rules_overrides else None)
    specs = input_specs(cfg, shape)
    bspec = _batch_sharding(mesh, shape.global_batch, rules)
    batch_sh = {k: NamedSharding(mesh, P(*(bspec + (None,) * (len(v.shape) - len(bspec)))))
                for k, v in specs.items()}
    batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                         sharding=batch_sh[k])
                 for k, v in specs.items()}

    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "mesh": dict(mesh.shape), "cfg_overrides": cfg_overrides or {},
            "dp_reduce": run_cfg.dp_reduce}
    return model, cfg, rules, shape, batch_abs, meta


def lower_cell(arch: str, shape_name: str, mesh, run_cfg: RunConfig,
               cfg_overrides: dict | None = None):
    model, cfg, rules, shape, batch_abs, meta = build_cell(
        arch, shape_name, mesh, run_cfg, cfg_overrides)

    # abstract params + shardings (no allocation anywhere): eval_shape
    # traces init in Python, so the STATIC axes tree is captured by side
    # effect while the array tree stays abstract.
    axes_box = {}

    def init_fn(key):
        p, a = model.init(key)
        axes_box["axes"] = a
        return p

    params_abs = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    axes = axes_box["axes"]
    param_sh = partition_params(axes, rules)

    if shape.kind == "train":
        aer_abs = None
        if run_cfg.dp_reduce == "aer_topk":
            from ..core.sparse_collectives import AerState
            rep = NamedSharding(mesh, P())
            aer_abs = jax.tree.map(
                lambda t: AerState(residual=jax.ShapeDtypeStruct(
                    t.shape, t.dtype, sharding=rep)),
                params_abs,
                is_leaf=lambda t: isinstance(t, jax.ShapeDtypeStruct))
        state_abs = TrainState(
            params=_abstract(params_abs, param_sh),
            opt=adamw.AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P())),
                mu=_abstract(jax.tree.map(
                    lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32),
                    params_abs), param_sh),
                nu=_abstract(jax.tree.map(
                    lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32),
                    params_abs), param_sh)),
            aer=aer_abs,
            step=jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=NamedSharding(mesh, P())))
        step_fn = make_train_step(model, run_cfg, rules)
        with set_mesh(mesh):
            lowered = step_fn.lower(state_abs, batch_abs)
        return lowered, meta

    params_in = _abstract(params_abs, param_sh)

    if shape.kind == "prefill":
        if not cfg.causal:
            def score(p, b):
                with use_rules(rules):
                    return model.score(p, b)
            fn = jax.jit(score)
        else:
            def prefill(p, b):
                with use_rules(rules):
                    return model.prefill(p, b, max_len=shape.seq_len)
            fn = jax.jit(prefill)
        with set_mesh(mesh):
            lowered = fn.lower(params_in, batch_abs)
        return lowered, meta

    # decode: one token against a seq_len cache
    b = shape.global_batch
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(b, shape.seq_len))
    cache_sh = _cache_shardings(cache_abs, mesh, rules)
    cache_in = _abstract(cache_abs, cache_sh)
    bax = _batch_axis(mesh, b, rules)
    tok = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=NamedSharding(mesh, P(bax, None)))
    pos = jax.ShapeDtypeStruct(
        (b,), jnp.int32, sharding=NamedSharding(mesh, P(bax)))

    def decode(p, c, t, q):
        with use_rules(rules):
            return model.decode_step(p, c, t, q)

    fn = jax.jit(decode)
    with set_mesh(mesh):
        lowered = fn.lower(params_in, cache_in, tok, pos)
    return lowered, meta


def _bspec_tuple(mesh, b):
    dp = _dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if b % dp_size == 0:
        return ((dp if len(dp) > 1 else dp[0]),)
    return (None,)


def _cache_shardings(cache_abs, mesh, rules):
    """Cache leaves: (periods, B, S|W, K, dh) k/v; (periods, B, W) slot_pos;
    (periods, B, d_in, N) mamba h; (periods, B, d_conv-1, d_in) conv.
    All specs follow the logical rules (incl. overrides)."""
    inner = rules.act_map.get("mamba_inner", "model")

    def spec_for(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = len(leaf.shape)
        b = leaf.shape[1]
        bspec = _batch_axis(mesh, b, rules)
        if name in ("k", "v") and nd == 5:
            return NamedSharding(mesh, P(None, bspec,
                                         rules.act_map.get("kv_seq"),
                                         rules.act_map.get("heads_kv"),
                                         None))
        if name == "slot_pos":
            return NamedSharding(mesh, P(None, bspec, None))
        if name == "h" and nd == 4:
            return NamedSharding(mesh, P(None, bspec, inner, None))
        if name == "conv" and nd == 4:
            return NamedSharding(mesh, P(None, bspec, None, inner))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec_for, cache_abs)


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------

def run_cell(arch, shape_name, mesh_kind, run_cfg, cfg_overrides=None,
             out_dir=OUT_DIR, tag=""):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, run_cfg,
                               cfg_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    from . import hlo_cost
    loop_aware = hlo_cost.analyze(hlo)

    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    rec = dict(meta)
    rec.update({
        "mesh_kind": mesh_kind,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "xla_flops_once": float(cost.get("flops", -1)),
        "xla_bytes_once": float(cost.get("bytes accessed", -1)),
        "flops": loop_aware["flops"],
        "bytes_accessed": loop_aware["bytes_accessed"],
        "collectives": loop_aware["collectives"],
        "collective_bytes_total": loop_aware["collective_bytes_total"],
        "unknown_trip_count_loops": loop_aware["unknown_trip_count_loops"],
        "collectives_static_text": coll,
        "memory": {
            "argument_size_in_bytes": getattr(
                mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(
                mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
    })
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}--{shape_name}--{mesh_kind}{('--' + tag) if tag else ''}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    import gzip
    with gzip.open(os.path.join(out_dir, name + ".hlo.gz"), "wt") as f:
        f.write(hlo)
    print(f"[OK] {name}: compile={t_compile:.1f}s flops={rec['flops']:.3e} "
          f"bytes={rec['bytes_accessed']:.3e} "
          f"coll={rec['collective_bytes_total']:.3e}B "
          f"loops?={rec['unknown_trip_count_loops']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dp-reduce", default="psum")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--kv-chunk", type=int, default=0)
    ap.add_argument("--param-dtype", default=None,
                    choices=[None, "bf16", "f32"],
                    help="bf16 = inference-style weights (serve cells)")
    ap.add_argument("--rules-override", action="append", default=[],
                    help="logical rule override, e.g. "
                         "mamba_inner=data+model or act:batch=none")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    def _parse_rule(s):
        k, v = s.split("=", 1)
        if v == "none":
            val = None
        elif "+" in v:
            val = tuple(v.split("+"))
        else:
            val = v
        return k, val

    run_cfg = RunConfig(dp_reduce=args.dp_reduce, fsdp=not args.no_fsdp,
                        seq_parallel=args.sp,
                        rules_overrides=tuple(
                            _parse_rule(s) for s in args.rules_override))
    overrides = {}
    if args.remat:
        overrides["remat"] = args.remat
    if args.q_chunk:
        overrides["q_chunk"] = args.q_chunk
    if args.kv_chunk:
        overrides["kv_chunk"] = args.kv_chunk
    if args.param_dtype:
        overrides["param_dtype"] = (jnp.bfloat16 if args.param_dtype == "bf16"
                                    else jnp.float32)

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in shapes_for(cfg):
                cells.append((arch, shape.name))
    else:
        cells.append((args.arch, args.shape))

    failures = []
    for mesh_kind in meshes:
        for arch, shape in cells:
            try:
                run_cell(arch, shape, mesh_kind, run_cfg,
                         overrides or None, out_dir=args.out_dir,
                         tag=args.tag)
            except Exception as e:
                failures.append((arch, shape, mesh_kind, repr(e)))
                print(f"[FAIL] {arch}--{shape}--{mesh_kind}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
