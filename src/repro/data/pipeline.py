"""Deterministic synthetic data pipeline with per-host sharding + prefetch.

Every batch is a pure function of (seed, step) — restart-safe by
construction: resuming at step k regenerates exactly the batch the failed
run would have seen (the checkpoint/restart test relies on this).  The
token stream has learnable affine structure plus noise, so short training
runs show real loss decrease.

Per-host sharding follows the JAX SPMD convention: each process feeds its
slice of the global batch; here ``local_slice`` implements the split and a
background-thread prefetcher hides generation latency.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, *, structure: float = 0.7,
                 modality: str = "text", d_frontend: int = 0,
                 n_img_tokens: int = 0):
        self.vocab = vocab
        self.seq = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.structure = structure
        self.modality = modality
        self.d_frontend = d_frontend
        self.n_img_tokens = n_img_tokens

    # ------------------------------------------------------------------
    def batch(self, step: int) -> dict:
        """The full global batch for ``step`` (numpy)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s, v = self.global_batch, self.seq, self.vocab
        # learnable structure: per-sequence arithmetic ramp t_i = t0 + c*i
        # (the model infers the stride c from context); `structure` controls
        # the clean/noise mix so loss has real headroom to decrease.
        c = rng.integers(1, min(v, 17), (b, 1))
        t0 = rng.integers(0, v, (b, 1))
        ar = np.arange(s)[None, :]
        toks = (t0 + c * ar) % v
        noise = rng.random((b, s)) > self.structure
        toks = np.where(noise, rng.integers(0, v, (b, s)), toks)
        toks = toks.astype(np.int32)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        out = {"tokens": toks, "labels": labels}
        if self.modality == "audio_frames":
            frames = rng.standard_normal(
                (b, s, self.d_frontend)).astype(np.float32)
            out = {"frames": frames, "labels": toks,
                   "mask": np.ones((b, s), np.int32)}
        elif self.modality == "image+text":
            out["img_embed"] = rng.standard_normal(
                (b, self.n_img_tokens, self.d_frontend)).astype(np.float32)
        return out

    def local_slice(self, step: int, rank: int, world: int) -> dict:
        assert self.global_batch % world == 0
        per = self.global_batch // world
        full = self.batch(step)
        return {k: v[rank * per:(rank + 1) * per] for k, v in full.items()}

    # ------------------------------------------------------------------
    def prefetch(self, start_step: int, n_steps: int, depth: int = 2,
                 rank: int = 0, world: int = 1):
        """Background-thread prefetching iterator of (step, batch)."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = object()

        def worker():
            for s in range(start_step, start_step + n_steps):
                q.put((s, self.local_slice(s, rank, world)))
            q.put(stop)

        th = threading.Thread(target=worker, daemon=True)
        th.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item
