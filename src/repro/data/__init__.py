"""Deterministic synthetic data pipelines."""
from .pipeline import SyntheticLM  # noqa: F401
