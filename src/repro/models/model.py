"""Top-level model builder: embeddings/frontends + stack + head + loss +
serving entry points, uniform across all ten assigned architectures.

``build_model(cfg)`` returns an ``LM`` with pure functions:

  init(key)                      -> (params, axes)        axes: logical specs
  forward(params, batch)         -> (logits, aux)         full-sequence
  loss(params, batch)            -> (scalar, metrics)     train objective
  init_cache(batch, max_len)     -> cache pytree          (zeros; abstract ok)
  prefill(params, batch, ...)    -> (last_logits, cache)
  decode_step(params, cache, tokens, pos) -> (logits, cache)

Modality frontends are STUBS per the assignment: audio/vision inputs arrive
as precomputed frame/patch embeddings and pass through one projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard_activation as shard
from . import layers as L
from . import transformer as T


class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------- init --
    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        params, axes = {}, {}
        if cfg.modality == "audio_frames":
            params["frontend"], axes["frontend"] = L.linear_init(
                ks[0], cfg.d_frontend, cfg.d_model, ("none", "embed"),
                cfg.param_dtype)
        else:
            params["embed"], axes["embed"] = L.embed_init(
                ks[0], cfg.vocab, cfg.d_model, cfg.param_dtype)
        if cfg.modality == "image+text":
            params["frontend"], axes["frontend"] = L.linear_init(
                ks[3], cfg.d_frontend, cfg.d_model, ("none", "embed"),
                cfg.param_dtype)
        params["stack"], axes["stack"] = T.stack_init(ks[1], cfg)
        params["ln_f"], axes["ln_f"] = L.rmsnorm_init(cfg.d_model)
        if cfg.tie_embeddings:
            pass  # head reuses embed table
        else:
            params["head"], axes["head"] = L.head_init(ks[2], cfg)
        return params, axes

    # ------------------------------------------------------ embeddings --
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        if cfg.modality == "audio_frames":
            h = L.linear(params["frontend"], batch["frames"],
                         cfg.compute_dtype)
        else:
            h = L.embed(params["embed"], batch["tokens"], cfg.compute_dtype)
        img = None
        if cfg.modality == "image+text":
            img = L.linear(params["frontend"], batch["img_embed"],
                           cfg.compute_dtype)
            img = shard(img, ("batch", None, "embed"))
        return shard(h, ("batch", "seq_sp", "embed")), img

    def _head_raw(self, params, h):
        """Head projection WITHOUT the final norm (pre-normed input)."""
        cfg = self.cfg
        if cfg.tie_embeddings:
            w = params["embed"]["table"].astype(cfg.compute_dtype)
            logits = h @ w.T
        else:
            logits = L.linear(params["head"], h, cfg.compute_dtype)
        logits = L.mask_padded_vocab(logits, cfg.vocab)
        return shard(logits, ("batch", None, "vocab")) if logits.ndim == 3 \
            else logits

    def _head(self, params, h):
        h = L.rmsnorm(params["ln_f"], h, self.cfg.norm_eps)
        return self._head_raw(params, h)

    # ---------------------------------------------------------- forward --
    def forward(self, params, batch):
        cfg = self.cfg
        h, img = self._embed_inputs(params, batch)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, aux = T.stack_apply(params["stack"], cfg, h, positions, img)
        return self._head(params, h), aux

    def _loss_chunk(self, S):
        cfg = self.cfg
        if cfg.loss_chunk == -1:
            return 0
        if cfg.loss_chunk > 0:
            return cfg.loss_chunk
        # auto: chunk when the full (B,S,V) logits would be huge
        return 256 if (S > 512 and cfg.vocab >= 32768) else 0

    def loss(self, params, batch):
        cfg = self.cfg
        mask = batch.get("mask")
        S = batch["labels"].shape[1]
        chunk = self._loss_chunk(S)
        if chunk:
            h, img = self._embed_inputs(params, batch)
            B = h.shape[0]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            h, aux = T.stack_apply(params["stack"], cfg, h, positions, img)
            h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
            nll = L.chunked_cross_entropy(
                lambda hc: self._head_raw(params, hc), h, batch["labels"],
                mask, chunk)
        else:
            logits, aux = self.forward(params, batch)
            nll = L.cross_entropy(logits, batch["labels"], mask)
        total = nll + aux["aux_loss"] + aux["z_loss"]
        metrics = {"nll": nll, "aux_loss": aux["aux_loss"],
                   "z_loss": aux["z_loss"], "drop_frac": aux["drop_frac"],
                   "loss": total}
        return total, metrics

    # ---------------------------------------------------------- serving --
    def init_cache(self, batch_size, max_len, dtype=None):
        return T.init_cache(self.cfg, batch_size, max_len, dtype)

    def prefill(self, params, batch, max_len=None):
        """Returns (logits for the last position, decode cache with room
        for ``max_len`` total positions)."""
        cfg = self.cfg
        h, img = self._embed_inputs(params, batch)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, cache = T.stack_prefill(params["stack"], cfg, h, positions, img,
                                   max_len=max_len)
        logits = self._head(params, h[:, -1:])
        return logits, cache

    def score(self, params, batch):
        """Full-sequence logits for encoder-style scoring (no cache)."""
        logits, _ = self.forward(params, batch)
        return logits

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1) int32; pos: (B,) absolute positions."""
        cfg = self.cfg
        h = L.embed(params["embed"], tokens, cfg.compute_dtype)
        h = shard(h, ("batch", None, "embed"))
        h, cache = T.stack_decode(params["stack"], cfg, h, pos, cache)
        logits = self._head(params, h)
        return logits, cache


def build_model(cfg) -> LM:
    return LM(cfg)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
