"""Paper-native application: a 2D chip-array spiking network whose
inter-chip spike traffic flows as Address-Events over shared bi-directional
AER buses (the system of paper §IV Fig. 6: transceivers on all four chip
borders of a neuromorphic chip grid).

Each "chip" is a population of LIF neurons (fused Pallas update,
``kernels/lif_step``).  Per simulation tick:

  1. every chip integrates recurrent input and last tick's neighbor events;
  2. the LIF kernel updates membranes and emits spikes;
  3. spikes destined for the 4 neighbors become 26-bit AEs
     (``core/events.pack_aer_address``) on the shared East-West /
     North-South buses — ONE bus per chip pair, direction switched on
     demand (the paper's block), instead of two unidirectional buses.

Two report roll-ups share ONE energy model
(``core.network.link_energy_pj`` — the same function the fabric bills
through, so application figures can never drift from engine figures):

* ``fabric_report`` — the real thing: per-link transmission counts and
  busy-time telemetry from actual fabric runs (a
  :class:`~repro.core.network.FabricResult` or a
  ``repro.cosim`` closed-loop result) roll up into occupancy, energy
  and the wire economy vs the dual-bus baseline;
* ``link_report`` — the legacy pre-fabric ESTIMATE from per-tick
  expected event counts (kept as the A/B baseline for what the
  closed-loop co-simulation now measures instead of modelling).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import events as ev
from ..core.link import PAPER_TIMING, LinkTiming
from ..kernels import ops as K


class SnnConfig(NamedTuple):
    grid: tuple = (4, 4)        # chips (rows, cols)
    neurons: int = 256          # per chip (rows of 128 lanes)
    decay: float = 0.9
    v_th: float = 1.0
    v_reset: float = 0.0
    w_scale: float = 0.3
    input_rate: float = 0.05    # Poisson drive per neuron per tick
    xchip_fanout: float = 0.1   # fraction of spikes that cross each border


class SnnState(NamedTuple):
    v: jnp.ndarray              # (R, C, rows, 128) membranes
    spikes: jnp.ndarray         # (R, C, rows, 128) last tick's spikes
    key: jnp.ndarray


def init_snn(cfg: SnnConfig, key) -> tuple[dict, SnnState]:
    R, C = cfg.grid
    n = cfg.neurons
    rows = n // 128
    kw, kv = jax.random.split(key)
    params = {
        # local recurrent weights per chip (dense n x n, scaled)
        "w_rec": jax.random.normal(kw, (R, C, n, n), jnp.float32)
                 * cfg.w_scale / jnp.sqrt(n),
        # cross-chip projection: neighbor spikes -> local current
        "w_in": jax.random.normal(kv, (R, C, n, n), jnp.float32)
                * cfg.w_scale / jnp.sqrt(n),
    }
    state = SnnState(
        v=jnp.zeros((R, C, rows, 128), jnp.float32),
        spikes=jnp.zeros((R, C, rows, 128), jnp.float32),
        key=key,
    )
    return params, state


def _neighbor_sum(spikes_flat):
    """Sum of 4-neighborhood spike vectors with zero boundary.
    spikes_flat: (R, C, n)."""
    z = jnp.zeros_like(spikes_flat[:1, :, :])
    north = jnp.concatenate([spikes_flat[1:], z], axis=0)
    south = jnp.concatenate([z, spikes_flat[:-1]], axis=0)
    zc = jnp.zeros_like(spikes_flat[:, :1, :])
    east = jnp.concatenate([spikes_flat[:, 1:], zc], axis=1)
    west = jnp.concatenate([zc, spikes_flat[:, :-1]], axis=1)
    return north + south + east + west


def snn_step(params, cfg: SnnConfig, state: SnnState):
    """One network tick. Returns (state, tick_stats)."""
    R, C = cfg.grid
    n = cfg.neurons
    rows = n // 128
    key, k1 = jax.random.split(state.key)

    sp_flat = state.spikes.reshape(R, C, n)
    i_local = jnp.einsum("rcn,rcmn->rcm", sp_flat, params["w_rec"])
    i_nbr = jnp.einsum("rcn,rcmn->rcm",
                       cfg.xchip_fanout * _neighbor_sum(sp_flat),
                       params["w_in"])
    i_ext = (jax.random.uniform(k1, (R, C, n)) < cfg.input_rate).astype(
        jnp.float32)
    i_syn = (i_local + i_nbr + i_ext).reshape(R, C, rows, 128)

    v2, spk = K.lif_step(state.v.reshape(R * C * rows, 128),
                         i_syn.reshape(R * C * rows, 128),
                         decay=cfg.decay, v_th=cfg.v_th,
                         v_reset=cfg.v_reset)
    v2 = v2.reshape(R, C, rows, 128)
    spk = spk.reshape(R, C, rows, 128)

    # inter-chip AER traffic: spikes crossing each border (expected count
    # under the fanout model) — E/W pairs share one bus, N/S pairs too.
    per_chip = spk.reshape(R, C, n).sum(-1)                  # (R, C)
    tick = {
        "spikes": per_chip.sum(),
        "rate": spk.mean(),
        "ew_events_lr": cfg.xchip_fanout * per_chip[:, :-1].sum(),
        "ew_events_rl": cfg.xchip_fanout * per_chip[:, 1:].sum(),
        "ns_events": 2 * cfg.xchip_fanout * per_chip[:-1, :].sum(),
        "busiest_chip": per_chip.max(),
    }
    return SnnState(v=v2, spikes=spk, key=key), tick


def run_snn(params, cfg: SnnConfig, state: SnnState, n_ticks: int):
    def body(s, _):
        s, tick = snn_step(params, cfg, s)
        return s, tick

    return jax.lax.scan(body, state, None, length=n_ticks)


def spikes_to_events(spk_chip: jnp.ndarray, core_id: int) -> jnp.ndarray:
    """Dense spike vector (n,) -> packed 26-bit AE words of active units."""
    n = spk_chip.shape[0]
    idx = jnp.nonzero(spk_chip > 0, size=n, fill_value=0)[0]
    count = (spk_chip > 0).sum()
    words = ev.pack_aer_address(jnp.uint32(core_id), idx.astype(jnp.uint32))
    return words, count


def _bus_figures(ev_total: float, busy_ns: float, wall_ns: float,
                 energy_uj: float, timing: LinkTiming) -> dict:
    """The shared report shape: rate, occupancy, energy, wire economy."""
    return {
        "events_total": ev_total,
        "events_per_s": ev_total / (wall_ns * 1e-9),
        "bus_busy_frac": busy_ns / wall_ns,
        "energy_uj": energy_uj,
        "shared_bus_wires_per_link": timing.word_bits + 2,
        "dual_bus_wires_per_link": 2 * (timing.word_bits + 2),
        "throughput_headroom_x":
            (timing.bidir_throughput_mev_s() * 1e6) /
            max(ev_total / (wall_ns * 1e-9), 1.0),
    }


def link_report(ticks: dict, tick_dt_us: float = 100.0,
                timing: LinkTiming = PAPER_TIMING) -> dict:
    """Aggregate per-tick EXPECTED event counts into bus-level figures.

    Each chip pair shares ONE bus.  Per tick the bus carries both
    directions' events: busy time = events·t_req2req + reversals·penalty
    (≈ 2 reversals per tick under alternating bursts).  Compared against
    the dual-bus design: same events, two buses, 2× the wires.  Energy
    bills through :func:`repro.core.network.link_energy_pj` (the fabric's
    own model).  This is the pre-fabric estimator — prefer
    :func:`fabric_report` over results of a real fabric/cosim run.
    """
    import numpy as np

    from ..core.network import link_energy_pj
    lr = np.asarray(ticks["ew_events_lr"], float)
    rl = np.asarray(ticks["ew_events_rl"], float)
    n_ticks = lr.shape[0]

    ev_total = float(lr.sum() + rl.sum() + np.asarray(
        ticks["ns_events"], float).sum())
    busy_ns = ev_total * timing.t_req2req_ns \
        + 2 * n_ticks * timing.t_reverse_penalty_ns
    wall_ns = n_ticks * tick_dt_us * 1e3
    return _bus_figures(ev_total, busy_ns, wall_ns,
                        link_energy_pj(np.asarray([ev_total]),
                                       timing) * 1e-6, timing)


def fabric_report(res, n_ticks: int, tick_dt_us: float = 100.0,
                  timing: LinkTiming = PAPER_TIMING) -> dict:
    """Bus-level figures of an ACTUAL fabric run — measured, not modelled.

    ``res`` is anything with the fabric result surface: ``sent``
    ``(L, 2)`` per-link transmission counts, ``delivered`` (scalar or
    per-tick vector) and ``telemetry`` (per-link ``busy_ns`` counters)
    — a :class:`~repro.core.network.FabricResult` or a
    ``repro.cosim.CosimResult`` alike.  Energy is
    :func:`repro.core.network.link_energy_pj` over the counted
    transmissions (multi-hop and multicast traversals billed exactly);
    occupancy is the telemetry's measured per-link busy time against
    the run's wall-clock (``bus_busy_frac`` = mean over links,
    ``max_link_busy_frac`` = the busiest bus).
    """
    import numpy as np

    from ..core.network import link_energy_pj
    sent = np.asarray(res.sent)
    ev_total = float(np.asarray(res.delivered).sum())
    wall_ns = n_ticks * tick_dt_us * 1e3
    busy = (np.asarray(res.telemetry.busy_ns, np.float64)
            if res.telemetry is not None
            else np.zeros(sent.shape[0]))
    rep = _bus_figures(ev_total, float(busy.mean()), wall_ns,
                       link_energy_pj(sent, timing) * 1e-6, timing)
    rep["max_link_busy_frac"] = float(busy.max(initial=0.0)) / wall_ns
    rep["traversals"] = int(sent.sum())
    return rep
