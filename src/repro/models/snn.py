"""Paper-native application: a 2D chip-array spiking network whose
inter-chip spike traffic flows as Address-Events over shared bi-directional
AER buses (the system of paper §IV Fig. 6: transceivers on all four chip
borders of a neuromorphic chip grid).

Each "chip" is a population of LIF neurons (fused Pallas update,
``kernels/lif_step``).  Per simulation tick:

  1. every chip integrates recurrent input and last tick's neighbor events;
  2. the LIF kernel updates membranes and emits spikes;
  3. spikes destined for the 4 neighbors become 26-bit AEs
     (``core/events.pack_aer_address``) on the shared East-West /
     North-South buses — ONE bus per chip pair, direction switched on
     demand (the paper's block), instead of two unidirectional buses.

``link_report`` post-processes per-tick event counts with the measured
timing contract to give bus occupancy, switch counts, energy, and the
pin / wire economy vs the dual-bus baseline.  The busiest link can be
replayed exactly through ``core/protocol_sim`` for a cycle-accurate trace.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import events as ev
from ..core.link import PAPER_TIMING, LinkTiming
from ..kernels import ops as K


class SnnConfig(NamedTuple):
    grid: tuple = (4, 4)        # chips (rows, cols)
    neurons: int = 256          # per chip (rows of 128 lanes)
    decay: float = 0.9
    v_th: float = 1.0
    v_reset: float = 0.0
    w_scale: float = 0.3
    input_rate: float = 0.05    # Poisson drive per neuron per tick
    xchip_fanout: float = 0.1   # fraction of spikes that cross each border


class SnnState(NamedTuple):
    v: jnp.ndarray              # (R, C, rows, 128) membranes
    spikes: jnp.ndarray         # (R, C, rows, 128) last tick's spikes
    key: jnp.ndarray


def init_snn(cfg: SnnConfig, key) -> tuple[dict, SnnState]:
    R, C = cfg.grid
    n = cfg.neurons
    rows = n // 128
    kw, kv = jax.random.split(key)
    params = {
        # local recurrent weights per chip (dense n x n, scaled)
        "w_rec": jax.random.normal(kw, (R, C, n, n), jnp.float32)
                 * cfg.w_scale / jnp.sqrt(n),
        # cross-chip projection: neighbor spikes -> local current
        "w_in": jax.random.normal(kv, (R, C, n, n), jnp.float32)
                * cfg.w_scale / jnp.sqrt(n),
    }
    state = SnnState(
        v=jnp.zeros((R, C, rows, 128), jnp.float32),
        spikes=jnp.zeros((R, C, rows, 128), jnp.float32),
        key=key,
    )
    return params, state


def _neighbor_sum(spikes_flat):
    """Sum of 4-neighborhood spike vectors with zero boundary.
    spikes_flat: (R, C, n)."""
    z = jnp.zeros_like(spikes_flat[:1, :, :])
    north = jnp.concatenate([spikes_flat[1:], z], axis=0)
    south = jnp.concatenate([z, spikes_flat[:-1]], axis=0)
    zc = jnp.zeros_like(spikes_flat[:, :1, :])
    east = jnp.concatenate([spikes_flat[:, 1:], zc], axis=1)
    west = jnp.concatenate([zc, spikes_flat[:, :-1]], axis=1)
    return north + south + east + west


def snn_step(params, cfg: SnnConfig, state: SnnState):
    """One network tick. Returns (state, tick_stats)."""
    R, C = cfg.grid
    n = cfg.neurons
    rows = n // 128
    key, k1 = jax.random.split(state.key)

    sp_flat = state.spikes.reshape(R, C, n)
    i_local = jnp.einsum("rcn,rcmn->rcm", sp_flat, params["w_rec"])
    i_nbr = jnp.einsum("rcn,rcmn->rcm",
                       cfg.xchip_fanout * _neighbor_sum(sp_flat),
                       params["w_in"])
    i_ext = (jax.random.uniform(k1, (R, C, n)) < cfg.input_rate).astype(
        jnp.float32)
    i_syn = (i_local + i_nbr + i_ext).reshape(R, C, rows, 128)

    v2, spk = K.lif_step(state.v.reshape(R * C * rows, 128),
                         i_syn.reshape(R * C * rows, 128),
                         decay=cfg.decay, v_th=cfg.v_th,
                         v_reset=cfg.v_reset)
    v2 = v2.reshape(R, C, rows, 128)
    spk = spk.reshape(R, C, rows, 128)

    # inter-chip AER traffic: spikes crossing each border (expected count
    # under the fanout model) — E/W pairs share one bus, N/S pairs too.
    per_chip = spk.reshape(R, C, n).sum(-1)                  # (R, C)
    tick = {
        "spikes": per_chip.sum(),
        "rate": spk.mean(),
        "ew_events_lr": cfg.xchip_fanout * per_chip[:, :-1].sum(),
        "ew_events_rl": cfg.xchip_fanout * per_chip[:, 1:].sum(),
        "ns_events": 2 * cfg.xchip_fanout * per_chip[:-1, :].sum(),
        "busiest_chip": per_chip.max(),
    }
    return SnnState(v=v2, spikes=spk, key=key), tick


def run_snn(params, cfg: SnnConfig, state: SnnState, n_ticks: int):
    def body(s, _):
        s, tick = snn_step(params, cfg, s)
        return s, tick

    return jax.lax.scan(body, state, None, length=n_ticks)


def spikes_to_events(spk_chip: jnp.ndarray, core_id: int) -> jnp.ndarray:
    """Dense spike vector (n,) -> packed 26-bit AE words of active units."""
    n = spk_chip.shape[0]
    idx = jnp.nonzero(spk_chip > 0, size=n, fill_value=0)[0]
    count = (spk_chip > 0).sum()
    words = ev.pack_aer_address(jnp.uint32(core_id), idx.astype(jnp.uint32))
    return words, count


def link_report(ticks: dict, tick_dt_us: float = 100.0,
                timing: LinkTiming = PAPER_TIMING) -> dict:
    """Aggregate per-tick event counts into bus-level figures.

    Each chip pair shares ONE bus.  Per tick the bus carries both
    directions' events: busy time = events·t_req2req + reversals·penalty
    (≈ 2 reversals per tick under alternating bursts).  Compared against
    the dual-bus design: same events, two buses, 2× the wires.
    """
    import numpy as np
    lr = np.asarray(ticks["ew_events_lr"], float)
    rl = np.asarray(ticks["ew_events_rl"], float)
    n_ticks = lr.shape[0]

    ev_total = float(lr.sum() + rl.sum() + np.asarray(
        ticks["ns_events"], float).sum())
    busy_ns = ev_total * timing.t_req2req_ns \
        + 2 * n_ticks * timing.t_reverse_penalty_ns
    wall_ns = n_ticks * tick_dt_us * 1e3
    return {
        "events_total": ev_total,
        "events_per_s": ev_total / (wall_ns * 1e-9),
        "bus_busy_frac": busy_ns / wall_ns,
        "energy_uj": timing.e_event_pj * ev_total * 1e-6,
        "shared_bus_wires_per_link": timing.word_bits + 2,
        "dual_bus_wires_per_link": 2 * (timing.word_bits + 2),
        "throughput_headroom_x":
            (timing.bidir_throughput_mev_s() * 1e6) /
            max(ev_total / (wall_ns * 1e-9), 1.0),
    }
