"""Model substrate: functional layers with explicit param pytrees.

Conventions:
* every ``*_init`` returns ``(params, axes)`` — two parallel pytrees; the
  ``axes`` leaves are tuples of *logical* axis names consumed by
  ``parallel/sharding.py`` (e.g. ``("embed", "ff")``).  Logical names map to
  mesh axes via rules, so the same model code serves 1-device CPU tests and
  the 512-chip dry-run.
* compute happens in ``cfg.compute_dtype`` (bf16 on TPU), params are stored
  in ``cfg.param_dtype`` (f32 master copies), attention logits/softmax and
  normalization statistics in f32.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..parallel.sharding import shard_activation as shard


# ---------------------------------------------------------------------------
# Param helpers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def linear_init(key, d_in, d_out, axes, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return ({"w": _normal(key, (d_in, d_out), scale, dtype)},
            {"w": axes})


def linear(p, x, compute_dtype):
    return x.astype(compute_dtype) @ p["w"].astype(compute_dtype)


def rmsnorm_init(d, axes=("embed",)):
    return ({"scale": jnp.ones((d,), jnp.float32)}, {"scale": axes})


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


def padded_vocab(vocab: int, mult: int = 128) -> int:
    """Megatron-style vocab padding so the vocab axis shards evenly over
    the model axis (and MXU tiles); padded ids are masked to -1e9 in the
    head and never appear in labels."""
    return -(-vocab // mult) * mult


def embed_init(key, vocab, d, dtype):
    return ({"table": _normal(key, (padded_vocab(vocab), d), 0.02, dtype)},
            {"table": ("vocab", "embed")})


def embed(p, tokens, compute_dtype):
    return p["table"].astype(compute_dtype)[tokens]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta=1e4):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]   # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / SWA / cross) — chunked online-softmax ("flash") core
# ---------------------------------------------------------------------------

def _chunk_mask(q_idx, kv_idx, causal, window, kv_len):
    """(qc, kc) bool mask of *allowed* positions (kv_len masks padding)."""
    m = kv_idx[None, :] < kv_len
    if causal:
        m &= q_idx[:, None] >= kv_idx[None, :]
    if window > 0:
        m &= (q_idx[:, None] - kv_idx[None, :]) < window
    return m


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    q_chunk=1024, kv_chunk=1024):
    """Pure-JAX chunked attention with online softmax + BLOCK SKIPPING.

    q: (B, Sq, K, G, dh)  — GQA-grouped queries (G = H // K)
    k, v: (B, Skv, K, dh)

    Never materializes the (Sq, Skv) score matrix.  The q-chunk loop is a
    STATIC Python unroll so each q tile visits only the kv tiles its
    causal/sliding-window band allows: interior tiles run MASK-FREE inside
    a ``lax.scan``; only boundary tiles (causal diagonal, window edge,
    kv-padding) apply an explicit mask.  Versus the mask-everything scan
    this removes the fully-masked tiles' FLOPs (37% of causal attention at
    4 tiles, ~50% asymptotically) and never materializes per-tile-pair
    mask tensors (which XLA otherwise hoists into (nq·nk·qc·kc) buffers).
    ``q_offset`` positions queries inside the kv stream.
    """
    B, Sq0, K, G, dh = q.shape
    Skv0 = k.shape[1]
    qc = min(q_chunk, Sq0)
    kc = min(kv_chunk, Skv0)
    q_pad = (-Sq0) % qc
    kv_pad = (-Skv0) % kc
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    Sq, Skv = Sq0 + q_pad, Skv0 + kv_pad
    nq, nk = Sq // qc, Skv // kc

    scale = dh ** -0.5
    qf = (q * scale).astype(q.dtype).reshape(B, nq, qc, K, G, dh)
    kf = k.reshape(B, nk, kc, K, dh)
    vf = v.reshape(B, nk, kc, K, dh)

    def tile_update(carry, q_tile, k_tile, v_tile, mask):
        """Online-softmax update with one (qc × kc) tile.  mask=None for
        interior tiles (fully allowed — no mask tensor at all)."""
        m_run, l_run, acc = carry
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_tile, k_tile,
                       preferred_element_type=jnp.float32)
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        if mask is not None:
            p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0)
        l_new = l_run * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_tile.dtype),
                        v_tile, preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return m_new, l_new, acc

    def tile_is_interior(qi, ki):
        """Fully-allowed tile: every (q_idx, kv_idx) pair passes."""
        q_lo = q_offset + qi * qc
        q_hi = q_offset + (qi + 1) * qc - 1
        kv_lo, kv_hi = ki * kc, ki * kc + kc - 1
        if kv_hi >= Skv0:
            return False                         # padding tile
        if causal and kv_hi > q_lo:
            return False                         # crosses the diagonal
        if window > 0 and (q_hi - kv_lo) >= window:
            return False                         # crosses the window edge
        return True

    def tile_possible(qi, ki):
        """Any allowed pair at all? (skip entirely when not)"""
        q_lo = q_offset + qi * qc
        q_hi = q_offset + (qi + 1) * qc - 1
        kv_lo = ki * kc
        if kv_lo >= Skv0:
            return False
        if causal and kv_lo > q_hi:
            return False
        if window > 0 and (q_lo - (ki * kc + kc - 1)) >= window:
            return False
        return True

    outs = []
    for qi in range(nq):                         # STATIC unroll
        q_tile = qf[:, qi]
        q_idx = q_offset + qi * qc + jnp.arange(qc)
        m = jnp.full((B, K, G, qc), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, K, G, qc), jnp.float32)
        acc = jnp.zeros((B, K, G, qc, dh), jnp.float32)

        interior = [ki for ki in range(nk)
                    if tile_possible(qi, ki) and tile_is_interior(qi, ki)]
        boundary = [ki for ki in range(nk)
                    if tile_possible(qi, ki) and not tile_is_interior(qi, ki)]

        # contiguous interior ranges -> mask-free scans
        if interior:
            lo, hi = interior[0], interior[-1] + 1
            assert interior == list(range(lo, hi)), (qi, interior)

            def kv_step(carry, ki):
                k_tile = jax.lax.dynamic_index_in_dim(kf, ki, 1,
                                                      keepdims=False)
                v_tile = jax.lax.dynamic_index_in_dim(vf, ki, 1,
                                                      keepdims=False)
                return tile_update(carry, q_tile, k_tile, v_tile, None), None

            if hi - lo > 1:
                (m, l, acc), _ = jax.lax.scan(
                    kv_step, (m, l, acc), jnp.arange(lo, hi))
            else:
                (m, l, acc), _ = kv_step((m, l, acc), jnp.int32(lo))

        for ki in boundary:                      # few, static masks
            kv_idx = ki * kc + jnp.arange(kc)
            mask = _chunk_mask(q_idx, kv_idx, causal, window, Skv0)
            m, l, acc = tile_update((m, l, acc), q_tile, kf[:, ki],
                                    vf[:, ki], mask)

        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)  # (B,qc,K,G,dh)
        outs.append(out)

    out = jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]
    if q_pad:
        out = out[:, :Sq0]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid):
    """Single-token decode against a (B, Smax, K, dh) cache.

    q: (B, 1, K, G, dh); ``valid``: (B, Smax) bool — which cache slots may
    be attended (computed by the caller from lengths / ring-buffer slot
    positions / sliding windows).
    """
    B, _, K, G, dh = q.shape
    Smax = k_cache.shape[1]
    scale = dh ** -0.5
    s = jnp.einsum("bokgd,bskd->bkgos", (q * scale), k_cache,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, None], s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgos,bskd->bokgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + qk-norm + flash / decode core)
# ---------------------------------------------------------------------------

def attn_init(key, cfg, cross=False):
    ks = jax.random.split(key, 5)
    H, K, dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    d_kv_src = cfg.d_model  # cross-attn K/V source is projected frontend dim
    p, a = {}, {}
    p["wq"], a["wq"] = linear_init(ks[0], D, H * dh, ("embed", "heads_q"),
                                   cfg.param_dtype)
    p["wk"], a["wk"] = linear_init(ks[1], d_kv_src, K * dh,
                                   ("embed", "heads_kv"), cfg.param_dtype)
    p["wv"], a["wv"] = linear_init(ks[2], d_kv_src, K * dh,
                                   ("embed", "heads_kv"), cfg.param_dtype)
    p["wo"], a["wo"] = linear_init(ks[3], H * dh, D, ("heads_q", "embed"),
                                   cfg.param_dtype,
                                   scale=(H * dh) ** -0.5 / (2 * cfg.n_layers) ** 0.5)
    if cfg.qk_norm:
        p["qn"], a["qn"] = rmsnorm_init(dh, ("none",))
        p["kn"], a["kn"] = rmsnorm_init(dh, ("none",))
    return p, a


def _project_qkv(p, cfg, x, kv_src, positions, kv_positions, use_rope=True):
    B = x.shape[0]
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    cd = cfg.compute_dtype
    q = linear(p["wq"], x, cd).reshape(B, -1, H, dh)
    k = linear(p["wk"], kv_src, cd).reshape(B, -1, K, dh)
    v = linear(p["wv"], kv_src, cd).reshape(B, -1, K, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["qn"], q, cfg.norm_eps)
        k = rmsnorm(p["kn"], k, cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    q = shard(q, ("batch", None, "heads_q", None))
    k = shard(k, ("batch", None, "heads_kv", None))
    v = shard(v, ("batch", None, "heads_kv", None))
    return q, k, v


def attn_apply(p, cfg, x, positions, *, causal=None, kv_src=None,
               kv_positions=None, use_rope=True):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    B, S, D = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    causal = cfg.causal if causal is None else causal
    cross = kv_src is not None
    kv_src = x if kv_src is None else kv_src
    kv_positions = positions if kv_positions is None else kv_positions

    q, k, v = _project_qkv(p, cfg, x, kv_src, positions, kv_positions,
                           use_rope=use_rope and not cross)
    G = H // K
    q = q.reshape(B, S, K, G, dh)
    qc = cfg.q_chunk or min(1024, S)
    kc = cfg.kv_chunk or min(1024, k.shape[1])
    out = flash_attention(q, k, v, causal=causal and not cross,
                          window=cfg.sliding_window, q_chunk=qc, kv_chunk=kc)
    out = out.reshape(B, S, H * dh)
    out = linear(p["wo"], out, cfg.compute_dtype)
    return shard(out, ("batch", "seq_sp", "embed"))


def attn_decode(p, cfg, x, cache, pos, *, kv_src=None):
    """One-token decode. x: (B, 1, D); pos: (B,) absolute position of the
    new token. Two cache layouts:

      full cache:  {"k","v"} (B, Smax, K, dh) — slot index == position;
      ring cache:  {"k","v"} (B, W, K, dh) + {"slot_pos"} (B, W) absolute
                   positions per slot (−1 = empty) — for sliding-window
                   attention the cache is only window-deep, slots recycle.

    Cross-attention (kv_src=...) reads the static precomputed image cache
    {"k","v"} and never writes.  Returns (out, new_cache).
    """
    B = x.shape[0]
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if kv_src is not None:
        k, v = cache["k"], cache["v"]
        q = linear(p["wq"], x, cfg.compute_dtype).reshape(B, 1, H, dh)
        if cfg.qk_norm:
            q = rmsnorm(p["qn"], q, cfg.norm_eps)
        q = q.reshape(B, 1, K, H // K, dh)
        valid = jnp.ones((B, k.shape[1]), bool)
        out = decode_attention(q, k, v, valid)
        new_cache = cache
    else:
        q, kn, vn = _project_qkv(p, cfg, x, x, pos[:, None], pos[:, None])
        ring = "slot_pos" in cache
        Smax = cache["k"].shape[1]
        slot = (pos % Smax) if ring else pos
        k = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["k"], kn, slot)
        v = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))(cache["v"], vn, slot)
        new_cache = {"k": k, "v": v}
        if ring:
            slot_pos = jax.vmap(lambda sp, i, val: sp.at[i].set(val))(
                cache["slot_pos"], slot, pos)
            new_cache["slot_pos"] = slot_pos
            valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
            if cfg.sliding_window > 0:
                valid &= (pos[:, None] - slot_pos) < cfg.sliding_window
        else:
            idx = jnp.arange(Smax)
            valid = idx[None, :] <= pos[:, None]
            if cfg.sliding_window > 0:
                valid &= (pos[:, None] - idx[None, :]) < cfg.sliding_window
        k = shard(k, ("batch", "kv_seq", "heads_kv", None))
        v = shard(v, ("batch", "kv_seq", "heads_kv", None))
        q = q.reshape(B, 1, K, H // K, dh)
        out = decode_attention(q, k, v, valid)
    out = out.reshape(B, 1, H * dh)
    out = linear(p["wo"], out, cfg.compute_dtype)
    return out, new_cache


def init_attn_cache(cfg, batch, max_len, dtype=None):
    """Per-layer self-attention cache (caller stacks over layers)."""
    dtype = dtype or cfg.compute_dtype
    K, dh = cfg.n_kv_heads, cfg.d_head
    if cfg.sliding_window and cfg.sliding_window < max_len:
        w = cfg.sliding_window
        return {"k": jnp.zeros((batch, w, K, dh), dtype),
                "v": jnp.zeros((batch, w, K, dh), dtype),
                "slot_pos": jnp.full((batch, w), -1, jnp.int32)}
    return {"k": jnp.zeros((batch, max_len, K, dh), dtype),
            "v": jnp.zeros((batch, max_len, K, dh), dtype)}


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def ffn_init(key, cfg, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    gated = cfg.act in ("silu", "gelu") and cfg.family != "encoder"
    if gated:
        p["wg"], a["wg"] = linear_init(ks[0], cfg.d_model, d_ff,
                                       ("embed", "ff"), cfg.param_dtype)
    p["wi"], a["wi"] = linear_init(ks[1], cfg.d_model, d_ff, ("embed", "ff"),
                                   cfg.param_dtype)
    p["wo"], a["wo"] = linear_init(
        ks[2], d_ff, cfg.d_model, ("ff", "embed"), cfg.param_dtype,
        scale=d_ff ** -0.5 / (2 * cfg.n_layers) ** 0.5)
    return p, a


def ffn_apply(p, cfg, x):
    act = _ACTS[cfg.act]
    h = linear(p["wi"], x, cfg.compute_dtype)
    if "wg" in p:
        h = act(linear(p["wg"], x, cfg.compute_dtype)) * h
    else:
        h = act(h)
    h = shard(h, ("batch", None, "ff"))
    out = linear(p["wo"], h, cfg.compute_dtype)
    return shard(out, ("batch", "seq_sp", "embed"))


# ---------------------------------------------------------------------------
# Output head / loss
# ---------------------------------------------------------------------------

def head_init(key, cfg):
    return linear_init(key, cfg.d_model, padded_vocab(cfg.vocab),
                       ("embed", "vocab"), cfg.param_dtype,
                       scale=cfg.d_model ** -0.5)


def mask_padded_vocab(logits, vocab: int):
    v_pad = logits.shape[-1]
    if v_pad == vocab:
        return logits
    live = jnp.arange(v_pad) < vocab
    return logits + jnp.where(live, 0.0, -1e9).astype(logits.dtype)


def cross_entropy(logits, labels, mask=None):
    """Mean token NLL in f32. logits: (B, S, V); labels: (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_cross_entropy(head_fn, h, labels, mask=None, chunk=256):
    """Sequence-chunked NLL: per chunk, project hidden -> logits -> NLL and
    discard the logits (recomputed in backward via jax.checkpoint).  Peak
    logits memory is (B, chunk, V) instead of (B, S, V) — mandatory at
    vocab 152k–256k × seq 4k.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((B, S), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n = h.shape[1] // chunk
    hc = h.reshape(B, n, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.astype(jnp.float32).reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def step(carry, xs):
        hcc, lcc, mcc = xs
        logits = head_fn(hcc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lcc[..., None], axis=-1)[..., 0]
        nll_sum = ((logz - gold) * mcc).sum()
        return (carry[0] + nll_sum, carry[1] + mcc.sum()), None

    (total, count), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc, mc))
    return total / jnp.maximum(count, 1.0)
