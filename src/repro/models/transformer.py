"""Layer-stack builder: every architecture family as a scanned pattern.

A model is ``periods × pattern`` where the pattern is a short tuple of block
kinds, e.g. dense = ("attn_ffn",), Jamba = an 8-layer attn/mamba/MoE weave,
Llama-3.2-Vision = 5 layers with a gated cross-attention block at position 3.
Per pattern position the parameters are stacked over periods and the forward
is a single ``lax.scan`` — compile time and HLO size stay flat in depth
(88-layer granite-34b compiles the same program as a 2-layer smoke model).

Block kinds:
  attn_ffn | attn_moe | xattn_ffn | mamba | mamba_ffn | mamba_moe
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..parallel.sharding import shard_activation as shard
from . import layers as L
from . import mamba as M
from . import moe as MOE

AUX_ZERO = {"aux_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0),
            "drop_frac": jnp.float32(0.0)}


def pattern_for(cfg) -> tuple[str, ...]:
    if cfg.block_pattern:
        return tuple(cfg.block_pattern)
    if cfg.family == "mamba":
        return ("mamba",)
    if cfg.family == "vision":
        pat = ["attn_ffn"] * cfg.xattn_period
        pat[cfg.xattn_pos] = "xattn_ffn"
        return tuple(pat)
    if cfg.family == "moe":
        if cfg.moe_every <= 1:
            return ("attn_moe",)
        pat = ["attn_ffn"] * cfg.moe_every
        pat[-1] = "attn_moe"
        return tuple(pat)
    return ("attn_ffn",)   # dense / encoder


def n_periods(cfg) -> int:
    pat = pattern_for(cfg)
    assert cfg.n_layers % len(pat) == 0, (cfg.n_layers, pat)
    return cfg.n_layers // len(pat)


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def _block_init(key, cfg, kind):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.rmsnorm_init(cfg.d_model)
    if kind.startswith("attn") or kind.startswith("xattn"):
        p["attn"], a["attn"] = L.attn_init(ks[0], cfg)
        if kind.startswith("xattn"):
            p["xgate"] = jnp.zeros((), jnp.float32)
            a["xgate"] = ()
    else:
        p["mamba"], a["mamba"] = M.mamba_init(ks[0], cfg)
    if kind.endswith("_ffn"):
        p["ln2"], a["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"], a["ffn"] = L.ffn_init(ks[1], cfg)
    elif kind.endswith("_moe"):
        p["ln2"], a["ln2"] = L.rmsnorm_init(cfg.d_model)
        p["moe"], a["moe"] = MOE.moe_init(ks[1], cfg)
    return p, a


def _block_apply(p, cfg, kind, x, positions, img):
    aux = dict(AUX_ZERO)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.startswith("xattn"):
        mix = L.attn_apply(p["attn"], cfg, h, positions, kv_src=img,
                           causal=False)
        mix = jnp.tanh(p["xgate"]).astype(mix.dtype) * mix
    elif kind.startswith("attn"):
        mix = L.attn_apply(p["attn"], cfg, h, positions)
    else:
        mix = M.mamba_apply(p["mamba"], cfg, h)
    x = x + mix
    if kind.endswith("_ffn"):
        x = x + L.ffn_apply(p["ffn"], cfg,
                            L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    elif kind.endswith("_moe"):
        y, aux_m = MOE.moe_apply(p["moe"], cfg,
                                 L.rmsnorm(p["ln2"], x, cfg.norm_eps))
        x = x + y
        aux.update(aux_m)
    return x, aux


# ---------------------------------------------------------------------------
# Stack init / forward (train + scoring)
# ---------------------------------------------------------------------------

def stack_init(key, cfg):
    pat = pattern_for(cfg)
    P = n_periods(cfg)
    params, axes = {}, {}
    for i, kind in enumerate(pat):
        keys = jax.random.split(jax.random.fold_in(key, i), P)
        p_stacked = jax.vmap(lambda k: _block_init(k, cfg, kind)[0])(keys)
        _, a_single = _block_init(keys[0], cfg, kind)
        params[f"pos{i}"] = p_stacked
        axes[f"pos{i}"] = jax.tree.map(
            lambda t: ("layers",) + tuple(t), a_single,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                isinstance(e, (str, type(None))) for e in t))
    return params, axes


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)   # "full": save nothing


def stack_apply(params, cfg, x, positions, img=None):
    """Full-sequence forward. x: (B, S, D) -> (x, aux-sums)."""
    pat = pattern_for(cfg)

    def body(carry, per_params):
        x, aux = carry
        x = shard(x, ("batch", "seq_sp", "embed"))
        for i, kind in enumerate(pat):
            x, aux_i = _block_apply(per_params[f"pos{i}"], cfg, kind, x,
                                    positions, img)
            aux = jax.tree.map(jnp.add, aux, aux_i)
        return (x, aux), None

    body = _remat(cfg, body)
    (x, aux), _ = jax.lax.scan(body, (x, dict(AUX_ZERO)), params)
    return x, aux


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch, max_len, dtype=None):
    """Abstract cache structure; stacked over periods per pattern position."""
    pat = pattern_for(cfg)
    P = n_periods(cfg)

    def stk(tree):
        return jax.tree.map(
            lambda t: jnp.zeros((P,) + t.shape, t.dtype) + (
                -1 if t.dtype == jnp.int32 else 0), tree)

    cache = {}
    for i, kind in enumerate(pat):
        if kind.startswith("xattn"):
            c = {"k": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads,
                                 cfg.d_head), dtype or cfg.compute_dtype),
                 "v": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads,
                                 cfg.d_head), dtype or cfg.compute_dtype)}
        elif kind.startswith("attn"):
            c = L.init_attn_cache(cfg, batch, max_len, dtype)
        else:
            c = M.init_mamba_cache(cfg, batch)
        cache[f"pos{i}"] = stk(c)
    return cache


def precompute_cross_cache(params, cfg, cache, img):
    """Fill the xattn positions of ``cache`` from stub image embeddings."""
    pat = pattern_for(cfg)
    cd = cfg.compute_dtype
    K, dh = cfg.n_kv_heads, cfg.d_head
    for i, kind in enumerate(pat):
        if not kind.startswith("xattn"):
            continue
        blk = params[f"pos{i}"]["attn"]

        def kv(wk, wv):
            k = (img.astype(cd) @ wk.astype(cd)).reshape(
                img.shape[0], -1, K, dh)
            v = (img.astype(cd) @ wv.astype(cd)).reshape(
                img.shape[0], -1, K, dh)
            return k, v

        ks, vs = jax.vmap(kv)(blk["wk"]["w"], blk["wv"]["w"])
        cache = dict(cache)
        cache[f"pos{i}"] = {"k": ks, "v": vs}
    return cache


def stack_prefill(params, cfg, x, positions, img=None, max_len=None):
    """Forward that also materializes the decode cache.

    Returns (hidden, cache).  Attention layers keep their (possibly window-
    truncated) K/V in a cache with room for ``max_len`` positions; mamba
    layers keep the final recurrent + conv state.
    """
    pat = pattern_for(cfg)
    B, S, D = x.shape
    max_len = max(max_len or 0, S)
    W = cfg.sliding_window if (cfg.sliding_window and
                               cfg.sliding_window < max_len) else 0

    def grow(k):
        if k.shape[1] == max_len:
            return k
        pad = jnp.zeros((B, max_len - k.shape[1]) + k.shape[2:], k.dtype)
        return jnp.concatenate([k, pad], axis=1)

    def body(x, per_params):
        x = shard(x, ("batch", "seq_sp", "embed"))
        caches = {}
        for i, kind in enumerate(pat):
            p = per_params[f"pos{i}"]
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            if kind.startswith("xattn"):
                mix = L.attn_apply(p["attn"], cfg, h, positions, kv_src=img,
                                   causal=False)
                mix = jnp.tanh(p["xgate"]).astype(mix.dtype) * mix
                cd = cfg.compute_dtype
                K, dh = cfg.n_kv_heads, cfg.d_head
                caches[f"pos{i}"] = {
                    "k": (img.astype(cd) @ p["attn"]["wk"]["w"].astype(cd)
                          ).reshape(B, -1, K, dh),
                    "v": (img.astype(cd) @ p["attn"]["wv"]["w"].astype(cd)
                          ).reshape(B, -1, K, dh)}
            elif kind.startswith("attn"):
                mix = L.attn_apply(p["attn"], cfg, h, positions)
                q, k, v = L._project_qkv(p["attn"], cfg, h, h, positions,
                                         positions)
                if W:
                    if S >= W:
                        # ring invariant: slot j holds position p, p % W == j
                        kw = jnp.roll(k[:, -W:], S % W, axis=1)
                        vw = jnp.roll(v[:, -W:], S % W, axis=1)
                        sp = _ring_positions(S, W, B)
                    else:
                        pad = jnp.zeros((B, W - S) + k.shape[2:], k.dtype)
                        kw = jnp.concatenate([k, pad], axis=1)
                        vw = jnp.concatenate([v, pad], axis=1)
                        sp = jnp.concatenate(
                            [jnp.broadcast_to(jnp.arange(S), (B, S)),
                             jnp.full((B, W - S), -1)], axis=1).astype(
                                 jnp.int32)
                    caches[f"pos{i}"] = {"k": kw, "v": vw, "slot_pos": sp}
                else:
                    caches[f"pos{i}"] = {"k": grow(k), "v": grow(v)}
            else:
                mix, st = M.mamba_prefill(p["mamba"], cfg, h)
                caches[f"pos{i}"] = st
            x = x + mix
            if kind.endswith("_ffn"):
                x = x + L.ffn_apply(p["ffn"], cfg,
                                    L.rmsnorm(p["ln2"], x, cfg.norm_eps))
            elif kind.endswith("_moe"):
                y, _ = MOE.moe_apply(p["moe"], cfg,
                                     L.rmsnorm(p["ln2"], x, cfg.norm_eps))
                x = x + y
        return x, caches

    x, cache = jax.lax.scan(body, x, params)
    return x, cache


def _ring_positions(S, W, B):
    """Absolute positions of ring slots after prefilling S tokens: slot
    j holds position p with p % W == j and p in [S-W, S)."""
    base = jnp.arange(W)
    start = S - W
    pos = start + (base - (start % W)) % W
    return jnp.broadcast_to(pos, (B, W)).astype(jnp.int32)


def stack_decode(params, cfg, x, pos, cache):
    """One-token decode. x: (B, 1, D); pos: (B,). Returns (x, cache)."""
    pat = pattern_for(cfg)

    def body(x, scanned):
        per_params, per_cache = scanned
        new_cache = {}
        for i, kind in enumerate(pat):
            p = per_params[f"pos{i}"]
            c = per_cache[f"pos{i}"]
            h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
            if kind.startswith("xattn"):
                mix, nc = L.attn_decode(p["attn"], cfg, h, c, pos,
                                        kv_src="static")
                mix = jnp.tanh(p["xgate"]).astype(mix.dtype) * mix
            elif kind.startswith("attn"):
                mix, nc = L.attn_decode(p["attn"], cfg, h, c, pos)
            else:
                mix, nc = M.mamba_decode(p["mamba"], cfg, h, c)
            new_cache[f"pos{i}"] = nc
            x = x + mix
            if kind.endswith("_ffn"):
                x = x + L.ffn_apply(p["ffn"], cfg,
                                    L.rmsnorm(p["ln2"], x, cfg.norm_eps))
            elif kind.endswith("_moe"):
                y, _ = MOE.moe_apply(p["moe"], cfg,
                                     L.rmsnorm(p["ln2"], x, cfg.norm_eps))
                x = x + y
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params, cache))
    return x, new_cache
