"""Model substrate: layers and the architecture families."""
