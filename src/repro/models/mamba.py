"""Mamba-1 (S6) block: in-proj → causal depthwise conv → selective scan.

TPU adaptation: the CUDA kernel of the paper fuses the recurrence in SRAM;
here the selective scan is CHUNKED — within a chunk (default 128 steps) an
``associative_scan`` (log-depth, VMEM-resident working set) computes the
state trajectory, and a ``lax.scan`` carries the boundary state across
chunks.  Working set per chunk is (B, chunk, d_inner, d_state) instead of
(B, S, d_inner, d_state): 32× smaller at train_4k.  d_inner is tensor-
parallel over the model axis (every op is pointwise in d_inner except the
out-projection reduce, mirroring Mamba TP practice).

Decode is the O(1) recurrence with a (d_conv-1)-deep convolution cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard_activation as shard
from .layers import _normal


def mamba_init(key, cfg):
    m = cfg.mamba
    D = cfg.d_model
    d_in = m.expand * D
    N = m.d_state
    R = cfg.dt_rank
    ks = jax.random.split(key, 7)
    pd = cfg.param_dtype

    # dt bias initialised so softplus(bias) spans [1e-3, 1e-1] (paper init)
    u = jax.random.uniform(ks[5], (d_in,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus

    p = {
        "in_proj": _normal(ks[0], (D, 2 * d_in), D ** -0.5, pd),
        "conv_w": _normal(ks[1], (m.d_conv, d_in), m.d_conv ** -0.5, pd),
        "conv_b": jnp.zeros((d_in,), pd),
        "x_proj": _normal(ks[2], (d_in, R + 2 * N), d_in ** -0.5, pd),
        "dt_proj": _normal(ks[3], (R, d_in), R ** -0.5, pd),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (d_in, N))),
        "D_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": _normal(ks[4], (d_in, D),
                            d_in ** -0.5 / (2 * cfg.n_layers) ** 0.5, pd),
    }
    a = {
        "in_proj": ("embed", "mamba_inner"),
        "conv_w": ("none", "mamba_inner"),
        "conv_b": ("mamba_inner",),
        "x_proj": ("mamba_inner", "none"),
        "dt_proj": ("none", "mamba_inner"),
        "dt_bias": ("mamba_inner",),
        "A_log": ("mamba_inner", "none"),
        "D_skip": ("mamba_inner",),
        "out_proj": ("mamba_inner", "embed"),
    }
    return p, a


def _ssm_inputs(p, cfg, x_conv):
    """x_conv: (..., d_in) -> dt (..., d_in), B/C (..., N) in f32."""
    m = cfg.mamba
    R = cfg.dt_rank
    bcd = x_conv.astype(jnp.float32) @ p["x_proj"].astype(jnp.float32)
    dt_low, B_ssm, C_ssm = jnp.split(bcd, [R, R + m.d_state], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])
    return dt, B_ssm, C_ssm


def selective_scan(x, dt, B_ssm, C_ssm, A, chunk: int):
    """Chunked selective scan.

    x, dt: (B, S, d_in); B_ssm, C_ssm: (B, S, N); A: (d_in, N).
    Returns y: (B, S, d_in) f32.
    """
    import math
    Bb, S, d_in = x.shape
    N = A.shape[1]
    cn = min(chunk, S)
    if S % cn:
        cn = math.gcd(cn, S)
    nc = S // cn

    def to_chunks(t):
        return t.reshape(Bb, nc, cn, *t.shape[2:]).swapaxes(0, 1)

    xs = jax.tree.map(to_chunks, (x.astype(jnp.float32), dt, B_ssm, C_ssm))

    def chunk_step(h0, inp):
        x_c, dt_c, B_c, C_c = inp                      # (B, cn, ...)
        dA = dt_c[..., None] * A                       # (B, cn, d_in, N)
        abar = jnp.exp(dA)
        bx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        a_acc, b_acc = jax.lax.associative_scan(comb, (abar, bx), axis=1)
        h = b_acc + a_acc * h0[:, None]                # (B, cn, d_in, N)
        y_c = jnp.einsum("bcdn,bcn->bcd", h, C_c)
        return h[:, -1], y_c

    h0 = jnp.zeros((Bb, d_in, N), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    return ys.swapaxes(0, 1).reshape(Bb, S, d_in), h_final


def _causal_depthwise_conv(x, w, b):
    """x: (B, S, d_in); w: (k, d_in) -> (B, S, d_in), causal."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],                  # (k, 1, d_in) HIO
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1])
    return out + b


def _mamba_fwd(p, cfg, x):
    m = cfg.mamba
    cd = cfg.compute_dtype
    B, S, D = x.shape
    xz = (x.astype(cd) @ p["in_proj"].astype(cd))
    x_part, z = jnp.split(xz, 2, axis=-1)
    x_part = shard(x_part, ("batch", None, "mamba_inner"))

    x_conv = _causal_depthwise_conv(x_part.astype(jnp.float32),
                                    p["conv_w"].astype(jnp.float32),
                                    p["conv_b"].astype(jnp.float32))
    x_conv = jax.nn.silu(x_conv)

    dt, B_ssm, C_ssm = _ssm_inputs(p, cfg, x_conv)
    A = -jnp.exp(p["A_log"])
    y, h_final = selective_scan(x_conv, dt, B_ssm, C_ssm, A, m.chunk)
    y = y + x_conv * p["D_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(cd)
    y = shard(y, ("batch", None, "mamba_inner"))
    out = y @ p["out_proj"].astype(cd)
    out = shard(out, ("batch", "seq_sp", "embed"))
    conv_state = x_part[:, S - (m.d_conv - 1):].astype(jnp.float32)
    return out, h_final, conv_state


def mamba_apply(p, cfg, x):
    """Full-sequence Mamba block. x: (B, S, D) -> (B, S, D)."""
    out, _, _ = _mamba_fwd(p, cfg, x)
    return out


def mamba_prefill(p, cfg, x):
    """Forward + decode state: returns (out, {"h", "conv"})."""
    out, h, conv = _mamba_fwd(p, cfg, x)
    return out, {"h": h, "conv": conv}


def init_mamba_cache(cfg, batch):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_in, m.d_state), jnp.float32),
        "conv": jnp.zeros((batch, m.d_conv - 1, d_in), jnp.float32),
    }


def mamba_decode(p, cfg, x, cache):
    """One-token recurrence. x: (B, 1, D); cache: {"h", "conv"}."""
    m = cfg.mamba
    cd = cfg.compute_dtype
    B = x.shape[0]
    xz = (x.astype(cd) @ p["in_proj"].astype(cd))      # (B, 1, 2*d_in)
    x_part, z = jnp.split(xz, 2, axis=-1)
    x1 = x_part[:, 0].astype(jnp.float32)              # (B, d_in)

    window = jnp.concatenate([cache["conv"], x1[:, None, :]], axis=1)
    wf = p["conv_w"].astype(jnp.float32)
    x_conv = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, wf)
                         + p["conv_b"].astype(jnp.float32))
    new_conv = window[:, 1:]

    dt, B_ssm, C_ssm = _ssm_inputs(p, cfg, x_conv)     # (B,d_in),(B,N),(B,N)
    A = -jnp.exp(p["A_log"])
    abar = jnp.exp(dt[..., None] * A)                  # (B, d_in, N)
    bx = (dt * x_conv)[..., None] * B_ssm[:, None, :]
    h = abar * cache["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, C_ssm) + x_conv * p["D_skip"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(cd)
    out = (y @ p["out_proj"].astype(cd))[:, None, :]
    return out, {"h": h, "conv": new_conv}
