"""Mixture-of-Experts FFN: top-k router + capacity-based dispatch.

Dispatch is gather/scatter-based (sort-free, static shapes — SPMD friendly):
per batch-group, each token's k expert choices get a position-in-expert from
a running one-hot cumsum; tokens beyond an expert's capacity are dropped
(their gate mass is simply not combined — residual connection carries them,
the standard Switch/GShard behaviour).  No (tokens × experts × capacity)
one-hot einsum is ever materialized: slot tables are built by scatter and
read by gather, so dispatch is O(tokens) memory and 0 matmul FLOPs.

Layouts (cfg.moe.layout):
  "ep": expert axis sharded over the model mesh axis (requires E % tp == 0);
        SPMD inserts the token all-to-all at the dispatch boundary.
  "tp": every expert's d_ff sharded over the model axis (for E < tp, e.g.
        Mixtral's 8 experts on a 16-wide axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard_activation as shard
from .layers import _ACTS, _normal


def moe_init(key, cfg):
    m = cfg.moe
    D, F, E = cfg.d_model, cfg.d_ff, m.num_experts
    ks = jax.random.split(key, 4)
    ep = m.layout == "ep"
    e_ax = "experts" if ep else "none"
    f_ax = "none" if ep else "ff"
    p = {
        "router": _normal(ks[0], (D, E), D ** -0.5, jnp.float32),
        "wg": _normal(ks[1], (E, D, F), D ** -0.5, cfg.param_dtype),
        "wi": _normal(ks[2], (E, D, F), D ** -0.5, cfg.param_dtype),
        "wo": _normal(ks[3], (E, F, D),
                      F ** -0.5 / (2 * cfg.n_layers) ** 0.5, cfg.param_dtype),
    }
    a = {
        "router": ("none", "none"),
        "wg": (e_ax, "embed", f_ax),
        "wi": (e_ax, "embed", f_ax),
        "wo": (e_ax, f_ax, "embed"),
    }
    return p, a


def _capacity(cfg, tokens_per_group: int) -> int:
    m = cfg.moe
    c = int(-(-tokens_per_group * m.top_k * m.capacity_factor //
              m.num_experts))
    return max(c, 1)


def moe_apply(p, cfg, x):
    """x: (B, S, D) -> (out, aux) with aux = {aux_loss, z_loss, drop_frac}.

    Each batch row is a dispatch group (rows are data-sharded, so the
    position cumsum stays shard-local — no cross-device dispatch state).
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    C = _capacity(cfg, S)
    cd = cfg.compute_dtype
    act = _ACTS[cfg.act]

    logits = x.astype(jnp.float32) @ p["router"]          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, choice = jax.lax.top_k(probs, K)               # (B,S,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via one-hot cumsum over the (S*K) dispatch order
    oh = jax.nn.one_hot(choice, E, dtype=jnp.int32)       # (B,S,K,E)
    oh_flat = oh.reshape(B, S * K, E)
    pos_flat = jnp.cumsum(oh_flat, axis=1) - oh_flat
    pos = (pos_flat.reshape(B, S, K, E) * oh).sum(-1)     # (B,S,K)
    valid = pos < C

    # slot tables: token index + combine gate per (expert, slot), by scatter
    tok_ids = jnp.broadcast_to(jnp.arange(S)[:, None], (S, K))
    gate_flat = (gates * valid.astype(jnp.float32)).astype(jnp.float32)

    def build_slots(choice_g, pos_g, gate_g):
        st = jnp.zeros((E, C), jnp.int32)
        sv = jnp.zeros((E, C), bool)
        sg = jnp.zeros((E, C), jnp.float32)
        st = st.at[choice_g.reshape(-1), pos_g.reshape(-1)].set(
            tok_ids.reshape(-1), mode="drop")
        sv = sv.at[choice_g.reshape(-1), pos_g.reshape(-1)].set(
            True, mode="drop")
        sg = sg.at[choice_g.reshape(-1), pos_g.reshape(-1)].set(
            gate_g.reshape(-1), mode="drop")
        return st, sv, sg

    slot_tok, slot_valid, slot_gate = jax.vmap(build_slots)(
        choice, pos, gate_flat)                                # (B,E,C)

    # gather tokens into expert buffers
    buf = jax.vmap(lambda xg, st: xg[st])(x, slot_tok)         # (B,E,C,D)
    buf = jnp.where(slot_valid[..., None], buf, 0).astype(cd)
    ep = m.layout == "ep"
    buf = shard(buf, ("batch", "experts" if ep else None, None, None))

    # expert FFN (grouped einsum)
    h = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(cd))
    hg = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(cd))
    h = act(hg) * h
    h = shard(h, ("batch", "experts" if ep else None, None,
                  None if ep else "ff"))
    y = jnp.einsum("becf,efd->becd", h, p["wo"].astype(cd))    # (B,E,C,D)
    y = shard(y, ("batch", "experts" if ep else None, None, None))

    # combine: WEIGHT-THEN-SCATTER.  Each expert slot's output is scaled by
    # its combine gate and scatter-added back to its token position.  The
    # gate multiply happens on the expert-sharded side of the collective,
    # so the cross-device reduction is one (B,S,D) psum — a gather-then-
    # weight combine reduces (B,S,top_k,D) instead (top_k× the traffic;
    # 6× for this arch — measured in EXPERIMENTS.md §Perf cell D).
    contrib = y * slot_gate[..., None].astype(cd)              # (B,E,C,D)

    def scatter_back(cg, st):
        return jnp.zeros((S, D), cd).at[st.reshape(-1)].add(
            cg.reshape(-1, D))

    out = jax.vmap(scatter_back)(contrib, slot_tok)
    out = shard(out, ("batch", "seq_sp", "embed"))

    # aux losses (Switch-style load balance + router z-loss)
    frac_tok = jnp.mean(oh.astype(jnp.float32).sum(2), axis=(0, 1))   # f_e
    frac_prob = probs.mean(axis=(0, 1))                               # p_e
    aux = E * jnp.sum(frac_tok * frac_prob) * m.aux_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    drop = 1.0 - valid.astype(jnp.float32).mean()
    return out, {"aux_loss": aux, "z_loss": z, "drop_frac": drop}
