"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them bit-for-bit (integer
outputs) / to float tolerance (float outputs) in the per-kernel sweep tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# aer_encode: block-local thresholded event extraction (the TX path).
#
# Given a (num_blocks, block) dense tensor and a per-block threshold tau,
# select entries with |x| >= tau in index order, keeping at most `budget`
# per block (overflow stays behind for the error-feedback residual), and
# emit fixed-width event slots:
#   idx[r, e]  = block-local index of the e-th selected entry, or -1
#   val[r, e]  = its value, or 0
#   count[r]   = number of entries selected AND emitted (<= budget)
#   wanted[r]  = number of entries over threshold (>= count)
# ---------------------------------------------------------------------------

def aer_encode(x: jnp.ndarray, tau: jnp.ndarray, budget: int):
    nb, blk = x.shape
    tau = jnp.broadcast_to(jnp.asarray(tau, x.dtype).reshape(-1, 1), (nb, 1))
    # AER semantics: no activity, no event — zeros never ship, even when the
    # threshold collapses to 0 (else they'd waste budget slots).
    mask = (jnp.abs(x) >= tau) & (x != 0)
    csum = jnp.cumsum(mask.astype(jnp.int32), axis=1)
    sel = mask & (csum <= budget)
    dest = csum - 1  # target slot for selected entries

    iota_e = jnp.arange(budget, dtype=jnp.int32)
    # one-hot scatter: slot e receives the entry whose dest == e
    onehot = (dest[:, :, None] == iota_e[None, None, :]) & sel[:, :, None]
    onehot_f = onehot.astype(jnp.float32)
    val = jnp.einsum("rbe,rb->re", onehot_f, x.astype(jnp.float32))
    iota_b = jnp.arange(blk, dtype=jnp.float32) + 1.0
    idx = jnp.einsum("rbe,b->re", onehot_f, iota_b).astype(jnp.int32) - 1

    wanted = csum[:, -1]
    count = jnp.minimum(wanted, budget)
    return idx, val.astype(x.dtype), count, wanted


# ---------------------------------------------------------------------------
# aer_decode: event slots -> dense accumulation (the RX path).
# Duplicate addresses accumulate (sum semantics); idx == -1 slots are void.
# ---------------------------------------------------------------------------

def aer_decode(idx: jnp.ndarray, val: jnp.ndarray, block: int):
    nb, budget = idx.shape
    iota_b = jnp.arange(block, dtype=jnp.int32)
    onehot = (idx[:, :, None] == iota_b[None, None, :]) & (idx[:, :, None] >= 0)
    dense = jnp.einsum("reb,re->rb", onehot.astype(jnp.float32),
                       val.astype(jnp.float32))
    return dense.astype(val.dtype)


# ---------------------------------------------------------------------------
# lif_step: fused leaky integrate-and-fire neuron update.
#   v'      = v * decay + i_syn
#   spike   = v' >= v_th
#   v_next  = v_reset where spike else v'
# Shapes: (rows, lanes) float32; returns (v_next, spike as input dtype).
# ---------------------------------------------------------------------------

def lif_step(v: jnp.ndarray, i_syn: jnp.ndarray, decay: float, v_th: float,
             v_reset: float):
    v2 = v * jnp.asarray(decay, v.dtype) + i_syn
    spike = (v2 >= jnp.asarray(v_th, v.dtype))
    v_next = jnp.where(spike, jnp.asarray(v_reset, v.dtype), v2)
    return v_next, spike.astype(v.dtype)


# ---------------------------------------------------------------------------
# fabric_queue_scan / fabric_queue_update: the per-micro-transaction queue
# step of core/network.py's slot engines.  q_time is (Q, C) int32 release
# times with BIG_NS (2**30) marking empty/consumed one-shot slots; t_q is
# the (Q,) per-queue clock.  These ARE the reference engine's per-step
# queue semantics — the Pallas kernels in fabric_queue.py must match them
# bit-for-bit (tested in tests/test_fabric_queue_kernel.py).
# ---------------------------------------------------------------------------

from ..core.protocol_sim import BIG_NS as _QBIG  # noqa: E402


def fabric_queue_scan(q_time: jnp.ndarray, q_dest: jnp.ndarray,
                      t_q: jnp.ndarray):
    """Per-queue released-count / min-release / next-arrival / argmin-pop
    / backlog indicator / head route.

    Returns ``(pend, r_min, nxt, amin, busy, head_route)``, each (Q,)
    int32; ``amin`` is the slot a pop must consume (lowest released slot
    of the minimum release time — FIFO among simultaneous arrivals; 0
    for empty rows); ``busy`` is the 0/1 released-work indicator
    (``pend > 0``) the telemetry plane accumulates per
    micro-transaction; ``head_route`` is ``q_dest[q, amin[q]]`` — the
    route id a pop of this queue would dispatch, read here so the
    flow-control gate can inspect each head's downstream targets
    *before* the FSM step without a second O(C) pass (garbage-but-valid
    for empty rows, exactly like the engines' post-step gather).
    """
    released = q_time <= t_q[:, None]
    pend = jnp.sum(released.astype(jnp.int32), axis=1)
    val = jnp.where(released, q_time, _QBIG)
    r_min = jnp.min(val, axis=1)
    nxt = jnp.min(jnp.where(released, _QBIG, q_time), axis=1)
    amin = jnp.argmin(val, axis=1).astype(jnp.int32)
    busy = (pend > 0).astype(jnp.int32)
    head_route = jnp.take_along_axis(q_dest, amin[:, None], axis=1)[:, 0]
    return pend, r_min, nxt, amin, busy, head_route


def fabric_queue_update(q_time, q_dest, q_inj, pop_q, pop_slot,
                        app_q, app_slot, app_t, app_dest, app_inj):
    """Consume popped slots (back to BIG_NS) and append forwarded copies.

    ``pop_q``: (Lp,) queue row per link; ``app_q``: (La,) queue row per
    append lane — La may exceed Lp (L·K lanes when in-fabric multicast
    replicates one pop into up to K child copies).  Any id >= Q skips
    the lane (dropped indices).  Append targets are unique (queue, slot)
    pairs, and pop and append slots are disjoint by construction
    (appends land at ``n_ins``, beyond released slots).
    """
    q_time = q_time.at[pop_q, pop_slot].set(_QBIG, mode="drop")
    q_time = q_time.at[app_q, app_slot].set(app_t, mode="drop")
    q_dest = q_dest.at[app_q, app_slot].set(app_dest, mode="drop")
    q_inj = q_inj.at[app_q, app_slot].set(app_inj, mode="drop")
    return q_time, q_dest, q_inj


def fabric_queue_multistep(carry, consts, base, *, step_fn, chunk: int,
                           max_steps: int):
    """Multi-step oracle: the semantics of one
    ``fabric_queue_multistep_pallas`` launch in pure jnp (no Pallas).

    Steps the packed carry ``min(chunk, max_steps - base)`` times with a
    plain ``lax.fori_loop`` — same dynamic bound as the kernel, so a
    binding ``max_steps`` truncates the final chunk identically.  The
    injected ``step_fn`` should be built over *this module's*
    ``fabric_queue_scan`` / ``fabric_queue_update`` (the engine's
    ``kernels="ref"`` wiring does exactly that), making the oracle
    Pallas-free end to end; the kernel must match it bit-for-bit for
    any step_fn (tested in tests/test_fabric_queue_kernel.py).
    """
    b = jnp.asarray(base).reshape(-1)[0]
    n = jnp.minimum(chunk, max_steps - b)

    def body(i, c):
        return step_fn(c, tuple(consts), b + i)

    return jax.lax.fori_loop(0, n, body, tuple(carry))


# ---------------------------------------------------------------------------
# selective_scan_ref: plain time-step loop oracle for the S6 recurrence
#   h_t = exp(dt_t · A) ⊙ h_{t-1} + (dt_t · x_t) ⊗ B_t ;  y_t = h_t · C_t
# ---------------------------------------------------------------------------

def selective_scan_ref(x, dt, b_ssm, c_ssm, a):
    """x, dt: (B, S, d_in); b_ssm/c_ssm: (B, S, N); a: (d_in, N).
    Returns (y (B,S,d_in), h_final (B,d_in,N)), all f32."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp            # (B,d_in),(B,d_in),(B,N),(B,N)
        abar = jnp.exp(dtt[..., None] * a)
        bx = (dtt * xt)[..., None] * bt[:, None, :]
        h = abar * h + bx
        y = (h * ct[:, None, :]).sum(-1)
        return h, y

    B, S, d_in = x.shape
    h0 = jnp.zeros((B, d_in, a.shape[1]), jnp.float32)
    hf, ys = jax.lax.scan(step, h0,
                          (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                           b_ssm.swapaxes(0, 1), c_ssm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), hf
