"""Pallas TPU kernel: AER event decoder (RX path of the transceiver).

Accumulates fixed-width event slots back into a dense block:
``dense[r, b] = sum_e [idx[r, e] == b] * val[r, e]``.  As with the encoder,
the gather/scatter is recast as a one-hot contraction so the accumulation
runs on the MXU; duplicate addresses therefore sum naturally (the AER
semantics — two spikes at one address are two contributions).

VMEM per grid step (rows_per_block=4, budget=128, block=1024): one-hot
2 MiB + slots 4 KiB.  idx == -1 marks a void slot (matches no address).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _decode_kernel(idx_ref, val_ref, out_ref):
    idx = idx_ref[...]                  # (rows, budget) i32
    val = val_ref[...]                  # (rows, budget)
    rows, budget = idx.shape
    block = out_ref.shape[-1]

    iota_b = jax.lax.broadcasted_iota(jnp.int32, (rows, budget, block), 2)
    onehot = ((idx[:, :, None] == iota_b) & (idx[:, :, None] >= 0)).astype(
        jnp.float32)

    dense = jax.lax.dot_general(
        val.astype(jnp.float32)[:, None, :], onehot,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, 0, :]
    out_ref[...] = dense.astype(out_ref.dtype)


def aer_decode_pallas(idx: jnp.ndarray, val: jnp.ndarray, block: int,
                      *, rows_per_block: int = 4,
                      interpret: bool | str | None = None):
    """idx/val: (num_blocks, budget); returns dense (num_blocks, block)."""
    nb, budget = idx.shape
    assert nb % rows_per_block == 0, (nb, rows_per_block)
    grid = (nb // rows_per_block,)

    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, budget), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, budget), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), val.dtype),
        interpret=resolve_interpret(interpret),
    )(idx, val)
