"""Pallas TPU kernels: the fabric event-queue step (network.py hot path).

The fabric simulator's slot layout keeps, per endpoint queue, ``C``
one-shot slots of int32 release times (``BIG_NS`` = empty/consumed).
Each micro-transaction needs four reductions over every queue —

  pend   how many slots have been released (release <= clock),
  r_min  the earliest released release time (conservative-pop guard),
  nxt    the earliest *future* release (idle-link wake-up target),
  amin   the slot to pop: first index of the released minimum
         (``jnp.argmin`` semantics — lowest slot wins ties, i.e. FIFO
         among simultaneous arrivals),

— followed by a sparse update: consume at most one popped slot per link
(set it back to ``BIG_NS``) and append the step's forwarded copies at
their queues' insertion slots.  In-fabric multicast replication spawns
up to ``K`` child copies per pop, so the append operands are (L·K,)
lanes while the pop operands stay (L,) — the one-hot scatter handles
the two widths independently.  Off-kernel this is several separate
O(Q·C) passes per step; here each becomes ONE pass.

TPU adaptation notes (mirroring ``aer_encode.py``):

* The scan kernel materializes the released mask once per VMEM tile and
  feeds all four reductions from it.  argmin is recast as
  ``min(where(val == row_min, iota, C))`` — the first-minimum-index
  trick — so no argmin lowering is needed and the tie rule matches
  ``jnp.argmin`` exactly.
* The update kernel recasts both scatters as ONE-HOT MATMULS (VMEM has
  no scatter): with ``A[r, l] = [pop_q[l] == r]`` and
  ``S[l, c] = [pop_slot[l] == c]``, the pop mask is ``A @ S`` and the
  append values are ``(B * value) @ S_app`` — (rows × links × C)
  contractions that run on the MXU.  All arithmetic stays int32 so
  release times up to the ``BIG_NS`` sentinel (2**30) survive exactly
  (an f32 accumulator's 24-bit mantissa would corrupt them).
* Out-of-range ids (the caller's "no pop / no append on this link"
  sentinel ``Q``; dropped forwards) simply match no row — the one-hot
  formulation gives masked scatter for free.

Validated bit-exactly against ``ref.fabric_queue_scan`` /
``ref.fabric_queue_update`` in interpret mode (CPU container); the
grid/BlockSpec layout is the TPU deployment configuration.

These kernels back ``engine="pallas"`` of the fabric front-end
(``fabric.EngineSpec`` / the ``simulate_fabric`` wrapper).  They are
deliberately timing-agnostic: the queue step sees only release times and
per-queue clocks, so per-link timing heterogeneity (structure-of-arrays
``LinkTiming``) flows through the engine's dynamic cost vectors without
touching the kernel layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.protocol_sim import BIG_NS
from .dispatch import resolve_interpret

# plain Python int: a jnp scalar would be a captured constant inside the
# kernel, which pallas_call rejects
_BIG = int(BIG_NS)


def scan_math(q, qd, t):
    """Value-level body of the scan kernel (kernel-safe jnp only).

    Shared by ``_scan_kernel`` (one VMEM tile per grid step) and the
    multi-step kernel's in-loop queue scan, so the tile math — the
    first-minimum-index argmin recast, the one-hot head-route select —
    exists exactly once.  Returns the six (rows,) int32 reductions.
    """
    rows, ncols = q.shape
    released = q <= t[:, None]
    val = jnp.where(released, q, _BIG)
    row_min = jnp.min(val, axis=1)
    pend = jnp.sum(released.astype(jnp.int32), axis=1)
    nxt = jnp.min(jnp.where(released, _BIG, q), axis=1)
    # first-minimum-index == jnp.argmin (all-BIG rows resolve to slot 0)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (rows, ncols), 1)
    amin = jnp.min(
        jnp.where(val == row_min[:, None], iota_c, ncols), axis=1)
    # 0/1 backlog indicator: the released mask is already in VMEM, so the
    # telemetry plane's per-step counter costs one more reduction of the
    # same tile instead of a second O(Q*C) pass off-kernel
    busy = (pend > 0).astype(jnp.int32)
    # head route = q_dest[row, amin] as a one-hot select (no gather
    # lowering needed): amin matches exactly one column per row, so the
    # masked sum IS the gather.  Feeds the flow-control admission gate.
    route = jnp.sum(jnp.where(iota_c == amin[:, None], qd, 0), axis=1)
    return pend, row_min, nxt, amin, busy, route


def _scan_kernel(q_ref, qd_ref, t_ref, pend_ref, rmin_ref, nxt_ref,
                 amin_ref, busy_ref, route_ref):
    pend, r_min, nxt, amin, busy, route = scan_math(
        q_ref[...], qd_ref[...], t_ref[...])
    pend_ref[...] = pend
    rmin_ref[...] = r_min
    nxt_ref[...] = nxt
    amin_ref[...] = amin
    busy_ref[...] = busy
    route_ref[...] = route


def fabric_queue_step_pallas(q_time: jnp.ndarray, q_dest: jnp.ndarray,
                             t_q: jnp.ndarray, *,
                             rows_per_block: int = 8,
                             interpret: bool | str | None = None):
    """Fused queue-step reductions.

    Args:
      q_time: (Q, C) int32 release times, ``BIG_NS`` = empty slot.
      q_dest: (Q, C) int32 route ids riding the slots.
      t_q:    (Q,) int32 per-queue clock.

    Returns ``(pend, r_min, nxt, amin, busy, head_route)``, each (Q,)
    int32 (``busy`` = 0/1 released-backlog indicator for the telemetry
    plane; ``head_route`` = the would-pop slot's route id for the
    flow-control gate).
    """
    nq, _ = q_time.shape
    assert nq % rows_per_block == 0, (nq, rows_per_block)
    grid = (nq // rows_per_block,)

    out_shape = [jax.ShapeDtypeStruct((nq,), jnp.int32) for _ in range(6)]
    row_spec = pl.BlockSpec((rows_per_block,), lambda i: (i,))
    tile = pl.BlockSpec((rows_per_block, q_time.shape[1]), lambda i: (i, 0))
    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[tile, tile, row_spec],
        out_specs=[row_spec] * 6,
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(q_time, q_dest, t_q)


def update_math(qt, qd, qi, popq, pops, appq, apps, appt, appd, appi,
                row_base=0):
    """Value-level body of the update kernel (scatter-as-matmul, int32).

    ``row_base`` offsets the tile's row ids when the caller processes a
    (rows, C) slice of a larger array (the gridded per-step kernel); the
    multi-step kernel passes the whole array with ``row_base=0``.
    Shared so the one-hot matmul scatter exists exactly once.  Returns
    the updated ``(q_time, q_dest, q_inj)`` values.
    """
    rows, ncols = qt.shape
    row_ids = row_base + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)

    n_pop = popq.shape[0]                # (Lp,) lanes
    n_app = appq.shape[0]                # (La,) = Lp·K under mcast

    iota_pop = jax.lax.broadcasted_iota(jnp.int32, (n_pop, ncols), 1)
    iota_app = jax.lax.broadcasted_iota(jnp.int32, (n_app, ncols), 1)
    dn = (((1,), (0,)), ((), ()))

    # scatter-as-matmul, int32 end to end (exact for times < 2**31)
    a_pop = (row_ids == popq[None, :]).astype(jnp.int32)     # (rows, Lp)
    s_pop = (pops[:, None] == iota_pop).astype(jnp.int32)    # (Lp, C)
    p_pop = jax.lax.dot_general(a_pop, s_pop, dn,
                                preferred_element_type=jnp.int32)

    a_app = (row_ids == appq[None, :]).astype(jnp.int32)     # (rows, La)
    s_app = (apps[:, None] == iota_app).astype(jnp.int32)    # (La, C)
    p_app = jax.lax.dot_general(a_app, s_app, dn,
                                preferred_element_type=jnp.int32)

    def scatter(vals):
        return jax.lax.dot_general(a_app * vals[None, :], s_app, dn,
                                   preferred_element_type=jnp.int32)

    keep = 1 - p_pop - p_app             # pop/append slots are disjoint
    return (qt * keep + _BIG * p_pop + scatter(appt),
            qd * (1 - p_app) + scatter(appd),
            qi * (1 - p_app) + scatter(appi))


def _update_kernel(qt_ref, qd_ref, qi_ref, popq_ref, pops_ref,
                   appq_ref, apps_ref, appt_ref, appd_ref, appi_ref,
                   ot_ref, od_ref, oi_ref, *, rows_per_block: int):
    ot, od, oi = update_math(
        qt_ref[...], qd_ref[...], qi_ref[...], popq_ref[...], pops_ref[...],
        appq_ref[...], apps_ref[...], appt_ref[...], appd_ref[...],
        appi_ref[...], row_base=pl.program_id(0) * rows_per_block)
    ot_ref[...] = ot
    od_ref[...] = od
    oi_ref[...] = oi


def fabric_queue_update_pallas(q_time, q_dest, q_inj,
                               pop_q, pop_slot,
                               app_q, app_slot, app_t, app_dest, app_inj,
                               *, rows_per_block: int = 8,
                               interpret: bool | str | None = None):
    """Fused pop-consume + forward-append over the (Q, C) slot arrays.

    ``pop_q`` / ``app_q`` hold a queue id per lane, or ``Q`` (any id
    >= Q) to skip that lane; popped slots revert to ``BIG_NS``, appended
    slots receive ``(app_t, app_dest, app_inj)``.  The append lanes may
    outnumber the pop lanes (L·K vs L when in-fabric multicast
    replicates one pop into up to K child copies); every (queue, slot)
    append target must be unique, and pop and append slots must be
    disjoint (the engine appends at ``n_ins``, beyond any released
    slot).  Returns the three updated arrays.
    """
    nq, ncols = q_time.shape
    assert nq % rows_per_block == 0, (nq, rows_per_block)
    grid = (nq // rows_per_block,)

    kernel = functools.partial(_update_kernel, rows_per_block=rows_per_block)
    tile = pl.BlockSpec((rows_per_block, ncols), lambda i: (i, 0))
    whole_pop = pl.BlockSpec((pop_q.shape[0],), lambda i: (0,))
    whole_app = pl.BlockSpec((app_q.shape[0],), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((nq, ncols), jnp.int32)
                 for _ in range(3)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tile, tile, tile,
                  whole_pop, whole_pop,
                  whole_app, whole_app, whole_app, whole_app, whole_app],
        out_specs=[tile, tile, tile],
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(q_time, q_dest, q_inj, pop_q, pop_slot,
      app_q, app_slot, app_t, app_dest, app_inj)


# ---------------------------------------------------------------------------
# Multi-step fused kernel: the whole micro-transaction loop per launch
# ---------------------------------------------------------------------------

def fabric_queue_multistep_pallas(carry, consts, base, *, step_fn,
                                  chunk: int, max_steps: int,
                                  interpret: bool | str | None = None):
    """Run up to ``chunk`` fabric micro-transactions in ONE kernel launch.

    The per-step path above dispatches two ``pallas_call``s per
    micro-transaction and round-trips the full engine state through XLA
    between them — 2·max_steps kernel launches per simulation, each
    re-loading the (Q, C) slot arrays from HBM.  This kernel instead
    loads the packed carry once, steps it ``chunk`` times with a
    ``lax.fori_loop`` *inside* the kernel body (the carry stays resident
    in VMEM/registers across steps), and writes it back once: HBM
    traffic and launch count drop by the chunk factor.

    The step loop is an in-kernel ``fori_loop`` rather than a grid
    dimension deliberately: scratch carried across sequential grid steps
    is a TPU-only guarantee, and interpret mode *unrolls* grid
    iterations at trace time (chunk copies of the body), while a
    ``fori_loop`` body traces once on every backend.  The outer
    chunk-of-steps structure is the caller's ``lax.scan`` over
    ``base`` values (``core.network._slot_run_multistep``).

    Args:
      carry:  tuple of int32 state arrays (slot arrays + packed lane /
              side / log / counter planes — the caller owns the layout).
      consts: tuple of read-only int32 arrays (links, replication
              tables, timing planes, flow-control scalars).
      base:   (1,) int32 — global index of this chunk's first step.
      step_fn: ``step_fn(carry, consts, step_i) -> carry`` — one
              micro-transaction of physics, built by the engine so the
              kernel body and the pure-jnp oracle
              (``ref.fabric_queue_multistep``) share it verbatim.  The
              queue scan / scatter math inside it must use
              :func:`scan_math` / :func:`update_math` (kernel-safe,
              scatter-as-matmul) — that is what moves the pop/append
              contractions inside the kernel body.
      chunk / max_steps: static ints.  The loop bound is
              ``min(chunk, max_steps - base)`` — dynamic, so a binding
              ``max_steps`` is honoured exactly (post-bound steps are
              NOT executed; they are not guaranteed to be no-ops).

    Returns the stepped carry tuple (same shapes/dtypes).
    """
    carry = tuple(carry)
    consts = tuple(consts)
    n_car = len(carry)
    n_con = len(consts)

    def kernel(*refs):
        car = tuple(r[...] for r in refs[:n_car])
        con = tuple(r[...] for r in refs[n_car:n_car + n_con])
        b = refs[n_car + n_con][0]
        out_refs = refs[n_car + n_con + 1:]
        n = jnp.minimum(chunk, max_steps - b)

        def body(i, c):
            return step_fn(c, con, b + i)

        out = jax.lax.fori_loop(0, n, body, car)
        for o_ref, o in zip(out_refs, out):
            o_ref[...] = o

    out_shape = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in carry]
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(*carry, *consts, base)
