"""Public jit'd wrappers around the Pallas kernels.

Handles flattening/padding arbitrary tensors into (num_blocks, block) tiles,
threshold selection, event packing (26-bit-style wire words), and the
error-feedback compose used by the sparse collectives.

``interpret=None`` auto-selects via ``dispatch.resolve_interpret``:
compiled wherever a Pallas backend exists (TPU/GPU), interpret elsewhere
(this container is CPU-only; the BlockSpec layout is the TPU deployment
config).  ``PALLAS_INTERPRET=1`` forces interpret mode everywhere — note
it is read when a wrapper first traces, so set it before the first call.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import events as ev
from . import ref
from .aer_decode import aer_decode_pallas
from .aer_encode import aer_encode_pallas
from .dispatch import resolve_interpret as _auto_interpret
from .fabric_queue import (fabric_queue_multistep_pallas,
                           fabric_queue_step_pallas,
                           fabric_queue_update_pallas)
from .lif_step import lif_step_pallas

DEFAULT_BLOCK = 1024
DEFAULT_BUDGET = 128


class EventBlocks(NamedTuple):
    """A compressed tensor: fixed-width AER event slots per block."""
    idx: jnp.ndarray     # (num_blocks, budget) i32, -1 = void
    val: jnp.ndarray     # (num_blocks, budget) float
    count: jnp.ndarray   # (num_blocks,) i32 — events emitted
    wanted: jnp.ndarray  # (num_blocks,) i32 — events over threshold

    @property
    def wire_words(self):
        """Packed uint32 wire words ((idx:16|bf16:16) — events.py format)."""
        return ev.pack_events(jnp.maximum(self.idx, 0), self.val)

    def wire_bytes(self):
        """Actual bytes on the wire under run-length framing: only `count`
        slots per block ship (void slots are never driven onto the bus)."""
        return jnp.sum(self.count) * 4 + self.count.shape[0] * 4


def pad_to_blocks(x: jnp.ndarray, block: int = DEFAULT_BLOCK):
    """Flatten + zero-pad to (num_blocks, block). Returns (tiles, orig_size)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = max(1, -(-n // block))
    pad = nb * block - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(nb, block), n


def unpad_from_blocks(tiles: jnp.ndarray, orig_size: int, shape):
    return tiles.reshape(-1)[:orig_size].reshape(shape)


def tau_from_fraction(x_tiles: jnp.ndarray, frac: float):
    """Per-block threshold that keeps ~frac of entries (quantile of |x|)."""
    q = jnp.clip(1.0 - frac, 0.0, 1.0)
    return jnp.quantile(jnp.abs(x_tiles.astype(jnp.float32)), q, axis=1).astype(
        x_tiles.dtype)


@functools.partial(jax.jit, static_argnames=("budget", "interpret",
                                             "rows_per_block", "use_ref"))
def aer_compress(x_tiles: jnp.ndarray, tau: jnp.ndarray,
                 budget: int = DEFAULT_BUDGET, *, interpret: bool | None = None,
                 rows_per_block: int = 4, use_ref: bool = False) -> EventBlocks:
    """Encode (num_blocks, block) tiles into event slots."""
    if use_ref:
        out = ref.aer_encode(x_tiles, tau, budget)
    else:
        nb = x_tiles.shape[0]
        rpb = rows_per_block
        while nb % rpb:
            rpb //= 2
        out = aer_encode_pallas(x_tiles, tau, budget, rows_per_block=max(rpb, 1),
                                interpret=_auto_interpret(interpret))
    return EventBlocks(*out)


@functools.partial(jax.jit, static_argnames=("block", "interpret",
                                             "rows_per_block", "use_ref"))
def aer_decompress(events_: EventBlocks, block: int = DEFAULT_BLOCK, *,
                   interpret: bool | None = None, rows_per_block: int = 4,
                   use_ref: bool = False) -> jnp.ndarray:
    if use_ref:
        return ref.aer_decode(events_.idx, events_.val, block)
    nb = events_.idx.shape[0]
    rpb = rows_per_block
    while nb % rpb:
        rpb //= 2
    return aer_decode_pallas(events_.idx, events_.val, block,
                             rows_per_block=max(rpb, 1),
                             interpret=_auto_interpret(interpret))


def compress_with_feedback(x: jnp.ndarray, residual: jnp.ndarray, *,
                           frac: float = 0.05, budget: int = DEFAULT_BUDGET,
                           block: int = DEFAULT_BLOCK,
                           interpret: bool | None = None):
    """Error-feedback AER compression of one tensor.

    y = x + residual; events = encode(y); residual' = y - decode(events).
    Returns (EventBlocks, new_residual, orig_size).
    """
    y = x + residual
    tiles, n = pad_to_blocks(y, block)
    tau = tau_from_fraction(tiles, frac)
    events_ = aer_compress(tiles, tau, budget, interpret=interpret)
    dec = aer_decompress(events_, block, interpret=interpret)
    new_res = unpad_from_blocks(tiles - dec, n, x.shape)
    return events_, new_res, n


def _rows_per_block_for(nq: int, rows_per_block: int) -> int:
    rpb = rows_per_block
    while nq % rpb:
        rpb //= 2
    return max(rpb, 1)


@functools.partial(jax.jit, static_argnames=("interpret", "use_ref",
                                             "rows_per_block"))
def fabric_queue_scan(q_time: jnp.ndarray, q_dest: jnp.ndarray,
                      t_q: jnp.ndarray, *,
                      interpret: bool | None = None, use_ref: bool = False,
                      rows_per_block: int = 8):
    """Fused per-queue released-count / min-release / next-arrival /
    argmin-pop / backlog-indicator / head-route over (Q, C) slot arrays
    (the fabric engine's O(C) step).

    Returns ``(pend, r_min, nxt, amin, busy, head_route)``, each (Q,)
    int32.

    vmap-compatible: under a batched fabric run (``Fabric.run_batch``
    with ``engine="pallas"``) the leading ``(B,)`` instance axis lowers
    through ``pallas_call``'s batching rule as an extra grid dimension —
    B independent (Q, C) scans in one kernel launch, bit-exact with the
    solo calls (interpret mode included; asserted by the batch tests).
    """
    if use_ref:
        return ref.fabric_queue_scan(q_time, q_dest, t_q)
    return fabric_queue_step_pallas(
        q_time, q_dest, t_q,
        rows_per_block=_rows_per_block_for(q_time.shape[0], rows_per_block),
        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret", "use_ref",
                                             "rows_per_block"))
def fabric_queue_update(q_time, q_dest, q_inj, pop_q, pop_slot,
                        app_q, app_slot, app_t, app_dest, app_inj, *,
                        interpret: bool | None = None, use_ref: bool = False,
                        rows_per_block: int = 8):
    """Fused pop-consume + forward-append scatter on the slot arrays.

    Queue ids >= Q skip the lane (no pop / dropped forward); the append
    lanes may outnumber the pop lanes (in-fabric multicast replication:
    L·K candidate copies for L pops).  Returns the updated
    ``(q_time, q_dest, q_inj)``.  vmap-compatible like
    :func:`fabric_queue_scan` — per-instance queue/slot ids need no
    offsetting because each batch member scatters into its own (Q, C)
    slice.
    """
    if use_ref:
        return ref.fabric_queue_update(q_time, q_dest, q_inj, pop_q,
                                       pop_slot, app_q, app_slot, app_t,
                                       app_dest, app_inj)
    return fabric_queue_update_pallas(
        q_time, q_dest, q_inj, pop_q, pop_slot,
        app_q, app_slot, app_t, app_dest, app_inj,
        rows_per_block=_rows_per_block_for(q_time.shape[0], rows_per_block),
        interpret=_auto_interpret(interpret))


def fabric_queue_multistep(carry, consts, base, *, step_fn, chunk: int,
                           max_steps: int, interpret: bool | None = None,
                           use_ref: bool = False):
    """Fused multi-step fabric loop: ``chunk`` micro-transactions per
    kernel launch, carry resident across steps (vs. 2 launches + a full
    state round-trip per step on the per-step path).

    Not jitted here — the engine (``core.network._slot_run_multistep``)
    calls it inside its own jitted chunk scan, and ``step_fn`` is a
    per-engine closure (jit static-arg hashing by closure identity
    would defeat the cache).
    """
    if use_ref:
        return ref.fabric_queue_multistep(carry, consts, base,
                                          step_fn=step_fn, chunk=chunk,
                                          max_steps=max_steps)
    return fabric_queue_multistep_pallas(carry, consts, base,
                                         step_fn=step_fn, chunk=chunk,
                                         max_steps=max_steps,
                                         interpret=interpret)


def lif_step(v: jnp.ndarray, i_syn: jnp.ndarray, *, decay: float = 0.9,
             v_th: float = 1.0, v_reset: float = 0.0,
             interpret: bool | None = None, use_ref: bool = False):
    """Fused LIF update on (rows, lanes) state."""
    if use_ref:
        return ref.lif_step(v, i_syn, decay, v_th, v_reset)
    rows = v.shape[0]
    br = 8
    while rows % br:
        br //= 2
    return lif_step_pallas(v, i_syn, decay=decay, v_th=v_th, v_reset=v_reset,
                           block_rows=max(br, 1),
                           interpret=_auto_interpret(interpret))
