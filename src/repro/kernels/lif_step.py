"""Pallas TPU kernel: fused leaky integrate-and-fire update (SNN example).

One pass over the membrane state: decay, integrate synaptic current,
threshold, reset — four elementwise ops fused into a single VMEM-resident
kernel (the HBM-bound alternative reads/writes v four times).  Tiles are
(8k, 128)-aligned for the VPU lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _lif_kernel(v_ref, i_ref, v_out_ref, s_out_ref, *, decay, v_th, v_reset):
    v = v_ref[...]
    i_syn = i_ref[...]
    v2 = v * jnp.asarray(decay, v.dtype) + i_syn
    spike = v2 >= jnp.asarray(v_th, v.dtype)
    v_out_ref[...] = jnp.where(spike, jnp.asarray(v_reset, v.dtype), v2)
    s_out_ref[...] = spike.astype(v.dtype)


def lif_step_pallas(v: jnp.ndarray, i_syn: jnp.ndarray, *, decay: float,
                    v_th: float, v_reset: float,
                    block_rows: int = 8,
                    interpret: bool | str | None = None):
    """v, i_syn: (rows, lanes) float32; lanes should be a multiple of 128.

    Returns (v_next, spikes) with spikes in v.dtype (0.0 / 1.0).
    """
    rows, lanes = v.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    kernel = functools.partial(_lif_kernel, decay=decay, v_th=v_th,
                               v_reset=v_reset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, lanes), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, lanes), v.dtype),
            jax.ShapeDtypeStruct((rows, lanes), v.dtype),
        ],
        interpret=resolve_interpret(interpret),
    )(v, i_syn)
