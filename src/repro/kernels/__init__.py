"""Pallas TPU kernels for the paper's communication hot-spots:
AER event encode (TX), decode (RX), the fused LIF update used by the
paper-native SNN chip-array example, and the fused Mamba selective scan
(the compute hot-spot of the SSM/hybrid architectures).  See ops.py for the public API and
ref.py for the pure-jnp oracles."""

from .ops import (EventBlocks, aer_compress, aer_decompress,  # noqa: F401
                  compress_with_feedback, fabric_queue_scan,
                  fabric_queue_update, lif_step, pad_to_blocks,
                  tau_from_fraction, unpad_from_blocks)
from .selective_scan import selective_scan_pallas  # noqa: F401
