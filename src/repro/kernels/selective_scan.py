"""Pallas TPU kernel: fused Mamba selective scan.

The S6 recurrence  h_t = exp(dt_t·A)·h_t−1 + (dt_t·x_t)·B_t ;  y_t = h_t·C_t
is memory-bound when staged through HBM (the chunked-jnp path materializes
(B, chunk, d_inner, N) discretization tensors per chunk).  This kernel keeps
the ENTIRE state trajectory in VMEM: one grid step owns a (d_block × N)
state tile and walks the full sequence with a ``fori_loop``, reading one
(d_block,) x/dt lane-row and one (N,) B/C row per step, writing one y row.
HBM traffic collapses to the operands + outputs (no intermediate tensors).

Grid: (batch, d_inner / d_block).  VMEM per step (defaults d_block=512,
N=16, S≤4096): x/dt tiles 2·S·d_block·4B ≈ 16 MiB at S=4096/d_block=512 —
choose d_block so the tile fits (the wrapper auto-shrinks); state tile
512×16×4 = 32 KiB.  d_inner is TP-sharded over the model axis, so per-core
sequences see d_inner/16 lanes — d_block=512 covers falcon-mamba exactly.

Validated in interpret mode vs ``ref.selective_scan_ref`` and the
production chunked-associative-scan path (tests/test_kernels_scan.py).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_ref):
    S = x_ref.shape[1]
    d_blk = x_ref.shape[2]
    N = a_ref.shape[1]
    A = a_ref[...]                       # (d_blk, N)

    def body(t, h):
        dtv = dt_ref[0, t, :]            # (d_blk,)
        xv = x_ref[0, t, :]
        bv = b_ref[0, t, :]              # (N,)
        cv = c_ref[0, t, :]
        abar = jnp.exp(dtv[:, None] * A)
        bx = (dtv * xv)[:, None] * bv[None, :]
        h = abar * h + bx                # (d_blk, N)
        y_ref[0, t, :] = (h * cv[None, :]).sum(axis=-1)
        return h

    h = jax.lax.fori_loop(0, S, body,
                          jnp.zeros((d_blk, N), jnp.float32))
    h_ref[0] = h


def selective_scan_pallas(x, dt, b_ssm, c_ssm, a, *, d_block: int = 512,
                          interpret: bool = True):
    """x, dt: (B, S, d_in) f32; b_ssm/c_ssm: (B, S, N); a: (d_in, N).

    Returns (y: (B, S, d_in) f32, h_final: (B, d_in, N) f32).
    """
    B, S, d_in = x.shape
    N = a.shape[1]
    while d_in % d_block:
        d_block //= 2
    grid = (B, d_in // d_block)

    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, S, d_block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, S, d_block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, S, N), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, S, N), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((d_block, N), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, d_block), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, d_block, N), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, d_in), jnp.float32),
            jax.ShapeDtypeStruct((B, d_in, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, b_ssm, c_ssm, a)
