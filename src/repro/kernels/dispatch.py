"""Backend dispatch for the Pallas kernels: compiled vs interpret.

Every kernel entry point takes ``interpret=None`` ("auto") and routes it
through :func:`resolve_interpret`:

* ``PALLAS_INTERPRET=1`` in the environment forces interpret mode
  everywhere (the escape hatch for debugging a compiled backend);
  ``PALLAS_INTERPRET=0`` forces the compiled path.
* ``None`` / ``"auto"`` picks the compiled path exactly when the active
  JAX backend has a Pallas compiler (TPU via Mosaic, GPU via Triton) and
  interpret mode otherwise — this container is CPU-only, so auto means
  interpret here, but the same wheels on a TPU/GPU host stop silently
  interpreting every kernel.
* An explicit ``True`` / ``False`` is honoured as-is (absent the env
  override).

The resolver is a leaf module (imports only jax) so the individual
kernel files can use it without importing ``ops`` back.
"""

from __future__ import annotations

import os

import jax

__all__ = ["COMPILED_BACKENDS", "resolve_interpret"]

#: backends with a Pallas compiler: Mosaic (TPU) and Triton (GPU).
COMPILED_BACKENDS = frozenset({"tpu", "gpu", "cuda", "rocm"})


def resolve_interpret(interpret: bool | str | None = None) -> bool:
    """Resolve an ``interpret`` knob to a concrete bool.

    Precedence: ``PALLAS_INTERPRET`` env var, then an explicit bool,
    then backend auto-detection for ``None`` / ``"auto"``.
    """
    env = os.environ.get("PALLAS_INTERPRET")
    if env is not None and env.strip() != "":
        return env.strip() not in ("0", "false", "False")
    if interpret is None or interpret == "auto":
        return jax.default_backend() not in COMPILED_BACKENDS
    return bool(interpret)
