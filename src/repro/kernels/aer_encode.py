"""Pallas TPU kernel: AER event encoder (TX path of the transceiver).

Selects |x| >= tau entries of each block and compacts them into fixed-width
event slots.  TPU adaptation notes (vs. a GPU stream-compaction kernel):

* Compaction-by-scatter is hostile to the TPU vector unit (no VMEM scatter).
  We recast the scatter as a ONE-HOT MATMUL so it runs on the MXU: with
  ``dest = cumsum(mask) - 1``, slot ``e`` of the output receives
  ``sum_b [dest[b] == e] * x[b]`` — two (block × budget) contractions per
  row, hardware-aligned when block and budget are multiples of 128.
* The per-block budget keeps shapes static (SPMD-friendly); overflow beyond
  the budget is deliberately left in place for the caller's error-feedback
  residual — the AER analogue of FIFO back-pressure.
* VMEM working set per grid step (defaults rows_per_block=4, block=1024,
  budget=128): x tile 16 KiB + one-hot 2 MiB f32 — comfortably inside the
  ~16 MiB VMEM of a TPU core; MXU contraction dims are 128-aligned.

Validated against ``ref.aer_encode`` in interpret mode (CPU container);
the grid/BlockSpec layout is the TPU deployment configuration.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret


def _encode_kernel(x_ref, tau_ref, idx_ref, val_ref, count_ref, wanted_ref,
                   *, budget: int):
    x = x_ref[...]                      # (rows, block)
    tau = tau_ref[...]                  # (rows,)
    rows, block = x.shape

    # zeros never ship (AER: no activity, no event) — see ref.aer_encode
    mask = (jnp.abs(x) >= tau[:, None]) & (x != 0)
    csum = jnp.cumsum(mask.astype(jnp.int32), axis=1)
    sel = mask & (csum <= budget)
    dest = csum - 1

    iota_e = jax.lax.broadcasted_iota(jnp.int32, (rows, block, budget), 2)
    onehot = ((dest[:, :, None] == iota_e) & sel[:, :, None]).astype(
        jnp.float32)

    # scatter-as-matmul on the MXU: (rows, block) x (rows, block, budget)
    val = jax.lax.dot_general(
        x.astype(jnp.float32)[:, None, :], onehot,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, 0, :]
    iota_b = (jax.lax.broadcasted_iota(jnp.float32, (1, 1, block), 2) + 1.0)
    idx = jax.lax.dot_general(
        jnp.broadcast_to(iota_b, (rows, 1, block)), onehot,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, 0, :]

    idx_ref[...] = idx.astype(jnp.int32) - 1
    val_ref[...] = val.astype(val_ref.dtype)
    wanted = csum[:, -1]
    wanted_ref[...] = wanted
    count_ref[...] = jnp.minimum(wanted, budget)


def aer_encode_pallas(x: jnp.ndarray, tau: jnp.ndarray, budget: int,
                      *, rows_per_block: int = 4,
                      interpret: bool | str | None = None):
    """x: (num_blocks, block) float; tau: (num_blocks,) float.

    Returns (idx i32, val x.dtype, count i32, wanted i32) with event slots
    (num_blocks, budget).
    """
    nb, block = x.shape
    assert nb % rows_per_block == 0, (nb, rows_per_block)
    grid = (nb // rows_per_block,)

    kernel = functools.partial(_encode_kernel, budget=budget)
    out_shape = [
        jax.ShapeDtypeStruct((nb, budget), jnp.int32),
        jax.ShapeDtypeStruct((nb, budget), x.dtype),
        jax.ShapeDtypeStruct((nb,), jnp.int32),
        jax.ShapeDtypeStruct((nb,), jnp.int32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, block), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_block, budget), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, budget), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block,), lambda i: (i,)),
            pl.BlockSpec((rows_per_block,), lambda i: (i,)),
        ],
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(x, tau)
