"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<k>/arrays.npz + manifest.json  (written to a tmp dir,
fsync'd, then atomically renamed — a crash mid-write never corrupts the
latest checkpoint).  Saves run on a background thread (training continues);
``wait()`` joins before the next save or at shutdown.  ``restore`` rebuilds
the pytree and (optionally) re-shards every leaf onto a NEW mesh — elastic
restart across different topologies is a first-class path, tested in
tests/test_checkpoint.py.

At 1000-node scale each host writes its own shard files; here the
single-process container writes one file but keeps the same manifest/atomic
protocol.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save --
    def save(self, step: int, tree, blocking: bool = False):
        self.wait()
        arrays = _flatten_with_paths(tree)
        treedef = jax.tree.structure(tree)

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{os.getpid()}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": step,
                "time": time.time(),
                "treedef": str(treedef),
                "keys": sorted(arrays.keys()),
                "shapes": {k: list(v.shape) for k, v in arrays.items()},
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree``; optionally
        device_put every leaf with a (new-mesh) sharding tree."""
        self.wait()
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        arrays = {k: data[k] for k in data.files}

        flat = jax.tree_util.tree_flatten_with_path(target_tree)
        leaves = []
        for p, leaf in flat[0]:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            arr = arrays[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape,
                                                    leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        tree = jax.tree.unflatten(flat[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree
