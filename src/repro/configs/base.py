"""Config system: model / shape / mesh / run configs + the arch registry.

Every assigned architecture provides a module ``configs/<id>.py`` exposing
``CONFIG`` (the exact published configuration) and ``smoke_config()`` (a
reduced same-family config for CPU tests).  ``input_specs()`` builds
ShapeDtypeStruct stand-ins for the dry-run (never allocates).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoeConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # "ep": experts sharded over the model axis (needs E % tp == 0)
    # "tp": every expert's d_ff sharded over the model axis (E < tp)
    layout: str = "ep"
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> ceil(d_model / 16)
    chunk: int = 128          # selective-scan chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"      # dense | moe | mamba | hybrid | encoder | vision
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0    # 0 = full attention; >0 = SWA window
    causal: bool = True        # False for encoder-only
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    moe: MoeConfig | None = None
    moe_every: int = 1         # MoE replaces FFN every k-th layer (1 = all)
    mamba: MambaConfig | None = None
    # hybrid (Jamba): per-super-block layer pattern, e.g.
    #   ("mamba","mamba_moe",...) scanned over n_layers // len(pattern) periods
    block_pattern: Sequence[str] = ()
    # vision: cross-attention inserted at these positions within a period of
    # ``xattn_period`` layers; image tokens come from a stub frontend
    xattn_period: int = 0
    xattn_pos: int = 3
    n_img_tokens: int = 0
    d_frontend: int = 0        # stub modality frontend embedding width
    modality: str = "text"     # text | audio_frames | image+text

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    # attention chunking (pure-JAX flash): 0 = auto
    q_chunk: int = 0
    kv_chunk: int = 0
    # sequence-chunked cross-entropy (never materializes (B,S,V) logits):
    # 0 = auto (chunk when S*V is large), -1 = disabled
    loss_chunk: int = 0

    # remat policy for the layer scan: none | dots | full
    remat: str = "full"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head",
                               self.d_model // max(self.n_heads, 1))

    @property
    def dt_rank(self) -> int:
        m = self.mamba or MambaConfig()
        return m.dt_rank or -(-self.d_model // 16)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes (the assigned input-shape sets)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                  LONG_500K)}


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The spec's skip rules: encoder-only archs have no decode shapes;
    ``long_500k`` needs a sub-quadratic path (SSM / hybrid / SWA)."""
    out = [TRAIN_4K, PREFILL_32K]
    if cfg.causal:
        out.append(DECODE_32K)
        subquadratic = (cfg.family in ("mamba", "hybrid")
                        or cfg.sliding_window > 0)
        if subquadratic:
            out.append(LONG_500K)
    return out


# ---------------------------------------------------------------------------
# Run config (distribution + technique knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    # gradient cross-replica reduction: psum | bidir_ring | ring | aer_topk
    dp_reduce: str = "psum"
    aer_frac: float = 0.02          # fraction shipped per step (aer_topk)
    aer_budget: int = 128
    fsdp: bool = True               # shard params over the data axis too
    seq_parallel: bool = False      # shard residual-stream seq over model
    # logical-rule overrides, e.g. {"mamba_inner": ("data", "model")} for
    # 2D weight-stationary serving layouts ("act:" prefix = activation map)
    rules_overrides: tuple = ()     # of (key, value) pairs
    grad_accum: int = 1
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    checkpoint_every: int = 0       # 0 = off
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "minitron_8b", "granite_3_2b", "qwen3_14b", "granite_34b",
    "llama32_vision_11b", "hubert_xlarge", "mixtral_8x22b",
    "moonshot_v1_16b_a3b", "jamba_v01_52b", "falcon_mamba_7b",
]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.smoke_config()


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation) — dry-run fodder
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract input batch for (arch × shape).

    Token LMs take int32 tokens/labels; audio/vlm frontends are STUBS that
    feed precomputed frame/patch embeddings alongside text tokens.
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32

    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.modality == "audio_frames":
            specs = {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_frontend), f32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "mask": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        else:
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            }
        if cfg.modality == "image+text":
            specs["img_embed"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_frontend), f32)
        return specs

    # decode: one new token against a seq_len-deep cache
    specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
             "pos": jax.ShapeDtypeStruct((b,), jnp.int32)}
    if cfg.modality == "image+text":
        specs["img_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.d_frontend), f32)
    return specs
