"""HuBERT X-Large — [arXiv:2106.07447; unverified]. Encoder-only (bidir
attention, no decode shapes), GELU MLP, masked-prediction head over 504
cluster targets. Conv waveform frontend is a STUB (precomputed frames)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504, act="gelu",
    causal=False, modality="audio_frames", d_frontend=1280)


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_head=16, d_ff=128, vocab=32, d_frontend=64)
