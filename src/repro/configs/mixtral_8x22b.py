"""Mixtral-8x22B — [arXiv:2401.04088]. 8 experts top-2, SWA window 4096.
E=8 < tp=16, so experts use the "tp" layout (per-expert d_ff sharded)."""
from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768, act="silu",
    sliding_window=4096,
    moe=MoeConfig(num_experts=8, top_k=2, layout="tp"))


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=512, sliding_window=16,
                        moe=MoeConfig(num_experts=4, top_k=2, layout="tp"))
