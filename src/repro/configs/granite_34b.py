"""Granite-34B-Code — [arXiv:2405.04324]. Llama-arch, MQA (kv=1), 88 layers."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense", n_layers=88, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152, act="silu")


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                        d_head=16, d_ff=128, vocab=512)
