"""Qwen3-14B — [hf:Qwen/Qwen3-14B family]. Dense, GQA kv=8, qk-norm,
head_dim 128 (40 heads x 128 = 5120)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=8, d_head=128, d_ff=17408, vocab=151936,
    act="silu", qk_norm=True)


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=512)
