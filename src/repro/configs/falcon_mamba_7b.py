"""Falcon-Mamba-7B — [arXiv:2410.05355; unverified]. Pure Mamba-1, 64
layers, d_inner = 2*4096 = 8192, ssm_state=16, attention-free."""
from .base import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="mamba", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_head=128, d_ff=0, vocab=65024,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2))


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, vocab=512,
                        mamba=MambaConfig(d_state=4, d_conv=4, expand=2,
                                          chunk=16))
