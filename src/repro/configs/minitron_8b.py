"""Minitron-8B — width-pruned Nemotron-4 [arXiv:2407.14679; hf].
Dense, GQA kv=8, squared-ReLU MLP (Nemotron family), 256k vocab."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=16384, vocab=256000, act="relu2")


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=512)
