"""Jamba-v0.1 (52B total) — [arXiv:2403.19887]. Hybrid: 8-layer blocks with
attn:mamba 1:7 and MoE (16e top-2) every other layer; 4 blocks = 32 layers.
Pattern position 4 is the attention layer (middle of the block)."""
from .base import MambaConfig, ModelConfig, MoeConfig

_PATTERN = ("mamba_ffn", "mamba_moe", "mamba_ffn", "mamba_moe",
            "attn_ffn", "mamba_moe", "mamba_ffn", "mamba_moe")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536, act="silu",
    block_pattern=_PATTERN,
    moe=MoeConfig(num_experts=16, top_k=2, layout="ep"),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2))


def smoke_config():
    return CONFIG.with_(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=512,
                        moe=MoeConfig(num_experts=4, top_k=2, layout="ep"),
                        mamba=MambaConfig(d_state=4, d_conv=4, expand=2,
                                          chunk=16))
