"""Granite-3.0-2B base — [hf:ibm-granite/granite-3.0-2b-base].
Dense, GQA kv=8, SwiGLU."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, act="silu")


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=512)
