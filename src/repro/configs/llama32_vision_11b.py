"""Llama-3.2-11B-Vision — [hf:meta-llama/Llama-3.2-11B-Vision; unverified].
Text decoder with gated cross-attention layers every 5th position; vision
frontend is a STUB (precomputed patch embeddings, width 1280)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama32-vision-11b", family="vision", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, act="silu",
    xattn_period=5, xattn_pos=3, n_img_tokens=1600, d_frontend=1280,
    modality="image+text")


def smoke_config():
    return CONFIG.with_(n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
                        d_head=16, d_ff=128, vocab=512, n_img_tokens=8,
                        d_frontend=32)
