"""Moonlight-16B-A3B (moonshot) — [hf:moonshotai/Moonlight-16B-A3B].
Fine-grained MoE: 64 experts top-6, per-expert d_ff=1408, MHA kv=16."""
from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840, act="silu",
    moe=MoeConfig(num_experts=64, top_k=6, layout="ep"))


def smoke_config():
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_head=16, d_ff=64, vocab=512,
                        moe=MoeConfig(num_experts=8, top_k=2, layout="ep"))
