"""Architecture configs: one module per assigned arch + the paper-native SNN."""
from .base import (ALL_SHAPES, ARCH_IDS, ModelConfig, MoeConfig,  # noqa: F401
                   MambaConfig, RunConfig, ShapeConfig, get_config,
                   get_smoke_config, input_specs, shapes_for)
