"""Distribution layer: logical-axis sharding rules + mesh utilities."""
