"""Logical-axis sharding rules (MaxText-style) for params and activations.

Model code annotates tensors with *logical* axis names; a ``Rules`` object
(mesh + two name→mesh-axis dicts) resolves them to ``PartitionSpec``s.  With
no rules installed (CPU unit tests) every annotation is a no-op, so the same
model code runs from 1 CPU device to the 512-chip production mesh.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  DP spans pod×data; TP/EP/SP span model.

Param logical names        → default mapping
  embed                      "data" when FSDP else None   (d_model dims)
  ff / heads_q / vocab       "model"                      (TP dims)
  heads_kv                   "model" when (K·dh) % tp == 0 else None
  experts                    "model" for EP-MoE layouts
  mamba_inner                "model"  (Mamba TP: d_inner)
  layers / none              None

Activation logical names   → default mapping
  batch                      ("pod", "data")  /  ("data",)
  seq_sp                     "model" when sequence-parallel is on else None
  heads_q                    "model"
  heads_kv                   "model" when K % tp == 0 else None
  ff / vocab / experts       "model"
  kv_seq                     "model"  (decode caches with few kv heads)
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> "Rules | None":
    return getattr(_state, "rules", None)


@dataclass
class Rules:
    mesh: Mesh
    param_map: dict
    act_map: dict

    def spec(self, axes, table) -> P:
        parts = []
        for name in axes:
            if name is None:
                parts.append(None)
            else:
                parts.append(table.get(name))
        return P(*parts)

    def param_spec(self, axes) -> P:
        return self.spec(axes, self.param_map)

    def act_spec(self, axes) -> P:
        return self.spec(axes, self.act_map)

    def param_sharding(self, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_spec(axes))


def make_rules(mesh: Mesh, *, fsdp: bool = True, seq_parallel: bool = False,
               kv_heads: int = 1, d_head: int = 128,
               overrides: dict | None = None) -> Rules:
    axis_names = mesh.axis_names
    tp = mesh.shape["model"] if "model" in axis_names else 1
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    kv_w_ok = (kv_heads * d_head) % tp == 0
    kv_a_ok = kv_heads % tp == 0

    param_map = {
        "embed": "data" if (fsdp and "data" in axis_names) else None,
        "ff": "model",
        "heads_q": "model",
        "heads_kv": "model" if kv_w_ok else None,
        "vocab": "model",
        "experts": "model",
        "mamba_inner": "model",
        "none": None,
    }
    act_map = {
        "batch": dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None),
        "seq_sp": "model" if seq_parallel else None,
        "heads_q": "model",
        "heads_kv": "model" if kv_a_ok else None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "mamba_inner": "model",
        # decode caches: shard kv-heads over model when divisible, else fall
        # back to sharding the cache sequence axis (MQA / long-context)
        "kv_seq": None if kv_a_ok else "model",
        "none": None,
    }
    if overrides:
        for k, v in overrides.items():
            if k.startswith("act:"):
                act_map[k[4:]] = v
            else:
                param_map[k] = v
    return Rules(mesh=mesh, param_map=param_map, act_map=act_map)


@contextlib.contextmanager
def use_rules(rules: Rules | None):
    prev = _current()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard_activation(x, axes):
    """Annotate an activation with logical axes (no-op without rules)."""
    r = _current()
    if r is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} vs rank {x.ndim}")
    spec = r.act_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def partition_params(axes_tree, rules: Rules):
    """Map an axes pytree (parallel to params) to NamedShardings."""
    return jax.tree.map(
        lambda axes: rules.param_sharding(axes),
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))


def param_specs(axes_tree, rules: Rules):
    return jax.tree.map(
        lambda axes: rules.param_spec(axes),
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))
