"""JAX version compatibility for the sharding APIs this repo uses.

The codebase targets the modern surface (``jax.shard_map`` with
``check_vma``/``axis_names``, ``jax.make_mesh(..., axis_types=...)``,
``jax.set_mesh``).  Older installs (e.g. jax 0.4.x) spell these
``jax.experimental.shard_map.shard_map`` with ``check_rep``/``auto``,
``jax.make_mesh`` without axis types, and mesh context managers.  Route
through this module instead of calling jax directly and both work.
"""

from __future__ import annotations

import contextlib
from functools import partial

import jax

__all__ = ["shard_map", "make_mesh", "set_mesh", "axis_size",
           "AXIS_TYPE_AUTO"]

#: ``jax.sharding.AxisType.Auto`` where it exists (newer jax), else None —
#: older jax has exactly one (auto) axis behaviour, so None means "default".
AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=None,
              axis_names=None):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    Args follow the modern spelling: ``check_vma`` (replication/varying
    checking) and ``axis_names`` (the axes that become MANUAL; the rest of
    the mesh stays automatic).  On old jax these map to ``check_rep`` and
    ``auto`` (the complement set).
    """
    if f is None:
        return partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=check_vma,
                       axis_names=axis_names)
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, **kw)


def make_mesh(shape, axes, axis_types=None):
    """``jax.make_mesh`` accepting (and dropping, pre-AxisType) the
    ``axis_types`` keyword."""
    if axis_types is not None and AXIS_TYPE_AUTO is not None:
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)


def axis_size(axis_name):
    """``jax.lax.axis_size``; old jax spells it ``psum(1, axis)`` (still a
    static int at trace time)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def set_mesh(mesh):
    """``jax.set_mesh`` context; old jax activates the mesh context
    manager (enough for abstract lowering / dry runs)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh) if mesh is None else mesh
