"""Training step factory + loop: DP/TP-sharded step with selectable
gradient-reduction schedule (the paper technique as a first-class knob).

Three execution modes share one step definition:

  * single-device (CPU tests/examples): plain ``jax.jit``;
  * SPMD "auto" (production dry-run): pjit with logical-rule shardings,
    gradient sync is XLA's psum — the paper-faithful DENSE baseline;
  * SPMD "manual DP" (ring / bidir_ring / aer_topk): ``shard_map`` manual
    over the DP axes with the model axis left automatic, so the TP einsums
    stay XLA-partitioned while the DP gradient reduction is the explicit
    schedule from ``core/halfduplex.py`` / ``core/sparse_collectives.py``.

Comm/compute overlap: gradient reduction is applied per-parameter-leaf as
the backward produces them; with microbatch accumulation
(``run_cfg.grad_accum``) reduction of accumulated grads overlaps the next
microbatch's backward (the TX/RX-FIFO double-buffering analogue).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import sparse_collectives as sc
from ..optim import adamw
from ..parallel.compat import shard_map
from ..parallel.sharding import Rules, partition_params, use_rules


METRIC_KEYS = ("nll", "aux_loss", "z_loss", "drop_frac", "loss",
               "grad_norm", "lr", "wire_words")


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState
    aer: dict | None          # error-feedback residuals (aer_topk only)
    step: jnp.ndarray


def init_state(model, key, run_cfg) -> TrainState:
    params, _ = model.init(key)
    opt = adamw.init(params)
    aer = sc.init_aer_states(params) if run_cfg.dp_reduce == "aer_topk" \
        else None
    return TrainState(params=params, opt=opt, aer=aer,
                      step=jnp.zeros((), jnp.int32))


def _loss_with_accum(model, params, batch, n_accum: int):
    """Mean loss over ``n_accum`` microbatches (scanned, grads accumulate)."""
    if n_accum <= 1:
        return model.loss(params, batch)

    def micro(carry, mb):
        loss, metrics = model.loss(params, mb)
        return carry + loss, metrics

    split = jax.tree.map(
        lambda x: x.reshape((n_accum, x.shape[0] // n_accum) + x.shape[1:]),
        batch)
    total, metrics = jax.lax.scan(micro, jnp.float32(0.0), split)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return total / n_accum, metrics


def make_train_step(model, run_cfg, rules: Rules | None = None):
    """Returns ``step(state, batch) -> (state, metrics)``.

    With ``rules`` (a mesh present), inputs/outputs carry NamedShardings;
    without, it is a plain jitted single-device step.
    """
    mode = run_cfg.dp_reduce

    def core_step(state: TrainState, batch, axis_name=None):
        def loss_fn(p):
            return _loss_with_accum(model, p, batch, run_cfg.grad_accum)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)

        wire_words = jnp.int32(0)
        aer = state.aer
        if axis_name is not None:
            grads, aer, wire_words = sc.reduce_gradients(
                grads, aer, axis_name, mode=mode, frac=run_cfg.aer_frac,
                budget=run_cfg.aer_budget)
            # metrics are per-shard means -> average them too
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, axis_name), metrics)
            loss = jax.lax.pmean(loss, axis_name)

        lr = adamw.warmup_cosine(
            state.step, base_lr=run_cfg.learning_rate,
            warmup_steps=run_cfg.warmup_steps,
            total_steps=run_cfg.total_steps)
        params, opt, gnorm = adamw.update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=run_cfg.weight_decay, grad_clip=run_cfg.grad_clip)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr,
                       wire_words=wire_words.astype(jnp.float32))
        return TrainState(params=params, opt=opt, aer=aer,
                          step=state.step + 1), metrics

    # ---------------- single device ----------------
    if rules is None:
        return jax.jit(core_step)

    mesh = rules.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    if mode == "psum":
        # SPMD auto: replicate-or-FSDP params; XLA inserts the gradient psum
        def step(state, batch):
            with use_rules(rules):
                return core_step(state, batch, axis_name=None)
        return jax.jit(step)

    # ---------------- manual DP (paper technique schedules) -------------
    # shard_map is MANUAL over the DP axes only (axis_names); the model
    # axis stays automatic so TP constraints keep working.  Inside the
    # manual region the per-shard batch is local — its logical "batch"
    # axis maps to nothing.
    import dataclasses

    axis_name = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    inner_rules = dataclasses.replace(
        rules, act_map={**rules.act_map, "batch": None})

    def manual(state, batch):
        with use_rules(inner_rules):
            return core_step(state, batch, axis_name=axis_name)

    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])

    def stepped(state, batch):
        in_specs = (jax.tree.map(lambda _: P(), state),
                    jax.tree.map(lambda _: batch_spec, batch))
        out_specs = (jax.tree.map(lambda _: P(), state),
                     {k: P() for k in METRIC_KEYS})
        fn = shard_map(manual, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False,
                       axis_names=frozenset(dp_axes))
        return fn(state, batch)

    return jax.jit(stepped)


def state_shardings(state, axes, rules: Rules):
    """NamedShardings for a TrainState given the model's logical axes tree
    (params / opt moments follow the param specs; scalars replicated)."""
    pspec = partition_params(axes, rules)
    rep = NamedSharding(rules.mesh, P())
    return TrainState(
        params=pspec,
        opt=adamw.AdamWState(step=rep, mu=pspec, nu=pspec),
        aer=None if state.aer is None else jax.tree.map(
            lambda _: rep, state.aer),
        step=rep,
    )
