"""Fault tolerance: failure injection, restart-from-checkpoint driver,
straggler monitoring.

On a 1000-node fleet, node failures arrive hourly; the contract is:
deterministic data (pure function of step), periodic async checkpoints,
and a driver that restores the latest checkpoint and replays — producing
BITWISE-identical training to an uninterrupted run (tested).  Straggler
mitigation watches per-step wall time against a running EMA and fires a
pluggable action (log / re-dispatch / evict) past a threshold multiple.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


class SimulatedFailure(RuntimeError):
    """Stands in for a node crash / preemption in tests and drills."""


@dataclass
class FailureInjector:
    fail_at_steps: frozenset = frozenset()
    failed: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.failed:
            self.failed.add(step)     # fail once per step, then recover
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    """EMA step-time watchdog (the per-step heartbeat at fleet scale)."""
    threshold: float = 3.0
    alpha: float = 0.2
    ema: float | None = None
    events: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        is_straggler = (self.ema is not None
                        and dt > self.threshold * self.ema)
        if is_straggler:
            self.events.append((step, dt, self.ema))
        else:
            # stragglers don't poison the EMA
            self.ema = dt if self.ema is None else (
                (1 - self.alpha) * self.ema + self.alpha * dt)
        return is_straggler


def run_with_restarts(*, n_steps: int, state, train_step, data, ckpt,
                      checkpoint_every: int, injector=None, monitor=None,
                      max_restarts: int = 10, log_every: int = 0,
                      on_metrics=None):
    """The restartable training driver.

    Replays from the latest checkpoint on (injected or real) failure.
    Returns (final_state, info) where info records restarts + straggler
    events.  Determinism contract: ``data.batch(step)`` is pure, so replay
    reproduces the uninterrupted run exactly.
    """
    import jax

    restarts = 0
    start = int(state.step)
    step = start
    if checkpoint_every and ckpt.latest_step() is None:
        ckpt.save(start, state, blocking=True)   # recovery anchor
    while step < n_steps:
        try:
            while step < n_steps:
                t0 = time.perf_counter()
                if injector is not None:
                    injector.check(step)
                batch = jax.tree.map(lambda x: x, data.batch(step))
                state, metrics = train_step(state, batch)
                dt = time.perf_counter() - t0
                if monitor is not None:
                    monitor.record(step, dt)
                step += 1
                if checkpoint_every and step % checkpoint_every == 0:
                    ckpt.save(step, state)
                if log_every and step % log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    print(f"step {step}: " + " ".join(
                        f"{k}={v:.4f}" for k, v in sorted(m.items())))
                if on_metrics is not None:
                    on_metrics(step, metrics)
        except SimulatedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait()
            last = ckpt.latest_step()
            if last is None:
                raise SimulatedFailure(
                    "failure before any checkpoint") from e
            state = ckpt.restore(last, state)
            step = last
    ckpt.wait()
    info = {"restarts": restarts,
            "straggler_events": list(monitor.events) if monitor else []}
    return state, info
