"""Training/serving runtime: step factories, fault tolerance."""
