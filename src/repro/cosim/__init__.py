"""Closed-loop SNN <-> fabric co-simulation.

The layer that turns the repo's two previously-disjoint halves — LIF
population dynamics (``kernels/lif_step``, ``models/snn``) and the
N-chip AER transport (``core/fabric``) — into ONE loop:

* :mod:`repro.cosim.placement` maps neuron populations onto fabric
  chips and compiles projection specs (feedforward / recurrent /
  fan-out) into unicast routes and in-fabric multicast tags;
* :mod:`repro.cosim.engine` runs the tick-phased loop: populations
  spike, spikes pack into 26-bit AEs and ride ``Fabric.run`` (any
  engine, any flow mode), delivered events scatter back as next-tick
  synaptic current — optionally delayed by the fabric's own measured
  delivery latency, so congestion perturbs the dynamics;
* :mod:`repro.cosim.traffic_bridge` exposes the resulting spike-driven
  traffic as a first-class generator for sweeps and BENCH A/Bs against
  the synthetic ``core/traffic`` patterns on identical topologies.
"""

from .engine import (CosimConfig, CosimEngine, CosimResult, EventSpec,
                     reference_rollout)
from .placement import Placement, Population, Projection, place

__all__ = ["CosimConfig", "CosimEngine", "CosimResult", "EventSpec",
           "Placement", "Population", "Projection", "place",
           "reference_rollout"]
