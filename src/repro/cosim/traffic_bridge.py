"""Spike-driven traffic as a first-class generator for sweeps/BENCH.

Every workload the fabric benchmarks ran before this module was
synthetic (``core/traffic`` processes with hand-picked rate
parameters).  The bridge closes ROADMAP open item 3's other half: it
rolls a real LIF network out open-loop on the target topology and
returns the resulting inter-chip Address-Event stream as an ordinary
:class:`~repro.core.traffic.TrafficSpec` — same ``(key, n_chips,
events_per_chip)`` signature as ``traffic.PATTERNS`` generators, bare
chip-id destinations, so any plain :class:`~repro.core.fabric.Fabric`
consumes it unchanged and a sweep can A/B synthetic vs SNN load on
IDENTICAL topologies (the ``fabric_snn_*`` BENCH rows).

The load shape is the point: SNN traffic is tick-phased (bursts at
membrane-update boundaries, silence between), spatially structured by
the projection graph (feedforward ring vs bidirectional recurrent
coupling), and rate-modulated by the network's own dynamics — none of
which a Poisson/bursty generator reproduces.
"""

from __future__ import annotations

import jax
import numpy as np

from ..core.router import ring_topology
from ..core.traffic import TrafficSpec
from .engine import CosimConfig, CosimEngine
from .placement import LANES, Population, Projection, place

__all__ = ["spike_traffic", "snn_feedforward", "snn_recurrent",
           "SNN_PATTERNS"]

#: tick period of the bridge rollouts (ns) — 10 kHz network update
TICK_DT_NS = 10_000


def _ring_placement(n_chips: int, mode: str, addr=None):
    """One population per chip on a ring; projections by ``mode``:
    ``"feedforward"`` chains i -> i+1, ``"recurrent"`` adds the reverse
    chain and local self-recurrence.  Every cross route is unicast, so
    the default ``addr=None`` yields bare chip-id destinations that
    plain fabrics consume; pass an ``AddressSpec`` to get packed words
    instead (the closed-loop smoke gate does)."""
    pops = [Population(f"pop{i}", LANES) for i in range(n_chips)]
    projs = []
    for i in range(n_chips):
        projs.append(Projection(pre=i, posts=((i + 1) % n_chips,),
                                w_scale=0.4))
    if mode == "recurrent":
        for i in range(n_chips):
            projs.append(Projection(pre=i,
                                    posts=((i - 1) % n_chips,),
                                    w_scale=0.4))
            projs.append(Projection(pre=i, posts=(i,), w_scale=0.3))
    elif mode != "feedforward":
        raise ValueError(f"unknown bridge mode {mode!r}")
    return place(pops, projs, ring_topology(n_chips), addr=addr)


def spike_traffic(key, n_chips: int, events_per_chip: int, *,
                  mode: str = "feedforward", input_rate: float = 0.06,
                  max_ticks: int = 256) -> TrafficSpec:
    """Sample ``>= n_chips * events_per_chip`` inter-chip spike events
    from an open-loop LIF rollout on a ring of ``n_chips`` chips, then
    truncate to exactly that count (whole prefix, so per-source time
    order survives).  Deterministic in ``key``: the same key always
    yields the identical spec, which is what lets the BENCH baseline
    pin these rows.  Raises if ``max_ticks`` ticks cannot supply the
    budget — a silent short spec would skew every derived metric."""
    target = n_chips * events_per_chip
    pl = _ring_placement(n_chips, mode)
    eng = CosimEngine(pl, CosimConfig(input_rate=input_rate,
                                      tick_dt_ns=TICK_DT_NS,
                                      feedback="none"), key=key)
    res = eng.run(max_ticks, collect_events=True)
    total = int(sum(e.n_events for e in res.events))
    if total < target:
        raise ValueError(
            f"snn traffic underran: {total} events in {max_ticks} ticks "
            f"< {target} requested (raise input_rate or max_ticks)")
    src = np.concatenate([np.asarray(e.spec.src) for e in res.events])
    t = np.concatenate([np.asarray(e.spec.t) for e in res.events])
    dest = np.concatenate([np.asarray(e.spec.dest) for e in res.events])
    return TrafficSpec(src=jax.numpy.asarray(src[:target]),
                       t=jax.numpy.asarray(t[:target]),
                       dest=jax.numpy.asarray(dest[:target]))


def snn_feedforward(key, n_chips: int, events_per_chip: int) -> TrafficSpec:
    return spike_traffic(key, n_chips, events_per_chip,
                         mode="feedforward")


def snn_recurrent(key, n_chips: int, events_per_chip: int) -> TrafficSpec:
    return spike_traffic(key, n_chips, events_per_chip, mode="recurrent")


#: name -> generator(key, n_chips, events_per_chip): the spike-driven
#: counterpart of ``traffic.PATTERNS`` (kept separate so importing the
#: cosim layer never mutates the synthetic registry).
SNN_PATTERNS = {
    "snn_feedforward": snn_feedforward,
    "snn_recurrent": snn_recurrent,
}
