"""Tick-phased closed-loop co-simulation: LIF populations on a fabric.

One tick has four phases, mirroring how multi-core neuromorphic systems
(DYNAPs-family) run:

  1. **integrate** — every population's membrane update runs as one
     vmapped fused LIF kernel call (``kernels.ops.lif_step``) on the
     summed synaptic current: local recurrent input + external Poisson
     drive + the fabric feedback buffer of this tick;
  2. **pack** — spikes on populations with inter-chip projections
     become 26-bit Address-Events: the payload word carries
     ``(projection, neuron)`` (``core/events`` layout), the transport
     word the compiled route destination (unicast chip or multicast
     tag), and every event gets a UNIQUE injection timestamp
     ``tick * tick_dt_ns + position`` — the identity the delivery log
     hands back;
  3. **transport** — the tick's events run through ``Fabric.run`` as
     one :class:`EventSpec` (any engine, any flow mode; zero-spike
     ticks skip the fabric, which refuses empty plans);
  4. **scatter** — each delivered event's ``(log_inj, log_dest)`` pair
     maps back to its source neuron and target populations, and the
     projection's weight column accumulates into a FUTURE tick's
     feedback buffer: the next tick (``feedback="next_tick"``), or the
     tick after the fabric's own measured delivery time
     (``feedback="measured"`` — congestion delays spikes, so fabric
     backlog perturbs the dynamics).  Dropped events never feed back.

``feedback="none"`` is the open-loop control: the identical dynamics
with the fabric path severed (bit-exact with
:func:`reference_rollout`), the baseline every congestion-coupling
claim is measured against.

Per tick the conservation law ``delivered + drops == injected`` is
inherited directly from the fabric result — the engine adds no event
accounting of its own.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import events as ev
from ..core import traffic as tr
from ..core.fabric import Fabric
from ..core.telemetry import Telemetry, merge_telemetry
from ..kernels import ops as K
from .placement import LANES, Placement

__all__ = ["CosimConfig", "CosimEngine", "CosimResult", "EventSpec",
           "reference_rollout"]

#: feedback modes: open-loop control / idealised next-tick / fabric-timed
FEEDBACK_MODES = ("none", "next_tick", "measured")

#: injection-time bases must stay far below the engines' BIG_NS sentinel
_MAX_BASE_NS = 1 << 29


class CosimConfig(NamedTuple):
    """Dynamics + loop parameters (placement-independent)."""
    decay: float = 0.9
    v_th: float = 1.0
    v_reset: float = 0.0
    input_rate: float = 0.05     # Poisson drive per neuron per tick
    tick_dt_ns: int = 10_000     # network tick period (10 kHz default)
    feedback: str = "next_tick"  # FEEDBACK_MODES
    feedback_scale: float = 1.0  # gain on fabric-delivered current


class EventSpec(NamedTuple):
    """One tick's spike traffic, ready for ``Fabric.run``.

    ``spec`` is the transport view (src chip, unique time, routed dest
    word); ``words`` the 26-bit AER payload words; ``proj`` / ``neuron``
    the per-event identity the delivery scatter reads back via
    ``log_inj - base``.
    """
    tick: int
    base: int                 # injection-time base of this tick
    spec: tr.TrafficSpec
    words: np.ndarray         # (E,) uint32 packed (projection, neuron)
    proj: np.ndarray          # (E,) int32
    neuron: np.ndarray        # (E,) int32

    @property
    def n_events(self) -> int:
        return int(self.proj.shape[0])


class CosimResult(NamedTuple):
    """Per-tick trajectories of one co-simulation run (numpy)."""
    spikes: np.ndarray        # (T, P) per-population spike counts
    offered: np.ndarray       # (T,) events offered to the fabric
    injected: np.ndarray      # (T,) expected deliveries (post-fanout)
    delivered: np.ndarray     # (T,)
    drops: np.ndarray         # (T,)
    latency_ns: np.ndarray    # all delivered end-to-end latencies
    sent: np.ndarray          # (L, 2) summed link transmissions
    telemetry: Telemetry | None   # merged over all fabric ticks
    v: np.ndarray | None = None       # (T, P, n) with record_state
    raster: np.ndarray | None = None  # (T, P, n) with record_state
    events: tuple = ()        # per-tick EventSpecs (collect_events)
    fabric_results: tuple = ()  # (tick, FabricResult) (record_fabric)

    @property
    def total_spikes(self) -> int:
        return int(self.spikes.sum())

    @property
    def conservation_exact(self) -> bool:
        """Per-tick ``delivered + drops == injected`` — every tick."""
        return bool(np.all(self.delivered + self.drops == self.injected))


class CosimEngine:
    """Closed-loop runner binding a :class:`Placement` to a fabric.

    ``fabric`` may be any :class:`~repro.core.fabric.Fabric` whose
    topology / address space matches the placement (build one with
    ``placement.fabric(...)``); pass ``None`` for open-loop runs.  All
    projection weights are drawn once at construction from ``key``
    (dense ``(n, n)`` per projection, scaled by its ``w_scale /
    sqrt(n)``), so two engines built from the same placement and key
    are dynamically identical regardless of transport."""

    def __init__(self, placement: Placement, cfg: CosimConfig = None,
                 *, fabric: Fabric = None, key=None):
        self.placement = placement
        self.cfg = cfg if cfg is not None else CosimConfig()
        if self.cfg.feedback not in FEEDBACK_MODES:
            raise ValueError(f"feedback must be one of {FEEDBACK_MODES}, "
                             f"got {self.cfg.feedback!r}")
        if self.cfg.tick_dt_ns <= 0:
            raise ValueError("tick_dt_ns must be positive")
        self.fabric = fabric
        if fabric is not None:
            if fabric.topo.n_chips != placement.topo.n_chips:
                raise ValueError(
                    f"fabric topology ({fabric.topo.n_chips} chips) "
                    f"does not match the placement "
                    f"({placement.topo.n_chips} chips)")
            if (placement.mcast is not None) and fabric.mcast is None:
                raise ValueError("placement compiled multicast tags but "
                                 "the fabric has no multicast table — "
                                 "build it with placement.fabric(...)")
        key = key if key is not None else jax.random.PRNGKey(0)
        kw, self._drive_key = jax.random.split(key)
        P, n = placement.n_pops, placement.neurons
        n_proj = max(len(placement.projections), 1)
        w = np.zeros((n_proj, n, n), np.float32)
        for pi, proj in enumerate(placement.projections):
            w[pi] = np.asarray(
                jax.random.normal(jax.random.fold_in(kw, pi), (n, n),
                                  jnp.float32)) * (proj.w_scale
                                                   / float(np.sqrt(n)))
        self._w_np = w
        w_dev = jnp.asarray(w)
        local = placement.local
        c = self.cfg

        def step(v, spikes, fb, key_t):
            i_loc = jnp.zeros((P, n), jnp.float32)
            for (pi, pre, post) in local:
                i_loc = i_loc.at[post].add(w_dev[pi] @ spikes[pre])
            drive = jax.random.uniform(key_t, (P, n)) < c.input_rate
            i_syn = i_loc + drive.astype(jnp.float32) + fb
            v2, spk = K.lif_step(v.reshape(P * (n // LANES), LANES),
                                 i_syn.reshape(P * (n // LANES), LANES),
                                 decay=c.decay, v_th=c.v_th,
                                 v_reset=c.v_reset)
            return v2.reshape(P, n), spk.reshape(P, n)

        self._step = jax.jit(step)

    # --- phase 2: spikes -> one tick's EventSpec -----------------------

    def pack_events(self, spk: np.ndarray, tick: int) -> EventSpec | None:
        """Spike matrix (P, n) -> this tick's :class:`EventSpec`, or
        ``None`` when no inter-chip projection fired (the fabric refuses
        empty plans, so empty ticks never reach it).  Event ``i`` of the
        tick injects at ``base + i`` — times are unique and increasing,
        which (a) satisfies the per-source nondecreasing contract and
        (b) makes ``log_inj`` the delivery log's event identity."""
        base = tick * self.cfg.tick_dt_ns
        if base >= _MAX_BASE_NS:
            raise ValueError(f"tick {tick} overflows the int32 ns clock "
                             f"(base {base} >= {_MAX_BASE_NS})")
        pl = self.placement
        srcs, dests, projs, neurons = [], [], [], []
        seq = 0
        for r in pl.cross:
            pre = pl.projections[r.proj].pre
            j = np.flatnonzero(spk[pre] > 0.0).astype(np.int32)
            if not j.size:
                continue
            srcs.append(np.full(j.size, r.src_chip, np.int32))
            dests.append(np.full(j.size, r.dest_word, np.int32))
            projs.append(np.full(j.size, r.proj, np.int32))
            neurons.append(j)
            seq += j.size
        if seq == 0:
            return None
        if seq >= self.cfg.tick_dt_ns:
            raise ValueError(
                f"{seq} events in one tick exceed the tick_dt_ns="
                f"{self.cfg.tick_dt_ns} unique-timestamp budget")
        proj = np.concatenate(projs)
        neuron = np.concatenate(neurons)
        t = np.arange(seq, dtype=np.int32) + np.int32(base)
        words = (((proj.astype(np.uint32) << np.uint32(16))
                  | neuron.astype(np.uint32)) & np.uint32(ev.AER_ADDR_MASK))
        spec = tr.TrafficSpec(src=jnp.asarray(np.concatenate(srcs)),
                              t=jnp.asarray(t),
                              dest=jnp.asarray(np.concatenate(dests)))
        return EventSpec(tick=tick, base=base, spec=spec, words=words,
                         proj=proj, neuron=neuron)

    # --- phase 4: delivery log -> future feedback buffers --------------

    def _scatter(self, evs: EventSpec, res, tick: int, pend: dict):
        ndel = int(res.delivered)
        if ndel == 0:
            return
        inj = np.asarray(res.log_inj)[:ndel]
        chip = np.asarray(res.log_dest)[:ndel]
        idx = inj - evs.base          # unique times -> event identity
        proj = evs.proj[idx]
        neuron = evs.neuron[idx]
        if self.cfg.feedback == "measured":
            dlv = np.asarray(res.log_del)[:ndel]
            tt = dlv // self.cfg.tick_dt_ns + 1   # next update after
        else:                                     # arrival; >= tick + 1
            tt = np.full(ndel, tick + 1, np.int64)
        pl = self.placement
        P, n = pl.n_pops, pl.neurons
        cols = self._w_np[proj, :, neuron]        # (ndel, n) W[p][:, j]
        scale = np.float32(self.cfg.feedback_scale)
        n_proj = self._w_np.shape[0]
        group = (tt * n_proj + proj) * pl.topo.n_chips + chip
        for g in np.unique(group):
            sel = np.flatnonzero(group == g)
            d0 = sel[0]
            buf = pend.get(int(tt[d0]))
            if buf is None:
                buf = pend.setdefault(int(tt[d0]),
                                      np.zeros((P, n), np.float32))
            vec = cols[sel].sum(axis=0, dtype=np.float32) * scale
            for post in pl.posts_on[(int(proj[d0]), int(chip[d0]))]:
                buf[post] += vec

    # --- the loop -------------------------------------------------------

    def run(self, n_ticks: int, *, record_state: bool = False,
            collect_events: bool = False,
            record_fabric: bool = False) -> CosimResult:
        pl, c = self.placement, self.cfg
        P, n = pl.n_pops, pl.neurons
        closed = self.fabric is not None and c.feedback != "none"
        if c.feedback != "none" and self.fabric is None:
            raise ValueError(f"feedback={c.feedback!r} needs a fabric "
                             f"(pass fabric= or feedback='none')")
        v = jnp.zeros((P, n), jnp.float32)
        spikes = jnp.zeros((P, n), jnp.float32)
        zero_fb = jnp.zeros((P, n), jnp.float32)
        pend: dict[int, np.ndarray] = {}
        spk_counts = np.zeros((n_ticks, P), np.int64)
        offered = np.zeros(n_ticks, np.int64)
        injected = np.zeros(n_ticks, np.int64)
        delivered = np.zeros(n_ticks, np.int64)
        drops = np.zeros(n_ticks, np.int64)
        lats: list[np.ndarray] = []
        sent = np.zeros((pl.topo.n_links, 2), np.int64)
        tele: list[Telemetry] = []
        v_hist = np.zeros((n_ticks, P, n), np.float32) \
            if record_state else None
        raster = np.zeros((n_ticks, P, n), np.float32) \
            if record_state else None
        events: list[EventSpec] = []
        fres: list = []
        for tick in range(n_ticks):
            fb_np = pend.pop(tick, None)
            fb = zero_fb if fb_np is None else jnp.asarray(fb_np)
            v, spikes = self._step(
                v, spikes, fb, jax.random.fold_in(self._drive_key, tick))
            spk_np = np.asarray(spikes)
            spk_counts[tick] = (spk_np > 0.0).sum(axis=1)
            if record_state:
                v_hist[tick] = np.asarray(v)
                raster[tick] = spk_np
            if not (closed or collect_events):
                continue
            evs = self.pack_events(spk_np, tick)
            if evs is None:
                continue
            offered[tick] = evs.n_events
            if collect_events:
                events.append(evs)
            if not closed:
                continue
            res = self.fabric.run(evs.spec)
            injected[tick] = int(res.injected)
            delivered[tick] = int(res.delivered)
            drops[tick] = int(res.drops)
            ndel = int(res.delivered)
            lats.append((np.asarray(res.log_del)[:ndel]
                         - np.asarray(res.log_inj)[:ndel]).astype(np.int64))
            sent += np.asarray(res.sent, np.int64)
            if res.telemetry is not None:
                tele.append(res.telemetry)
            if record_fabric:
                fres.append((tick, res))
            self._scatter(evs, res, tick, pend)
        return CosimResult(
            spikes=spk_counts, offered=offered, injected=injected,
            delivered=delivered, drops=drops,
            latency_ns=(np.concatenate(lats) if lats
                        else np.zeros(0, np.int64)),
            sent=sent,
            telemetry=merge_telemetry(tele) if tele else None,
            v=v_hist, raster=raster, events=tuple(events),
            fabric_results=tuple(fres))

    def traffic(self, n_ticks: int) -> tr.TrafficSpec:
        """The spike-driven workload of an open-loop rollout, as ONE
        flat :class:`~repro.core.traffic.TrafficSpec` — what the traffic
        bridge hands to sweeps.  Transport-independent by construction
        (no fabric runs; destinations are the placement's compiled
        words, bare chip ids when the placement has no AddressSpec)."""
        res = self.run(n_ticks, collect_events=True)
        if not res.events:
            raise ValueError(f"no inter-chip spikes in {n_ticks} ticks — "
                             f"raise input_rate or n_ticks")
        return tr.TrafficSpec(
            src=jnp.concatenate([e.spec.src for e in res.events]),
            t=jnp.concatenate([e.spec.t for e in res.events]),
            dest=jnp.concatenate([e.spec.dest for e in res.events]))


def reference_rollout(engine: CosimEngine, n_ticks: int, *,
                      record_state: bool = False) -> CosimResult:
    """Standalone LIF rollout: the engine's dynamics with NO fabric, no
    placement routing, no feedback bookkeeping — just the membrane
    update iterated with a zero feedback buffer.  The open-loop
    contract (tested and CI-gated): ``engine.run`` with
    ``feedback="none"`` must match this bit-for-bit, proving the
    co-simulation plumbing adds nothing to the dynamics it transports.
    """
    P, n = engine.placement.n_pops, engine.placement.neurons
    v = jnp.zeros((P, n), jnp.float32)
    spikes = jnp.zeros((P, n), jnp.float32)
    fb = jnp.zeros((P, n), jnp.float32)
    spk_counts = np.zeros((n_ticks, P), np.int64)
    v_hist = np.zeros((n_ticks, P, n), np.float32) if record_state else None
    raster = np.zeros((n_ticks, P, n), np.float32) if record_state else None
    for tick in range(n_ticks):
        v, spikes = engine._step(
            v, spikes, fb, jax.random.fold_in(engine._drive_key, tick))
        spk_np = np.asarray(spikes)
        spk_counts[tick] = (spk_np > 0.0).sum(axis=1)
        if record_state:
            v_hist[tick] = np.asarray(v)
            raster[tick] = spk_np
    z = np.zeros(n_ticks, np.int64)
    return CosimResult(spikes=spk_counts, offered=z, injected=z.copy(),
                       delivered=z.copy(), drops=z.copy(),
                       latency_ns=np.zeros(0, np.int64),
                       sent=np.zeros((engine.placement.topo.n_links, 2),
                                     np.int64),
                       telemetry=None, v=v_hist, raster=raster)
