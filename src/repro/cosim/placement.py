"""Placement: neuron populations -> fabric chips, projections -> routes.

A :class:`Population` is a block of LIF neurons that lives together on
one chip (the paper's "core" granularity); a :class:`Projection` is a
synaptic pathway from one population to one or more target populations.
:func:`place` assigns populations to the chips of a
:class:`~repro.core.router.Topology` and compiles every projection into
its transport form:

* pre and post on the SAME chip — a local projection, applied directly
  to the membrane update (never touches the fabric, mirroring the
  paper's on-chip routing fabric);
* post on ONE other chip — a unicast cross route: spikes become AER
  events addressed to that chip (``AddressSpec.pack``);
* posts spread over SEVERAL other chips — a multicast tag: the
  member-chip set goes into a :class:`~repro.core.router.MulticastTable`
  entry and events carry the tagged word
  (``AddressSpec.pack_multicast``), so an ``in_fabric``
  :class:`~repro.core.fabric.MulticastPolicy` replicates them on the
  Steiner tree (``router.MulticastTree``) instead of at the source.

The compiled :class:`Placement` is a static artifact: the co-simulation
engine reads its route table every tick, and ``fabric()`` constructs a
:class:`~repro.core.fabric.Fabric` whose address space and multicast
table match it by construction.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from ..core.fabric import Fabric, MulticastPolicy
from ..core.router import (AddressSpec, MulticastTable, RoutingTable,
                           Topology)

__all__ = ["Population", "Projection", "CrossRoute", "Placement", "place"]

#: LIF kernel lane width: population sizes must tile into (rows, 128).
LANES = 128


class Population(NamedTuple):
    """``size`` LIF neurons placed together on one chip."""
    name: str
    size: int = LANES


class Projection(NamedTuple):
    """Synaptic pathway: every spike of ``pre`` drives current into each
    population in ``posts`` through that projection's dense weight
    matrix (owned by the engine — placement only routes)."""
    pre: int
    posts: tuple
    w_scale: float = 0.3


class CrossRoute(NamedTuple):
    """One compiled inter-chip pathway of a projection.

    ``dest_word`` is what the AER events carry: the packed unicast chip
    word, the packed multicast tag word, or (``addr=None``) the bare
    destination chip id.  ``chips`` is the ordered member-chip tuple the
    word expands to — the delivery-side key mapping a delivered event's
    ``log_dest`` chip back to the post populations fed on that chip.
    """
    proj: int
    src_chip: int
    dest_word: int
    chips: tuple
    tag: int = -1          # multicast tag id, -1 = unicast

    @property
    def fanout(self) -> int:
        return len(self.chips)


@dataclass(frozen=True, eq=False)
class Placement:
    """Populations bound to chips plus the compiled projection routes."""
    topo: Topology
    addr: AddressSpec | None
    populations: tuple
    projections: tuple
    chip_of: np.ndarray            # (P,) int32 chip of each population
    local: tuple                   # ((proj, pre, post), ...) same-chip
    cross: tuple                   # (CrossRoute, ...) proj-major order
    mcast: MulticastTable | None   # tags of the fan-out cross routes
    posts_on: dict = field(default_factory=dict)
    # (proj, chip) -> (post population ids,) — the delivery scatter key

    @property
    def n_pops(self) -> int:
        return len(self.populations)

    @property
    def neurons(self) -> int:
        """Per-population size (uniform — validated in :func:`place`)."""
        return self.populations[0].size

    def pops_on(self, chip: int) -> tuple:
        return tuple(int(p) for p in np.flatnonzero(self.chip_of == chip))

    def fabric(self, **kw) -> Fabric:
        """A :class:`Fabric` matching this placement: same topology,
        same address space, and — when any projection fans out — the
        compiled multicast table under ``in_fabric`` replication.
        Engine / queue / timing policies pass through ``kw``."""
        if self.mcast is not None and "mcast" not in kw:
            kw["mcast"] = MulticastPolicy("in_fabric", self.mcast)
        return Fabric(self.topo, addr=self.addr, **kw)


def place(populations, projections, topo: Topology, *,
          chips=None, strategy: str = "round_robin",
          addr: AddressSpec | None = None) -> Placement:
    """Assign populations to chips and compile projections into routes.

    ``chips`` pins the assignment explicitly (one chip id per
    population); otherwise ``strategy`` picks it: ``"round_robin"``
    (population p on chip ``p % n_chips``) or ``"block"`` (contiguous
    runs).  ``addr`` is required as soon as any projection fans out to
    more than one remote chip (the multicast tag needs the word's mcast
    bit); with ``addr=None`` every cross route must be unicast and
    events carry bare chip-id destinations — directly consumable by a
    plain (address-less) :class:`Fabric`, which is what the traffic
    bridge feeds to sweeps.

    Raises ``ValueError`` on anything the fabric would choke on later:
    empty or non-lane-aligned populations, chip ids out of range,
    projection endpoints out of range, unreachable destination chips,
    or address-field overflow (population size vs the AER word's neuron
    field, tag/chip count vs ``addr``'s bit budget).
    """
    populations = tuple(populations)
    projections = tuple(projections)
    if not populations:
        raise ValueError("need at least one population")
    sizes = {p.size for p in populations}
    if len(sizes) != 1:
        raise ValueError(f"population sizes must be uniform (one vmapped "
                         f"LIF state), got {sorted(sizes)}")
    n = populations[0].size
    if n <= 0 or n % LANES:
        raise ValueError(f"population size must be a positive multiple "
                         f"of {LANES} (LIF kernel lanes), got {n}")
    P = len(populations)

    if chips is not None:
        chip_of = np.asarray(list(chips), np.int32)
        if chip_of.shape != (P,):
            raise ValueError(f"chips must give one chip per population "
                             f"({P}), got shape {chip_of.shape}")
    elif strategy == "round_robin":
        chip_of = (np.arange(P) % topo.n_chips).astype(np.int32)
    elif strategy == "block":
        chip_of = (np.arange(P) * topo.n_chips // P).astype(np.int32)
    else:
        raise ValueError(f"unknown placement strategy {strategy!r}")
    if chip_of.min(initial=0) < 0 or \
            chip_of.max(initial=0) >= topo.n_chips:
        raise ValueError(f"population chip id out of range for "
                         f"{topo.n_chips}-chip topology: {chip_of}")
    if addr is not None:
        if topo.n_chips > addr.max_chips:
            raise ValueError(f"{topo.n_chips} chips exceed the address "
                             f"word's {addr.chip_bits}-bit chip field")
        if n > (1 << 16):
            raise ValueError(f"population size {n} exceeds the 16-bit "
                             f"neuron field of the 26-bit AER payload")

    rt = RoutingTable.build(topo)
    local: list = []
    cross: list = []
    members: list = []
    posts_on: dict = {}
    for pi, proj in enumerate(projections):
        if not (0 <= proj.pre < P):
            raise ValueError(f"projection {pi}: pre population "
                             f"{proj.pre} out of range [0, {P})")
        if not proj.posts:
            raise ValueError(f"projection {pi}: empty posts")
        src_chip = int(chip_of[proj.pre])
        remote: dict[int, list] = {}
        for post in proj.posts:
            if not (0 <= post < P):
                raise ValueError(f"projection {pi}: post population "
                                 f"{post} out of range [0, {P})")
            c = int(chip_of[post])
            if c == src_chip:
                local.append((pi, proj.pre, int(post)))
            else:
                if rt.hops[src_chip, c] < 0:
                    raise ValueError(
                        f"projection {pi}: destination chip {c} "
                        f"unreachable from chip {src_chip}")
                remote.setdefault(c, []).append(int(post))
        if not remote:
            continue
        chips_sorted = tuple(sorted(remote))
        for c in chips_sorted:
            posts_on[(pi, c)] = tuple(remote[c])
        if len(chips_sorted) == 1:
            c = chips_sorted[0]
            word = int(addr.pack(c)) if addr is not None else c
            cross.append(CrossRoute(pi, src_chip, word, (c,)))
        else:
            if addr is None:
                raise ValueError(
                    f"projection {pi} fans out to chips {chips_sorted} "
                    f"but the placement has no AddressSpec — multicast "
                    f"tags need the word's mcast bit (pass addr=)")
            tag = len(members)
            if tag >= (1 << addr.chip_bits):
                raise ValueError(f"more multicast tags than the "
                                 f"{addr.chip_bits}-bit tag field holds")
            row = np.zeros(topo.n_chips, bool)
            row[list(chips_sorted)] = True
            members.append(row)
            cross.append(CrossRoute(pi, src_chip,
                                    int(addr.pack_multicast(tag)),
                                    chips_sorted, tag=tag))
    mcast = MulticastTable(np.stack(members)) if members else None
    return Placement(topo=topo, addr=addr, populations=populations,
                     projections=projections, chip_of=chip_of,
                     local=tuple(local), cross=tuple(cross),
                     mcast=mcast, posts_on=posts_on)
